"""Train a reduced model-zoo architecture for a few hundred steps with the
production training machinery (checkpoint/restart, watchdog, AdamW).

    PYTHONPATH=src python examples/train_lm.py [--arch gemma2-2b --steps 300]
"""
import sys

sys.path.insert(0, "src")

from repro.launch.train import main

if __name__ == "__main__":
    main(sys.argv[1:] or ["--arch", "smollm-135m", "--steps", "200"])
