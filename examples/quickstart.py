"""Quickstart: the full VStore lifecycle in one script.

  1. profile operators on sample segments,
  2. backward-derive the video-format configuration,
  3. ingest camera streams into the derived storage formats,
  4. run a cascade query at two accuracy levels (speed/accuracy tradeoff).

    PYTHONPATH=src python examples/quickstart.py
"""
import shutil
import sys

sys.path.insert(0, "src")

from repro.analytics.query import run_query
from repro.analytics.scene import generate_segment
from repro.core import Profiler, derive_config
from repro.core.knobs import IngestSpec
from repro.videostore import VideoStore

ROOT = "/tmp/repro_quickstart"


def main():
    spec = IngestSpec()
    print("== 1. profiling + backward derivation (paper §4) ==")
    prof = Profiler(spec, n_segments=2, repeats=1)
    cfg = derive_config(prof, ops=("diff", "snn", "nn"),
                        accuracies=(0.9, 0.8))
    print(cfg.table())
    print(f"profiling: {prof.stats.consumption_runs} consumption runs, "
          f"{prof.stats.storage_runs} storage runs, "
          f"{prof.stats.memo_hits} memo hits")

    print("\n== 2. ingestion ==")
    shutil.rmtree(ROOT, ignore_errors=True)
    store = VideoStore(ROOT, spec)
    store.set_formats(cfg.storage_formats())
    for seg in range(4):
        frames, _ = generate_segment("jackson", seg, spec)
        store.ingest_segment("jackson", seg, frames)
    st = store.ingest_stats["jackson"]
    print(f"ingested 4 segments into {len(cfg.storage_formats())} formats: "
          f"{st.stored_bytes / 1e6:.2f} MB, "
          f"transcode cost {st.cost_xrealtime(spec):.3f}x realtime")

    print("\n== 3. queries (accuracy/cost tradeoff) ==")
    for acc in (0.9, 0.8):
        res = run_query(store, cfg, "A", "jackson", list(range(4)), acc)
        print(f"query A @ accuracy {acc}: {res.pipelined_speed:7.0f}x "
              f"realtime, {len(res.items)} detections")


if __name__ == "__main__":
    main()
