"""Resource-budget adaptation (paper §6.3): the same consumer set derives
different configurations as ingestion/storage budgets tighten.

    PYTHONPATH=src python examples/budget_adaptation.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import Profiler, coalesce, derive_config
from repro.core.erosion import plan_erosion
from repro.core.knobs import IngestSpec


def main():
    spec = IngestSpec()
    prof = Profiler(spec, n_segments=2, repeats=1)
    cfg = derive_config(prof, ops=("nn", "ocr", "license"),
                        accuracies=(0.9, 0.8))

    print("== ingestion budget sweep (paper Table 3) ==")
    free = coalesce(prof, cfg.plans)
    print(f"unconstrained: ingest={free.ingest_cost:.3f} enc-s/vid-s, "
          f"storage={free.storage_cost / 1e3:.1f} KB/vid-s, "
          f"SFs={[n.sf.name() for n in free.nodes]}")
    for frac in (0.7, 0.4):
        res = coalesce(prof, cfg.plans,
                       ingest_budget=free.ingest_cost * frac)
        print(f"budget x{frac}: ingest={res.ingest_cost:.3f} "
              f"(met={res.budget_met}) storage={res.storage_cost / 1e3:.1f} "
              f"KB/vid-s, SFs={[n.sf.name() for n in res.nodes]}")

    print("\n== storage budget sweep (paper Fig. 12) ==")
    subs = {}
    for i, node in enumerate(cfg.nodes):
        for p in node.plans:
            subs[p] = i
    daily = [prof.storage_profile(n.sf)[1] * 86400 for n in cfg.nodes]
    full = sum(daily) * 10
    for frac in (1.2, 0.6, 0.4):
        plan = plan_erosion(prof, cfg.nodes, subs, daily, 10, frac * full)
        print(f"budget x{frac}: k={plan.k:.2f} feasible={plan.feasible} "
              f"speeds day1..10: {plan.overall_speed[0]:.2f}"
              f"..{plan.overall_speed[-1]:.2f}")


if __name__ == "__main__":
    main()
