"""End-to-end serving driver: both paper queries (A: car detection,
B: license recognition) over two streams, with per-stage speed accounting
and the erosion-aged fallback path.

    PYTHONPATH=src python examples/analytics_query.py
"""
import shutil
import sys

sys.path.insert(0, "src")

from repro.analytics.query import run_query
from repro.analytics.scene import generate_segment
from repro.core import Profiler, derive_config
from repro.core.knobs import IngestSpec
from repro.videostore import VideoStore

ROOT = "/tmp/repro_analytics"


def main():
    spec = IngestSpec()
    prof = Profiler(spec, n_segments=2, repeats=1)
    cfg = derive_config(prof, accuracies=(0.8,))

    shutil.rmtree(ROOT, ignore_errors=True)
    store = VideoStore(ROOT, spec)
    store.set_formats(cfg.storage_formats())
    for stream in ("jackson", "dashcam"):
        for seg in range(3):
            frames, _ = generate_segment(stream, seg, spec)
            store.ingest_segment(stream, seg, frames)

    for query, stream in (("A", "jackson"), ("B", "dashcam")):
        res = run_query(store, cfg, query, stream, [0, 1, 2], 0.8)
        print(f"query {query} on {stream}: "
              f"{res.pipelined_speed:.0f}x realtime "
              f"(sequential {res.sequential_speed:.0f}x), "
              f"{len(res.items)} items")
        for st in res.stages:
            print(f"   {st.op:8s} cf={st.cf.name():24s} sf={st.sf_id:5s} "
                  f"retrieve={st.retrieve_s * 1e3:6.1f}ms "
                  f"consume={st.consume_s * 1e3:6.1f}ms "
                  f"frames={st.frames}")

    print("\nerosion fallback: deleting 50% of a child format's segments")
    sfs = [sid for sid in cfg.storage_formats() if sid != "sf_g"]
    if sfs:
        store.erode("jackson", sfs[0], 0.5)
        print(f"  eroded {sfs[0]}; consumers fall back to richer ancestors "
              "(golden never eroded)")


if __name__ == "__main__":
    main()
