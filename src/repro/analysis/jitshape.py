"""Jit-shape safety: jitted call sites must not be fed data-dependent
shapes.

Every distinct argument shape retraces and recompiles a jitted
function; a variable-bound slice (``x[:k]`` with ``k`` computed from
data) flowing straight into a jitted call fragments the jit cache that
the batch-shape ladder (``DEFAULT_BATCH_SHAPES``) and the pad-then-
slice idiom (``_pad_tail`` / ``_pad_to`` / ``_pad_chunk_count``)
deliberately bound.

The pass collects jitted callables — ``@jax.jit``-decorated defs,
``functools.partial(jax.jit, ...)`` decorations, and ``name =
jax.jit(fn)`` assignments — across the scanned tree, then flags any
call to one of them whose argument expression contains a subscript
with a non-constant slice bound, unless that subscript is wrapped in a
padding helper (function name containing ``pad``) inside the same
argument expression.  Arguments that are plain names are not chased
through dataflow: hoisting the slice through an explicit pad call is
exactly the idiom the rule wants to force.  Rule name: ``jit-shape``.
"""

from __future__ import annotations

import ast

from .core import Finding, Module, dotted_name

_JIT_NAMES = {"jax.jit", "jit"}


def _is_jit_expr(node: ast.AST) -> bool:
    """True for `jax.jit`, `jax.jit(...)`, `partial(jax.jit, ...)`."""
    if dotted_name(node) in _JIT_NAMES:
        return True
    if isinstance(node, ast.Call):
        d = dotted_name(node.func)
        if d in _JIT_NAMES:
            return True
        if d in ("functools.partial", "partial") and node.args \
                and _is_jit_expr(node.args[0]):
            return True
    return False


def collect_jitted(modules: list[Module]) -> set[str]:
    """Simple names of every jitted callable in the tree."""
    jitted: set[str] = set()
    for mod in modules:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.FunctionDef):
                if any(_is_jit_expr(dec) for dec in node.decorator_list):
                    jitted.add(node.name)
            elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and isinstance(node.value, ast.Call) \
                    and _is_jit_expr(node.value):
                jitted.add(node.targets[0].id)
    return jitted


def _variable_slice(node: ast.Subscript) -> bool:
    """Subscript whose slice has a non-constant bound."""
    def bound_varies(b) -> bool:
        if b is None or isinstance(b, ast.Constant):
            return False
        if isinstance(b, ast.UnaryOp) and isinstance(b.operand,
                                                     ast.Constant):
            return False
        return True

    sl = node.slice
    parts = sl.elts if isinstance(sl, ast.Tuple) else [sl]
    for p in parts:
        if isinstance(p, ast.Slice) and (bound_varies(p.lower)
                                         or bound_varies(p.upper)):
            return True
    return False


def _find_unpadded_slices(arg: ast.AST) -> list[ast.Subscript]:
    """Variable-bound slices in `arg` not wrapped by a pad helper."""
    hits: list[ast.Subscript] = []
    stack: list[tuple[ast.AST, bool]] = [(arg, False)]
    while stack:
        node, padded = stack.pop()
        if isinstance(node, ast.Call):
            d = dotted_name(node.func) or ""
            if "pad" in d.rsplit(".", 1)[-1].lower():
                padded = True
        if isinstance(node, ast.Subscript) and not padded \
                and _variable_slice(node):
            hits.append(node)
        for child in ast.iter_child_nodes(node):
            stack.append((child, padded))
    return hits


def check(modules: list[Module]) -> list[Finding]:
    jitted = collect_jitted(modules)
    if not jitted:
        return []
    findings: list[Finding] = []
    for mod in modules:
        func_stack: list[str] = []

        def walk(node: ast.AST):
            pushed = False
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                func_stack.append(node.name)
                pushed = True
            if isinstance(node, ast.Call):
                name = None
                if isinstance(node.func, ast.Name):
                    name = node.func.id
                elif isinstance(node.func, ast.Attribute):
                    name = node.func.attr
                if name in jitted:
                    for arg in list(node.args) + \
                            [kw.value for kw in node.keywords]:
                        for sub in _find_unpadded_slices(arg):
                            f = Finding(
                                "jit-shape", mod.path, sub.lineno,
                                ".".join(func_stack) or name,
                                f"call to jitted {name}() takes a "
                                f"variable-bound slice — every distinct "
                                f"shape retraces; pad to a static shape "
                                f"(_pad_tail/_pad_to) first")
                            if not mod.allowed(f.rule, f.line):
                                findings.append(f)
            for child in ast.iter_child_nodes(node):
                walk(child)
            if pushed:
                func_stack.pop()

        walk(mod.tree)
    return findings
