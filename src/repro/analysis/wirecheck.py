"""Wire completeness: everything crossing the cluster wire must have a
faithful ``to_wire``/``from_wire`` pair covering every field.

Two checks:

``wire-pair``    a class defines ``to_wire`` without ``from_wire`` (or
                 vice versa).
``wire-field``   a field is missing from the wire handling — either a
                 dataclass/``__slots__`` field not referenced in the
                 class's own ``to_wire``/``from_wire`` bodies, or a
                 field of a dataclass imported by ``cluster/wire.py``
                 that never appears in that module (as an attribute
                 access, keyword argument, or string key).

Coverage is judged syntactically: a field counts as covered if its name
appears as ``self.<field>`` / ``x.<field>``, a ``<field>=`` keyword, a
``"<field>"`` string constant, or if the body calls
``dataclasses.asdict`` / ``vars`` on self (which covers everything).
Missing-field findings anchor to the class (or the wire-module import
line) so an inline ``# analysis: allow[wire-field] reason`` can justify
fields that are deliberately not shipped.
"""

from __future__ import annotations

import ast

from .core import Finding, Module, dotted_name

WIRE_MODULE_SUFFIX = "cluster/wire.py"


def class_fields(cls: ast.ClassDef) -> list[str]:
    """Dataclass annotated fields or ``__slots__`` entries."""
    fields: list[str] = []
    for st in cls.body:
        if isinstance(st, ast.AnnAssign) and isinstance(st.target, ast.Name):
            if not st.target.id.startswith("_"):
                fields.append(st.target.id)
        elif (isinstance(st, ast.Assign) and len(st.targets) == 1
              and isinstance(st.targets[0], ast.Name)
              and st.targets[0].id == "__slots__"
              and isinstance(st.value, (ast.Tuple, ast.List))):
            for elt in st.value.elts:
                if isinstance(elt, ast.Constant) and isinstance(
                        elt.value, str) and not elt.value.startswith("_"):
                    fields.append(elt.value)
    return fields


def _is_dataclass(cls: ast.ClassDef) -> bool:
    for dec in cls.decorator_list:
        d = dotted_name(dec.func if isinstance(dec, ast.Call) else dec)
        if d in ("dataclass", "dataclasses.dataclass"):
            return True
    return False


def _mentions(tree: ast.AST, cls_name: str | None = None,
              n_fields: int = 0) -> tuple[set, bool]:
    """-> (mentioned field-ish names, covers_all).

    ``covers_all`` is set by ``dataclasses.asdict``/``vars`` (to_wire
    side) or by a constructor call that provably supplies every field:
    ``Cls(**d)`` or ``Cls(a, b, ..., z)`` with at least ``n_fields``
    positional arguments (from_wire side)."""
    names: set[str] = set()
    covers_all = False
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute):
            names.add(node.attr)
        elif isinstance(node, ast.Constant) and isinstance(node.value, str):
            names.add(node.value)
        elif isinstance(node, ast.keyword) and node.arg:
            names.add(node.arg)
        elif isinstance(node, ast.Name):
            names.add(node.id)
        elif isinstance(node, ast.Call):
            d = dotted_name(node.func)
            if d in ("dataclasses.asdict", "asdict", "vars"):
                covers_all = True
            last = (d or "").rsplit(".", 1)[-1]
            if cls_name is not None and last in (cls_name, "cls"):
                if any(kw.arg is None for kw in node.keywords):
                    covers_all = True
                elif n_fields and len(node.args) >= n_fields:
                    covers_all = True
    return names, covers_all


def _ctor_covers(tree: ast.AST, cls_name: str, n_fields: int) -> bool:
    """True if the module constructs ``cls_name`` in a way that covers
    every field by construction (splat or full positional call)."""
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        last = (dotted_name(node.func) or "").rsplit(".", 1)[-1]
        if last != cls_name:
            continue
        if any(kw.arg is None for kw in node.keywords):
            return True
        if n_fields and len(node.args) >= n_fields:
            return True
    return False


def check(modules: list[Module]) -> list[Finding]:
    findings: list[Finding] = []
    # index every class def for the wire-module import check
    class_index: dict[str, ast.ClassDef] = {}
    for mod in modules:
        for st in mod.tree.body:
            if isinstance(st, ast.ClassDef):
                class_index.setdefault(st.name, st)

    def add(mod: Module, f: Finding):
        if not mod.allowed(f.rule, f.line):
            findings.append(f)

    # method-style pairs on any class
    for mod in modules:
        for cls in mod.tree.body:
            if not isinstance(cls, ast.ClassDef):
                continue
            methods = {st.name: st for st in cls.body
                       if isinstance(st, ast.FunctionDef)}
            has_to, has_from = "to_wire" in methods, "from_wire" in methods
            if not (has_to or has_from):
                continue
            if has_to != has_from:
                missing = "from_wire" if has_to else "to_wire"
                add(mod, Finding(
                    "wire-pair", mod.path, cls.lineno, cls.name,
                    f"{cls.name} defines "
                    f"{'to_wire' if has_to else 'from_wire'} but no "
                    f"{missing}"))
                continue
            fields = class_fields(cls)
            for side in ("to_wire", "from_wire"):
                mentioned, covers_all = _mentions(
                    methods[side], cls.name, len(fields))
                if covers_all:
                    continue
                for f in fields:
                    if f not in mentioned:
                        add(mod, Finding(
                            "wire-field", mod.path, methods[side].lineno,
                            f"{cls.name}.{f}",
                            f"{cls.name}.{f} not covered by "
                            f"{cls.name}.{side} — adding a field without "
                            f"wire handling silently truncates it"))

    # dataclasses imported by the wire module must be fully referenced
    for mod in modules:
        if not mod.path.replace("\\", "/").endswith(WIRE_MODULE_SUFFIX):
            continue
        mentioned, _ = _mentions(mod.tree)
        for st in mod.tree.body:
            if not isinstance(st, ast.ImportFrom):
                continue
            internal = st.level > 0 or (st.module or "").startswith("repro")
            if not internal:
                continue
            for alias in st.names:
                cls = class_index.get(alias.name)
                if cls is None or not (_is_dataclass(cls)
                                       or class_fields(cls)):
                    continue
                flds = class_fields(cls)
                if _ctor_covers(mod.tree, cls.name, len(flds)):
                    continue
                for f in flds:
                    if f not in mentioned:
                        add(mod, Finding(
                            "wire-field", mod.path, st.lineno,
                            f"{alias.name}.{f}",
                            f"{alias.name}.{f} is imported into the wire "
                            f"module but never serialized there"))
    return findings
