"""Runtime concurrency checker: a mini-TSan for the test suite.

``install()`` replaces ``threading.Lock``/``threading.RLock`` with
wrappers that record, per thread, which locks are held and in what
order locks nest (an edge ``A -> B`` means B was acquired while A was
held).  ``time.sleep`` is wrapped to record blocking-under-lock.
Locks are identified by their construction site (``file:line`` of the
``threading.Lock()`` call), which is exactly the site the static pass
(``repro.analysis.locks``) knows each lock attribute by — so observed
orders can be cross-checked against the static graph:

- a cycle among observed edges is always a violation (real deadlock
  potential, whether or not the static pass could see it);
- an observed edge that *reverses* a static edge between two known
  locks is a violation even before a full cycle manifests.

Enable via ``REPRO_ANALYSIS=1`` (the root ``conftest.py`` installs the
checker before collection and fails the session on violations) — shard
worker processes install it themselves when they see the env var.

Locks created *before* ``install()`` are not traced (they are plain
``_thread`` locks); install as early as possible.  The wrappers add a
few hundred nanoseconds per acquire — fine for tests, not for
production serving.
"""

from __future__ import annotations

import os
import sys
import threading
import time
import _thread

_REAL_LOCK = threading.Lock
_REAL_RLOCK = threading.RLock
_REAL_SLEEP = time.sleep

# internal graph lock: a raw _thread lock so it is never itself traced
_graph_mu = _thread.allocate_lock()
_edges: dict[tuple, tuple] = {}   # (siteA, siteB) -> (thread, file:line)
_violations: list[str] = []
_installed = False
_tls = threading.local()

_SELF = os.path.abspath(__file__)


def _norm(path: str) -> str:
    p = path.replace("\\", "/")
    idx = p.rfind("/repro/")
    if idx >= 0:
        return p[idx + 1:]
    return p.rsplit("/", 1)[-1]


def _caller_site(skip_threading: bool = True) -> str:
    f = sys._getframe(2)
    while f is not None:
        fn = f.f_code.co_filename
        if fn != _SELF and not (skip_threading
                                and fn == threading.__file__):
            return f"{_norm(fn)}:{f.f_lineno}"
        f = f.f_back
    return "<unknown>"


def _held() -> list:
    lst = getattr(_tls, "held", None)
    if lst is None:
        lst = _tls.held = []
    return lst


def _note_acquire(lock: "_TracedLockBase") -> None:
    held = _held()
    if not any(h is lock for h in held):
        sites = {h.site for h in held}
        with _graph_mu:
            for s in sites:
                if s != lock.site and (s, lock.site) not in _edges:
                    _edges[(s, lock.site)] = (
                        threading.current_thread().name,
                        _caller_site())
    held.append(lock)


def _note_release(lock: "_TracedLockBase") -> None:
    held = _held()
    for i in range(len(held) - 1, -1, -1):
        if held[i] is lock:
            del held[i]
            return


class _TracedLockBase:
    __slots__ = ("_inner", "site")

    def __init__(self, inner, site: str):
        self._inner = inner
        self.site = site

    def acquire(self, blocking: bool = True, timeout: float = -1):
        ok = self._inner.acquire(blocking, timeout)
        if ok:
            _note_acquire(self)
        return ok

    def release(self):
        self._inner.release()
        _note_release(self)

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def locked(self):
        return self._inner.locked()

    def __repr__(self):
        return f"<traced {self._inner!r} @ {self.site}>"

    def __getattr__(self, name):
        return getattr(self._inner, name)


class _TracedLock(_TracedLockBase):
    __slots__ = ()


class _TracedRLock(_TracedLockBase):
    __slots__ = ()

    # Condition-protocol passthroughs with held-set bookkeeping
    def _is_owned(self):
        return self._inner._is_owned()

    def _release_save(self):
        state = self._inner._release_save()
        held = _held()
        _tls.held = [h for h in held if h is not self]
        return state

    def _acquire_restore(self, state):
        self._inner._acquire_restore(state)
        _note_acquire(self)


def _lock_factory():
    return _TracedLock(_REAL_LOCK(), _caller_site())


def _rlock_factory():
    return _TracedRLock(_REAL_RLOCK(), _caller_site())


class allow_block:
    """Marks a region where blocking while holding a lock is deliberate —
    the runtime mirror of the static ``# analysis: allow[block]``
    directive, and like it, a justification is mandatory.  Only
    sleep-under-lock recording is suppressed; acquisition-order edges are
    still collected."""

    __slots__ = ()

    def __init__(self, reason: str):
        if not reason or not reason.strip():
            raise ValueError("allow_block requires a justification")

    def __enter__(self):
        _tls.allow_block = getattr(_tls, "allow_block", 0) + 1
        return self

    def __exit__(self, *exc):
        _tls.allow_block -= 1
        return False


def _traced_sleep(secs):
    held = _held()
    if held and not getattr(_tls, "allow_block", 0):
        sites = sorted(h.site for h in held)
        msg = (f"time.sleep({secs!r}) while holding lock(s) {sites} "
               f"at {_caller_site(skip_threading=False)}")
        with _graph_mu:
            if msg not in _violations:
                _violations.append(msg)
    return _REAL_SLEEP(secs)


def install() -> bool:
    """Idempotent; returns True if this call did the installation."""
    global _installed
    if _installed:
        return False
    _installed = True
    threading.Lock = _lock_factory
    threading.RLock = _rlock_factory
    time.sleep = _traced_sleep
    return True


def uninstall() -> None:
    global _installed
    if not _installed:
        return
    _installed = False
    threading.Lock = _REAL_LOCK
    threading.RLock = _REAL_RLOCK
    time.sleep = _REAL_SLEEP


def installed() -> bool:
    return _installed


def reset() -> None:
    with _graph_mu:
        _edges.clear()
        _violations.clear()


class scoped:
    """Context manager for self-tests: snapshots the edge graph and
    violation list, restores them on exit, so an injected inversion does
    not fail the surrounding REPRO_ANALYSIS=1 session."""

    def __enter__(self):
        with _graph_mu:
            self._edges = dict(_edges)
            self._violations = list(_violations)
        return self

    def __exit__(self, *exc):
        with _graph_mu:
            _edges.clear()
            _edges.update(self._edges)
            _violations.clear()
            _violations.extend(self._violations)
        return False


def edges() -> dict:
    with _graph_mu:
        return dict(_edges)


def _find_cycle(adj: dict) -> list | None:
    color: dict[str, int] = {}
    for start in sorted(adj):
        if color.get(start):
            continue
        stack = [(start, iter(adj.get(start, ())))]
        path = [start]
        color[start] = 1
        while stack:
            node, it = stack[-1]
            nxt = next(it, None)
            if nxt is None:
                color[node] = 2
                stack.pop()
                path.pop()
                continue
            if color.get(nxt) == 1:
                return path[path.index(nxt):] + [nxt]
            if not color.get(nxt):
                color[nxt] = 1
                stack.append((nxt, iter(adj.get(nxt, ()))))
                path.append(nxt)
    return None


def check(static_sites: dict | None = None,
          static_edges: set | None = None) -> list[str]:
    """Current violations: recorded blocking-under-lock events, cycles
    in the observed acquisition graph, and (when the static lock
    analysis is provided) observed edges that reverse a static edge.

    ``static_sites`` maps ``(norm_path, line) -> node_id`` and
    ``static_edges`` is a set of ``(node_id, node_id)`` — both exactly
    as produced by ``repro.analysis.locks.analyze``."""
    with _graph_mu:
        observed = dict(_edges)
        out = list(_violations)
    adj: dict[str, list[str]] = {}
    for (a, b) in observed:
        adj.setdefault(a, []).append(b)
    cyc = _find_cycle(adj)
    if cyc is not None:
        detail = []
        for a, b in zip(cyc, cyc[1:]):
            thread, where = observed[(a, b)]
            detail.append(f"{a} -> {b} (thread {thread} at {where})")
        out.append("lock-order cycle observed: " + "; ".join(detail))
    if static_sites and static_edges:
        def to_node(site: str):
            path, _, line = site.rpartition(":")
            try:
                return static_sites.get((path, int(line)))
            except ValueError:
                return None
        for (a, b), (thread, where) in observed.items():
            na, nb = to_node(a), to_node(b)
            if na and nb and (nb, na) in static_edges \
                    and (na, nb) not in static_edges:
                out.append(
                    f"observed acquisition {na} -> {nb} (thread {thread} "
                    f"at {where}) reverses the static lock order "
                    f"{nb} -> {na}")
    return out


def install_from_env() -> bool:
    if os.environ.get("REPRO_ANALYSIS") == "1":
        return install()
    return False
