"""Lock discipline + lock-order analysis.

Three rules come out of this pass:

``guard``        a field declared ``# guarded-by: <lock>`` is mutated
                 (assigned, augmented, deleted, or hit with a mutating
                 container method) outside a ``with self.<lock>`` block.
                 ``__init__`` and init-only helpers are exempt (single-
                 threaded construction), as are methods carrying a
                 ``# holds: <lock>`` directive or the ``*_locked``
                 naming convention.
``block``        a blocking call (``time.sleep``, ``subprocess``,
                 socket send/recv, wire frames, worker RPC,
                 ``queue.Queue.get/put``, ``Future.result``) made while
                 any lock is held.
``lock-order``   the static lock-acquisition graph (nested ``with``
                 blocks, propagated through resolvable intra-repo calls)
                 contains a cycle.

The acquisition graph is deliberately *under*-approximate: only calls
whose receiver is statically resolvable (``self.method``, or
``self.attr.method`` where ``__init__`` assigned ``self.attr =
KnownClass(...)``) propagate acquisitions.  The runtime checker
(``repro.analysis.runtime``) covers what this misses, and cross-checks
observed orders against the edges collected here.
"""

from __future__ import annotations

import ast
import dataclasses

from .core import Finding, Module, dotted_name

LOCK_CTORS = {"threading.Lock", "threading.RLock"}
COND_CTOR = "threading.Condition"

# fully-qualified callables that block
BLOCKING_FUNCS = {
    "time.sleep", "socket.create_connection",
    "subprocess.run", "subprocess.call", "subprocess.check_call",
    "subprocess.check_output", "subprocess.Popen",
}
# method names that block regardless of receiver (receivers resolving to
# a known lock/Condition attribute are exempted for "wait")
BLOCKING_METHODS = {
    "recv", "recv_exact", "recv_msg", "send_msg", "sendall", "accept",
    "connect", "call", "call_retry", "broadcast", "result", "wait",
}
# component classes whose get/put are queue-style blocking calls
QUEUE_CTORS = {"queue.Queue", "Queue", "queue.SimpleQueue", "SimpleQueue"}

MUTATING_METHODS = {
    "append", "extend", "add", "remove", "discard", "pop", "popleft",
    "appendleft", "clear", "update", "setdefault", "insert", "sort",
    "move_to_end", "popitem", "rotate",
}


def norm_path(path: str) -> str:
    """Stable path key shared with the runtime checker: the part of the
    path from the last ``repro/`` component on (else the basename)."""
    p = path.replace("\\", "/")
    idx = p.rfind("/repro/")
    if idx >= 0:
        return p[idx + 1:]
    if p.startswith("repro/"):
        return p
    return p.rsplit("/", 1)[-1]


@dataclasses.dataclass
class ClassInfo:
    name: str
    module: Module
    node: ast.ClassDef
    locks: dict = dataclasses.field(default_factory=dict)       # attr -> canonical attr
    lock_sites: dict = dataclasses.field(default_factory=dict)  # canonical -> (path, line)
    guarded: dict = dataclasses.field(default_factory=dict)     # field -> canonical lock
    guard_lines: dict = dataclasses.field(default_factory=dict)
    components: dict = dataclasses.field(default_factory=dict)  # attr -> ctor dotted name
    methods: dict = dataclasses.field(default_factory=dict)     # name -> FunctionDef
    init_only: set = dataclasses.field(default_factory=set)

    def node_id(self, canonical: str) -> str:
        return f"{self.name}.{canonical}"


@dataclasses.dataclass
class LockAnalysis:
    findings: list
    # (src_node, dst_node) -> (path, line) of first example acquisition
    edges: dict
    # (norm_path, line) -> node_id, for runtime site translation
    sites: dict


def _self_attr(node: ast.AST) -> str | None:
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def _collect_classes(modules: list[Module]) -> tuple[dict, dict, list]:
    """-> (classes by name, module-level locks by name, findings)."""
    classes: dict[str, ClassInfo] = {}
    module_locks: dict[str, tuple[str, str, int]] = {}  # name -> (id, path, line)
    findings: list[Finding] = []
    for mod in modules:
        stem = norm_path(mod.path).rsplit("/", 1)[-1]
        for st in mod.tree.body:
            if (isinstance(st, ast.Assign) and len(st.targets) == 1
                    and isinstance(st.targets[0], ast.Name)
                    and isinstance(st.value, ast.Call)
                    and dotted_name(st.value.func) in LOCK_CTORS):
                name = st.targets[0].id
                module_locks[name] = (f"{stem}::{name}", mod.path, st.lineno)
            if not isinstance(st, ast.ClassDef):
                continue
            info = ClassInfo(name=st.name, module=mod, node=st)
            for item in st.body:
                if isinstance(item, ast.FunctionDef):
                    info.methods[item.name] = item
            # first sweep: lock constructions + component types + guards
            cond_aliases: dict[str, str] = {}
            for meth in info.methods.values():
                for sub in ast.walk(meth):
                    if isinstance(sub, ast.Assign) \
                            and len(sub.targets) == 1:
                        target, value = sub.targets[0], sub.value
                    elif isinstance(sub, ast.AnnAssign):
                        target, value = sub.target, sub.value
                    else:
                        continue
                    attr = _self_attr(target)
                    if attr is None:
                        continue
                    if sub.lineno in mod.guards:
                        info.guarded[attr] = mod.guards[sub.lineno]
                        info.guard_lines[attr] = sub.lineno
                    if not isinstance(value, ast.Call):
                        continue
                    ctor = dotted_name(value.func)
                    if ctor in LOCK_CTORS:
                        info.locks[attr] = attr
                        info.lock_sites[attr] = (mod.path, sub.lineno)
                    elif ctor == COND_CTOR:
                        arg = _self_attr(value.args[0]) \
                            if value.args else None
                        if arg is not None:
                            cond_aliases[attr] = arg
                        else:
                            info.locks[attr] = attr
                            info.lock_sites[attr] = (mod.path, sub.lineno)
                    elif ctor is not None:
                        info.components[attr] = ctor
            for alias, target in cond_aliases.items():
                if target in info.locks:
                    info.locks[alias] = info.locks[target]
                else:
                    findings.append(Finding(
                        "bad-guard-decl", mod.path,
                        info.methods.get("__init__", st).lineno,
                        f"{info.name}.{alias}",
                        f"Condition({info.name}.{alias}) wraps unknown "
                        f"lock {target!r}"))
            # guard declarations must name a known lock
            for field, lockname in list(info.guarded.items()):
                if lockname in info.locks:
                    info.guarded[field] = info.locks[lockname]  # canonical
                elif lockname in module_locks:
                    info.guarded[field] = f"::{lockname}"
                else:
                    findings.append(Finding(
                        "bad-guard-decl", mod.path,
                        info.guard_lines[field],
                        f"{info.name}.{field}",
                        f"guarded-by names unknown lock {lockname!r} on "
                        f"{info.name}.{field}"))
                    del info.guarded[field]
            # init-only helpers: private methods reachable only from
            # __init__ (fixpoint over intra-class self.m() calls)
            callers: dict[str, set] = {m: set() for m in info.methods}
            for mname, meth in info.methods.items():
                for sub in ast.walk(meth):
                    if isinstance(sub, ast.Call):
                        tgt = _self_attr(sub.func)
                        if tgt in callers:
                            callers[tgt].add(mname)
            changed = True
            init_only = set()
            while changed:
                changed = False
                for mname, who in callers.items():
                    if mname == "__init__" or mname in init_only:
                        continue
                    if (mname.startswith("_") and who
                            and all(c == "__init__" or c in init_only
                                    for c in who)):
                        init_only.add(mname)
                        changed = True
            info.init_only = init_only
            classes[st.name] = info
    return classes, module_locks, findings


class _MethodWalker:
    """Walks one function body tracking the lexically-held lock set."""

    def __init__(self, analysis: "_Analyzer", info: ClassInfo | None,
                 mod: Module, fn: ast.FunctionDef, entry_held: frozenset,
                 exempt_guard: bool, method_key: tuple):
        self.an = analysis
        self.info = info
        self.mod = mod
        self.fn = fn
        self.entry_held = entry_held
        self.exempt_guard = exempt_guard
        self.method_key = method_key
        self.direct_acquires: set[str] = set()
        self.calls: list[tuple] = []  # (callee_key, held, line)

    # -- lock resolution -------------------------------------------------------
    def resolve_lock(self, expr: ast.AST) -> str | None:
        """Node id for an expression naming a lock, else None."""
        attr = _self_attr(expr)
        if attr is not None and self.info and attr in self.info.locks:
            return self.info.node_id(self.info.locks[attr])
        if isinstance(expr, ast.Name) and expr.id in self.an.module_locks:
            return self.an.module_locks[expr.id][0]
        d = dotted_name(expr)
        if d and "." in d:
            last = d.rsplit(".", 1)[-1]
            if last in self.an.module_locks:
                return self.an.module_locks[last][0]
        return None

    def _is_lock_attr(self, expr: ast.AST) -> bool:
        attr = _self_attr(expr)
        return (attr is not None and self.info is not None
                and attr in self.info.locks)

    # -- traversal -------------------------------------------------------------
    def run(self):
        self.walk_body(self.fn.body, self.entry_held)

    def walk_body(self, stmts, held: frozenset) -> frozenset:
        for st in stmts:
            held = self.visit_stmt(st, held)
        return held

    def visit_stmt(self, st, held: frozenset) -> frozenset:
        if isinstance(st, ast.With):
            inner = held
            for item in st.items:
                self.scan_expr(item.context_expr, inner)
                lock = self.resolve_lock(item.context_expr)
                if lock is not None:
                    self.acquire(lock, inner, item.context_expr.lineno)
                    inner = inner | {lock}
            self.walk_body(st.body, inner)
            return held
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # closure: runs later, not under the current held set
            saved = self.exempt_guard
            self.walk_body(st.body, frozenset())
            self.exempt_guard = saved
            return held
        if isinstance(st, ast.ClassDef):
            return held
        # manual acquire()/release() on a known lock adjusts held state
        # for the remainder of the current block
        if (isinstance(st, ast.Expr) and isinstance(st.value, ast.Call)
                and isinstance(st.value.func, ast.Attribute)
                and st.value.func.attr in ("acquire", "release")):
            lock = self.resolve_lock(st.value.func.value)
            if lock is not None:
                if st.value.func.attr == "acquire":
                    self.acquire(lock, held, st.lineno)
                    return held | {lock}
                return held - {lock}
        # guard checks on assignment-like statements
        if isinstance(st, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = st.targets if isinstance(st, ast.Assign) \
                else [st.target]
            for tgt in targets:
                self.check_mutation(tgt, held, st.lineno)
        if isinstance(st, ast.Delete):
            for tgt in st.targets:
                self.check_mutation(tgt, held, st.lineno)
        # recurse into nested statement bodies; scan everything else
        for field, value in ast.iter_fields(st):
            if isinstance(value, list) and value \
                    and isinstance(value[0], ast.stmt):
                self.walk_body(value, held)
            elif isinstance(value, list):
                for v in value:
                    if isinstance(v, ast.AST):
                        self.scan_expr(v, held)
            elif isinstance(value, ast.AST):
                self.scan_expr(value, held)
        return held

    def check_mutation(self, tgt, held: frozenset, line: int):
        """Flag writes to guarded fields outside their lock."""
        if self.exempt_guard or self.info is None:
            return
        node = tgt
        while isinstance(node, (ast.Subscript, ast.Starred)):
            node = node.value
        attr = _self_attr(node)
        if attr is None and isinstance(node, ast.Attribute):
            # `self.stats.hits += 1` mutates the object behind
            # `self.stats`
            attr = _self_attr(node.value)
        if attr is None or attr not in self.info.guarded:
            return
        lock = self.info.guarded[attr]
        need = lock if lock.startswith("::") is False \
            else self.an.module_locks.get(lock[2:], ("?",))[0]
        need_id = self.info.node_id(lock) if not lock.startswith("::") \
            else need
        if need_id not in held:
            self.an.add(Finding(
                "guard", self.mod.path, line,
                f"{self.info.name}.{attr}",
                f"{self.info.name}.{attr} is guarded by "
                f"{lock.lstrip(':')} but mutated without holding it "
                f"(in {self.fn.name})"), self.mod)

    def scan_expr(self, expr: ast.AST, held: frozenset):
        """Find calls / mutating-method calls in an expression tree,
        skipping Lambda bodies (deferred execution)."""
        stack = [(expr, held)]
        while stack:
            node, h = stack.pop()
            if isinstance(node, ast.Lambda):
                stack.append((node.body, frozenset()))
                continue
            if isinstance(node, ast.Call):
                self.visit_call(node, h)
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.stmt,)):
                    continue
                stack.append((child, h))

    def visit_call(self, call: ast.Call, held: frozenset):
        func = call.func
        d = dotted_name(func)
        line = call.lineno
        # mutating container method on a guarded field
        if isinstance(func, ast.Attribute) \
                and func.attr in MUTATING_METHODS:
            recv = _self_attr(func.value)
            if (not self.exempt_guard and self.info is not None
                    and recv in self.info.guarded):
                lock = self.info.guarded[recv]
                need_id = self.info.node_id(lock) \
                    if not lock.startswith("::") \
                    else self.an.module_locks.get(lock[2:], ("?",))[0]
                if need_id not in held:
                    self.an.add(Finding(
                        "guard", self.mod.path, line,
                        f"{self.info.name}.{recv}",
                        f"{self.info.name}.{recv} is guarded by "
                        f"{lock.lstrip(':')} but .{func.attr}() called "
                        f"without holding it (in {self.fn.name})"),
                        self.mod)
        # blocking call while holding any lock
        if held:
            blocked = None
            if d in BLOCKING_FUNCS:
                blocked = d
            elif isinstance(func, ast.Attribute) \
                    and func.attr in BLOCKING_METHODS \
                    and not isinstance(func.value, ast.Constant) \
                    and not self._is_lock_attr(func.value):
                blocked = f".{func.attr}()"
            elif isinstance(func, ast.Attribute) \
                    and func.attr in ("get", "put") \
                    and self.info is not None:
                recv = _self_attr(func.value)
                if self.info.components.get(recv) in QUEUE_CTORS:
                    blocked = f"queue.{func.attr}()"
            if blocked is not None:
                self.an.add(Finding(
                    "block", self.mod.path, line,
                    f"{self.method_key[0]}.{self.fn.name}",
                    f"blocking call {blocked} while holding "
                    f"{sorted(held)} (in {self.fn.name})"), self.mod)
        # *_locked convention: callee expects a lock already held
        recv_attr = _self_attr(func) if isinstance(func, ast.Attribute) \
            else None
        if (recv_attr is not None and recv_attr.endswith("_locked")
                and self.info is not None
                and recv_attr in self.info.methods):
            need = self.an.entry_held_of(self.info, recv_attr)
            if need and not need <= held:
                self.an.add(Finding(
                    "locked-call", self.mod.path, line,
                    f"{self.info.name}.{recv_attr}",
                    f"{recv_attr}() expects {sorted(need)} held but "
                    f"caller {self.fn.name} holds {sorted(held)}"),
                    self.mod)
        # record resolvable calls for interprocedural acquisition edges
        callee = self.resolve_callee(func)
        if callee is not None:
            self.calls.append((callee, held, line))

    def resolve_callee(self, func) -> tuple | None:
        if isinstance(func, ast.Name) and self.an.functions.get(
                ("", func.id)) is not None:
            return ("", func.id)
        attr = _self_attr(func)
        if attr is not None and self.info and attr in self.info.methods:
            return (self.info.name, attr)
        if isinstance(func, ast.Attribute):
            recv = _self_attr(func.value)
            if recv is not None and self.info:
                comp = self.info.components.get(recv)
                if comp is not None:
                    cname = comp.rsplit(".", 1)[-1]
                    if (cname, func.attr) in self.an.functions:
                        return (cname, func.attr)
        return None

    def acquire(self, lock: str, held: frozenset, line: int):
        self.direct_acquires.add(lock)
        for h in held:
            if h != lock:
                self.an.edge(h, lock, self.mod.path, line)


class _Analyzer:
    def __init__(self, modules: list[Module]):
        self.modules = modules
        self.findings: list[Finding] = []
        self.edges: dict = {}
        self.classes, self.module_locks, pre = _collect_classes(modules)
        self.findings.extend(pre)
        # (ClassName|"", method) -> (info | None, Module, FunctionDef)
        self.functions: dict = {}
        for info in self.classes.values():
            for mname, fn in info.methods.items():
                self.functions[(info.name, mname)] = (info, info.module, fn)
        for mod in modules:
            for st in mod.tree.body:
                if isinstance(st, ast.FunctionDef):
                    self.functions.setdefault(("", st.name),
                                              (None, mod, st))
        self._entry_cache: dict = {}

    def add(self, finding: Finding, mod: Module):
        if not mod.allowed(finding.rule, finding.line):
            self.findings.append(finding)

    def edge(self, a: str, b: str, path: str, line: int):
        self.edges.setdefault((a, b), (path, line))

    def entry_held_of(self, info: ClassInfo, mname: str) -> frozenset:
        key = (info.name, mname)
        if key in self._entry_cache:
            return self._entry_cache[key]
        fn = info.methods[mname]
        held = set()
        names = info.module.holds.get(fn.lineno)
        if names:
            for n in names:
                if n in info.locks:
                    held.add(info.node_id(info.locks[n]))
                elif n in self.module_locks:
                    held.add(self.module_locks[n][0])
                else:
                    self.findings.append(Finding(
                        "bad-guard-decl", info.module.path, fn.lineno,
                        f"{info.name}.{mname}",
                        f"holds: names unknown lock {n!r}"))
        elif mname.endswith("_locked"):
            canon = set(info.locks.values())
            if len(canon) == 1:
                held.add(info.node_id(next(iter(canon))))
            elif canon:
                self.findings.append(Finding(
                    "locked-needs-holds", info.module.path, fn.lineno,
                    f"{info.name}.{mname}",
                    f"{mname} uses the *_locked convention but "
                    f"{info.name} has several locks — add a "
                    f"'# holds: <lock>' directive"))
        result = frozenset(held)
        self._entry_cache[key] = result
        return result

    def run(self) -> LockAnalysis:
        walkers = {}
        for key, (info, mod, fn) in self.functions.items():
            entry = self.entry_held_of(info, key[1]) if info else frozenset()
            exempt = (key[1] == "__init__"
                      or (info is not None and key[1] in info.init_only))
            w = _MethodWalker(self, info, mod, fn, entry, exempt, key)
            w.run()
            walkers[key] = w
        # fixpoint: transitive may-acquire sets through resolvable calls
        acq = {key: set(w.direct_acquires) for key, w in walkers.items()}
        changed = True
        while changed:
            changed = False
            for key, w in walkers.items():
                for callee, _, _ in w.calls:
                    extra = acq.get(callee, set()) - acq[key]
                    if extra:
                        acq[key].update(extra)
                        changed = True
        for key, w in walkers.items():
            for callee, held, line in w.calls:
                for lock in acq.get(callee, ()):
                    for h in held:
                        if h != lock:
                            self.edge(h, lock, w.mod.path, line)
        self._check_cycles()
        sites = {}
        for info in self.classes.values():
            for canon, (path, line) in info.lock_sites.items():
                sites[(norm_path(path), line)] = info.node_id(canon)
        for name, (nid, path, line) in self.module_locks.items():
            sites[(norm_path(path), line)] = nid
        return LockAnalysis(self.findings, self.edges, sites)

    def _check_cycles(self):
        adj: dict[str, list[str]] = {}
        for (a, b) in self.edges:
            adj.setdefault(a, []).append(b)
        WHITE, GRAY, BLACK = 0, 1, 2
        color = {n: WHITE for n in
                 set(adj) | {b for (_, b) in self.edges}}
        reported = set()
        for start in sorted(color):
            if color[start] != WHITE:
                continue
            stack = [(start, iter(adj.get(start, ())))]
            path = [start]
            color[start] = GRAY
            while stack:
                node, it = stack[-1]
                nxt = next(it, None)
                if nxt is None:
                    color[node] = BLACK
                    stack.pop()
                    path.pop()
                    continue
                if color[nxt] == GRAY:
                    cyc = tuple(path[path.index(nxt):] + [nxt])
                    if frozenset(cyc) not in reported:
                        reported.add(frozenset(cyc))
                        sites = []
                        for a, b in zip(cyc, cyc[1:]):
                            p, ln = self.edges[(a, b)]
                            sites.append(f"{a}->{b} at {p}:{ln}")
                        self.findings.append(Finding(
                            "lock-order", sites and
                            self.edges[(cyc[0], cyc[1])][0] or "?",
                            self.edges[(cyc[0], cyc[1])][1],
                            "->".join(cyc),
                            "lock-order cycle: " + "; ".join(sites)))
                elif color[nxt] == WHITE:
                    color[nxt] = GRAY
                    stack.append((nxt, iter(adj.get(nxt, ()))))
                    path.append(nxt)


def analyze(modules: list[Module]) -> LockAnalysis:
    return _Analyzer(modules).run()
