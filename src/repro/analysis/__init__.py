"""repro.analysis: invariant linter + runtime concurrency checker.

Static passes (stdlib ``ast`` only — this package must stay importable
on a bare interpreter with no third-party deps):

- ``locks``        lock discipline (guarded fields, blocking-under-lock)
                   and the static lock-acquisition order graph;
- ``wirecheck``    wire completeness for everything crossing the cluster
                   wire protocol;
- ``determinism``  no ``hash()`` / unseeded randomness / wall-clock reads
                   in placement, merge, seed, or bench-identity paths;
- ``jitshape``     jitted call sites must not be fed data-dependent
                   shapes (jit-cache fragmentation).

Run the suite with ``python -m repro.analysis.lint src/``.  The runtime
companion (``repro.analysis.runtime``) wraps ``threading.Lock``/``RLock``
to record real acquisition orders while the test suite runs
(``REPRO_ANALYSIS=1``) and cross-checks them against the static graph.

See ``README.md`` in this directory for rules, the ``# guarded-by:``
annotation syntax, and the baseline / suppression format.
"""

from .core import Finding, Module, load_modules, load_tree

__all__ = ["Finding", "Module", "load_modules", "load_tree"]
