"""Determinism: placement / merge / seed / bench-identity paths must be
process-stable.

``PYTHONHASHSEED`` randomizes ``hash()`` per process, wall clocks differ
across machines, and unseeded RNGs differ across runs — any of these in
a path that decides shard placement, erosion victims, synthetic-scene
content, or bench identity breaks the ``--check`` regression gate and
the bit-identical single-process-vs-cluster guarantee (crc32 and the
golden-ratio integer hash are the sanctioned tools; see
``cluster.router.stable_shard`` and ``videostore.stratified_pick``).

Scoped to ``DETERMINISM_PATHS`` plus any module carrying an
``# analysis: determinism-path`` comment.  Rule name: ``determinism``.
"""

from __future__ import annotations

import ast

from .core import Finding, Module, dotted_name

DETERMINISM_PATHS = (
    "analytics/scene.py",        # synthetic scenes: bench identity
    "cluster/router.py",         # shard placement + scatter-gather merge
    "videostore/video_store.py",  # stratified erosion victim spread
    "ingest/erosion_exec.py",    # cohort erosion seeds
    "core/erosion.py",           # erosion plan math
)

# dotted call names that are nondeterministic across processes/machines
BANNED_CALLS = {
    "time.time": "wall-clock read",
    "time.time_ns": "wall-clock read",
    "datetime.now": "wall-clock read",
    "datetime.utcnow": "wall-clock read",
    "datetime.datetime.now": "wall-clock read",
    "datetime.datetime.utcnow": "wall-clock read",
    "date.today": "wall-clock read",
    "uuid.uuid4": "random identity",
    "os.urandom": "random bytes",
    "secrets.token_bytes": "random bytes",
    "secrets.token_hex": "random bytes",
}

# the stdlib `random` module: any use is banned in these paths (seeded
# determinism goes through np.random.default_rng(seed) instead)
_RANDOM_PREFIXES = ("random.",)
_NP_RANDOM_DIRECT = {
    "np.random.rand", "np.random.randn", "np.random.randint",
    "np.random.random", "np.random.choice", "np.random.permutation",
    "np.random.shuffle", "np.random.seed",
    "numpy.random.rand", "numpy.random.randn", "numpy.random.randint",
    "numpy.random.random", "numpy.random.choice",
    "numpy.random.permutation", "numpy.random.shuffle",
    "numpy.random.seed",
}


def _in_scope(mod: Module) -> bool:
    if mod.determinism_opt_in:
        return True
    p = mod.path.replace("\\", "/")
    return any(p.endswith(suffix) for suffix in DETERMINISM_PATHS)


def check(modules: list[Module]) -> list[Finding]:
    findings: list[Finding] = []
    for mod in modules:
        if not _in_scope(mod):
            continue

        def add(f: Finding):
            if not mod.allowed(f.rule, f.line):
                findings.append(f)

        func_stack: list[str] = []

        def sym(line_hint: str) -> str:
            return ".".join(func_stack) if func_stack else line_hint

        def walk(node: ast.AST):
            pushed = False
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                func_stack.append(node.name)
                pushed = True
            if isinstance(node, ast.Call):
                d = dotted_name(node.func)
                if isinstance(node.func, ast.Name) \
                        and node.func.id == "hash":
                    add(Finding(
                        "determinism", mod.path, node.lineno,
                        sym("hash"),
                        "hash() is randomized per process "
                        "(PYTHONHASHSEED) — use zlib.crc32 or the "
                        "golden-ratio integer hash"))
                elif d in BANNED_CALLS:
                    add(Finding(
                        "determinism", mod.path, node.lineno, sym(d),
                        f"{d}() is a {BANNED_CALLS[d]} — not stable "
                        f"across processes/machines"))
                elif d and d.startswith(_RANDOM_PREFIXES):
                    add(Finding(
                        "determinism", mod.path, node.lineno, sym(d),
                        f"stdlib {d}() in a determinism path — use "
                        f"np.random.default_rng(seed)"))
                elif d in _NP_RANDOM_DIRECT:
                    add(Finding(
                        "determinism", mod.path, node.lineno, sym(d),
                        f"{d}() uses global RNG state — use "
                        f"np.random.default_rng(seed)"))
                elif d and d.endswith("default_rng") and not node.args \
                        and not node.keywords:
                    add(Finding(
                        "determinism", mod.path, node.lineno, sym(d),
                        "default_rng() without a seed is entropy-"
                        "seeded — pass an explicit seed"))
            for child in ast.iter_child_nodes(node):
                walk(child)
            if pushed:
                func_stack.pop()

        walk(mod.tree)
    return findings
