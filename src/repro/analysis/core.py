"""Shared plumbing for the analysis passes.

A pass consumes ``Module`` objects (source + AST + comment directives)
and yields ``Finding``s.  Three kinds of comment directives exist:

``# guarded-by: <lock>``
    On a ``self.<attr> = ...`` line: declares that every subsequent
    mutation of ``<attr>`` must happen while ``self.<lock>`` is held
    (``<lock>`` names a ``threading.Lock``/``RLock``/``Condition``
    attribute of the same class, or a module-level lock).

``# holds: <lock>[, <lock>...]``
    On a ``def`` line: the method is documented to be called with the
    named lock(s) already held (the ``*_locked`` naming convention
    implies this for single-lock classes without the directive).

``# analysis: allow[<rule>[,<rule>...]] <justification>``
    Suppresses findings of the named rule(s) on that line.  The
    justification text is mandatory — an allow without a reason is
    itself a finding (rule ``bare-allow``).

``# analysis: determinism-path``
    Anywhere in a file: opts the whole module into the determinism
    pass (in addition to the built-in path patterns).

Findings are fingerprinted as ``rule:path:symbol`` (no line numbers, so
baselines survive unrelated edits).  The baseline file holds one
fingerprint per line with a mandatory trailing ``# reason`` comment.
"""

from __future__ import annotations

import ast
import dataclasses
import io
import os
import re
import tokenize

_ALLOW_RE = re.compile(
    r"#\s*analysis:\s*allow\[([A-Za-z0-9_,\- ]+)\]\s*(.*)")
_GUARDED_RE = re.compile(r"#\s*guarded-by:\s*([A-Za-z_][A-Za-z0-9_]*)")
_HOLDS_RE = re.compile(
    r"#\s*holds:\s*([A-Za-z_][A-Za-z0-9_]*(?:\s*,\s*[A-Za-z_][A-Za-z0-9_]*)*)")
_DETPATH_RE = re.compile(r"#\s*analysis:\s*determinism-path\b")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str        # e.g. "guard", "lock-order", "wire-field", ...
    path: str        # path as given on the command line (relative)
    line: int
    symbol: str      # stable anchor: "Class.attr", "Class.method", ...
    message: str

    @property
    def fingerprint(self) -> str:
        return f"{self.rule}:{self.path}:{self.symbol}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


class Module:
    """One parsed source file plus its comment directives."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.tree = ast.parse(source, filename=path)
        # line -> set of suppressed rules; line -> guard lock name; ...
        self.allows: dict[int, set[str]] = {}
        self.bare_allows: list[int] = []
        self.guards: dict[int, str] = {}
        self.holds: dict[int, list[str]] = {}
        self.determinism_opt_in = False
        self._scan_comments()

    def _scan_comments(self) -> None:
        src_lines = self.source.splitlines()

        def _attach_line(line: int) -> int:
            """An allow on a comment-only line suppresses the next code
            line (standard suppress-next-line semantics); an end-of-line
            allow suppresses its own line."""
            text = src_lines[line - 1].strip() if line <= len(src_lines) \
                else ""
            if not text.startswith("#"):
                return line
            nxt = line + 1
            while nxt <= len(src_lines):
                stripped = src_lines[nxt - 1].strip()
                if stripped and not stripped.startswith("#"):
                    return nxt
                nxt += 1
            return line

        try:
            toks = tokenize.generate_tokens(io.StringIO(self.source).readline)
            for tok in toks:
                if tok.type != tokenize.COMMENT:
                    continue
                text, line = tok.string, tok.start[0]
                m = _ALLOW_RE.search(text)
                if m:
                    rules = {r.strip() for r in m.group(1).split(",")
                             if r.strip()}
                    if not m.group(2).strip():
                        self.bare_allows.append(line)
                    target = _attach_line(line)
                    self.allows.setdefault(target, set()).update(rules)
                    if target != line:
                        self.allows.setdefault(line, set()).update(rules)
                m = _GUARDED_RE.search(text)
                if m:
                    self.guards[line] = m.group(1)
                m = _HOLDS_RE.search(text)
                if m:
                    self.holds[line] = [s.strip()
                                        for s in m.group(1).split(",")]
                if _DETPATH_RE.search(text):
                    self.determinism_opt_in = True
        except tokenize.TokenError:
            pass

    def allowed(self, rule: str, line: int) -> bool:
        return rule in self.allows.get(line, ())


def load_tree(root: str) -> list[Module]:
    """Parse every ``.py`` file under ``root`` (or the single file)."""
    paths: list[str] = []
    if os.path.isfile(root):
        paths = [root]
    else:
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in ("__pycache__", ".git"))
            for name in sorted(filenames):
                if name.endswith(".py"):
                    paths.append(os.path.join(dirpath, name))
    return load_modules(paths)


def load_modules(paths: list[str]) -> list[Module]:
    mods = []
    for path in paths:
        with open(path, encoding="utf-8") as fh:
            source = fh.read()
        mods.append(Module(os.path.normpath(path), source))
    return mods


# -- baseline ------------------------------------------------------------------

def load_baseline(path: str) -> dict[str, str]:
    """fingerprint -> reason.  Entries without a reason are rejected by
    the CLI (the baseline must justify every suppression)."""
    entries: dict[str, str] = {}
    if not os.path.exists(path):
        return entries
    with open(path, encoding="utf-8") as fh:
        for raw in fh:
            line = raw.strip()
            if not line or line.startswith("#"):
                continue
            fp, _, reason = line.partition("#")
            entries[fp.strip()] = reason.strip()
    return entries


def dotted_name(node: ast.AST) -> str | None:
    """'a.b.c' for Name/Attribute chains, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None
