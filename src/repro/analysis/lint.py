"""CLI driver: ``python -m repro.analysis.lint src/``.

Runs every static pass, applies inline ``# analysis: allow[...]``
suppressions (done inside each pass) and the baseline file, and exits
non-zero on any remaining finding.  ``--write-baseline`` records the
current findings as the new baseline (each entry still needs a reason
added by hand — a baseline entry without one fails the next run).
"""

from __future__ import annotations

import argparse
import json
import sys

from . import determinism, jitshape, locks, wirecheck
from .core import Finding, load_baseline, load_tree

DEFAULT_BASELINE = "src/repro/analysis/baseline.txt"


def run(paths: list[str], baseline_path: str | None = None
        ) -> tuple[list[Finding], locks.LockAnalysis, dict]:
    """-> (unsuppressed findings, lock analysis, stale-baseline map)."""
    modules = []
    for p in paths:
        modules.extend(load_tree(p))
    findings: list[Finding] = []
    for mod in modules:
        for line in mod.bare_allows:
            findings.append(Finding(
                "bare-allow", mod.path, line, f"allow@{line}",
                "analysis: allow[...] without a justification — state "
                "why the finding is acceptable"))
    lock_an = locks.analyze(modules)
    findings.extend(lock_an.findings)
    findings.extend(wirecheck.check(modules))
    findings.extend(determinism.check(modules))
    findings.extend(jitshape.check(modules))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))

    baseline = load_baseline(baseline_path) if baseline_path else {}
    kept, used = [], set()
    for f in findings:
        if f.fingerprint in baseline:
            used.add(f.fingerprint)
            if not baseline[f.fingerprint]:
                kept.append(Finding(
                    "bare-allow", f.path, f.line, f.symbol,
                    f"baseline entry {f.fingerprint} has no reason "
                    f"comment"))
        else:
            kept.append(f)
    stale = {fp: r for fp, r in baseline.items() if fp not in used}
    return kept, lock_an, stale


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="invariant linter: lock discipline/order, wire "
                    "completeness, determinism, jit-shape safety")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to scan (default: src)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline file of accepted fingerprints")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline file")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings to the baseline file")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable output")
    ap.add_argument("--graph", action="store_true",
                    help="also dump the static lock-acquisition graph")
    args = ap.parse_args(argv)

    paths = args.paths or ["src"]
    baseline = None if args.no_baseline else args.baseline
    findings, lock_an, stale = run(paths, baseline_path=baseline)

    if args.write_baseline:
        with open(args.baseline, "w", encoding="utf-8") as fh:
            fh.write("# repro.analysis baseline — every entry needs a "
                     "'# reason' justifying it.\n")
            for f in findings:
                fh.write(f"{f.fingerprint}  # TODO: justify\n")
        print(f"wrote {len(findings)} entries to {args.baseline}")
        return 0

    if args.json:
        print(json.dumps({
            "findings": [vars(f) | {"fingerprint": f.fingerprint}
                         for f in findings],
            "stale_baseline": sorted(stale),
            "lock_edges": sorted(f"{a} -> {b}" for a, b in lock_an.edges),
        }, indent=2))
    else:
        for f in findings:
            print(f.render())
        if args.graph:
            print("lock-acquisition graph:")
            for (a, b), (p, ln) in sorted(lock_an.edges.items()):
                print(f"  {a} -> {b}   ({p}:{ln})")
        for fp in sorted(stale):
            print(f"warning: stale baseline entry {fp} "
                  f"(no longer triggered — remove it)", file=sys.stderr)
        if findings:
            print(f"{len(findings)} finding(s)", file=sys.stderr)
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
