"""Production meshes.

Single pod: (data=16, model=16) — 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the ``pod`` axis is pure
data parallelism so only gradient reduction crosses the inter-pod links.

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(jax.devices())} "
            "(the dry-run must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before any jax import)")
    return jax.make_mesh(shape, axes, devices=devices)


def make_test_mesh(data: int = 2, model: int = 2, pod: int | None = None):
    """Small mesh for subprocess-based distribution tests."""
    shape = (pod, data, model) if pod else (data, model)
    axes = ("pod", "data", "model") if pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


def mesh_axis(mesh, name: str, default: int = 1) -> int:
    return mesh.shape.get(name, default)
