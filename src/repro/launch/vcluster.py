"""Sharded multi-process launcher: ``python -m repro.launch.vcluster``.

Spawns ``--shards`` worker processes (each a full per-shard VStore stack
over its own store directory), ingests N simulated camera streams through
the scatter-gather router — each stream hashes to exactly one shard — and
drives a mixed concurrent query workload through the cluster, verifying
the merged answers bit-identical against a single-process reference store.
With ``--budget-x`` the workers run live-ingest schedulers whose budget
leases the ``ClusterIngest`` coordinator owns and rebalances; with
``--erode-days`` erosion passes run cluster-wide and the reclaimed bytes
roll up in the coordinator's report.
"""

from __future__ import annotations

import argparse
import os
import shutil
import time

from ..analytics.query import run_query
from ..analytics.scene import generate_segment
from ..cluster import ClusterIngest, ShardRouter, merge_results
from ..core.knobs import IngestSpec
from ..videostore import VideoStore
from .vserve import demo_config, demo_erosion_plan

DEFAULT_STREAMS = ("jackson", "miami", "tucson", "dashcam",
                   "airport", "plaza", "harbor", "depot")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default="/tmp/repro_vcluster")
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--streams", type=int, default=4)
    ap.add_argument("--segments", type=int, default=3)
    ap.add_argument("--queries", type=int, default=8)
    ap.add_argument("--workers", type=int, default=1,
                    help="query worker threads inside each shard process")
    ap.add_argument("--cross-query-batching", action="store_true",
                    help="shard servers fuse detects across concurrent "
                         "queries through a shared consumption scheduler")
    ap.add_argument("--batch-max-wait-ms", type=float, default=4.0,
                    help="max time a non-full fused batch waits for "
                         "co-batching partners inside each shard")
    ap.add_argument("--budget-x", type=float, default=None,
                    help="run live-ingest schedulers in the workers under "
                         "this global transcode budget (default: blocking "
                         "full materialization)")
    ap.add_argument("--erode-days", type=int, default=0)
    ap.add_argument("--index", action="store_true",
                    help="workers build shard-local semantic indexes at "
                         "ingest and serve with exact predicate pushdown "
                         "(requires --budget-x for the sketching tasks)")
    ap.add_argument("--verify", action="store_true",
                    help="rebuild the same content single-process and check "
                         "the cluster's answers are bit-identical")
    ap.add_argument("--trace", metavar="FILE", default=None,
                    help="trace every shard plus the router and write one "
                         "merged Chrome trace-event JSON (Perfetto-loadable)")
    ap.add_argument("--telemetry", metavar="DIR", default=None,
                    help="every shard samples a crash-safe telemetry series "
                         "into DIR/shard-NN.vtl and the router scrapes a "
                         "cluster-merged DIR/cluster.vtl; watch live with "
                         "python -m repro.launch.vtop --telemetry DIR")
    ap.add_argument("--telemetry-interval", type=float, default=1.0,
                    help="telemetry sampling interval in seconds")
    args = ap.parse_args(argv)
    if args.trace:
        from ..obs import trace as obs
        obs.enable(True)
        obs.TRACER.pid = 0  # display convention: router=0, shard i -> i+1

    cfg = demo_config(index_ops=("diff", "motion") if args.index else None)
    spec = IngestSpec()
    shutil.rmtree(args.root, ignore_errors=True)
    names = [DEFAULT_STREAMS[i % len(DEFAULT_STREAMS)] +
             ("" if i < len(DEFAULT_STREAMS) else f"-{i}")
             for i in range(args.streams)]
    segs = list(range(args.segments))

    opts = {"workers": args.workers}
    if args.cross_query_batching:
        opts.update(cross_query_batching=True,
                    batch_max_wait_ms=args.batch_max_wait_ms)
    if args.trace:
        opts["trace"] = True
    if args.telemetry:
        opts["telemetry_dir"] = args.telemetry
        opts["telemetry_interval_s"] = args.telemetry_interval
    if args.budget_x is not None:
        opts.update(ingest=True, budget_x=args.budget_x,
                    materialize_on_read=True)
        if args.erode_days:
            from ..cluster import erosion_plan_to_wire
            plan = demo_erosion_plan(cfg, spec, args.erode_days)
            opts.update(
                erosion_plan=erosion_plan_to_wire(plan),
                node_ids=[cfg.node_id(i) for i in range(len(cfg.nodes))])

    with ShardRouter(os.path.join(args.root, "cluster"), cfg, args.shards,
                     spec=spec, opts=opts) as router:
        if args.telemetry:
            router.attach_telemetry(interval_s=args.telemetry_interval)
        coord = (ClusterIngest(router, budget_x=args.budget_x)
                 if args.budget_x is not None else None)
        by_shard: dict[int, list[str]] = {}
        for n in names:
            by_shard.setdefault(router.shard_of(n), []).append(n)
        print(f"{args.shards} shards; stream placement: "
              + "; ".join(f"shard {i}: {', '.join(ss)}"
                          for i, ss in sorted(by_shard.items())))

        t0 = time.perf_counter()
        for seg in segs:
            for n in names:
                frames, _ = generate_segment(n, seg, spec)
                (coord or router).ingest(n, seg, frames)
        ingest_wall = time.perf_counter() - t0
        vsec = args.streams * args.segments * spec.segment_seconds
        print(f"ingested {args.streams * args.segments} segments "
              f"({vsec:.0f} video-seconds) in {ingest_wall:.2f}s "
              f"-> {vsec / ingest_wall:.1f}x realtime across the cluster")
        if coord is not None:
            st = coord.stats()
            print(f"transcode debt {st['debt_s']:.2f}s est across shards "
                  f"({st['pending']} pending); grants "
                  f"{[f'{g:.2f}' if g else g for g in coord.grants]}")

        mix = [("A", 0.8), ("B", 0.8), ("A", 0.9), ("B", 0.9)]
        subs = [(mix[i % 4][0], names[i % len(names)], segs, mix[i % 4][1])
                for i in range(args.queries)]
        router.query_many(subs)  # warm each worker's jit caches
        t0 = time.perf_counter()
        results = router.query_many(subs)
        wall = time.perf_counter() - t0
        qsec = sum(r.video_seconds for r in results)
        print(f"served {len(subs)} queries ({qsec:.0f} video-seconds) in "
              f"{wall:.2f}s -> aggregate {qsec / wall:.0f}x realtime")
        st = router.stats()
        print(f"cluster: {st['completed']} completed over "
              f"{st['n_shards']} shards, {st['restarts']} restarts, "
              f"cache hit rate {st['cache']['hit_rate']:.2f}, "
              f"{st['decodes']} decodes")
        if args.cross_query_batching:
            print(f"scheduler: {st['sched_detect_calls']} fused detects "
                  f"over {st['sched_units']} units across shards "
                  f"(fusion ratio {st['sched_fusion_ratio']:.2f}, "
                  f"occupancy {st['sched_batch_occupancy']:.2f})")
        if coord is not None:
            coord.set_budget_x(None)
            n = coord.drain()
            cst = coord.stats()  # one cluster-wide sweep, read twice
            print(f"budget raised -> drained {n} transcodes "
                  f"(debt now {cst['debt_s']:.2f}s, "
                  f"write-backs {cst['write_backs']})")

        if args.index:
            # sketch tasks ride the budgeted transcode queue, so the index
            # is complete only after the drain above — query again to show
            # pushdown actually skipping segments
            router.query_many(subs)
            st = router.stats()
            print(f"index: {st['index_sketches']} sketches across shards "
                  f"({st['index_builds']} built, "
                  f"{st['index_build_s']:.2f}s), pushdown pruned "
                  f"{st['index_pruned_segments']} segments / "
                  f"{st['index_pruned_bytes']} bytes before the decoder")

        if args.verify:
            ref = VideoStore(os.path.join(args.root, "ref"), spec)
            ref.set_formats(cfg.storage_formats())
            for seg in segs:
                for n in names:
                    frames, _ = generate_segment(n, seg, spec)
                    ref.ingest_segment(n, seg, frames)
            ok = all(
                res.items == run_query(ref, cfg, q, s, list(sg), acc).items
                for (q, s, sg, acc), res in zip(subs, results))
            multi = router.query("A", names, segs, 0.8)
            ref_multi = merge_results(
                {n: run_query(ref, cfg, "A", n, segs, 0.8) for n in names})
            ok &= multi.items == ref_multi.items
            print(f"cluster answers bit-identical to single-process: {ok}")

        if args.erode_days and coord is not None:
            rep = coord.erode_advance(args.erode_days)
            print(f"cluster erosion day {rep['day']}: -{rep['segments']} "
                  f"segments, {rep['bytes']} bytes reclaimed "
                  f"({', '.join(rep['per_format']) or 'nothing'})")

        if args.trace:
            # pull spans that didn't ride back with query responses
            # (ingest/transcode/erosion work) while workers are still up
            from ..obs import export_trace
            router.harvest_spans()
            names_by_pid = {0: "router"}
            names_by_pid.update({i + 1: f"shard-{i}"
                                 for i in range(args.shards)})
            n = export_trace(args.trace, process_names=names_by_pid)
            print(f"wrote {n} spans across {args.shards + 1} processes "
                  f"to {args.trace}")

    if args.telemetry:
        print(f"telemetry: {args.shards} shard logs + cluster.vtl in "
              f"{args.telemetry} (view: python -m repro.launch.vtop "
              f"--telemetry {args.telemetry})")


if __name__ == "__main__":
    main()
