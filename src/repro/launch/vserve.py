"""Concurrent query-serving launcher: ``python -m repro.launch.vserve``.

Builds a demo VideoStore (synthetic street scenes, two storage formats),
then drives the serving stack — decoded-segment cache, shared-retrieval
planner, pipelined executor — with a mixed concurrent workload and prints
per-query plus aggregate stats against the sequential baseline.
"""

from __future__ import annotations

import argparse
import os
import shutil
import time

from ..analytics.query import run_query
from ..analytics.scene import generate_segment
from ..core.coalesce import SFNode
from ..core.configure import DerivedConfig
from ..core.consumption import Consumer, ConsumerPlan
from ..core.knobs import GOLDEN_CODING, RAW, FidelityOption, IngestSpec
from ..serving import VStoreServer
from ..videostore import VideoStore


def demo_config(accuracies=(0.8, 0.9), index_ops=None) -> DerivedConfig:
    """Hand-built two-SF configuration (skips profiling so the launcher
    starts in seconds; ``repro.core.derive_config`` is the real path).
    ``index_ops`` enables ingest-time semantic indexing (repro.index) of
    those cascade-head ops, e.g. ``("diff", "motion")``."""
    cf_diff = FidelityOption("good", 1.0, 270, 1 / 2)
    cf_snn = FidelityOption("good", 1.0, 360, 1 / 2)
    cf_motion = FidelityOption("bad", 1.0, 180, 1 / 5)
    cf_nn = FidelityOption("best", 1.0, 720, 2 / 3)
    cf_license = FidelityOption("best", 1.0, 540, 1 / 2)
    cf_ocr = FidelityOption("best", 1.0, 720, 1 / 2)
    fast_cfs = (cf_diff, cf_snn, cf_motion)
    plans = []
    for acc in accuracies:
        plans += [ConsumerPlan(Consumer("diff", acc), cf_diff, 0.85, 3000.0),
                  ConsumerPlan(Consumer("snn", acc), cf_snn, 0.86, 500.0),
                  ConsumerPlan(Consumer("motion", acc), cf_motion, 0.84, 2000.0),
                  ConsumerPlan(Consumer("nn", acc), cf_nn, 0.82, 30.0),
                  ConsumerPlan(Consumer("license", acc), cf_license, 0.83, 60.0),
                  ConsumerPlan(Consumer("ocr", acc), cf_ocr, 0.81, 40.0)]
    fast = SFNode(cf_diff.join(cf_snn).join(cf_motion), RAW,
                  [p for p in plans if p.cf in fast_cfs])
    golden = SFNode(FidelityOption(), GOLDEN_CODING,
                    [p for p in plans if p.cf not in fast_cfs], golden=True)

    class _Log:
        nodes = [fast, golden]
        ingest_cost = storage_cost = 0.0
        rounds = []
        budget_met = True

    return DerivedConfig(plans=plans, nodes=[fast, golden],
                         coalesce_log=_Log(),
                         index_ops=(tuple(index_ops) if index_ops else None))


def demo_erosion_plan(cfg: DerivedConfig, spec: IngestSpec, days: int):
    """The demo launchers' shared erosion plan: byte-ratio profiler, daily
    volume from the raw segment bytes of each node, storage budget at 50%
    of the unretired volume over ``days``."""
    from ..core.erosion import plan_erosion
    from ..ingest import ByteRatioProfiler
    prof = ByteRatioProfiler(spec)
    subs = {p: i for i, n in enumerate(cfg.nodes) for p in n.plans}
    daily = [spec.raw_bytes_per_segment(n.fidelity) * 86400
             / spec.segment_seconds for n in cfg.nodes]
    return plan_erosion(prof, cfg.nodes, subs, daily, days,
                        0.5 * sum(daily) * days)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default="/tmp/repro_vserve")
    ap.add_argument("--stream", default="jackson")
    ap.add_argument("--segments", type=int, default=4)
    ap.add_argument("--queries", type=int, default=16)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--max-inflight", type=int, default=16)
    ap.add_argument("--cache-mb", type=int, default=256)
    ap.add_argument("--prefetch-depth", type=int, default=1)
    ap.add_argument("--batch-segments", type=int, default=4,
                    help="segments fused per operator call in the pipelined "
                         "executor (0 = one detect per segment)")
    ap.add_argument("--no-collapse", action="store_true",
                    help="disable in-flight duplicate-query collapsing")
    ap.add_argument("--cross-query-batching", action="store_true",
                    help="fuse detects across concurrent queries through "
                         "the shared consumption scheduler (with "
                         "frame-granular duplicate-work dedup)")
    ap.add_argument("--batch-max-wait-ms", type=float, default=4.0,
                    help="max time a non-full fused batch waits for "
                         "co-batching partners (fairness knob for "
                         "--cross-query-batching)")
    ap.add_argument("--index", action="store_true",
                    help="build an ingest-time semantic index of the "
                         "cascade-head ops and serve queries with exact "
                         "predicate pushdown (skip sketched-inactive "
                         "segments before the decoder)")
    ap.add_argument("--pushdown", default="exact",
                    choices=("exact", "conservative", "off"),
                    help="pushdown mode for --index: exact (bit-identical "
                         "results) or conservative (also prunes across "
                         "knob mismatches; bounded recall loss)")
    ap.add_argument("--baseline", action="store_true",
                    help="also time the same workload as sequential "
                         "run_query calls")
    ap.add_argument("--trace", metavar="FILE", default=None,
                    help="enable span tracing and write a Chrome trace-event "
                         "JSON (load in Perfetto / chrome://tracing)")
    ap.add_argument("--telemetry", metavar="DIR", default=None,
                    help="sample a crash-safe telemetry series into "
                         "DIR/server.vtl; watch live with python -m "
                         "repro.launch.vtop --telemetry DIR")
    ap.add_argument("--telemetry-interval", type=float, default=1.0,
                    help="telemetry sampling interval in seconds")
    args = ap.parse_args(argv)
    if args.trace:
        from ..obs import enable
        enable(True)

    cfg = demo_config(index_ops=("diff", "motion") if args.index else None)
    shutil.rmtree(args.root, ignore_errors=True)
    spec = IngestSpec()
    vs = VideoStore(os.path.join(args.root, "store"), spec)
    vs.set_formats(cfg.storage_formats())
    t0 = time.perf_counter()
    for seg in range(args.segments):
        frames, _ = generate_segment(args.stream, seg, spec)
        vs.ingest_segment(args.stream, seg, frames)
    print(f"ingested {args.segments} segments x {len(vs.formats)} formats "
          f"in {time.perf_counter() - t0:.1f}s "
          f"({vs.storage_bytes(args.stream)} bytes)")

    index = None
    if args.index:
        from ..index import SemanticIndex
        index = SemanticIndex(os.path.join(args.root, "index"), spec, cfg)
        t0 = time.perf_counter()
        for seg in range(args.segments):
            for op in cfg.index_ops:
                index.build(vs, args.stream, seg, op)
        index.flush()
        print(f"indexed {cfg.index_ops} sketches for {args.segments} "
              f"segments in {time.perf_counter() - t0:.1f}s "
              f"({index.store.total_bytes()} bytes)")

    segs = list(range(args.segments))
    mix = [("A", a) for a in (0.8, 0.9)] + [("B", a) for a in (0.8, 0.9)]
    subs = [(mix[i % len(mix)][0], args.stream, segs, mix[i % len(mix)][1])
            for i in range(args.queries)]

    # one warm pass per unique query so jit compile time isn't billed below
    # (both the per-segment shapes the baseline uses and the static batch
    # shapes the server's batched consumption uses)
    for q, stream, sg, acc in {s[:2] + (tuple(s[2]), s[3]) for s in subs}:
        run_query(vs, cfg, q, stream, list(sg), acc)
        if args.batch_segments:
            run_query(vs, cfg, q, stream, list(sg), acc,
                      batch_segments=args.batch_segments)

    seq_wall = None
    if args.baseline:
        t0 = time.perf_counter()
        for q, stream, sg, acc in subs:
            run_query(vs, cfg, q, stream, sg, acc)
        seq_wall = time.perf_counter() - t0

    with VStoreServer(vs, cfg, workers=args.workers,
                      max_inflight=args.max_inflight,
                      cache_bytes=args.cache_mb << 20,
                      prefetch_depth=args.prefetch_depth,
                      batch_segments=args.batch_segments,
                      collapse=not args.no_collapse,
                      cross_query_batching=args.cross_query_batching,
                      batch_max_wait_ms=args.batch_max_wait_ms,
                      index=index, pushdown=args.pushdown) as srv:
        sampler = None
        if args.telemetry:
            from ..obs.telemetry import TelemetryLog, TelemetrySampler
            sampler = TelemetrySampler(
                srv.telemetry_body,
                TelemetryLog(os.path.join(args.telemetry, "server.vtl")),
                interval_s=args.telemetry_interval).start()
        t0 = time.perf_counter()
        results = srv.run_batch(subs)
        wall = time.perf_counter() - t0
        stats = srv.stats()
        if sampler is not None:
            sampler.stop(final=True)

    for (q, _s, sg, acc), res in zip(subs, results):
        calls = sum(s.detect_calls for s in res.stages)
        frames = sum(s.frames for s in res.stages)
        print(f"  query {q} acc={acc}: {len(res.items)} items, "
              f"wall {res.wall_s * 1e3:.0f}ms, "
              f"{res.measured_speed:.0f}x realtime, "
              f"{calls} detect calls / {frames} frames")
    vsec = sum(r.video_seconds for r in results)
    print(f"served {len(subs)} queries ({vsec:.0f} video-seconds) in "
          f"{wall:.2f}s -> aggregate {vsec / wall:.0f}x realtime")
    if seq_wall is not None:
        print(f"sequential baseline: {seq_wall:.2f}s "
              f"({vsec / seq_wall:.0f}x) -> speedup {seq_wall / wall:.2f}x")
    c = stats["cache"]
    print(f"cache: {c['hits']} hits + {c['richer_hits']} richer / "
          f"{c['lookups']} lookups (hit rate {c['hit_rate']:.2f}), "
          f"{stats['cache_bytes']} bytes resident, "
          f"{c['evictions']} evictions")
    print(f"planner: {stats['decodes']} decodes, "
          f"{stats['coalesced_cfs']} CFs coalesced, "
          f"{stats['collapsed']} queries collapsed")
    if args.index:
        print(f"index: {stats['index_sketches']} sketches, "
              f"{stats['index_lookups']} lookups -> "
              f"{stats['index_pruned_segments']} segments / "
              f"{stats['index_pruned_bytes']} bytes pruned before the "
              f"decoder ({stats['index_pruned_conservative']} conservative)")
    if args.cross_query_batching:
        print(f"scheduler: {stats['sched_detect_calls']} fused detects over "
              f"{stats['sched_units']} units "
              f"({stats['sched_deduped']} deduped; fusion ratio "
              f"{stats['sched_fusion_ratio']:.2f}, occupancy "
              f"{stats['sched_batch_occupancy']:.2f})")
    if args.trace:
        from ..obs import export_trace
        n = export_trace(args.trace, process_names={os.getpid(): "vserve"})
        print(f"wrote {n} spans to {args.trace}")
    if args.telemetry:
        print(f"telemetry: {sampler.samples} frames in "
              f"{os.path.join(args.telemetry, 'server.vtl')} "
              f"(view: python -m repro.launch.vtop --telemetry "
              f"{args.telemetry})")
    return results


if __name__ == "__main__":
    main()
