"""Assigned input shapes x architecture cell definitions.

Four shapes per LM architecture (40 cells):

  train_4k     seq 4,096   global batch 256   -> train_step
  prefill_32k  seq 32,768  global batch 32    -> prefill (encoder: forward)
  decode_32k   seq 32,768  global batch 128   -> serve_step (1 new token)
  long_500k    seq 524,288 global batch 1     -> serve_step (1 new token)

Skip rules (recorded, not silently dropped):
  * encoder-only archs (hubert) have no decode step -> skip decode shapes
  * long_500k needs sub-quadratic attention -> only ssm/hybrid archs run it

``input_specs`` returns jax.ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no allocation) for every model input of a cell, plus which step
function the cell lowers.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..models import init_cache
from ..models.config import ArchConfig

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def skip_reason(cfg: ArchConfig, shape: ShapeSpec) -> str | None:
    if shape.kind == "decode" and not cfg.supports_decode:
        return "encoder-only: no autoregressive decode step"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return "full-attention arch: long_500k requires sub-quadratic attention"
    return None


def _token_inputs(cfg: ArchConfig, batch: int, seq: int) -> dict:
    if cfg.frontend == "tokens":
        d = {"tokens": SDS((batch, seq), jnp.int32)}
    else:
        d = {"embeds": SDS((batch, seq, cfg.d_model), jnp.bfloat16)}
        if cfg.mrope:
            d["mrope_positions"] = SDS((3, batch, seq), jnp.int32)
    return d


def input_specs(cfg: ArchConfig, shape: ShapeSpec,
                cache_dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStructs for one cell.  Keys: 'batch' (model inputs) and,
    for decode, 'cache'."""
    if shape.kind == "train":
        batch = _token_inputs(cfg, shape.batch, shape.seq)
        batch["labels"] = SDS((shape.batch, shape.seq), jnp.int32)
        return {"batch": batch}
    if shape.kind == "prefill":
        return {"batch": _token_inputs(cfg, shape.batch, shape.seq)}
    # decode: one new token + a cache of seq_len
    batch = _token_inputs(cfg, shape.batch, 1)
    cache = jax.eval_shape(
        lambda: init_cache(cfg, shape.batch, shape.seq, cache_dtype))
    return {"batch": batch, "cache": cache}


def params_specs(cfg: ArchConfig, rng=None, dtype=jnp.bfloat16):
    """Abstract parameter tree (no allocation)."""
    from ..models import init_params
    return jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0), dtype))


def all_cells(archs: dict[str, ArchConfig]) -> list[tuple[str, str, str | None]]:
    """[(arch_id, shape_name, skip_reason|None)] — the full 40-cell grid."""
    out = []
    for arch_id, cfg in archs.items():
        for sname, sh in SHAPES.items():
            out.append((arch_id, sname, skip_reason(cfg, sh)))
    return out
