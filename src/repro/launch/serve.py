"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Batched greedy decoding with prefill + KV-cache/SSM-state steps — the
paper's kind of system is retrieval->consumption serving, so this is the
end-to-end inference driver (reduced configs on CPU; the same step is what
the decode_* dry-run cells lower at production scale).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..models import init_params, prefill
from ..train import make_serve_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(n_layers=4, d_model=128, n_heads=4, d_ff=512,
                          vocab=1024)
    if not cfg.supports_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode step")
    if cfg.frontend != "tokens":
        raise SystemExit(f"{args.arch}: serve driver needs token frontend")

    params = init_params(cfg, jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.new_tokens
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.perf_counter()
    logits, cache = prefill(params, cfg, {"tokens": prompts},
                            max_len=max_len, moe_dispatch="dense")
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    t_prefill = time.perf_counter() - t0

    serve_step = jax.jit(make_serve_step(cfg, moe_dispatch="dense"))
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(args.new_tokens - 1):
        tok, cache = serve_step(params, {"tokens": tok[:, None]}, cache)
        out.append(tok)
    jax.block_until_ready(out[-1])
    t_decode = time.perf_counter() - t0
    gen = jnp.stack(out, axis=1)
    tps = args.batch * (args.new_tokens - 1) / t_decode
    print(f"{cfg.name}: prefill {args.batch}x{args.prompt_len} in "
          f"{t_prefill * 1e3:.0f}ms; decoded {args.new_tokens} tokens/seq "
          f"at {tps:.0f} tok/s")
    print("sample:", gen[0, :16].tolist())
    return gen


if __name__ == "__main__":
    main()
