"""Trip-count-aware HLO cost walker.

``compiled.cost_analysis()`` counts a while (lax.scan) body ONCE, which
under-reports layer-loop models by ~L x.  This walker parses the optimized
HLO text and computes, from the ENTRY computation down:

  * flops             — dot ops: 2 * |output| * K (K from lhs contracting
                        dims); while bodies multiplied by their
                        ``known_trip_count``; fusion-called computations
                        walked for dots.
  * bytes             — HBM-traffic estimate: per top-level op, operand +
                        output bytes (fusion internals are free — matching
                        XLA's fusion memory model); while bodies x trips.
  * collective bytes  — output bytes of all-gather / all-reduce /
                        reduce-scatter / all-to-all / collective-permute
                        (x trips; async '-done' halves skipped).

Validated against compiled.cost_analysis() on unrolled programs (ratio 1.0)
— see tests/test_hlo_walker.py.
"""

from __future__ import annotations

import json
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_COND_BODY_RE = re.compile(r"condition=%([\w.\-]+),\s*body=%([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OP_NAME_RE = re.compile(
    r"^(?:\(.*?\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s+([\w\-]+)\(")


def _shape_bytes_from_text(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape(text: str):
    m = _SHAPE_RE.search(text)
    if not m:
        return None, []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",")] if dims else []


class _Op:
    __slots__ = ("name", "kind", "out_bytes", "shape", "rhs", "line",
                 "is_root")

    def __init__(self, name, kind, out_bytes, shape, rhs, line,
                 is_root=False):
        self.name, self.kind = name, kind
        self.out_bytes, self.shape = out_bytes, shape
        self.rhs, self.line = rhs, line
        self.is_root = is_root


def _parse_computations(hlo: str) -> dict[str, list[_Op]]:
    comps: dict[str, list[_Op]] = {}
    cur = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line.startswith(" ") and "{" in line and ("%" in line or
                                                         line.startswith("ENTRY")):
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                if line.strip().startswith("ENTRY") or raw.startswith("ENTRY"):
                    comps["__entry__"] = comps[cur]
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        opm = _OP_NAME_RE.match(rhs)
        kind = opm.group(1) if opm else rhs.split("(")[0].split()[-1]
        # output bytes: shapes before the op name (result type)
        result_part = rhs.split(kind + "(")[0] if kind + "(" in rhs else rhs
        out_bytes = _shape_bytes_from_text(result_part)
        _, shape = _first_shape(result_part)
        comps[cur].append(_Op(name, kind, out_bytes, shape, rhs, line,
                              is_root="ROOT" in line.split("=")[0]))
    return comps


def _dot_flops(op: _Op, sym: dict[str, _Op]) -> float:
    out_elems = 1
    for d in op.shape:
        out_elems *= d
    k = 1
    m = _LHS_CDIMS.search(op.rhs)
    opnds = _OPND_RE.findall(op.rhs.split("(", 1)[1])
    lhs = sym.get(opnds[0]) if opnds else None
    if m and lhs is not None and lhs.shape:
        dims = [int(x) for x in m.group(1).split(",")] if m.group(1) else []
        for d in dims:
            if d < len(lhs.shape):
                k *= lhs.shape[d]
    return 2.0 * out_elems * k


class HloCost:
    def __init__(self, hlo_text: str):
        self.comps = _parse_computations(hlo_text)
        self._memo: dict[str, dict] = {}

    def _zero(self):
        z = {"flops": 0.0, "bytes": 0.0}
        for c in COLLECTIVES:
            z[c] = 0.0
        return z

    def computation_cost(self, name: str) -> dict:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = self._zero()  # cycle guard
        total = self._zero()
        ops = self.comps.get(name, [])
        sym = {op.name: op for op in ops}
        for op in ops:
            if op.kind == "while":
                mcb = _COND_BODY_RE.search(op.rhs)
                trips = 1
                mt = _TRIP_RE.search(op.rhs)
                if mt:
                    trips = int(mt.group(1))
                if mcb:
                    cond, body = mcb.groups()
                    for sub in (cond, body):
                        c = self.computation_cost(sub)
                        for k in total:
                            total[k] += trips * c[k]
                total["bytes"] += op.out_bytes
                continue
            if op.kind in ("fusion", "call", "conditional", "map",
                           "reduce", "reduce-window", "sort", "scatter",
                           "select-and-scatter", "custom-call"):
                m = _CALLS_RE.search(op.rhs)
                names = ([m.group(1)] if m else
                         re.findall(r"to_apply=%([\w.\-]+)", op.rhs))
                for sub in names:
                    c = self.computation_cost(sub)
                    # fusion internals contribute flops but not bytes
                    total["flops"] += c["flops"]
                    for cname in COLLECTIVES:
                        total[cname] += c[cname]
                total["bytes"] += self._op_io_bytes(op, sym)
                continue
            if op.kind == "dot" or op.kind == "convolution":
                total["flops"] += _dot_flops(op, sym)
                total["bytes"] += self._op_io_bytes(op, sym)
                continue
            base = None
            for c in COLLECTIVES:
                if op.kind == c or op.kind.startswith(c + "-"):
                    base = c
                    break
            if base is not None:
                if op.kind.endswith("-done"):
                    continue  # counted at -start
                total[base] += op.out_bytes
                total["bytes"] += self._op_io_bytes(op, sym)
                continue
            if op.kind in ("parameter", "constant", "get-tuple-element",
                           "tuple", "bitcast", "after-all"):
                continue
            # plain elementwise / copy / broadcast / etc.
            total["bytes"] += self._op_io_bytes(op, sym)
        self._memo[name] = total
        return total

    def _operands(self, op: _Op) -> list[str]:
        arglist = op.rhs.split("(", 1)
        if len(arglist) != 2:
            return []
        return _OPND_RE.findall(arglist[1])

    def _op_io_bytes(self, op: _Op, sym: dict) -> float:
        """HBM traffic of one op.  dynamic-slice reads only the slice;
        dynamic-update-slice rewrites only the updated region (the buffer
        itself is aliased in place); fusions are inspected so a fused
        slice-of-a-parameter is charged slice-size, not buffer-size."""
        if op.kind == "dynamic-slice":
            return float(op.out_bytes)
        if op.kind == "dynamic-update-slice":
            opnds = self._operands(op)
            upd = sym.get(opnds[1]) if len(opnds) > 1 else None
            return 2.0 * (upd.out_bytes if upd else op.out_bytes)
        if op.kind == "fusion":
            return self._fusion_io_bytes(op, sym)
        b = float(op.out_bytes)
        for nm in self._operands(op):
            src = sym.get(nm)
            if src is not None:
                b += src.out_bytes
        return b

    def _fusion_io_bytes(self, op: _Op, sym: dict) -> float:
        m = _CALLS_RE.search(op.rhs)
        called = self.comps.get(m.group(1), []) if m else []
        csym = {o.name: o for o in called}
        # map fusion operands to the called computation's parameters
        opnds = self._operands(op)
        params: dict[int, _Op | None] = {}
        for o in called:
            pm = re.search(r"parameter\((\d+)\)", o.rhs)
            if pm:
                params[int(pm.group(1))] = o
        # per-parameter traffic: slice-size if only dynamic-sliced, else full
        b = 0.0
        root_dus_bufs: set[str] = set()
        for o in called:
            if o.kind == "dynamic-update-slice" and o.is_root:
                dus_ops = self._operands(o)
                if dus_ops:
                    root_dus_bufs.add(dus_ops[0])
        for idx, pop in params.items():
            if pop is None or idx >= len(opnds):
                continue
            src = sym.get(opnds[idx])
            full = src.out_bytes if src else pop.out_bytes
            uses_full = False
            slice_bytes = 0.0
            used = False
            for o in called:
                onames = self._operands(o)
                if pop.name not in onames:
                    continue
                used = True
                if o.kind == "dynamic-slice" and onames[0] == pop.name:
                    slice_bytes += o.out_bytes
                elif o.kind == "dynamic-update-slice" and \
                        onames[0] == pop.name:
                    upd = csym.get(onames[1]) if len(onames) > 1 else None
                    slice_bytes += (upd.out_bytes if upd else o.out_bytes)
                elif o.kind in ("get-tuple-element", "bitcast", "tuple"):
                    uses_full = True
                else:
                    uses_full = True
            if used:
                b += full if uses_full else slice_bytes
        # output: in-place root dynamic-update-slice writes only the update
        root = next((o for o in called if o.is_root), None)
        if root is not None and root.kind == "dynamic-update-slice":
            ron = self._operands(root)
            upd = csym.get(ron[1]) if len(ron) > 1 else None
            b += upd.out_bytes if upd else root.out_bytes
        else:
            b += op.out_bytes
        return b

    def entry_cost(self) -> dict:
        return self.computation_cost("__entry__")


def hlo_cost(hlo_text: str) -> dict:
    c = HloCost(hlo_text).entry_cost()
    coll = {k: c[k] for k in COLLECTIVES}
    return {
        "flops": c["flops"],
        "bytes": c["bytes"],
        "collective_bytes": {"total": sum(coll.values()), "by_kind": coll},
    }


def collective_bytes(hlo_text: str) -> dict:
    return hlo_cost(hlo_text)["collective_bytes"]


if __name__ == "__main__":
    import sys
    print(json.dumps(hlo_cost(open(sys.argv[1]).read()), indent=1))
