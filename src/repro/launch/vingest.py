"""Live ingest + serve launcher: ``python -m repro.launch.vingest``.

Drives the full live data path: N simulated camera streams feed the
``IngestScheduler`` (golden written synchronously, other formats
materialized by the budgeted background transcode queue) while a
``VStoreServer`` answers cascade queries *mid-ingest* over the fallback
chain.  After ingest the budget is raised, the transcode debt drains, and
the mid-ingest answers are verified identical against the fully
materialized store; an optional erosion pass then ages the footage and
reports the bytes reclaimed.
"""

from __future__ import annotations

import argparse
import os
import shutil
import time

from ..analytics.query import run_query
from ..ingest import (ErosionExecutor, IngestScheduler, StreamSource,
                      interleave)
from ..core.knobs import IngestSpec
from ..serving import VStoreServer
from ..videostore import VideoStore
from .vserve import demo_config, demo_erosion_plan

DEFAULT_STREAMS = ("jackson", "miami", "tucson", "dashcam")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default="/tmp/repro_vingest")
    ap.add_argument("--streams", type=int, default=4,
                    help="number of simulated camera streams")
    ap.add_argument("--segments", type=int, default=3,
                    help="segments ingested per stream")
    ap.add_argument("--budget-x", type=float, default=None,
                    help="transcode-cycle budget in encode-seconds per "
                         "video-second (default: 60%% of the measured "
                         "full-materialization cost)")
    ap.add_argument("--pace-x", type=float, default=None,
                    help="pace arrivals at this multiple of realtime "
                         "(default: flat out)")
    ap.add_argument("--queries", type=int, default=4,
                    help="queries submitted mid-ingest")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--erode-days", type=int, default=0,
                    help="after ingest, age the footage this many days "
                         "through the erosion executor")
    ap.add_argument("--index", action="store_true",
                    help="sketch cascade-head activations at ingest "
                         "(budget-charged, shed-able tasks beside the "
                         "transcodes) and serve with exact predicate "
                         "pushdown")
    ap.add_argument("--trace", metavar="FILE", default=None,
                    help="enable span tracing and write a Chrome trace-event "
                         "JSON (load in Perfetto / chrome://tracing)")
    ap.add_argument("--telemetry", metavar="DIR", default=None,
                    help="sample a crash-safe telemetry series into "
                         "DIR/server.vtl; watch live with python -m "
                         "repro.launch.vtop --telemetry DIR")
    ap.add_argument("--telemetry-interval", type=float, default=1.0,
                    help="telemetry sampling interval in seconds")
    args = ap.parse_args(argv)
    if args.trace:
        from ..obs import enable
        enable(True)

    cfg = demo_config(index_ops=("diff", "motion") if args.index else None)
    shutil.rmtree(args.root, ignore_errors=True)
    spec = IngestSpec()
    vs = VideoStore(os.path.join(args.root, "store"), spec)
    vs.set_formats(cfg.storage_formats())

    names = [DEFAULT_STREAMS[i % len(DEFAULT_STREAMS)] +
             ("" if i < len(DEFAULT_STREAMS) else f"-{i}")
             for i in range(args.streams)]
    sources = [StreamSource(n, spec, args.segments) for n in names]

    # calibrate the budget against this machine: measure one full blocking
    # ingest (after a warm-up pass, so jit compile time doesn't inflate
    # the estimate), then give the scheduler a fraction of that cost
    probe = sources[0].segment(0)
    vs.ingest_segment("_probe", 0, probe)  # warm the jit caches
    t0 = time.perf_counter()
    vs.ingest_segment("_probe", 1, probe)
    full_cost_x = (time.perf_counter() - t0) / spec.segment_seconds
    for sid in vs.formats:
        vs.erode("_probe", sid, 1.0)
    budget_x = args.budget_x if args.budget_x is not None \
        else 0.6 * full_cost_x
    print(f"full materialization cost {full_cost_x:.2f}x realtime; "
          f"transcode budget {budget_x:.2f}x")

    sched = IngestScheduler(vs, cfg, budget_x=budget_x)
    index = None
    if args.index:
        from ..index import SemanticIndex
        index = SemanticIndex(os.path.join(args.root, "index"), spec, cfg)
        sched.attach_sketcher(index)
    executor = None
    if args.erode_days:
        plan = demo_erosion_plan(cfg, spec, args.erode_days)
        executor = ErosionExecutor(
            vs, plan, [cfg.node_id(i) for i in range(len(cfg.nodes))])
        sched.on_ingest(executor.note_ingested)

    sched.start()
    mid_results = []
    with VStoreServer(vs, cfg, workers=args.workers, index=index) as srv:
        srv.attach_ingest(sched, executor)
        sampler = None
        if args.telemetry:
            from ..obs.telemetry import TelemetryLog, TelemetrySampler
            sampler = TelemetrySampler(
                srv.telemetry_body,
                TelemetryLog(os.path.join(args.telemetry, "server.vtl")),
                interval_s=args.telemetry_interval).start()
        t0 = time.perf_counter()
        n_arrived = 0
        for arr in interleave(sources, pace_x=args.pace_x):
            sched.ingest(arr.stream, arr.seg, arr.frames)
            n_arrived += 1
            # mid-ingest queries over everything golden so far (later
            # formats may still be queued -> fallback-chain retrieval)
            if (len(mid_results) < args.queries
                    and n_arrived % max(1, args.streams) == 0):
                segs = list(range(arr.seg + 1))
                q = "A" if len(mid_results) % 2 == 0 else "B"
                ticket = srv.submit(q, names[0], segs, 0.8, block=True)
                mid_results.append((q, names[0], segs, 0.8, ticket))
        ingest_wall = time.perf_counter() - t0
        mid_answers = [((q, s, sg, a), t.result())
                       for q, s, sg, a, t in mid_results]
        st = srv.stats()

        vsec = st["ingest"]["video_seconds"]
        print(f"\ningested {n_arrived} segments ({vsec:.0f} video-seconds, "
              f"{args.streams} streams) in {ingest_wall:.2f}s "
              f"-> {vsec / ingest_wall:.1f}x realtime sustained")
        for name, s in st["ingest"]["streams"].items():
            print(f"  {name:10s} golden {s['golden_x']:6.1f}x realtime, "
                  f"max durability lag {s['max_golden_lag_s'] * 1e3:.0f}ms")
        print(f"transcode debt {st['ingest']['debt_s']:.2f}s est "
              f"({st['ingest']['pending']} tasks pending, "
              f"{st['ingest']['shed']} shed)")
        for sid, f in st["ingest"]["formats"].items():
            print(f"  {sid:6s} pending={f['pending']:3d} "
                  f"debt={f['est_debt_s']:.2f}s "
                  f"recovery_cost={f['recovery_cost']:.3f}")
        fb = st["ingest"]["fallback"]
        print(f"fallback-chain reads mid-ingest: {fb['fallback_reads']} "
              f"({fb['reconstructions']} reconstructions)")

        # raise the budget: debt must drain to zero
        t0 = time.perf_counter()
        sched.set_budget_x(None)
        sched.stop(drain=True)
        print(f"\nbudget raised -> drained remaining debt in "
              f"{time.perf_counter() - t0:.2f}s "
              f"(debt now {sched.debt_seconds():.2f}s)")
        if index is not None:
            index.flush()
            ist = sched.stats()
            print(f"sketches: {ist['sketches']} built in "
                  f"{ist['sketch_s']:.2f}s (budget-charged; "
                  f"{ist['sketch_pending']} still pending), "
                  f"{st['index_pruned_segments']} segments pruned "
                  f"mid-ingest by pushdown")

        # verify: mid-ingest answers identical to the materialized store
        ok = True
        for (q, stream, segs, acc), res in mid_answers:
            full = run_query(vs, cfg, q, stream, segs, acc)
            same = res.items == full.items
            ok &= same
            print(f"  query {q} over {len(segs)} seg: {len(res.items)} items "
                  f"mid-ingest, identical={same}")
        print(f"mid-ingest answers identical to materialized store: {ok}")
        if sampler is not None:
            sampler.stop(final=True)
            print(f"telemetry: {sampler.samples} frames in "
                  f"{os.path.join(args.telemetry, 'server.vtl')} "
                  f"(view: python -m repro.launch.vtop --telemetry "
                  f"{args.telemetry})")

    if executor is not None:
        b0 = vs.storage_bytes()
        for _ in range(args.erode_days):
            rep = executor.advance()
            print(f"erosion day {rep.day}: -{rep.segments} segments, "
                  f"{rep.bytes} bytes ({rep.chunks} chunks, "
                  f"{rep.chunk_bytes} chunk-span bytes), "
                  f"compactions={rep.compactions}")
        print(f"store bytes {b0} -> {vs.storage_bytes()}")
        res = run_query(vs, cfg, "A", names[0], list(range(args.segments)),
                        0.8)
        print(f"post-erosion query A still answers: {len(res.items)} items")

    if args.trace:
        from ..obs import export_trace
        n = export_trace(args.trace, process_names={os.getpid(): "vingest"})
        print(f"wrote {n} spans to {args.trace}")


if __name__ == "__main__":
    main()
