"""Roofline analysis over the dry-run artifacts (single-pod mesh).

Three terms per (arch x shape), in seconds per step, from the per-device
partitioned HLO (trip-count-aware walker, launch/hlo.py):

    compute    = flops_per_device / 197e12        (bf16 peak, v5e)
    memory     = bytes_per_device / 819e9         (HBM bandwidth)
    collective = sum_k mult_k * bytes_k / 50e9    (ICI link bandwidth;
                 all-reduce counts 2x: reduce-scatter + all-gather phases)

MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) measures how much of the
compiled compute is useful; the dominant term is the hillclimbing target.

Usage: python -m repro.launch.roofline [--dryrun experiments/dryrun]
       [--mesh pod16x16] [--markdown]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
LINK_BW = 50e9            # bytes/s / link

COLL_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
             "all-to-all": 1.0, "collective-permute": 1.0}


def tokens_for(shape: str, batch: int, seq: int) -> int:
    if shape.startswith("train") or shape.startswith("prefill"):
        return batch * seq
    return batch  # decode: one token per sequence


def model_flops(arch_id: str, shape: str) -> float:
    from ..configs import ARCHS
    from .specs import SHAPES
    cfg = ARCHS[arch_id]
    sh = SHAPES[shape]
    n = cfg.active_param_count()
    d = tokens_for(shape, sh.batch, sh.seq)
    mult = 6.0 if sh.kind == "train" else 2.0   # fwd+bwd vs fwd only
    return mult * n * d


def ideal_bytes(arch_id: str, shape: str, n_dev: int) -> float:
    """Necessary HBM traffic per device per step — the memory-roofline
    floor.  Weights are read once per pass (sharded across the mesh);
    training adds grad writes + fp32 moment read/write; decode adds one
    KV-cache read + one-column write; activations ~ 2 x layer I/O bf16."""
    from ..configs import ARCHS
    from .specs import SHAPES
    cfg = ARCHS[arch_id]
    sh = SHAPES[shape]
    p_bytes = cfg.param_count() * 2 / n_dev          # bf16, fully sharded
    act_unit = sh.batch * sh.seq * cfg.d_model * 2 / n_dev
    acts = 2 * cfg.n_layers * act_unit
    if sh.kind == "train":
        # fwd read + bwd read + grad write + m,v fp32 read+write
        return 3 * p_bytes + (4 + 4) * 2 * cfg.param_count() / n_dev + acts
    if sh.kind == "prefill":
        return p_bytes + acts
    # decode: active weights once + cache read/write (token column)
    active = cfg.active_param_count() * 2 / n_dev
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    if cfg.family == "ssm":
        cache = cfg.n_layers * sh.batch * cfg.ssm.expand * cfg.d_model * \
            cfg.ssm.state_dim * 4 * 2 / n_dev
    elif cfg.family == "hybrid":
        n_attn = sum(cfg.layer_kind(i) == "local_attn"
                     for i in range(cfg.n_layers))
        cache = n_attn * sh.batch * min(cfg.rglru.window, sh.seq) * kv * \
            hd * 2 * 2 / n_dev
    else:
        cache = cfg.n_layers * sh.batch * sh.seq * kv * hd * 2 * 2 / n_dev
    return active + cache


def analyze_record(rec: dict) -> dict:
    arch, shape = rec["arch"], rec["shape"]
    n_dev = rec["devices"]
    compute_s = rec["flops"] / PEAK_FLOPS
    memory_s = rec["hlo_bytes"] / HBM_BW
    coll = rec["collective_bytes"]["by_kind"]
    collective_s = sum(COLL_MULT.get(k, 1.0) * v for k, v in coll.items()
                       if k in COLL_MULT) / LINK_BW
    mf = model_flops(arch, shape) / n_dev
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", collective_s), key=lambda t: t[1])[0]
    bound = max(compute_s, memory_s, collective_s)
    # the achievable floor: useful compute at peak OR necessary bytes at
    # full bandwidth, whichever binds
    ideal = max(mf / PEAK_FLOPS, ideal_bytes(arch, shape, n_dev) / HBM_BW)
    out = {
        "arch": arch, "shape": shape, "mesh": rec["mesh"],
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "model_flops_per_dev": mf,
        "ideal_s": ideal,
        "useful_flops_ratio": mf / rec["flops"] if rec["flops"] else 0.0,
        "roofline_fraction": ideal / bound if bound else 0.0,
        "suggestion": _suggest(dominant, arch, shape,
                               mf / rec["flops"] if rec["flops"] else 0.0),
    }
    return out


def _suggest(dominant: str, arch: str, shape: str, useful: float) -> str:
    if dominant == "compute" and useful < 0.5:
        return ("compute-bound with low useful-FLOP ratio: remove replicated"
                " or padded compute (head-divisible layouts, pure-DP for"
                " small models, tighter MoE capacity)")
    if dominant == "compute":
        return "compute-bound near useful peak: only kernel-level wins left"
    if dominant == "memory":
        return ("memory-bound: cut HBM traffic — bf16 carries, fuse"
                " elementwise chains, avoid cache rewrites, smaller remat"
                " footprint")
    return ("collective-bound: reshard to cut cross-device traffic —"
            " fewer all-gathers (TP instead of FSDP at this size), overlap"
            " via latency-hiding scheduler, gradient compression")


def load(dryrun_dir: str, mesh: str) -> list[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        if f.endswith("summary.json"):
            continue
        rec = json.load(open(f))
        if rec.get("status") != "ok" or rec.get("mesh") != mesh:
            continue
        out.append(analyze_record(rec))
    return out


def table(rows: list[dict], markdown: bool = True) -> str:
    hdr = ["arch", "shape", "compute_s", "memory_s", "collective_s",
           "dominant", "useful", "roofline"]
    lines = []
    if markdown:
        lines.append("| " + " | ".join(hdr) + " |")
        lines.append("|" + "---|" * len(hdr))
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        vals = [r["arch"], r["shape"], f"{r['compute_s']:.3e}",
                f"{r['memory_s']:.3e}", f"{r['collective_s']:.3e}",
                r["dominant"], f"{r['useful_flops_ratio']:.3f}",
                f"{r['roofline_fraction']:.3f}"]
        lines.append(("| " + " | ".join(vals) + " |") if markdown
                     else ",".join(vals))
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="experiments/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rows = load(args.dryrun, args.mesh)
    txt = table(rows, markdown=args.markdown)
    print(txt)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
    # worst cells, for hillclimb targeting
    worst = sorted(rows, key=lambda r: r["roofline_fraction"])[:5]
    print("\n# worst roofline fraction:")
    for r in worst:
        print(f"#   {r['arch']}/{r['shape']}: {r['roofline_fraction']:.4f}"
              f" dominant={r['dominant']}")
    coll = sorted(rows, key=lambda r: -r["collective_s"])[:3]
    print("# most collective-bound:")
    for r in coll:
        print(f"#   {r['arch']}/{r['shape']}: coll={r['collective_s']:.3e}s")


if __name__ == "__main__":
    main()
