import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, with real SPMD partitioning over 512 placeholder
devices.  The FIRST two lines above must run before ANY jax import.

Per cell this records:
  * compile success,
  * ``compiled.memory_analysis()`` — bytes per device (proves it fits),
  * ``compiled.cost_analysis()``   — HLO FLOPs / bytes for the roofline,
  * collective bytes parsed from the optimized HLO (all-gather, all-reduce,
    reduce-scatter, all-to-all, collective-permute).

Usage:
  python -m repro.launch.dryrun                      # all cells, single-pod
  python -m repro.launch.dryrun --multi-pod          # all cells, 2 pods
  python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  python -m repro.launch.dryrun --out experiments/dryrun
"""

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp                      # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import ARCHS                  # noqa: E402
from ..distributed import sharding as SH     # noqa: E402
from ..models import forward, prefill        # noqa: E402
from ..train import AdamWConfig, init_opt_state, make_serve_step, \
    make_train_step                          # noqa: E402
from .hlo import hlo_cost                    # noqa: E402
from .mesh import make_production_mesh       # noqa: E402
from .specs import SHAPES, input_specs, params_specs, skip_reason  # noqa: E402

# per-arch lowering options (memory-driven; see EXPERIMENTS.md §Dry-run)
ARCH_OPTS = {
    "arctic-480b": dict(preset="fsdp_tp", n_micro=8, moment_dtype="bfloat16",
                        moe_dispatch="ep"),
    "qwen2-vl-72b": dict(preset="fsdp_tp", n_micro=4),
    "recurrentgemma-9b": dict(preset="tp", n_micro=2),
    "falcon-mamba-7b": dict(preset="tp", n_micro=2),
    "smollm-135m": dict(preset="dp"),   # §Perf cell 2: pure-DP layout
}
DEFAULT_OPTS = dict(preset="tp", n_micro=1, moment_dtype="float32",
                    moe_dispatch="scatter")


def arch_opts(arch_id: str, overrides: dict | None = None) -> dict:
    o = dict(DEFAULT_OPTS)
    o.update(ARCH_OPTS.get(arch_id, {}))
    o.update(overrides or {})
    return o


def _named(mesh, spec_tree):
    return SH.shardings(mesh, spec_tree)


def lower_cell(arch_id: str, shape_name: str, mesh, opts: dict | None = None):
    """Lower + compile one cell.  Returns (lowered, compiled, meta)."""
    cfg = ARCHS[arch_id]
    shape = SHAPES[shape_name]
    o = arch_opts(arch_id, opts)
    reason = skip_reason(cfg, shape)
    if reason:
        raise ValueError(f"cell is skipped: {reason}")
    from ..distributed import context
    context.set_mesh(mesh)

    pspec = params_specs(cfg, dtype=jnp.bfloat16)
    p_spec_tree = SH.param_specs(pspec, mesh, o["preset"])
    p_shard = _named(mesh, p_spec_tree)
    specs = input_specs(cfg, shape)
    batch_axes = (("pod", "data", "model") if o["preset"] == "dp"
                  else SH.DATA_AXES)
    b_shard = _named(mesh, SH.batch_specs(specs["batch"], mesh,
                                          axes=batch_axes))

    if shape.kind == "train":
        opt_cfg = AdamWConfig(
            moment_dtype=jnp.bfloat16 if o.get("moment_dtype") == "bfloat16"
            else jnp.float32)
        step = make_train_step(cfg, opt_cfg, n_micro=o["n_micro"],
                               moe_dispatch=o["moe_dispatch"])
        opt_shape = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg),
                                   pspec)
        m_spec_tree = {"mu": SH.moment_specs(pspec, mesh, o["preset"]),
                       "nu": SH.moment_specs(pspec, mesh, o["preset"]),
                       "step": P()}
        o_shard = _named(mesh, m_spec_tree)
        out_shape = jax.eval_shape(step, pspec, opt_shape, specs["batch"])
        metrics_shard = jax.tree.map(
            lambda _: NamedSharding(mesh, P()), out_shape[2])
        fn = jax.jit(step,
                     in_shardings=(p_shard, o_shard, b_shard),
                     out_shardings=(p_shard, o_shard, metrics_shard),
                     donate_argnums=(0, 1))
        lowered = fn.lower(pspec, opt_shape, specs["batch"])
    elif shape.kind == "prefill":
        if not cfg.supports_decode:  # encoder: plain forward
            def enc(params, batch):
                return forward(params, cfg, batch, moe_dispatch="scatter",
                               remat=False)
            out_s = _named(mesh, SH.batch_specs(
                jax.eval_shape(enc, pspec, specs["batch"]), mesh))
            fn = jax.jit(enc, in_shardings=(p_shard, b_shard),
                         out_shardings=out_s)
            lowered = fn.lower(pspec, specs["batch"])
        else:
            def pre(params, batch):
                return prefill(params, cfg, batch, max_len=shape.seq,
                               moe_dispatch="scatter")
            out_shape = jax.eval_shape(pre, pspec, specs["batch"])
            logits_s = _named(mesh, SH.batch_specs(out_shape[0], mesh))
            cache_s = _named(mesh, SH.cache_specs(out_shape[1], mesh))
            fn = jax.jit(pre, in_shardings=(p_shard, b_shard),
                         out_shardings=(logits_s, cache_s))
            lowered = fn.lower(pspec, specs["batch"])
    else:  # decode
        step = make_serve_step(cfg, moe_dispatch="scatter"
                               if cfg.family == "moe" else "dense")
        cache_s = _named(mesh, SH.cache_specs(specs["cache"], mesh))
        tok_s = _named(mesh, SH.batch_specs(
            jax.ShapeDtypeStruct((shape.batch,), jnp.int32), mesh))
        fn = jax.jit(step, in_shardings=(p_shard, b_shard, cache_s),
                     out_shardings=(tok_s, cache_s), donate_argnums=(2,))
        lowered = fn.lower(pspec, specs["batch"], specs["cache"])

    t0 = time.perf_counter()
    compiled = lowered.compile()
    compile_s = time.perf_counter() - t0
    meta = {"arch": arch_id, "shape": shape_name,
            "mesh": dict(mesh.shape), "opts": o, "compile_s": compile_s}
    return lowered, compiled, meta


def analyze(lowered, compiled, meta) -> dict:
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    # trip-count-aware walk of the optimized per-device HLO (XLA's own
    # cost_analysis counts while bodies once — see launch/hlo.py)
    walk = hlo_cost(compiled.as_text())
    n_dev = 1
    for v in meta["mesh"].values():
        n_dev *= v
    out = dict(meta)
    out.update({
        "flops": walk["flops"],                     # per device
        "hlo_bytes": walk["bytes"],                 # per device
        "collective_bytes": walk["collective_bytes"],
        "xla_cost_analysis": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_bytes":
                getattr(mem, "generated_code_size_in_bytes", 0),
        },
        "devices": n_dev,
    })
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--preset", default=None,
                    choices=[None, "tp", "fsdp_tp", "dp"])
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)

    results = []
    for multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mname = "pod2x16x16" if multi_pod else "pod16x16"
        for arch_id in archs:
            for shape_name in shapes:
                reason = skip_reason(ARCHS[arch_id], SHAPES[shape_name])
                tag = f"{arch_id}_{shape_name}_{mname}"
                if reason:
                    rec = {"arch": arch_id, "shape": shape_name,
                           "mesh": mname, "status": "skip",
                           "reason": reason}
                    print(f"SKIP {tag}: {reason}", flush=True)
                else:
                    try:
                        overrides = ({"preset": args.preset}
                                     if args.preset else None)
                        lowered, compiled, meta = lower_cell(
                            arch_id, shape_name, mesh, overrides)
                        rec = analyze(lowered, compiled, meta)
                        rec["status"] = "ok"
                        rec["mesh"] = mname
                        print(f"OK   {tag}: compile={rec['compile_s']:.1f}s "
                              f"flops={rec['flops']:.3e} "
                              f"coll={rec['collective_bytes']['total']:.3e}B",
                              flush=True)
                        del lowered, compiled
                    except Exception as e:  # noqa: BLE001
                        rec = {"arch": arch_id, "shape": shape_name,
                               "mesh": mname, "status": "fail",
                               "error": f"{type(e).__name__}: {e}",
                               "trace": traceback.format_exc()[-2000:]}
                        print(f"FAIL {tag}: {type(e).__name__}: {e}",
                              flush=True)
                results.append(rec)
                with open(os.path.join(args.out, f"{tag}.json"), "w") as f:
                    json.dump(rec, f, indent=1)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_fail = sum(r["status"] == "fail" for r in results)
    print(f"\n== dry-run: {n_ok} ok, {n_skip} skip, {n_fail} fail ==")
    with open(os.path.join(args.out, "summary.json"), "w") as f:
        json.dump(results, f, indent=1)
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
