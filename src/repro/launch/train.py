"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs a real (CPU-scale by default: --reduced) training loop with the full
production machinery: sharded step, AdamW, checkpoint/restart supervision,
straggler watchdog, synthetic token pipeline.  On a TPU cluster the same
entrypoint runs the full config on the production mesh.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..distributed import TrainSupervisor
from ..models import init_params
from ..train import AdamWConfig, init_opt_state, make_train_step


def synthetic_batches(cfg, batch: int, seq: int, seed: int = 0):
    """Deterministic synthetic LM data: a mixture of repeated n-grams so a
    model can actually learn (loss decreases measurably)."""
    rng = np.random.default_rng(seed)
    vocab = cfg.vocab_size
    motifs = rng.integers(0, vocab, (32, 8))

    def make(step):
        r = np.random.default_rng(seed * 100003 + step)
        toks = np.empty((batch, seq + 1), np.int64)
        for b in range(batch):
            parts = [motifs[r.integers(0, len(motifs))]
                     for _ in range((seq + 8) // 8 + 1)]
            toks[b] = np.concatenate(parts)[: seq + 1]
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:])}

    return make


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--compress", default=None, choices=[None, "int8"])
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(n_layers=4, d_model=128, n_heads=4, d_ff=512,
                          vocab=1024)
    if cfg.frontend != "tokens":
        raise SystemExit(f"{args.arch}: train driver needs token frontend "
                         "(vlm/audio use the dry-run path)")
    print(f"training {cfg.name}: {cfg.param_count() / 1e6:.2f}M params, "
          f"batch={args.batch} seq={args.seq}")

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=20,
                          total_steps=args.steps, weight_decay=0.0)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, moe_dispatch="dense",
                                      compress=args.compress))
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params, opt_cfg)
    if args.compress == "int8":
        from ..train import init_feedback
        opt["fb"] = init_feedback(params)
    batches = synthetic_batches(cfg, args.batch, args.seq)

    def supervised_step(state, step):
        params, opt = state
        params, opt, metrics = step_fn(params, opt, batches(step))
        return (params, opt), {"loss": float(metrics["loss"]),
                               "grad_norm": float(metrics["grad_norm"])}

    sup = TrainSupervisor(args.ckpt_dir, supervised_step,
                          jax.eval_shape(lambda: (params, opt)),
                          ckpt_every=args.ckpt_every)
    _, (params, opt), hist = sup.run((params, opt), args.steps)
    for h in hist[:: args.log_every] + hist[-1:]:
        print(f"step {h['step']:5d} loss {h['loss']:.4f} "
              f"gnorm {h['grad_norm']:.3f} {h['seconds'] * 1e3:.0f}ms")
    print(f"final loss: {hist[-1]['loss']:.4f} "
          f"(start {hist[0]['loss']:.4f}); stragglers: "
          f"{len(sup.watchdog.events)}")
    return hist


if __name__ == "__main__":
    main()
