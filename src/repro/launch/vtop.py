"""Live telemetry dashboard: ``python -m repro.launch.vtop``.

Read-only: tails a telemetry directory (the ``*.vtl`` logs that
``--telemetry DIR`` makes vserve/vingest/vcluster write) or scrapes a
shard socket's ``telemetry`` op, and renders a text dashboard — query
throughput and latency percentiles, SLO hit/miss + burn rate per class,
cache/decode/scheduler counters, deduplicated alerts, and per-shard
health rows from the router's cluster-merged series.  It never writes:
``read_frames`` skips a torn tail without truncating it, so pointing
vtop at a live (or crashed) writer is always safe.

    python -m repro.launch.vtop --telemetry /tmp/vtl          # tail dir
    python -m repro.launch.vtop --telemetry /tmp/vtl --once   # one frame
    python -m repro.launch.vtop --sock /tmp/cluster/shard-00.sock
"""

from __future__ import annotations

import argparse
import glob
import os
import socket
import time

from ..obs.telemetry import read_frames


def load_series(dirname: str) -> dict[str, list[dict]]:
    """Read every ``*.vtl`` log under ``dirname`` -> ``{name: frames}``
    (name = file stem; unreadable/empty logs are skipped, not fatal —
    a worker may be mid-first-write)."""
    out: dict[str, list[dict]] = {}
    for path in sorted(glob.glob(os.path.join(dirname, "*.vtl"))):
        name = os.path.splitext(os.path.basename(path))[0]
        try:
            frames = read_frames(path)
        except Exception:  # noqa: BLE001 — partial header mid-create
            continue
        if frames:
            out[name] = frames
    return out


def scrape_sock(path: str) -> dict:
    """One ``telemetry`` op against a shard/worker unix socket."""
    from ..cluster import wire
    s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    try:
        s.connect(path)
        wire.send_msg(s, {"op": "telemetry"})
        resp = wire.recv_msg(s)
    finally:
        s.close()
    if not resp.get("ok"):
        raise ConnectionError(f"telemetry scrape failed: "
                              f"{resp.get('error')}")
    body = resp["value"] or {}
    body.setdefault("t", time.time())
    return body


def _counters(frame: dict) -> dict:
    return (frame.get("metrics") or {}).get("counters") or {}


def _rate(frames: list[dict], key: str) -> float:
    """Current rate of a monotone counter: delta over the last two
    frames' wall-clock span (0 if the series is too short/stalled)."""
    if len(frames) < 2:
        return 0.0
    a, b = frames[-2], frames[-1]
    dt = float(b.get("t", 0)) - float(a.get("t", 0))
    if dt <= 0:
        return 0.0
    return (_counters(b).get(key, 0) - _counters(a).get(key, 0)) / dt


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024 or unit == "TB":
            return f"{n:.0f}{unit}" if unit == "B" else f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}TB"


def _ms(v: float) -> str:
    return f"{v * 1e3:.0f}ms" if v < 10 else f"{v:.1f}s"


def render_source(name: str, frames: list[dict]) -> list[str]:
    """Dashboard lines for one log's series (pure text; unit-testable)."""
    last = frames[-1]
    m = last.get("metrics") or {}
    c = m.get("counters") or {}
    g = m.get("gauges") or {}
    h = m.get("histograms") or {}
    slo = last.get("slo") or {}
    span = float(last.get("t", 0)) - float(frames[0].get("t", 0))
    lines = [f"{name}: {len(frames)} frames over {span:.0f}s "
             f"(seq {last.get('seq', '?')})"]
    if "sources" in last:
        lines[-1] += f", merged from {last['sources']} shards"

    done = c.get("completed", 0)
    if done or c.get("failed") or c.get("rejected"):
        lines.append(
            f"  queries   {done:.0f} done ({_rate(frames, 'completed'):.1f}/s)"
            f", {c.get('failed', 0):.0f} failed, "
            f"{c.get('collapsed', 0):.0f} collapsed, "
            f"{c.get('rejected', 0):.0f} rejected, "
            f"inflight {g.get('inflight', 0):.0f}")
    lat = h.get("query_latency_s")
    if lat and lat.get("count"):
        qw = h.get("queue_wait_s") or {}
        lines.append(
            f"  latency   p50 {_ms(lat['p50'])}  p95 {_ms(lat['p95'])}  "
            f"p99 {_ms(lat['p99'])}  max {_ms(lat['max'])}"
            f"   queue-wait p95 {_ms(qw.get('p95', 0.0))}")

    hits, misses = c.get("deadline_hits", 0), c.get("deadline_misses", 0)
    if hits or misses:
        late = h.get("deadline_lateness_s") or {}
        lines.append(f"  slo       {hits:.0f} hit / {misses:.0f} missed "
                     f"deadlines, lateness p95 "
                     f"{_ms(late.get('p95', 0.0))}")
    for cls, row in sorted((slo.get("classes") or {}).items()):
        burn = row.get("burn", 0.0)
        flag = "  << BURNING" if burn > 1.0 else ""
        lines.append(
            f"  slo[{cls}] burn {burn:.2f} "
            f"(window {row.get('window_misses', 0)}/"
            f"{row.get('window_total', 0)} missed, budget "
            f"{row.get('target_miss_frac', 0) * 100:.1f}% over "
            f"{row.get('window_s', 0):.0f}s){flag}")

    lookups = c.get("cache_lookups", 0)
    if lookups:
        hit = c.get("cache_hits", 0) + c.get("cache_richer_hits", 0)
        lines.append(f"  cache     {hit / lookups * 100:.0f}% hit "
                     f"({hit:.0f}/{lookups:.0f}), "
                     f"{c.get('cache_evictions', 0):.0f} evictions")
    if c.get("decodes"):
        lines.append(f"  decode    {c['decodes']:.0f} decodes / "
                     f"{_fmt_bytes(c.get('decode_bytes', 0))} / "
                     f"{c.get('decode_chunks', 0):.0f} chunks, "
                     f"{c.get('coalesced_cfs', 0):.0f} CFs coalesced, "
                     f"{c.get('inflight_hits', 0):.0f} inflight hits")
    if c.get("sched_units"):
        lines.append(f"  sched     {c.get('sched_detect_calls', 0):.0f} "
                     f"fused detects / {c['sched_units']:.0f} units "
                     f"({c.get('sched_deduped', 0):.0f} deduped), "
                     f"occupancy {g.get('batch_occupancy', 0):.2f}")

    shards = last.get("shards")
    if shards:
        rows = []
        for s in shards:
            state = "up" if s.get("alive") else "DOWN"
            rows.append(f"{s.get('shard')}:{state}"
                        f"/g{s.get('generation', 0)}"
                        f"/r{s.get('restarts', 0)}")
        lines.append("  shards    " + "  ".join(rows))

    # alerts accumulate over the tail of the series, newest last
    seen: list[dict] = []
    for f in frames[-30:]:
        seen.extend(f.get("alerts") or [])
    for a in seen[-5:]:
        lines.append(f"  alert[{a.get('severity', '?')}] "
                     f"{a.get('key')}: {a.get('message')}")
    return lines


def render(series: dict[str, list[dict]], clock=time.time) -> str:
    """The full dashboard for a set of series.  ``cluster`` (the router's
    merged log) renders first; per-shard logs follow."""
    if not series:
        return "vtop: no telemetry frames yet"
    order = sorted(series, key=lambda n: (n != "cluster", n))
    stamp = max(float(s[-1].get("t", 0)) for s in series.values())
    age = max(0.0, clock() - stamp) if stamp else 0.0
    out = [f"vtop — {len(series)} series, last sample {age:.0f}s ago"]
    for name in order:
        out.append("")
        out.extend(render_source(name, series[name]))
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="live dashboard over VStore telemetry logs")
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--telemetry", metavar="DIR",
                     help="telemetry directory to tail (*.vtl logs)")
    src.add_argument("--sock", metavar="PATH",
                     help="scrape a live worker unix socket's "
                          "'telemetry' op instead of reading logs")
    ap.add_argument("--interval", type=float, default=2.0,
                    help="refresh interval in seconds")
    ap.add_argument("--once", action="store_true",
                    help="render one frame and exit (no screen clear)")
    args = ap.parse_args(argv)

    scraped: list[dict] = []

    def snap() -> dict[str, list[dict]]:
        if args.telemetry:
            return load_series(args.telemetry)
        scraped.append(scrape_sock(args.sock))
        del scraped[:-120]  # bound the live-scrape history
        return {"live": list(scraped)}

    if args.once:
        print(render(snap()))
        return 0
    try:
        while True:
            text = render(snap())
            print("\x1b[H\x1b[2J" + text, flush=True)
            time.sleep(args.interval)
    except KeyboardInterrupt:
        return 0


if __name__ == "__main__":
    raise SystemExit(main())
