"""Launchers: production meshes, the multi-pod dry-run, roofline analysis,
and the train/serve drivers.  Note: ``dryrun`` must be imported only in a
fresh process (it sets XLA_FLAGS for 512 host devices)."""
