"""VideoStore: ingestion, multi-version storage, retrieval with chunk-skip
decode, and erosion execution — the data-path half of VStore (the
configuration engine in ``repro.core`` decides *what* formats this layer
materializes).
"""

from __future__ import annotations

import dataclasses
import json
import os
import time

import numpy as np

from ..codec import segment as codec
from ..codec import transform as T
from ..core.knobs import (CodingOption, FidelityOption, IngestSpec,
                          StorageFormat)
from .store import SegmentStore


@dataclasses.dataclass
class IngestStats:
    """Per-ingest accounting: the paper's ingestion cost (transcode compute)
    and storage cost (bytes/sec of stored video)."""
    encode_seconds: float = 0.0
    stored_bytes: int = 0
    segments: int = 0

    def add(self, sec: float, nbytes: int):
        self.encode_seconds += sec
        self.stored_bytes += nbytes

    def bytes_per_video_second(self, spec: IngestSpec) -> float:
        dur = max(1e-9, self.segments * spec.segment_seconds)
        return self.stored_bytes / dur

    def cost_xrealtime(self, spec: IngestSpec) -> float:
        """Transcode compute normalized to video realtime (1.0 = keeps up)."""
        dur = max(1e-9, self.segments * spec.segment_seconds)
        return self.encode_seconds / dur


def _sf_key(sf_id: str, stream: str, seg: int) -> str:
    return f"{stream}:{sf_id}:{seg:06d}"


class VideoStore:
    """Owns the on-disk segments for all streams × storage formats."""

    def __init__(self, root: str, spec: IngestSpec | None = None):
        self.root = root
        self.spec = spec or IngestSpec()
        self.backend = SegmentStore(os.path.join(root, "segments"))
        self.formats: dict[str, StorageFormat] = {}
        self.ingest_stats: dict[str, IngestStats] = {}
        self._meta_path = os.path.join(root, "meta.json")
        self._retriever = None  # serving-layer hook (see attach_retriever)
        self._load_meta()

    # -- configuration -------------------------------------------------------
    def set_formats(self, formats: dict[str, StorageFormat]):
        """Install the storage-format set derived by the config engine.
        Keys are stable sf ids ('sf_g', 'sf1', ...)."""
        self.formats = dict(formats)
        self._save_meta()

    def _save_meta(self):
        blob = {
            sid: {
                "quality": sf.fidelity.quality, "crop": sf.fidelity.crop,
                "resolution": sf.fidelity.resolution,
                "sampling": sf.fidelity.sampling,
                "speed": sf.coding.speed, "keyframe": sf.coding.keyframe,
                "bypass": sf.coding.bypass,
            } for sid, sf in self.formats.items()
        }
        tmp = self._meta_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(blob, f, indent=1)
        os.replace(tmp, self._meta_path)

    def _load_meta(self):
        if not os.path.exists(self._meta_path):
            return
        with open(self._meta_path) as f:
            blob = json.load(f)
        self.formats = {
            sid: StorageFormat(
                FidelityOption(v["quality"], v["crop"], v["resolution"],
                               v["sampling"]),
                CodingOption(v["speed"], v["keyframe"], v["bypass"]))
            for sid, v in blob.items()
        }

    # -- ingestion ------------------------------------------------------------
    def ingest_segment(self, stream: str, seg: int, frames_u8: np.ndarray,
                       ingest_fidelity: FidelityOption | None = None):
        """Transcode one arriving segment into every configured storage
        format.  ``frames_u8`` is at the ingest (richest) fidelity."""
        src_f = ingest_fidelity or FidelityOption()
        stats = self.ingest_stats.setdefault(stream, IngestStats())
        stats.segments += 1
        for sid, sf in self.formats.items():
            t0 = time.perf_counter()
            frames = T.convert_fidelity(frames_u8, src_f, sf.fidelity, self.spec)
            frames = np.asarray(frames)
            if sf.coding.bypass:
                blob = codec.encode_raw(frames)
            else:
                blob = codec.encode_segment(
                    frames, quant_scale=sf.fidelity.quant_scale,
                    keyframe_interval=sf.coding.keyframe,
                    zstd_level=sf.coding.zstd_level)
            dt = time.perf_counter() - t0
            self.backend.put(_sf_key(sid, stream, seg), blob)
            stats.add(dt, len(blob))

    # -- retrieval -------------------------------------------------------------
    def attach_retriever(self, retriever) -> None:
        """Install a cache-aware retrieval hook (repro.serving): ``retrieve``
        then routes through it, so every consumer of this store — including
        plain ``run_query`` — shares the serving layer's decoded-segment
        cache.  Pass ``None`` to restore direct decoding."""
        self._retriever = retriever

    def retrieve(self, stream: str, seg: int, sf_id: str,
                 cf: FidelityOption) -> tuple[np.ndarray, dict]:
        """Decode a stored segment (chunk-skip under the consumer's sparser
        sampling) and convert to the consumption fidelity.  Returns
        (frames_u8, timing/cost dict)."""
        if self._retriever is not None:
            return self._retriever(stream, seg, sf_id, cf)
        return self.retrieve_direct(stream, seg, sf_id, cf)

    def retrieve_direct(self, stream: str, seg: int, sf_id: str,
                        cf: FidelityOption) -> tuple[np.ndarray, dict]:
        """The uncached decode path (bypasses any attached retriever)."""
        want = self.want_indices(sf_id, cf)
        frames, cost = self.decode_for(stream, seg, sf_id, want)
        t0 = time.perf_counter()
        out = self.convert(frames, sf_id, cf)
        cost["convert_s"] = time.perf_counter() - t0
        return out, cost

    def retrieve_many(self, stream: str, segs: list[int], sf_id: str,
                      cf: FidelityOption) -> tuple[list[np.ndarray], dict]:
        """Retrieve several segments at one consumption fidelity.

        Amortizes the per-segment fixed costs: ``want_indices`` is computed
        once for the whole group, the chunk-skip *decode* of every segment
        runs as one batched dispatch (``decode_many_for`` stacks all wanted
        chunks), and the crop/resize ``convert`` runs as one fused call over
        the concatenated decode, then splits back per segment — decode and
        ``convert`` are per-frame programs, so results are bit-exact with
        ``retrieve``.  When
        a serving-layer retriever is attached, routes each segment through
        it instead (the decoded-segment cache owns reuse there).  Returns
        ``(frames_per_segment, aggregate_cost)``.
        """
        if self._retriever is not None:
            outs = [self._retriever(stream, s, sf_id, cf) for s in segs]
            cost = {"decode_s": 0.0, "convert_s": 0.0, "bytes": 0,
                    "chunks": 0, "frames": 0}
            for _, c in outs:
                for k in cost:
                    cost[k] += c.get(k, 0)
            return [f for f, _ in outs], cost
        cost = {"decode_s": 0.0, "convert_s": 0.0, "bytes": 0,
                "chunks": 0, "frames": 0}
        if not segs:
            return [], cost
        want = self.want_indices(sf_id, cf)
        decoded, c = self.decode_many_for(stream, segs, sf_id, want)
        for k in ("decode_s", "bytes", "chunks", "frames"):
            cost[k] += c[k]
        t0 = time.perf_counter()
        stacked = decoded[0] if len(decoded) == 1 else np.concatenate(decoded)
        conv = self.convert(stacked, sf_id, cf)
        cost["convert_s"] = time.perf_counter() - t0
        n = len(want)
        return [conv[i * n:(i + 1) * n] for i in range(len(segs))], cost

    # serving-layer primitives: retrieval = want_indices -> decode_for ->
    # convert.  The decoded-segment cache keeps decode_for outputs (frames on
    # the storage fidelity's grid) so any CF a cached decode covers is served
    # by the exact same convert() a direct retrieve would run — bit-exact
    # reuse by construction.
    def want_indices(self, sf_id: str, cf: FidelityOption) -> np.ndarray:
        """Stored-frame indices realizing ``cf``'s sampling (R1-checked)."""
        sf = self.formats[sf_id]
        if not sf.fidelity.richer_eq(cf):
            raise ValueError(
                f"R1 violated: SF {sf.fidelity.name()} poorer than CF {cf.name()}")
        return T.temporal_indices(sf.fidelity, cf, self.spec)

    def decode_for(self, stream: str, seg: int, sf_id: str,
                   want: np.ndarray) -> tuple[np.ndarray, dict]:
        """Fetch + chunk-skip-decode stored frames ``want`` at the storage
        fidelity's own grid (no consumption conversion).  The decode's own
        single header parse supplies the cost accounting, and ``bytes`` /
        ``chunks`` report what the decode actually touched — with v2 blobs
        a sparse read only pays for the chunks it lands in."""
        blob = self.backend.get(_sf_key(sf_id, stream, seg))
        t0 = time.perf_counter()
        frames, info = codec.decode_segment_ex(blob, np.asarray(want))
        t_dec = time.perf_counter() - t0
        cost = {
            "decode_s": t_dec, "convert_s": 0.0, "bytes": info["bytes"],
            "chunks": info["chunks"], "frames": info["frames"],
        }
        return frames, cost

    def decode_many_for(self, stream: str, segs: list[int], sf_id: str,
                        want: np.ndarray) -> tuple[list[np.ndarray], dict]:
        """Chunk-skip-decode ``want`` from several segments of one storage
        format in a single batched jit dispatch (``codec.decode_many``
        stacks every wanted chunk across the group), instead of one
        dispatch + host transfer per segment."""
        blobs = [self.backend.get(_sf_key(sf_id, stream, s)) for s in segs]
        t0 = time.perf_counter()
        frames_list, info = codec.decode_many(blobs, np.asarray(want))
        cost = {
            "decode_s": time.perf_counter() - t0, "convert_s": 0.0,
            "bytes": info["bytes"], "chunks": info["chunks"],
            "frames": info["frames"], "dispatches": info["dispatches"],
        }
        return frames_list, cost

    def convert(self, frames: np.ndarray, sf_id: str,
                cf: FidelityOption) -> np.ndarray:
        """Storage-grid frames -> consumption fidelity (crop + resize)."""
        sf = self.formats[sf_id]
        return np.asarray(T.spatial_convert(frames, sf.fidelity, cf, self.spec))

    def has_segment(self, stream: str, seg: int, sf_id: str) -> bool:
        return _sf_key(sf_id, stream, seg) in self.backend

    def available_segments(self, stream: str, sf_id: str) -> list[int]:
        prefix = f"{stream}:{sf_id}:"
        return [int(k.rsplit(":", 1)[1]) for k in self.backend.keys(prefix)]

    # -- erosion ----------------------------------------------------------------
    def erode(self, stream: str, sf_id: str, fraction: float, seed: int = 0):
        """Delete ``fraction`` of this stream x format's segments
        (deterministic spread across the timeline, as the erosion plan
        accumulates per age)."""
        segs = self.available_segments(stream, sf_id)
        n_del = int(round(len(segs) * fraction))
        if n_del <= 0:
            return 0
        rng = np.random.default_rng(seed)
        victims = rng.choice(segs, size=n_del, replace=False)
        for s in victims:
            self.backend.delete(_sf_key(sf_id, stream, int(s)))
        return n_del

    def storage_bytes(self, stream: str | None = None) -> int:
        return self.backend.total_bytes(f"{stream}:" if stream else "")

    def flush(self):
        self.backend.flush()
