"""VideoStore: ingestion, multi-version storage, retrieval with chunk-skip
decode, and erosion execution — the data-path half of VStore (the
configuration engine in ``repro.core`` decides *what* formats this layer
materializes).
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading
import time

import numpy as np

from ..codec import segment as codec
from ..codec import transform as T
from ..obs.trace import span as _span
from ..core.knobs import (CodingOption, FidelityOption, IngestSpec,
                          StorageFormat)
from .store import SegmentStore


@dataclasses.dataclass
class IngestStats:
    """Per-ingest accounting: the paper's ingestion cost (transcode compute)
    and storage cost (bytes/sec of stored video).  Chunk-level byte spans
    (blob v2 headers) are recorded alongside — the chunk, not the segment,
    is the natural deletion quantum for erosion accounting."""
    encode_seconds: float = 0.0
    stored_bytes: int = 0
    segments: int = 0
    chunks: int = 0          # entropy-coded chunks written (0 for RAW blobs)
    chunk_bytes: int = 0     # payload bytes of those chunks (v2 spans)

    def add(self, sec: float, nbytes: int, chunks: int = 0,
            chunk_bytes: int = 0):
        self.encode_seconds += sec
        self.stored_bytes += nbytes
        self.chunks += chunks
        self.chunk_bytes += chunk_bytes

    def bytes_per_video_second(self, spec: IngestSpec) -> float:
        dur = max(1e-9, self.segments * spec.segment_seconds)
        return self.stored_bytes / dur

    def cost_xrealtime(self, spec: IngestSpec) -> float:
        """Transcode compute normalized to video realtime (1.0 = keeps up)."""
        dur = max(1e-9, self.segments * spec.segment_seconds)
        return self.encode_seconds / dur


@dataclasses.dataclass
class ErodeResult:
    """Byte-level accounting of one erosion sweep: what the executor needs
    to prove space was actually reclaimed.  ``chunks``/``chunk_bytes``
    break the reclaimed payload down to the chunk quantum (blob v2 spans);
    v1/RAW blobs report their whole payload under ``chunk_bytes`` with
    ``chunks`` = 0."""
    segments: int = 0
    bytes: int = 0
    chunks: int = 0
    chunk_bytes: int = 0
    victims: list[int] = dataclasses.field(default_factory=list)

    def merge(self, other: "ErodeResult") -> "ErodeResult":
        self.segments += other.segments
        self.bytes += other.bytes
        self.chunks += other.chunks
        self.chunk_bytes += other.chunk_bytes
        self.victims.extend(other.victims)
        return self


def _sf_key(sf_id: str, stream: str, seg: int) -> str:
    return f"{stream}:{sf_id}:{seg:06d}"


def stratified_pick(items: list, n_pick: int, seed: int = 0) -> list:
    """Pick ``n_pick`` of ``items`` spread evenly across the (ordered) list,
    deterministically: one pick per stratum of ``len/n_pick`` items, at a
    seed-derived phase within the stratum.  Unlike ``rng.choice`` this can
    never cluster all victims in one stretch of the timeline, so an eroded
    format degrades uniformly instead of losing a contiguous era."""
    n = len(items)
    if n_pick >= n:
        return list(items)
    if n_pick <= 0:
        return []
    # golden-ratio multiplicative hash: distinct seeds -> distinct phases
    phase = ((seed * 0x9E3779B9 + 0x7F4A7C15) % (1 << 32)) / float(1 << 32)
    stride = n / n_pick
    used: set[int] = set()
    out = []
    for i in range(n_pick):
        j = int((i + phase) * stride) % n
        while j in used:  # int() collisions: walk to the next free slot
            j = (j + 1) % n
        used.add(j)
        out.append(items[j])
    return sorted(out)


def blob_chunk_profile(blob: bytes) -> tuple[int, int]:
    """(chunks, chunk_bytes) of a stored blob: the number of entropy-coded
    chunks and their payload bytes.  v2 headers carry exact per-chunk byte
    spans; v1 charges the whole entropy stream and RAW blobs report their
    payload as chunkless bytes."""
    header = codec.segment_info(blob)
    if header.get("raw"):
        return 0, header["n"] * header["h"] * header["w"]
    spans = header.get("spans")
    if spans is not None:  # blob v2: exact per-chunk byte spans
        return len(spans), int(sum(spans))
    n, k = header["n"], header["k"]
    return -(-n // k), len(blob)


class VideoStore:
    """Owns the on-disk segments for all streams × storage formats.

    ``readonly=True`` attaches to an existing store without mutating it —
    no meta/identity writes, no compaction, writes raise — so another
    process (the cluster router) can inspect formats and the persisted
    ``store_id`` of a shard a worker process owns.  ``store_id`` is a
    random token minted when a writable store first touches its meta file
    and stable for the store's lifetime; the router's generation-checked
    reattach uses it to prove a restarted worker reopened the same data.
    """

    def __init__(self, root: str, spec: IngestSpec | None = None,
                 readonly: bool = False):
        self.root = root
        self.spec = spec or IngestSpec()
        self.readonly = readonly
        self.backend = SegmentStore(os.path.join(root, "segments"),
                                    readonly=readonly)
        self.formats: dict[str, StorageFormat] = {}
        self.store_id: str | None = None
        self.ingest_stats: dict[str, IngestStats] = {}  # guarded-by: _stats_mu
        self._meta_path = os.path.join(root, "meta.json")
        self._retriever = None  # serving-layer hook (see attach_retriever)
        self._fallback = None   # ingest-layer hook (see set_fallback)
        # the live path writes golden (ingest thread) and background
        # transcodes (worker thread) concurrently; stats stay consistent
        self._stats_mu = threading.Lock()
        self._load_meta()
        if self.store_id is None and not readonly:
            # analysis: allow[determinism] store identity is minted once
            # at creation and persisted in meta.json; it must be unique
            # across stores (shard-identity checks), not reproducible
            self.store_id = os.urandom(8).hex()
            self._save_meta()

    # -- configuration -------------------------------------------------------
    def set_formats(self, formats: dict[str, StorageFormat]):
        """Install the storage-format set derived by the config engine.
        Keys are stable sf ids ('sf_g', 'sf1', ...)."""
        if self.readonly:
            raise RuntimeError(f"read-only VideoStore at {self.root}")
        self.formats = dict(formats)
        self._save_meta()

    def _save_meta(self):
        blob = {
            sid: {
                "quality": sf.fidelity.quality, "crop": sf.fidelity.crop,
                "resolution": sf.fidelity.resolution,
                "sampling": sf.fidelity.sampling,
                "speed": sf.coding.speed, "keyframe": sf.coding.keyframe,
                "bypass": sf.coding.bypass,
            } for sid, sf in self.formats.items()
        }
        blob["__store__"] = {"store_id": self.store_id}
        tmp = self._meta_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(blob, f, indent=1)
        os.replace(tmp, self._meta_path)

    def _load_meta(self):
        if not os.path.exists(self._meta_path):
            return
        with open(self._meta_path) as f:
            blob = json.load(f)
        self.store_id = blob.pop("__store__", {}).get("store_id")
        self.formats = {
            sid: StorageFormat(
                FidelityOption(v["quality"], v["crop"], v["resolution"],
                               v["sampling"]),
                CodingOption(v["speed"], v["keyframe"], v["bypass"]))
            for sid, v in blob.items()
        }

    # -- ingestion ------------------------------------------------------------
    def encode_format(self, frames_u8: np.ndarray, src_f: FidelityOption,
                      sf: StorageFormat) -> bytes:
        """Transcode frames at fidelity ``src_f`` into ``sf``'s blob bytes
        (fidelity conversion + coding).  Deterministic: the single transcode
        implementation shared by blocking ingest, the background scheduler
        and fallback-chain reconstruction, so all three produce identical
        bytes from identical input."""
        frames = np.asarray(T.convert_fidelity(frames_u8, src_f, sf.fidelity,
                                               self.spec))
        if sf.coding.bypass:
            return codec.encode_raw(frames)
        return codec.encode_segment(
            frames, quant_scale=sf.fidelity.quant_scale,
            keyframe_interval=sf.coding.keyframe,
            zstd_level=sf.coding.zstd_level)

    def put_segment(self, stream: str, seg: int, sf_id: str, blob: bytes,
                    encode_s: float = 0.0, count_segment: bool = False):
        """Write one materialized blob and account it (bytes + chunk spans).
        ``count_segment`` increments the stream's segment counter — set by
        the path that writes the segment's first (golden) version."""
        chunks, chunk_bytes = blob_chunk_profile(blob)
        self.backend.put(_sf_key(sf_id, stream, seg), blob)
        with self._stats_mu:
            stats = self.ingest_stats.setdefault(stream, IngestStats())
            if count_segment:
                stats.segments += 1
            stats.add(encode_s, len(blob), chunks, chunk_bytes)

    def ingest_segment(self, stream: str, seg: int, frames_u8: np.ndarray,
                       ingest_fidelity: FidelityOption | None = None):
        """Blocking ingest: transcode one arriving segment into every
        configured storage format before returning.  ``frames_u8`` is at
        the ingest (richest) fidelity.  The live path (repro.ingest) writes
        only golden synchronously and materializes the rest in the
        background instead."""
        src_f = ingest_fidelity or FidelityOption()
        with self._stats_mu:
            stats = self.ingest_stats.setdefault(stream, IngestStats())
            stats.segments += 1
        for sid, sf in self.formats.items():
            t0 = time.perf_counter()
            blob = self.encode_format(frames_u8, src_f, sf)
            dt = time.perf_counter() - t0
            self.put_segment(stream, seg, sid, blob, encode_s=dt)

    # -- retrieval -------------------------------------------------------------
    def attach_retriever(self, retriever) -> None:
        """Install a cache-aware retrieval hook (repro.serving): ``retrieve``
        then routes through it, so every consumer of this store — including
        plain ``run_query`` — shares the serving layer's decoded-segment
        cache.  Pass ``None`` to restore direct decoding."""
        self._retriever = retriever

    def set_fallback(self, fallback) -> None:
        """Install a fallback-chain blob provider (repro.ingest.fallback):
        when a stored segment is missing — not yet materialized by the
        background transcoder, or reclaimed by erosion — ``_blob`` asks it
        to reconstruct the exact blob from the nearest richer ancestor on
        the format tree.  Pass ``None`` to restore strict reads."""
        self._fallback = fallback

    def _blob(self, stream: str, seg: int, sf_id: str
              ) -> tuple[bytes, bool]:
        """Fetch a stored blob, reconstructing via the fallback chain when
        the physical copy is absent.  Returns ``(blob, fallback)`` where
        ``fallback`` reports which path actually served the read.  Raises
        KeyError only when the chain (ultimately golden) cannot serve it
        either."""
        try:
            return self.backend.get(_sf_key(sf_id, stream, seg)), False
        except KeyError:
            if self._fallback is None:
                raise
            return self._fallback.reconstruct(self, stream, seg, sf_id), True

    def retrieve(self, stream: str, seg: int, sf_id: str,
                 cf: FidelityOption) -> tuple[np.ndarray, dict]:
        """Decode a stored segment (chunk-skip under the consumer's sparser
        sampling) and convert to the consumption fidelity.  Returns
        (frames_u8, timing/cost dict)."""
        if self._retriever is not None:
            return self._retriever(stream, seg, sf_id, cf)
        return self.retrieve_direct(stream, seg, sf_id, cf)

    def retrieve_direct(self, stream: str, seg: int, sf_id: str,
                        cf: FidelityOption) -> tuple[np.ndarray, dict]:
        """The uncached decode path (bypasses any attached retriever)."""
        want = self.want_indices(sf_id, cf)
        frames, cost = self.decode_for(stream, seg, sf_id, want)
        t0 = time.perf_counter()
        out = self.convert(frames, sf_id, cf)
        cost["convert_s"] = time.perf_counter() - t0
        return out, cost

    def retrieve_many(self, stream: str, segs: list[int], sf_id: str,
                      cf: FidelityOption) -> tuple[list[np.ndarray], dict]:
        """Retrieve several segments at one consumption fidelity.

        Amortizes the per-segment fixed costs: ``want_indices`` is computed
        once for the whole group, the chunk-skip *decode* of every segment
        runs as one batched dispatch (``decode_many_for`` stacks all wanted
        chunks), and the crop/resize ``convert`` runs as one fused call over
        the concatenated decode, then splits back per segment — decode and
        ``convert`` are per-frame programs, so results are bit-exact with
        ``retrieve``.  When
        a serving-layer retriever is attached, routes each segment through
        it instead (the decoded-segment cache owns reuse there).  Returns
        ``(frames_per_segment, aggregate_cost)``.
        """
        if self._retriever is not None:
            outs = [self._retriever(stream, s, sf_id, cf) for s in segs]
            cost = {"decode_s": 0.0, "convert_s": 0.0, "bytes": 0,
                    "chunks": 0, "frames": 0}
            for _, c in outs:
                for k in cost:
                    cost[k] += c.get(k, 0)
            return [f for f, _ in outs], cost
        cost = {"decode_s": 0.0, "convert_s": 0.0, "bytes": 0,
                "chunks": 0, "frames": 0}
        if not segs:
            return [], cost
        want = self.want_indices(sf_id, cf)
        decoded, c = self.decode_many_for(stream, segs, sf_id, want)
        for k in ("decode_s", "bytes", "chunks", "frames"):
            cost[k] += c[k]
        t0 = time.perf_counter()
        stacked = decoded[0] if len(decoded) == 1 else np.concatenate(decoded)
        conv = self.convert(stacked, sf_id, cf)
        cost["convert_s"] = time.perf_counter() - t0
        n = len(want)
        return [conv[i * n:(i + 1) * n] for i in range(len(segs))], cost

    # serving-layer primitives: retrieval = want_indices -> decode_for ->
    # convert.  The decoded-segment cache keeps decode_for outputs (frames on
    # the storage fidelity's grid) so any CF a cached decode covers is served
    # by the exact same convert() a direct retrieve would run — bit-exact
    # reuse by construction.
    def want_indices(self, sf_id: str, cf: FidelityOption) -> np.ndarray:
        """Stored-frame indices realizing ``cf``'s sampling (R1-checked)."""
        sf = self.formats[sf_id]
        if not sf.fidelity.richer_eq(cf):
            raise ValueError(
                f"R1 violated: SF {sf.fidelity.name()} poorer than CF {cf.name()}")
        return T.temporal_indices(sf.fidelity, cf, self.spec)

    def decode_for(self, stream: str, seg: int, sf_id: str,
                   want: np.ndarray) -> tuple[np.ndarray, dict]:
        """Fetch + chunk-skip-decode stored frames ``want`` at the storage
        fidelity's own grid (no consumption conversion).  The decode's own
        single header parse supplies the cost accounting, and ``bytes`` /
        ``chunks`` report what the decode actually touched — with v2 blobs
        a sparse read only pays for the chunks it lands in.  A missing blob
        is transparently served over the fallback chain when one is
        installed (``cost['fallback']`` flags it)."""
        blob, fb = self._blob(stream, seg, sf_id)
        t0 = time.perf_counter()
        with _span("codec.decode", sf=sf_id, seg=seg,
                   fallback=bool(fb)) as sp:
            frames, info = codec.decode_segment_ex(blob, np.asarray(want))
            sp.set(bytes=info["bytes"], chunks=info["chunks"],
                   frames=info["frames"])
        t_dec = time.perf_counter() - t0
        cost = {
            "decode_s": t_dec, "convert_s": 0.0, "bytes": info["bytes"],
            "chunks": info["chunks"], "frames": info["frames"],
        }
        if fb:
            cost["fallback"] = 1
        return frames, cost

    def decode_many_for(self, stream: str, segs: list[int], sf_id: str,
                        want: np.ndarray) -> tuple[list[np.ndarray], dict]:
        """Chunk-skip-decode ``want`` from several segments of one storage
        format in a single batched jit dispatch (``codec.decode_many``
        stacks every wanted chunk across the group), instead of one
        dispatch + host transfer per segment."""
        fetched = [self._blob(stream, s, sf_id) for s in segs]
        blobs = [b for b, _fb in fetched]
        t0 = time.perf_counter()
        with _span("codec.decode", sf=sf_id, segments=len(segs)) as sp:
            frames_list, info = codec.decode_many(blobs, np.asarray(want))
            sp.set(bytes=info["bytes"], chunks=info["chunks"],
                   frames=info["frames"])
        cost = {
            "decode_s": time.perf_counter() - t0, "convert_s": 0.0,
            "bytes": info["bytes"], "chunks": info["chunks"],
            "frames": info["frames"], "dispatches": info["dispatches"],
        }
        n_fb = sum(fb for _b, fb in fetched)
        if n_fb:
            cost["fallback"] = n_fb
        return frames_list, cost

    def convert(self, frames: np.ndarray, sf_id: str,
                cf: FidelityOption) -> np.ndarray:
        """Storage-grid frames -> consumption fidelity (crop + resize)."""
        sf = self.formats[sf_id]
        with _span("convert", sf=sf_id, cf=cf.name(), frames=len(frames)):
            return np.asarray(
                T.spatial_convert(frames, sf.fidelity, cf, self.spec))

    def has_segment(self, stream: str, seg: int, sf_id: str) -> bool:
        """Whether the blob is physically materialized (fallback excluded)."""
        return _sf_key(sf_id, stream, seg) in self.backend

    def can_serve(self, stream: str, seg: int, sf_id: str) -> bool:
        """Whether a retrieve would succeed: materialized, or reachable
        over the installed fallback chain."""
        if self.has_segment(stream, seg, sf_id):
            return True
        if self._fallback is None:
            return False
        return self._fallback.can_reconstruct(self, stream, seg, sf_id)

    def available_segments(self, stream: str, sf_id: str) -> list[int]:
        prefix = f"{stream}:{sf_id}:"
        return [int(k.rsplit(":", 1)[1]) for k in self.backend.keys(prefix)]

    # -- erosion ----------------------------------------------------------------
    def erode(self, stream: str, sf_id: str, fraction: float | None = None,
              seed: int = 0, *, segments: list[int] | None = None,
              count: int | None = None) -> ErodeResult:
        """Delete segments of this stream × format and account the bytes.

        Victims are chosen with a stratified deterministic spread across
        the (sorted) timeline — one per stratum at a seed-derived phase —
        so repeated erosion sweeps thin the format uniformly.  ``segments``
        restricts candidates (the erosion executor passes one age cohort);
        ``count`` deletes an exact number instead of a ``fraction`` of the
        candidates.  Returns an ``ErodeResult`` with segment, byte and
        chunk-span accounting (the bytes the executor reports reclaimed)."""
        cands = self.available_segments(stream, sf_id)
        if segments is not None:
            allowed = set(segments)
            cands = [s for s in cands if s in allowed]
        if count is None:
            if fraction is None:
                raise ValueError("erode needs fraction= or count=")
            count = int(round(len(cands) * fraction))
        res = ErodeResult()
        for s in stratified_pick(cands, count, seed):
            key = _sf_key(sf_id, stream, int(s))
            try:
                blob = self.backend.get(key)
            except KeyError:
                continue  # raced with a concurrent deleter; not ours
            chunks, chunk_bytes = blob_chunk_profile(blob)
            if self.backend.delete(key):
                res.segments += 1
                res.bytes += len(blob)
                res.chunks += chunks
                res.chunk_bytes += chunk_bytes
                res.victims.append(int(s))
        return res

    def segment_bytes(self, stream: str, seg: int, sf_id: str) -> int:
        """Stored size of one materialized blob, 0 when absent (eroded or
        not yet transcoded) — what predicate pushdown reports as bytes a
        pruned segment never read."""
        try:
            return self.backend.size_of(_sf_key(sf_id, stream, seg))
        except KeyError:
            return 0

    def storage_bytes(self, stream: str | None = None) -> int:
        return self.backend.total_bytes(f"{stream}:" if stream else "")

    def flush(self):
        self.backend.flush()
