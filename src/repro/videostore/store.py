"""On-disk segment store (LMDB-like: MB-size values behind a keyed index).

Layout: ``root/shard-XXXX.bin`` append-only blob shards + ``root/index.msgpack``
mapping key -> (shard, offset, length).  Deletes drop index entries (space is
reclaimed by compaction).  This mirrors the paper's use of LMDB for 8-second
MB-size segment values without an external dependency.
"""

from __future__ import annotations

import os
import threading

import msgpack

_SHARD_LIMIT = 64 * 1024 * 1024


class SegmentStore:
    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._lock = threading.Lock()
        self._index: dict[str, tuple[int, int, int]] = {}
        self._shard_id = 0
        self._shard_size = 0
        self._gen = 0  # bumped by compact(); lets readers detect shard rewrites
        self._load()

    # -- persistence --------------------------------------------------------
    def _index_path(self) -> str:
        return os.path.join(self.root, "index.msgpack")

    def _shard_path(self, sid: int) -> str:
        return os.path.join(self.root, f"shard-{sid:04d}.bin")

    def _load(self):
        if os.path.exists(self._index_path()):
            with open(self._index_path(), "rb") as f:
                raw = msgpack.unpackb(f.read())
            self._index = {k: tuple(v) for k, v in raw["index"].items()}
            self._shard_id = raw["shard_id"]
            self._shard_size = raw["shard_size"]

    def flush(self):
        with self._lock:
            blob = msgpack.packb({
                "index": {k: list(v) for k, v in self._index.items()},
                "shard_id": self._shard_id, "shard_size": self._shard_size,
            })
        tmp = self._index_path() + ".tmp"
        with open(tmp, "wb") as f:
            f.write(blob)
        os.replace(tmp, self._index_path())  # atomic

    # -- KV API --------------------------------------------------------------
    def put(self, key: str, value: bytes):
        with self._lock:
            if self._shard_size + len(value) > _SHARD_LIMIT and self._shard_size:
                self._shard_id += 1
                self._shard_size = 0
            sid = self._shard_id
            path = self._shard_path(sid)
            with open(path, "ab") as f:
                offset = f.tell()
                f.write(value)
            self._shard_size = offset + len(value)
            self._index[key] = (sid, offset, len(value))

    def get(self, key: str) -> bytes:
        # Optimistic read: snapshot the index entry under the lock, read the
        # shard without it (gets stay concurrent), then verify no compact()
        # rewrote the shard layout mid-read.  compact() holds the lock for
        # its whole rewrite, so an unchanged generation proves the bytes
        # came from the layout the entry described.
        while True:
            with self._lock:
                gen = self._gen
                sid, offset, length = self._index[key]
                path = self._shard_path(sid)
            try:
                with open(path, "rb") as f:
                    f.seek(offset)
                    blob = f.read(length)
            except FileNotFoundError:
                with self._lock:
                    if self._gen != gen:
                        continue  # compacted away mid-read; retry new index
                raise  # shard genuinely missing (corrupt/partial store)
            with self._lock:
                if self._gen == gen:
                    return blob

    def delete(self, key: str) -> bool:
        with self._lock:
            return self._index.pop(key, None) is not None

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._index

    def keys(self, prefix: str = "") -> list[str]:
        with self._lock:
            return sorted(k for k in self._index if k.startswith(prefix))

    def size_of(self, key: str) -> int:
        with self._lock:
            return self._index[key][2]

    def total_bytes(self, prefix: str = "") -> int:
        with self._lock:
            return sum(v[2] for k, v in self._index.items()
                       if k.startswith(prefix))

    def compact(self):
        """Rewrite shards dropping deleted blobs (reclaims space)."""
        with self._lock:
            items = sorted(self._index.items())
            new_index, sid, size = {}, 0, 0
            out = open(self._shard_path(10000), "wb")  # temp shard namespace
            paths = [out.name]
            for key, (osid, off, ln) in items:
                with open(self._shard_path(osid), "rb") as f:
                    f.seek(off)
                    blob = f.read(ln)
                if size + ln > _SHARD_LIMIT and size:
                    out.close()
                    sid += 1
                    out = open(self._shard_path(10000 + sid), "wb")
                    paths.append(out.name)
                    size = 0
                new_index[key] = (sid, size, ln)
                out.write(blob)
                size += ln
            out.close()
            for name in os.listdir(self.root):
                if name.startswith("shard-") and \
                        int(name[6:].split(".")[0]) < 10000:
                    os.remove(os.path.join(self.root, name))
            for i, p in enumerate(paths):
                os.replace(p, self._shard_path(i))
            self._index = new_index
            self._shard_id, self._shard_size = sid, size
            self._gen += 1
        self.flush()
