"""On-disk segment store (LMDB-like: MB-size values behind a keyed index).

Layout: ``root/shard-XXXX.bin`` append-only blob shards + ``root/index.msgpack``
mapping key -> (shard, offset, length).  Deletes drop index entries; the dead
bytes they leave in the shards are tracked and reclaimed by compaction —
either an explicit ``compact()`` or automatically once dead bytes exceed
``auto_compact_frac`` of the store (erosion deletes many segments over time,
so space reclamation must not depend on a manual call).  This mirrors the
paper's use of LMDB for 8-second MB-size segment values without an external
dependency.
"""

from __future__ import annotations

import os
import threading

import msgpack

from ..obs.trace import span as _span

_SHARD_LIMIT = 64 * 1024 * 1024


class SegmentStore:
    def __init__(self, root: str, auto_compact_frac: float | None = 0.5,
                 auto_compact_min_bytes: int = 1 << 16,
                 readonly: bool = False):
        """``readonly=True`` attaches without any mutation: writes raise,
        auto-compaction is off, and the load-time orphan-shard sweep is
        skipped — safe for inspecting a store another process owns (the
        cluster router's shard identity checks)."""
        if auto_compact_frac is not None and not 0 < auto_compact_frac <= 1:
            raise ValueError(f"auto_compact_frac must be in (0, 1], "
                             f"got {auto_compact_frac}")
        self.root = root
        self.readonly = readonly
        if not readonly:
            os.makedirs(root, exist_ok=True)
        self.auto_compact_frac = None if readonly else auto_compact_frac
        self.auto_compact_min_bytes = auto_compact_min_bytes
        self._lock = threading.Lock()
        self._index: dict[str, tuple[int, int, int]] = {}  # guarded-by: _lock
        self._shard_id = 0    # guarded-by: _lock
        self._shard_size = 0  # guarded-by: _lock
        self._live_bytes = 0  # guarded-by: _lock (sum of indexed lengths)
        self._dead_bytes = 0  # guarded-by: _lock (unreferenced shard bytes)
        self._gen = 0  # guarded-by: _lock (compact() bump; detects rewrites)
        self.compactions = 0  # guarded-by: _lock (manual + automatic)
        self.auto_compactions = 0  # guarded-by: _lock
        self._load()

    # -- persistence --------------------------------------------------------
    def _index_path(self) -> str:
        return os.path.join(self.root, "index.msgpack")

    def _shard_path(self, sid: int) -> str:
        return os.path.join(self.root, f"shard-{sid:04d}.bin")

    def _load(self):
        if not os.path.exists(self._index_path()):
            return
        with open(self._index_path(), "rb") as f:
            raw = msgpack.unpackb(f.read())
        self._index = {k: tuple(v) for k, v in raw["index"].items()}
        self._shard_id = raw["shard_id"]
        self._shard_size = raw["shard_size"]
        self._live_bytes = sum(v[2] for v in self._index.values())
        self._dead_bytes = raw.get("dead_bytes", 0)
        if self.readonly:
            return  # the orphan sweep below mutates; owner's job
        # drop shard files the durable index no longer references — the
        # garbage a crash may leave on either side of a compaction (old
        # shards not yet removed, or new shards written before the index
        # flush); never data loss, because compaction makes the new index
        # durable before deleting the old shards
        live = {v[0] for v in self._index.values()} | {self._shard_id}
        for name in os.listdir(self.root):
            if name.startswith("shard-") and name.endswith(".bin"):
                sid = int(name[6:-4])
                if sid not in live:
                    os.remove(os.path.join(self.root, name))

    def flush(self):
        if self.readonly:
            return  # nothing of ours to persist
        with self._lock:
            self._flush_locked()

    def _flush_locked(self):
        blob = msgpack.packb({
            "index": {k: list(v) for k, v in self._index.items()},
            "shard_id": self._shard_id, "shard_size": self._shard_size,
            "dead_bytes": self._dead_bytes,
        })
        tmp = self._index_path() + ".tmp"
        with open(tmp, "wb") as f:
            f.write(blob)
        os.replace(tmp, self._index_path())  # atomic

    def _check_writable(self):
        if self.readonly:
            raise RuntimeError(f"read-only SegmentStore at {self.root}")

    # -- KV API --------------------------------------------------------------
    def put(self, key: str, value: bytes):
        self._check_writable()
        with self._lock:
            if self._shard_size + len(value) > _SHARD_LIMIT and self._shard_size:
                self._shard_id += 1
                self._shard_size = 0
            sid = self._shard_id
            path = self._shard_path(sid)
            with open(path, "ab") as f:
                offset = f.tell()
                f.write(value)
            self._shard_size = offset + len(value)
            old = self._index.get(key)
            if old is not None:
                self._dead_bytes += old[2]
                self._live_bytes -= old[2]
            self._index[key] = (sid, offset, len(value))
            self._live_bytes += len(value)
            self._maybe_compact_locked()

    def get(self, key: str) -> bytes:
        with _span("store.get", key=key) as sp:
            blob = self._get(key)
            sp.set(bytes=len(blob))
            return blob

    def _get(self, key: str) -> bytes:
        # Optimistic read: snapshot the index entry under the lock, read the
        # shard without it (gets stay concurrent), then verify no compact()
        # rewrote the shard layout mid-read.  compact() holds the lock for
        # its whole rewrite, so an unchanged generation proves the bytes
        # came from the layout the entry described.
        while True:
            with self._lock:
                gen = self._gen
                sid, offset, length = self._index[key]
                path = self._shard_path(sid)
            try:
                with open(path, "rb") as f:
                    f.seek(offset)
                    blob = f.read(length)
            except FileNotFoundError:
                with self._lock:
                    if self._gen != gen:
                        continue  # compacted away mid-read; retry new index
                raise  # shard genuinely missing (corrupt/partial store)
            with self._lock:
                if self._gen == gen:
                    return blob

    def delete(self, key: str) -> bool:
        self._check_writable()
        with self._lock:
            entry = self._index.pop(key, None)
            if entry is None:
                return False
            self._dead_bytes += entry[2]
            self._live_bytes -= entry[2]
            self._maybe_compact_locked()
            return True

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._index

    def keys(self, prefix: str = "") -> list[str]:
        with self._lock:
            return sorted(k for k in self._index if k.startswith(prefix))

    def size_of(self, key: str) -> int:
        with self._lock:
            return self._index[key][2]

    def total_bytes(self, prefix: str = "") -> int:
        with self._lock:
            return sum(v[2] for k, v in self._index.items()
                       if k.startswith(prefix))

    @property
    def dead_bytes(self) -> int:
        """Shard bytes deletes/overwrites orphaned (reclaimed by compact)."""
        with self._lock:
            return self._dead_bytes

    def _maybe_compact_locked(self):
        """Auto-compaction check (caller holds the lock): rewrite the shards
        once orphaned bytes exceed ``auto_compact_frac`` of the store (the
        rewrite itself makes the index durable before deleting shards)."""
        if self.auto_compact_frac is None:
            return
        if (self._dead_bytes >= self.auto_compact_min_bytes
                and self._dead_bytes > self.auto_compact_frac
                * max(1, self._live_bytes + self._dead_bytes)):
            self._compact_locked()
            self.auto_compactions += 1

    def compact(self):
        """Rewrite shards dropping deleted blobs (reclaims space)."""
        self._check_writable()
        with self._lock:
            self._compact_locked()

    def _compact_locked(self):
        """Crash-safe rewrite: surviving blobs are copied into *fresh*
        shard ids (never reusing old names, so no renames), the index is
        made durable pointing at them, and only then are the old shards
        deleted.  A crash at any point leaves a readable store — before
        the index flush the old index + old shards are intact (new shards
        are orphans ``_load`` cleans up); after it, the new layout is live
        (old shards are the orphans)."""
        old_sids = {v[0] for v in self._index.values()} | {self._shard_id}
        base = self._shard_id + 1
        items = sorted(self._index.items())
        new_index, si, size = {}, 0, 0
        out = open(self._shard_path(base), "wb")
        for key, (osid, off, ln) in items:
            with open(self._shard_path(osid), "rb") as f:
                f.seek(off)
                blob = f.read(ln)
            if size + ln > _SHARD_LIMIT and size:
                out.close()
                si += 1
                out = open(self._shard_path(base + si), "wb")
                size = 0
            new_index[key] = (base + si, size, ln)
            out.write(blob)
            size += ln
        out.close()
        self._index = new_index
        self._shard_id, self._shard_size = base + si, size
        self._live_bytes = sum(v[2] for v in new_index.values())
        self._dead_bytes = 0
        self._gen += 1
        self.compactions += 1
        self._flush_locked()  # durable before the destructive deletes
        for sid in old_sids:
            path = self._shard_path(sid)
            if os.path.exists(path):
                os.remove(path)
