from .store import SegmentStore
from .video_store import IngestStats, VideoStore

__all__ = ["SegmentStore", "VideoStore", "IngestStats"]
