"""Budgeted multi-stream ingest scheduler.

The paper's ingestion contract (§4.3): the store must keep pace with many
live camera streams under a bounded transcoding budget.  This scheduler
splits one arriving segment's work in two:

* **golden, synchronously** — the richest format is encoded and made durable
  before ``ingest`` returns (the segment can never be lost; every other
  format is derivable from it);
* **everything else, in the background** — one transcode task per remaining
  format goes onto a priority queue ordered by *recovery cost* (the erosion
  chain math of ``repro.core.erosion.recovery_cost``: how much the consumer
  fleet slows down if the format is absent and reads fall back to its
  ancestor).  Under budget pressure the cheapest-to-recover formats are the
  ones that wait (or are shed outright past a debt cap) — exactly the
  formats whose fallback chain serves reads nearly as fast.

The budget is a token bucket in *encode-seconds per video-second*: each
arriving segment credits ``budget_x × segment_seconds``; the synchronous
golden encode and every background transcode debit their measured cost.
Background work only runs while credit is positive, so a budget below the
full materialization cost accumulates *transcode debt* (estimated encode
seconds still queued) that ``stats()`` surfaces per stream and per format —
and that drains to zero once the budget is raised (``set_budget_x`` +
``drain``), because shed tasks are kept re-enqueueable.

Queries issued mid-ingest are correct throughout: unmaterialized formats are
served over the fallback chain (``repro.ingest.fallback``) with bit-exact
results, since the background worker and the read-time reconstruction run
the identical golden-derived transcode.
"""

from __future__ import annotations

import bisect
import dataclasses
import threading
import time

from ..core.erosion import recovery_cost
from ..core.knobs import FidelityOption
from ..obs.metrics import Histogram
from ..obs.trace import span as _span
from .fallback import ByteRatioProfiler, FallbackChain


def recovery_rank_for(config, spec, profiler=None) -> dict[str, float]:
    """sf_id -> recovery cost for a derived configuration: how much the
    consumer fleet slows down when that format is absent and reads fall
    back to its ancestor (``core.erosion.recovery_cost`` chain math).
    The single ranking shared by the scheduler's transcode priorities and
    the serving cache's erosion-aware eviction.  ``profiler`` defaults to
    the deterministic byte-ratio model."""
    prof = profiler or ByteRatioProfiler(spec)
    subs = {}
    for i, node in enumerate(config.nodes):
        for p in node.plans:
            subs[p] = i
    by_idx = recovery_cost(prof, config.nodes, subs)
    return {config.node_id(i): c for i, c in by_idx.items()}


@dataclasses.dataclass(order=True)
class TranscodeTask:
    """One deferred materialization, ordered most-expensive-to-recover
    first (the head of the queue is the format the fleet misses most).
    ``kind="sketch"`` tasks build semantic-index sketches instead of
    blobs (repro.index): same queue, same budget accounting, ordered
    right after their source format's own transcode (sort-key suffix);
    ``op`` names the sketched operator and ``sf_id`` the source format
    the sketch decodes from."""
    sort_key: tuple
    stream: str = dataclasses.field(compare=False)
    seg: int = dataclasses.field(compare=False)
    sf_id: str = dataclasses.field(compare=False)
    est_s: float = dataclasses.field(compare=False, default=0.0)
    kind: str = dataclasses.field(compare=False, default="transcode")
    op: str = dataclasses.field(compare=False, default="")


class BudgetLease:
    """Externally-owned slice of a transcode budget.

    The scheduler reads its rate (encode-seconds per arriving video-second)
    from the lease instead of owning it; the lease's owner — a cluster
    coordinator splitting one global budget across shard schedulers, or
    the scheduler itself when constructed standalone — adjusts the share
    with ``grant``.  A raise re-credits the attached scheduler's token
    bucket retroactively (same semantics ``set_budget_x`` always had), so
    reassigned budget starts draining debt immediately."""

    def __init__(self, budget_x: float | None = None):
        self.budget_x = budget_x
        self._sched: "IngestScheduler | None" = None

    def attach(self, scheduler: "IngestScheduler") -> None:
        if self._sched is not None and self._sched is not scheduler:
            raise ValueError("lease already attached to another scheduler")
        self._sched = scheduler

    def grant(self, budget_x: float | None) -> None:
        """Set the leased rate (None = unbounded)."""
        if self._sched is None:
            self.budget_x = budget_x
            return
        self._sched._regrant(budget_x)


@dataclasses.dataclass
class _StreamState:
    segments: int = 0
    video_seconds: float = 0.0
    golden_encode_s: float = 0.0
    max_golden_lag_s: float = 0.0   # worst sync (durability) latency


class IngestScheduler:
    """Live ingestion front end for one ``VideoStore``."""

    def __init__(self, store, config=None, *, budget_x: float | None = None,
                 lease: BudgetLease | None = None,
                 profiler=None, golden_id: str | None = None,
                 shed_debt_s: float | None = None, ema: float = 0.3,
                 materialize_on_read: bool = False):
        """``config`` (a DerivedConfig) supplies consumer subscriptions for
        the recovery-cost ranking; ``profiler`` supplies measured retrieval
        speeds for it (falling back to the deterministic byte-ratio model).
        ``budget_x`` is the transcode-cycle budget in encode-seconds per
        arriving video-second (None = unbounded); passing ``lease`` instead
        hands rate ownership to an external coordinator (see
        ``BudgetLease``).  ``shed_debt_s`` caps the queue's estimated debt:
        beyond it the cheapest-to-recover tasks are shed (kept aside,
        re-enqueueable via ``requeue_shed``).  ``materialize_on_read=True``
        writes fallback-chain reconstructions back to the store (charged
        to this budget) so hot unmaterialized segments stop paying the
        chain walk."""
        if not store.formats:
            raise ValueError("store has no formats installed")
        if lease is not None and budget_x is not None:
            raise ValueError("pass budget_x or lease, not both")
        self.store = store
        self.spec = store.spec
        self.lease = lease if lease is not None else BudgetLease(budget_x)
        self.lease.attach(self)
        self.shed_debt_s = shed_debt_s
        self._ema = ema
        self.fallback = FallbackChain(store.formats, store.spec,
                                      golden_id=golden_id)
        store.set_fallback(self.fallback)
        if materialize_on_read:
            self.fallback.enable_write_back(self._charge_write_back)
        self.golden_id = self.fallback.golden_id
        self._rank = self._build_rank(config, profiler)
        self._mu = threading.Lock()
        self._work = threading.Condition(self._mu)
        self._queue: list[TranscodeTask] = []  # guarded-by: _mu ([0]=next)
        self._shed: list[TranscodeTask] = []   # guarded-by: _mu
        self._est_s: dict[str, float] = {}     # guarded-by: _mu (EMA enc s)
        self._credit = 0.0                     # guarded-by: _mu
        self._video_s_arrived = 0.0   # guarded-by: _mu (stream s admitted)
        self._spent_s = 0.0           # guarded-by: _mu (encode s spent)
        self._streams: dict[str, _StreamState] = {}  # guarded-by: _mu
        self._stop = threading.Event()
        self._worker: threading.Thread | None = None
        self.transcodes = 0           # guarded-by: _mu
        self.transcode_s = 0.0        # guarded-by: _mu
        self.shed_total = 0           # guarded-by: _mu
        self.task_errors = 0          # guarded-by: _mu
        self.last_task_error: str | None = None  # guarded-by: _mu
        self.write_backs = 0          # guarded-by: _mu (blobs persisted)
        self.write_back_s = 0.0       # guarded-by: _mu (budget charge)
        self.write_backs_skipped = 0  # guarded-by: _mu (no credit)
        self._index = None            # semantic index (attach_sketcher)
        self.sketches = 0             # guarded-by: _mu (sketch tasks done)
        self.sketch_s = 0.0           # guarded-by: _mu (budget charge)
        self._h_golden = Histogram()     # per-segment golden encode seconds
        self._h_transcode = Histogram()  # per-task background encode seconds
        self._on_ingest: list = []   # callbacks(stream, seg) after golden

    @property
    def budget_x(self) -> float | None:
        """Current transcode rate — read through the (possibly externally
        owned) lease."""
        return self.lease.budget_x

    # -- ranking --------------------------------------------------------------
    def _build_rank(self, config, profiler) -> dict[str, float]:
        """sf_id -> recovery cost (higher = materialize sooner)."""
        if config is not None:
            return recovery_rank_for(config, self.spec, profiler)
        # no config: deeper formats are cheaper to recover (their parent is
        # closer in fidelity), golden never queued anyway
        return {sid: float("inf") if sid == self.golden_id
                else 1.0 / (1.0 + self.fallback.depth(sid))
                for sid in self.store.formats}

    def recovery_rank(self) -> dict[str, float]:
        return dict(self._rank)

    # -- ingest (the synchronous golden path) ---------------------------------
    def on_ingest(self, cb) -> None:
        """Register ``cb(stream, seg)`` to run after each golden write
        (the erosion executor uses this to place segments in age cohorts)."""
        self._on_ingest.append(cb)

    def attach_sketcher(self, index) -> None:
        """Attach a semantic index (``repro.index.SemanticIndex``): every
        admitted segment also enqueues one budget-charged sketch task per
        indexed op, priced and shed exactly like transcodes.  Re-ingest
        invalidates the segment's existing sketches first."""
        self._index = index

    def _sketch_tasks_locked(self, stream: str, seg: int,
                             golden_dt: float) -> int:
        """Enqueue missing-sketch tasks for one segment (caller holds
        ``_mu``).  Returns how many were enqueued."""
        n = 0
        for op_name in self._index.ops:
            src_sf = self._index.specs[op_name][2]
            task = TranscodeTask(
                self._sort_key(src_sf, seg, stream) + (1,), stream, seg,
                src_sf, est_s=self._estimate_sketch(op_name, golden_dt),
                kind="sketch", op=op_name)
            bisect.insort(self._queue, task)
            n += 1
        return n

    def ingest(self, stream: str, seg: int, frames_u8,
               ingest_fidelity: FidelityOption | None = None) -> float:
        """Admit one arriving segment: golden written durably before
        returning, all other formats queued for background transcode.
        Returns the golden (durability) latency in seconds."""
        src_f = ingest_fidelity or FidelityOption()
        self.fallback.invalidate(stream, seg)  # re-ingest: stale memos die
        if self._index is not None:
            self._index.invalidate(stream, seg)  # footage may differ now
        for sf_id in self.store.formats:
            # re-ingest: derived blobs of the old footage must not outlive
            # the new golden, or transcode tasks would skip them as
            # already-materialized and queries keep serving stale frames
            if (sf_id != self.golden_id
                    and self.store.has_segment(stream, seg, sf_id)):
                self.store.erode(stream, sf_id, segments=[seg], count=1)
        with _span("ingest.golden", stream=stream, seg=seg) as sp:
            t0 = time.perf_counter()
            blob = self.store.encode_format(
                frames_u8, src_f, self.store.formats[self.golden_id])
            golden_dt = time.perf_counter() - t0
            self.store.put_segment(stream, seg, self.golden_id, blob,
                                   encode_s=golden_dt, count_segment=True)
            sp.set(bytes=len(blob))
        self._h_golden.observe(golden_dt)
        with self._mu:
            st = self._streams.setdefault(stream, _StreamState())
            st.segments += 1
            st.video_seconds += self.spec.segment_seconds
            st.golden_encode_s += golden_dt
            st.max_golden_lag_s = max(st.max_golden_lag_s, golden_dt)
            self._video_s_arrived += self.spec.segment_seconds
            self._spent_s += golden_dt
            if self.budget_x is not None:
                self._credit += (self.budget_x * self.spec.segment_seconds
                                 - golden_dt)
            for sf_id in self.store.formats:
                if sf_id == self.golden_id:
                    continue
                task = TranscodeTask(
                    self._sort_key(sf_id, seg, stream), stream, seg, sf_id,
                    est_s=self._estimate(sf_id, golden_dt))
                bisect.insort(self._queue, task)
            if self._index is not None:
                self._sketch_tasks_locked(stream, seg, golden_dt)
            self._shed_over_cap_locked()
            self._work.notify_all()
        for cb in self._on_ingest:
            cb(stream, seg)
        return golden_dt

    def _sort_key(self, sf_id: str, seg: int, stream: str) -> tuple:
        # most expensive to recover first; FIFO within a format's cost tier
        return (-self._rank.get(sf_id, 0.0), self.fallback.depth(sf_id),
                seg, stream, sf_id)

    def _estimate(self, sf_id: str, golden_dt: float) -> float:
        """Expected encode seconds for one segment of ``sf_id``: observed
        EMA once available, else the golden cost scaled by raw-byte ratio."""
        got = self._est_s.get(sf_id)
        if got is not None:
            return got
        g = self.store.formats[self.golden_id].fidelity
        f = self.store.formats[sf_id].fidelity
        ratio = (self.spec.raw_bytes_per_segment(f)
                 / max(1, self.spec.raw_bytes_per_segment(g)))
        return max(1e-4, golden_dt * ratio)

    def _estimate_sketch(self, op_name: str, golden_dt: float) -> float:
        """Expected seconds for one sketch build: observed EMA once
        available, else a fraction of the golden encode (cascade-head ops
        decode a cheap format and run the cheapest operators)."""
        got = self._est_s.get("sketch:" + op_name)
        if got is not None:
            return got
        return max(1e-4, 0.2 * golden_dt)

    def _shed_over_cap_locked(self):
        if self.shed_debt_s is None:
            return
        while self._queue and self._debt_locked() > self.shed_debt_s:
            task = self._queue.pop()  # tail = cheapest to recover
            self._shed.append(task)
            self.shed_total += 1

    # -- background transcode -------------------------------------------------
    def _debt_locked(self) -> float:
        return sum(t.est_s for t in self._queue)

    def debt_seconds(self) -> float:
        """Estimated encode-seconds of queued (unshed) transcode work."""
        with self._mu:
            return self._debt_locked()

    def pending(self) -> int:
        with self._mu:
            return len(self._queue)

    def set_budget_x(self, budget_x: float | None):
        """Raise/lower the transcode budget through the lease (None =
        unbounded)."""
        self.lease.grant(budget_x)

    def _regrant(self, budget_x: float | None):
        """Lease-owner rate change.  A raise re-credits the bucket
        retroactively — credit becomes at least ``new_rate ×
        video-seconds-arrived − encode-seconds-spent`` — and wakes the
        worker, so accumulated debt the new budget can afford starts
        draining immediately rather than waiting for new arrivals."""
        with self._mu:
            cur = self.lease.budget_x
            raised = budget_x is None or (cur is not None
                                          and budget_x > cur)
            self.lease.budget_x = budget_x
            if raised and budget_x is not None:
                self._credit = max(
                    self._credit,
                    budget_x * self._video_s_arrived - self._spent_s)
            self._work.notify_all()

    # -- materialize-on-read --------------------------------------------------
    def _charge_write_back(self, store, stream: str, seg: int, sf_id: str,
                           blob: bytes, dt: float) -> bool:
        """Persist a fallback-chain reconstruction, charged to this budget.

        The transcode cost ``dt`` was already paid serving the read; the
        charge debits the token bucket so the materialization is accounted
        exactly as if the background worker had run the queued task (which
        now becomes a no-op via its ``has_segment`` check).  Skipped —
        returning False — when the bucket is out of credit: under budget
        pressure hot segments keep paying the chain walk rather than
        sneaking materialization past the budget.  Never raises: the
        write-back is an optional optimization riding on a read that is
        already served (the blob is in hand and memoized), so a persist
        failure is recorded, not propagated — and the bucket is only
        debited after the persist actually succeeded."""
        with self._mu:
            if self.budget_x is not None and self._credit <= 0:
                self.write_backs_skipped += 1
                return False
        try:
            store.put_segment(stream, seg, sf_id, blob, encode_s=dt)
        except Exception as e:  # noqa: BLE001
            with self._mu:
                self.task_errors += 1
                self.last_task_error = f"write-back: {type(e).__name__}: {e}"
            return False
        with self._mu:
            if self.budget_x is not None:
                self._credit -= dt
            self._spent_s += dt
            self.write_backs += 1
            self.write_back_s += dt
        return True

    def adopt_missing(self, streams: list[str] | None = None) -> int:
        """Re-enqueue transcode tasks for stored golden segments whose
        non-golden formats are not materialized.

        The queue is in-memory: a process crash after golden was acked
        (durable) but before background materialization loses the pending
        tasks, which would otherwise leave those formats on the fallback
        chain forever *and* invisible to debt accounting.  A restarted
        owner (the cluster's ShardWorker) calls this on startup so the
        backlog is visible and drainable again.  Estimates seed from the
        EMA when available, else the raw-byte-ratio model against a
        nominal golden cost; they converge after the first real task.

        The arrived-footage ledger is restored from the durable store
        alongside: the token bucket accrues credit per *arrived*
        video-second, so a restart that zeroed ``_video_s_arrived`` would
        make every future finite grant compute a retroactive credit of
        zero and the adopted backlog could never drain under budget.  The
        re-adopted footage genuinely needs its transcodes redone, so
        granting budget for it again is the honest accounting.

        Returns the number of tasks enqueued."""
        if streams is None:
            streams = sorted({k.split(":", 1)[0]
                              for k in self.store.backend.keys()})
        with self._mu:
            have = {(t.stream, t.seg, t.kind, t.op or t.sf_id)
                    for t in self._queue + self._shed}
            golden_dt = self._est_s.get(self.golden_id,
                                        0.05 * self.spec.segment_seconds)
            n = 0
            adopted_video_s = 0.0
            for stream in streams:
                golden_segs = self.store.available_segments(stream,
                                                            self.golden_id)
                st = self._streams.setdefault(stream, _StreamState())
                known = st.segments
                st.segments = max(known, len(golden_segs))
                st.video_seconds = st.segments * self.spec.segment_seconds
                adopted_video_s += (st.segments - known) \
                    * self.spec.segment_seconds
                for seg in golden_segs:
                    for sf_id in self.store.formats:
                        if sf_id == self.golden_id:
                            continue
                        if (stream, seg, "transcode", sf_id) in have:
                            continue
                        if self.store.has_segment(stream, seg, sf_id):
                            continue
                        task = TranscodeTask(
                            self._sort_key(sf_id, seg, stream), stream,
                            seg, sf_id,
                            est_s=self._estimate(sf_id, golden_dt))
                        bisect.insort(self._queue, task)
                        n += 1
                    if self._index is None:
                        continue
                    # index backfill rides the same queue: sketches for
                    # pre-index (or crash-lost unacked) footage
                    for op_name in self._index.ops:
                        if (stream, seg, "sketch", op_name) in have:
                            continue
                        if self._index.has_sketch(stream, seg, op_name):
                            continue
                        src_sf = self._index.specs[op_name][2]
                        task = TranscodeTask(
                            self._sort_key(src_sf, seg, stream) + (1,),
                            stream, seg, src_sf,
                            est_s=self._estimate_sketch(op_name, golden_dt),
                            kind="sketch", op=op_name)
                        bisect.insort(self._queue, task)
                        n += 1
            self._video_s_arrived += adopted_video_s
            if self.budget_x is not None:
                self._credit += self.budget_x * adopted_video_s
            self._shed_over_cap_locked()
            if n:
                self._work.notify_all()
            return n

    def requeue_shed(self) -> int:
        """Put shed tasks back on the queue (after a budget raise)."""
        with self._mu:
            n = len(self._shed)
            for task in self._shed:
                bisect.insort(self._queue, task)
            self._shed.clear()
            self._work.notify_all()
            return n

    def _pop_runnable_locked(self) -> TranscodeTask | None:
        if not self._queue:
            return None
        if self.budget_x is not None and self._credit <= 0:
            return None
        return self._queue.pop(0)

    def _run_sketch(self, task: TranscodeTask):
        """Build one sketch (budget-charged like a transcode).  The build
        decodes over the fallback chain when its source format is still
        queued, so sketch order vs transcode order never matters for
        correctness — reconstruction is bit-exact."""
        if self._index is None or self._index.has_sketch(
                task.stream, task.seg, task.op):
            return  # detached, or raced with another builder
        dt = self._index.build(self.store, task.stream, task.seg, task.op)
        with self._mu:
            self.sketches += 1
            self.sketch_s += dt
            self._spent_s += dt
            if self.budget_x is not None:
                self._credit -= dt
            key = "sketch:" + task.op
            prev = self._est_s.get(key)
            self._est_s[key] = (dt if prev is None else
                                (1 - self._ema) * prev + self._ema * dt)

    def _run_task(self, task: TranscodeTask):
        if task.kind == "sketch":
            self._run_sketch(task)
            return
        if self.store.has_segment(task.stream, task.seg, task.sf_id):
            return  # raced with another materializer
        # bill only this level's decode+encode: an unmaterialized parent
        # fetched inside the call charges itself (its own queued task, or
        # a materialize-on-read write-back) — an inclusive timer would
        # debit the bucket twice for the same ancestor transcode
        with _span("ingest.transcode", stream=task.stream, seg=task.seg,
                   sf=task.sf_id):
            blob, dt = self.fallback.transcode_from_parent_timed(
                self.store, task.stream, task.seg, task.sf_id)
        self._h_transcode.observe(dt)
        # a concurrent materialize-on-read may have landed (and charged)
        # this exact blob during our slow transcode; overwriting would
        # double-bill the bucket and orphan the bytes it just wrote
        if self.store.has_segment(task.stream, task.seg, task.sf_id):
            return
        self.store.put_segment(task.stream, task.seg, task.sf_id, blob,
                               encode_s=dt)
        with self._mu:
            self.transcodes += 1
            self.transcode_s += dt
            self._spent_s += dt
            if self.budget_x is not None:
                self._credit -= dt
            prev = self._est_s.get(task.sf_id)
            self._est_s[task.sf_id] = (dt if prev is None else
                                       (1 - self._ema) * prev + self._ema * dt)

    def _run_task_guarded(self, task: TranscodeTask, reraise: bool):
        """Run one popped task; on failure park it with the shed set (so
        ``requeue_shed`` can retry it — a popped task must never simply
        vanish from the accounting) and optionally re-raise."""
        try:
            self._run_task(task)
        except Exception as e:  # noqa: BLE001
            with self._mu:
                self.task_errors += 1
                self.last_task_error = f"{type(e).__name__}: {e}"
                self._shed.append(task)
            if reraise:
                raise

    def pump(self, max_tasks: int | None = None) -> int:
        """Synchronously run queued transcodes while budget credit lasts
        (deterministic alternative to the worker thread).  Returns the
        number of tasks completed."""
        done = 0
        while max_tasks is None or done < max_tasks:
            with self._mu:
                task = self._pop_runnable_locked()
            if task is None:
                break
            self._run_task_guarded(task, reraise=True)
            done += 1
        return done

    def drain(self, include_shed: bool = True) -> int:
        """Run the whole queue to empty, ignoring budget credit (the
        'budget raised' path).  Returns tasks completed."""
        if include_shed:
            self.requeue_shed()
        done = 0
        while True:
            with self._mu:
                if not self._queue:
                    return done
                task = self._queue.pop(0)
            self._run_task_guarded(task, reraise=True)
            done += 1

    # -- worker thread --------------------------------------------------------
    def start(self):
        """Run background transcodes on a worker thread (budget-gated)."""
        if self._worker is not None:
            return
        self._stop.clear()
        self._worker = threading.Thread(target=self._worker_loop,
                                        name="vstore-ingest", daemon=True)
        self._worker.start()

    def stop(self, drain: bool = False):
        """Stop the worker; ``drain=True`` first empties the queue
        (ignoring budget)."""
        if drain:
            self.drain()
        self._stop.set()
        with self._mu:
            self._work.notify_all()
        if self._worker is not None:
            self._worker.join()
            self._worker = None

    def _worker_loop(self):
        while not self._stop.is_set():
            with self._mu:
                task = self._pop_runnable_locked()
                if task is None:
                    self._work.wait(timeout=0.05)
                    continue
            self._run_task_guarded(task, reraise=False)  # keep worker alive

    # -- stats ----------------------------------------------------------------
    def stats(self) -> dict:
        with self._mu:
            streams = {}
            for name, st in self._streams.items():
                streams[name] = {
                    "segments": st.segments,
                    "video_seconds": st.video_seconds,
                    "golden_encode_s": st.golden_encode_s,
                    "golden_x": st.video_seconds
                    / max(st.golden_encode_s, 1e-9),
                    "max_golden_lag_s": st.max_golden_lag_s,
                }
            per_format: dict[str, dict] = {}
            for sid in self.store.formats:
                if sid == self.golden_id:
                    continue
                per_format[sid] = {"pending": 0, "est_debt_s": 0.0,
                                   "shed": 0,
                                   "recovery_cost": self._rank.get(sid, 0.0)}
            sketch_pending = 0
            for t in self._queue:
                if t.kind != "transcode":  # sketches tracked separately
                    sketch_pending += 1
                    continue
                per_format[t.sf_id]["pending"] += 1
                per_format[t.sf_id]["est_debt_s"] += t.est_s
            for t in self._shed:
                if t.kind != "transcode":
                    continue
                per_format[t.sf_id]["shed"] += 1
            total_video = sum(st.video_seconds
                              for st in self._streams.values())
            out = {
                "streams": streams,
                "formats": per_format,
                "debt_s": self._debt_locked(),
                "pending": len(self._queue),
                "shed": len(self._shed),
                "shed_total": self.shed_total,
                "credit_s": self._credit,
                "budget_x": self.budget_x,
                "transcodes": self.transcodes,
                "transcode_s": self.transcode_s,
                "task_errors": self.task_errors,
                "last_task_error": self.last_task_error,
                "write_backs": self.write_backs,
                "write_back_s": self.write_back_s,
                "write_backs_skipped": self.write_backs_skipped,
                "video_seconds": total_video,
                "sketches": self.sketches,
                "sketch_s": self.sketch_s,
                "sketch_pending": sketch_pending,
            }
        # the histogram and fallback sub-snapshots take their owners'
        # locks — never acquire those while holding _mu (lock-order
        # discipline: component locks are leaves, see repro.analysis)
        out["golden_hist"] = self._h_golden.snapshot()
        out["transcode_hist"] = self._h_transcode.snapshot()
        out["fallback"] = self.fallback.stats()
        return out
