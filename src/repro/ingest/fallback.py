"""Fallback-chain retrieval: serve a missing storage format from its
nearest richer ancestor, bit-exactly.

Storage formats form a *richer-than* tree rooted at the golden format (the
same tree the erosion planner's chain math assumes, ``repro.core.erosion``).
The live ingest path writes only golden synchronously; every other format is
materialized later by transcoding **from its tree parent's blob** — a
deterministic function of the parent bytes (``VideoStore.encode_format``).
Because materialization and read-time reconstruction run the identical
function on the identical parent bytes, a query served over the fallback
chain sees *the same blob bytes* the materialized format would hold: queries
issued mid-ingest (or after erosion reclaimed a format's segments) return
items identical to a fully-materialized store, not merely accuracy-preserving
approximations.

Scope of that bit-exactness: it holds for stores whose non-golden formats
were materialized by this golden-derived path (the ``IngestScheduler``).  A
store populated by the blocking ``VideoStore.ingest_segment`` encodes every
format from the original ingest frames, and the golden roundtrip is lossy —
reconstruction of an *eroded* format there is accuracy-preserving (richer
ancestor, R1) but not byte-identical to the deleted blob.

``FallbackChain`` is installed on a ``VideoStore`` via ``set_fallback``; the
store's ``_blob`` routes every decode path (direct retrieve, retrieve_many,
the serving planner's ``decode_for``) through ``reconstruct`` on a miss.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict

from ..core.knobs import IngestSpec, StorageFormat
from ..obs.trace import span as _span


def build_parents(formats: dict[str, StorageFormat],
                  golden_id: str | None = None
                  ) -> tuple[str, dict[str, str]]:
    """(golden_id, parent map) for a storage-format set.

    Parent = nearest richer ancestor: among formats whose fidelity is
    richer-than-or-equal, the one with minimal total fidelity rank
    (tie-broken on sf id) — the same nearest-ancestor rule
    ``repro.core.erosion._Chains`` builds its fallback chains with.  The
    golden root must be richer-eq every other format (it is the knob-wise
    join by construction)."""
    if golden_id is None:
        golden_id = "sf_g" if "sf_g" in formats else None
    if golden_id is None:
        roots = [sid for sid, sf in formats.items()
                 if all(sf.fidelity.richer_eq(o.fidelity)
                        for o in formats.values())]
        if not roots:
            raise ValueError("no golden root: no format is richer-eq all "
                             "others")
        golden_id = sorted(roots)[0]
    root_f = formats[golden_id].fidelity
    ids = sorted(formats)
    parent: dict[str, str] = {}
    for sid, sf in formats.items():
        if sid == golden_id:
            continue
        if not root_f.richer_eq(sf.fidelity):
            raise ValueError(f"golden {golden_id} is not richer-eq {sid}")
        # strictly-richer candidates keep the tree acyclic (richness is a
        # partial order); a format sharing golden's fidelity parents golden
        cands = [oid for oid in ids
                 if oid != sid and oid != golden_id
                 and formats[oid].fidelity.richer(sf.fidelity)]
        parent[sid] = min(
            cands, key=lambda oid: (sum(formats[oid].fidelity.rank()), oid),
            default=golden_id)
    return golden_id, parent


def chain_of(sf_id: str, golden_id: str, parents: dict[str, str]
             ) -> list[str]:
    """The fallback chain sf_id -> ... -> golden (inclusive)."""
    chain = [sf_id]
    while chain[-1] != golden_id:
        chain.append(parents[chain[-1]])
    return chain


class FallbackChain:
    """Reconstructs missing blobs from tree ancestors, with a small memo.

    The memo caches reconstructed blob bytes keyed (stream, seg, sf_id) so
    a multi-stage cascade that reads the same unmaterialized format several
    times pays the transcode once.  Entries stay valid forever: a later
    materialization of the same format writes byte-identical content (same
    deterministic transcode from the same parent bytes)."""

    def __init__(self, formats: dict[str, StorageFormat],
                 spec: IngestSpec | None = None,
                 golden_id: str | None = None, memo_blobs: int = 32):
        self.formats = dict(formats)
        self.spec = spec
        self.golden_id, self.parents = build_parents(formats, golden_id)
        self.memo_blobs = memo_blobs
        self._memo: OrderedDict[tuple, bytes] = OrderedDict()  # guarded-by: _lock
        self._lock = threading.Lock()
        self._inflight: dict[tuple, threading.Event] = {}  # guarded-by: _lock
        self._write_back = None        # materialize-on-read hook
        self.reconstructions = 0       # guarded-by: _lock (transcodes run)
        self.fallback_reads = 0        # guarded-by: _lock (chain reads)
        self.per_format: dict[str, int] = {}  # guarded-by: _lock

    def enable_write_back(self, charge) -> None:
        """Materialize-on-read: after a reconstruction, call
        ``charge(store, stream, seg, sf_id, blob, transcode_seconds)`` —
        the ingest scheduler's budget-charging writer — so hot
        unmaterialized segments are persisted (when the budget allows)
        instead of paying the chain walk on every read.  The written
        bytes are the reconstruction itself, i.e. exactly what deferred
        materialization would store.  Pass ``None`` to disable."""
        self._write_back = charge

    def depth(self, sf_id: str) -> int:
        return len(chain_of(sf_id, self.golden_id, self.parents)) - 1

    def invalidate(self, stream: str, seg: int) -> None:
        """Drop memoized reconstructions of one segment — required when
        the segment is *re-ingested* with different content (the memo's
        stay-valid-forever rule assumes the golden source is immutable)."""
        with self._lock:
            for key in [k for k in self._memo
                        if k[0] == stream and k[1] == seg]:
                del self._memo[key]

    # -- reconstruction ------------------------------------------------------
    def can_reconstruct(self, store, stream: str, seg: int,
                        sf_id: str) -> bool:
        """True when some ancestor on the chain is materialized."""
        for anc in chain_of(sf_id, self.golden_id, self.parents):
            if store.has_segment(stream, seg, anc):
                return True
        return False

    def reconstruct(self, store, stream: str, seg: int, sf_id: str) -> bytes:
        """The exact blob bytes format ``sf_id`` would hold for this
        segment, derived from the nearest materialized ancestor.  Raises
        KeyError when no ancestor (not even golden) holds the segment."""
        with self._lock:
            self.fallback_reads += 1
            self.per_format[sf_id] = self.per_format.get(sf_id, 0) + 1
        with _span("fallback.reconstruct", sf=sf_id, seg=seg,
                   depth=self.depth(sf_id)) as sp:
            blob = self._blob_of(store, stream, seg, sf_id)
            sp.set(bytes=len(blob))
            return blob

    def _blob_of(self, store, stream: str, seg: int, sf_id: str) -> bytes:
        from ..videostore.video_store import _sf_key
        key = (stream, seg, sf_id)
        while True:
            try:  # physical copy wins; KeyError = missing (or eroded)
                return store.backend.get(_sf_key(sf_id, stream, seg))
            except KeyError:
                pass
            # single-flight: concurrent misses on one blob elect a leader
            # to run the (expensive, recursive) transcode; followers wait
            # and re-check the memo instead of duplicating it
            with self._lock:
                memo = self._memo.get(key)
                if memo is not None:
                    self._memo.move_to_end(key)
                    return memo
                leader_ev = self._inflight.get(key)
                if leader_ev is None:
                    self._inflight[key] = threading.Event()
            if leader_ev is not None:
                leader_ev.wait()
                continue  # re-check memo (or physical) on wakeup
            try:
                if sf_id == self.golden_id:
                    raise KeyError(
                        f"segment {stream}:{seg} missing everywhere "
                        f"(golden {sf_id} not ingested)")
                blob, dt = self.transcode_from_parent_timed(
                    store, stream, seg, sf_id)
                with self._lock:
                    self.reconstructions += 1
                    self._memo[key] = blob
                    while len(self._memo) > self.memo_blobs:
                        self._memo.popitem(last=False)
                if self._write_back is not None:
                    self._write_back(store, stream, seg, sf_id, blob, dt)
                return blob
            finally:
                with self._lock:
                    self._inflight.pop(key).set()

    def transcode_from_parent(self, store, stream: str, seg: int,
                              sf_id: str) -> bytes:
        """Materialize ``sf_id``'s blob from its tree parent: dense-decode
        the parent (recursively reconstructed if needed), convert fidelity,
        encode with the format's own coding.  The single transcode function
        the background scheduler also runs — so read-time reconstruction
        and deferred materialization are byte-identical by construction."""
        return self.transcode_from_parent_timed(store, stream, seg, sf_id)[0]

    def transcode_from_parent_timed(self, store, stream: str, seg: int,
                         sf_id: str) -> tuple[bytes, float]:
        """``(blob, seconds)`` where the timer covers only *this level's*
        decode+encode — the recursive parent fetch is excluded, because a
        reconstructed parent charges its own write-back; including it here
        would bill the bucket twice for the same ancestor transcode."""
        from ..codec import segment as codec
        parent = self.parents[sf_id]
        parent_blob = self._blob_of(store, stream, seg, parent)
        t0 = time.perf_counter()
        parent_frames = codec.decode_segment(parent_blob)
        blob = store.encode_format(parent_frames,
                                   self.formats[parent].fidelity,
                                   self.formats[sf_id])
        return blob, time.perf_counter() - t0

    def stats(self) -> dict:
        with self._lock:
            return {
                "fallback_reads": self.fallback_reads,
                "reconstructions": self.reconstructions,
                "per_format": dict(self.per_format),
                "memo_blobs": len(self._memo),
            }


class ByteRatioProfiler:
    """Deterministic profiler stand-in for chain math when no measured
    profiler exists (e.g. the hand-built demo config): models retrieval
    speed from decoded bytes — ``segment_seconds / (bytes_touched / rate)``
    with a fixed penalty for entropy-coded formats.  Only *relative* speeds
    matter to ``repro.core.erosion`` ranking; the rate is pitched low
    enough that retrieval (not the consumer's own speed) is usually the
    binding term, as in the paper's decode-bound regime — otherwise every
    format would rank as free to erode/shed."""

    def __init__(self, spec: IngestSpec, bytes_per_second: float = 5e6,
                 coded_penalty: float = 4.0):
        self.spec = spec
        self.bytes_per_second = bytes_per_second
        self.coded_penalty = coded_penalty

    def retrieval_speed(self, sf: StorageFormat, cf) -> float:
        n_cf, _, _ = self.spec.resolve(cf)
        _, h, w = self.spec.resolve(sf.fidelity)
        work = n_cf * h * w
        if not sf.coding.bypass:
            work *= self.coded_penalty
        return self.spec.segment_seconds / (work / self.bytes_per_second)
