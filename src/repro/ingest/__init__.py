"""Live ingestion: the third leg (ingest) of the paper's
ingest -> store -> retrieve -> consume path.

* ``StreamSource`` / ``interleave`` — deterministic simulated cameras;
* ``IngestScheduler`` — golden written synchronously (durability), all
  other storage formats materialized by a prioritized background transcode
  queue under a transcode-cycle budget, shedding the cheapest-to-recover
  formats first (ranked by the erosion fallback-chain math);
* ``FallbackChain`` — bit-exact retrieval of not-yet-materialized (or
  eroded) formats from the nearest richer ancestor on the format tree;
* ``ErosionExecutor`` — applies ``ErosionPlan`` fractions to the live
  store on an age schedule and triggers compaction to reclaim bytes.
"""

from .erosion_exec import ErosionExecutor, ErosionReport
from .fallback import (ByteRatioProfiler, FallbackChain, build_parents,
                       chain_of)
from .scheduler import (BudgetLease, IngestScheduler, TranscodeTask,
                        recovery_rank_for)
from .source import Arrival, StreamSource, interleave

__all__ = [
    "Arrival", "BudgetLease", "ByteRatioProfiler", "ErosionExecutor",
    "ErosionReport", "FallbackChain", "IngestScheduler", "StreamSource",
    "TranscodeTask", "build_parents", "chain_of", "interleave",
    "recovery_rank_for",
]
