"""ErosionExecutor: drives an ``ErosionPlan`` against the live store.

The planner (``repro.core.erosion``) decides per-age erosion *fractions*;
until now nothing ever applied them.  The executor keeps an age ledger —
segments are registered into per-(stream, day) cohorts as golden ingest
admits them — and on every ``advance()`` of the logical day clock erodes
each cohort up to its age's cumulative target: for cohort age ``a`` and
plan node ``i``, ``round(fractions[a-1][i] × cohort_size)`` segments of
that format must be gone.  Victims are chosen by ``VideoStore.erode``'s
stratified deterministic spread, deletions are counted in bytes and chunk
spans (blob v2), and the backing ``SegmentStore``'s auto-compaction (or an
explicit ``compact()``) turns the dead index entries into reclaimed disk
bytes.  Golden is never eroded, and queries keep answering across erosion:
reads of an eroded format fall back to the nearest richer ancestor
(``repro.ingest.fallback``) bit-exactly.
"""

from __future__ import annotations

import bisect
import dataclasses
import threading

from ..core.erosion import ErosionPlan
from ..obs.trace import span as _span


@dataclasses.dataclass
class ErosionReport:
    """One ``advance()``'s accounting."""
    day: int
    segments: int = 0
    bytes: int = 0
    chunks: int = 0
    chunk_bytes: int = 0
    per_format: dict = dataclasses.field(default_factory=dict)
    dead_bytes_after: int = 0
    compactions: int = 0


class ErosionExecutor:
    def __init__(self, store, plan: ErosionPlan, node_ids: list[str],
                 *, golden_id: str = "sf_g", seed: int = 0,
                 compact: bool = True):
        """``node_ids`` aligns the plan's node indices with the store's
        sf ids (``DerivedConfig.node_id``).  ``compact=True`` forces a
        compaction after any sweep that deleted segments (auto-compaction
        may have already run; forcing makes reclaim deterministic)."""
        self.store = store
        self.plan = plan
        self.node_ids = list(node_ids)
        self.golden_id = golden_id
        self.seed = seed
        self.compact = compact
        # note_ingested arrives on ingest threads (IngestScheduler's
        # on_ingest callbacks) concurrently with advance()/apply() on
        # whoever drives the day clock (worker op loop, tests): the whole
        # age ledger is one lock domain
        self._mu = threading.Lock()
        self.day = 0  # guarded-by: _mu
        # (stream, ingest_day) -> [segs]; ages derive from the day clock
        self._cohorts: dict[tuple[str, int], list[int]] = {}  # guarded-by: _mu
        # (stream, ingest_day, sf_id) -> segments already eroded
        self._eroded: dict[tuple[str, int, str], int] = {}  # guarded-by: _mu
        self.total = ErosionReport(day=0)  # guarded-by: _mu

    # -- age ledger -----------------------------------------------------------
    def note_ingested(self, stream: str, seg: int):
        """Place a segment in today's cohort (wire to
        ``IngestScheduler.on_ingest``, or call directly)."""
        with self._mu:
            self._cohorts.setdefault((stream, self.day), []).append(seg)

    def register_existing(self, streams: list[str], day: int | None = None):
        """Adopt already-stored golden segments into a cohort (e.g. a store
        ingested before the executor attached)."""
        for stream in streams:
            segs = self.store.available_segments(stream, self.golden_id)
            if segs:
                with self._mu:
                    d = self.day if day is None else day
                    self._cohorts.setdefault((stream, d), []).extend(segs)

    # -- execution ------------------------------------------------------------
    def advance(self, days: int = 1) -> ErosionReport:
        """Move the day clock and erode every cohort to its age target."""
        with self._mu:
            self.day += days
            day = self.day
        with _span("erosion.advance", day=day) as sp:
            rep = self.apply()
            sp.set(segments=rep.segments, bytes=rep.bytes)
            return rep

    def apply(self) -> ErosionReport:
        # snapshot the ledger under the lock, erode outside it: the
        # store calls (erode/compact) are far too slow to hold _mu
        # across, and note_ingested must stay wait-free for the ingest
        # hot path.  Segments ingested after the snapshot simply join
        # the next apply() — same semantics as arriving a moment later.
        with self._mu:
            day = self.day
            cohorts = sorted((key, list(segs))
                             for key, segs in self._cohorts.items())
            eroded = dict(self._eroded)
        rep = ErosionReport(day=day)
        erode_deltas: dict[tuple[str, int, str], int] = {}
        before_compactions = self.store.backend.compactions
        for (stream, born), segs in cohorts:
            age = day - born
            if age < 1 or not segs:
                continue
            # the plan's fractions are cumulative per planned age; apply
            # the latest planned age <= this cohort's age (sparse age
            # schedules allowed), saturating at the plan's last entry
            ai = bisect.bisect_right(self.plan.ages, age) - 1
            if ai < 0:
                continue  # younger than the first planned age
            frac = self.plan.fractions[ai]
            for idx, sf_id in enumerate(self.node_ids):
                if sf_id == self.golden_id:
                    continue
                target = int(round(frac.get(idx, 0.0) * len(segs)))
                done_key = (stream, born, sf_id)
                done = eroded.get(done_key, 0)
                delta = target - done
                if delta <= 0:
                    continue
                res = self.store.erode(
                    stream, sf_id, segments=segs, count=delta,
                    seed=self.seed + day + idx)
                erode_deltas[done_key] = \
                    erode_deltas.get(done_key, 0) + res.segments
                rep.segments += res.segments
                rep.bytes += res.bytes
                rep.chunks += res.chunks
                rep.chunk_bytes += res.chunk_bytes
                slot = rep.per_format.setdefault(
                    sf_id, {"segments": 0, "bytes": 0, "chunks": 0,
                            "chunk_bytes": 0})
                slot["segments"] += res.segments
                slot["bytes"] += res.bytes
                slot["chunks"] += res.chunks
                slot["chunk_bytes"] += res.chunk_bytes
        if self.compact and rep.segments and self.store.backend.dead_bytes:
            self.store.backend.compact()
        rep.compactions = self.store.backend.compactions - before_compactions
        rep.dead_bytes_after = self.store.backend.dead_bytes
        with self._mu:
            for done_key, n in erode_deltas.items():
                self._eroded[done_key] = self._eroded.get(done_key, 0) + n
            self.total.segments += rep.segments
            self.total.bytes += rep.bytes
            self.total.chunks += rep.chunks
            self.total.chunk_bytes += rep.chunk_bytes
        return rep

    def stats(self) -> dict:
        with self._mu:
            return {
                "day": self.day,
                "cohorts": len(self._cohorts),
                "eroded_segments": self.total.segments,
                "eroded_bytes": self.total.bytes,
                "eroded_chunks": self.total.chunks,
                "eroded_chunk_bytes": self.total.chunk_bytes,
            }
