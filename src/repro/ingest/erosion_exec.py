"""ErosionExecutor: drives an ``ErosionPlan`` against the live store.

The planner (``repro.core.erosion``) decides per-age erosion *fractions*;
until now nothing ever applied them.  The executor keeps an age ledger —
segments are registered into per-(stream, day) cohorts as golden ingest
admits them — and on every ``advance()`` of the logical day clock erodes
each cohort up to its age's cumulative target: for cohort age ``a`` and
plan node ``i``, ``round(fractions[a-1][i] × cohort_size)`` segments of
that format must be gone.  Victims are chosen by ``VideoStore.erode``'s
stratified deterministic spread, deletions are counted in bytes and chunk
spans (blob v2), and the backing ``SegmentStore``'s auto-compaction (or an
explicit ``compact()``) turns the dead index entries into reclaimed disk
bytes.  Golden is never eroded, and queries keep answering across erosion:
reads of an eroded format fall back to the nearest richer ancestor
(``repro.ingest.fallback``) bit-exactly.
"""

from __future__ import annotations

import bisect
import dataclasses

from ..core.erosion import ErosionPlan
from ..obs.trace import span as _span


@dataclasses.dataclass
class ErosionReport:
    """One ``advance()``'s accounting."""
    day: int
    segments: int = 0
    bytes: int = 0
    chunks: int = 0
    chunk_bytes: int = 0
    per_format: dict = dataclasses.field(default_factory=dict)
    dead_bytes_after: int = 0
    compactions: int = 0


class ErosionExecutor:
    def __init__(self, store, plan: ErosionPlan, node_ids: list[str],
                 *, golden_id: str = "sf_g", seed: int = 0,
                 compact: bool = True):
        """``node_ids`` aligns the plan's node indices with the store's
        sf ids (``DerivedConfig.node_id``).  ``compact=True`` forces a
        compaction after any sweep that deleted segments (auto-compaction
        may have already run; forcing makes reclaim deterministic)."""
        self.store = store
        self.plan = plan
        self.node_ids = list(node_ids)
        self.golden_id = golden_id
        self.seed = seed
        self.compact = compact
        self.day = 0
        # (stream, ingest_day) -> [segs]; ages derive from the day clock
        self._cohorts: dict[tuple[str, int], list[int]] = {}
        # (stream, ingest_day, sf_id) -> segments already eroded
        self._eroded: dict[tuple[str, int, str], int] = {}
        self.total = ErosionReport(day=0)

    # -- age ledger -----------------------------------------------------------
    def note_ingested(self, stream: str, seg: int):
        """Place a segment in today's cohort (wire to
        ``IngestScheduler.on_ingest``, or call directly)."""
        self._cohorts.setdefault((stream, self.day), []).append(seg)

    def register_existing(self, streams: list[str], day: int | None = None):
        """Adopt already-stored golden segments into a cohort (e.g. a store
        ingested before the executor attached)."""
        d = self.day if day is None else day
        for stream in streams:
            segs = self.store.available_segments(stream, self.golden_id)
            if segs:
                self._cohorts.setdefault((stream, d), []).extend(segs)

    # -- execution ------------------------------------------------------------
    def advance(self, days: int = 1) -> ErosionReport:
        """Move the day clock and erode every cohort to its age target."""
        self.day += days
        with _span("erosion.advance", day=self.day) as sp:
            rep = self.apply()
            sp.set(segments=rep.segments, bytes=rep.bytes)
            return rep

    def apply(self) -> ErosionReport:
        rep = ErosionReport(day=self.day)
        before_compactions = self.store.backend.compactions
        for (stream, born), segs in sorted(self._cohorts.items()):
            age = self.day - born
            if age < 1 or not segs:
                continue
            # the plan's fractions are cumulative per planned age; apply
            # the latest planned age <= this cohort's age (sparse age
            # schedules allowed), saturating at the plan's last entry
            ai = bisect.bisect_right(self.plan.ages, age) - 1
            if ai < 0:
                continue  # younger than the first planned age
            frac = self.plan.fractions[ai]
            for idx, sf_id in enumerate(self.node_ids):
                if sf_id == self.golden_id:
                    continue
                target = int(round(frac.get(idx, 0.0) * len(segs)))
                done_key = (stream, born, sf_id)
                done = self._eroded.get(done_key, 0)
                delta = target - done
                if delta <= 0:
                    continue
                res = self.store.erode(
                    stream, sf_id, segments=segs, count=delta,
                    seed=self.seed + self.day + idx)
                self._eroded[done_key] = done + res.segments
                rep.segments += res.segments
                rep.bytes += res.bytes
                rep.chunks += res.chunks
                rep.chunk_bytes += res.chunk_bytes
                slot = rep.per_format.setdefault(
                    sf_id, {"segments": 0, "bytes": 0, "chunks": 0,
                            "chunk_bytes": 0})
                slot["segments"] += res.segments
                slot["bytes"] += res.bytes
                slot["chunks"] += res.chunks
                slot["chunk_bytes"] += res.chunk_bytes
        if self.compact and rep.segments and self.store.backend.dead_bytes:
            self.store.backend.compact()
        rep.compactions = self.store.backend.compactions - before_compactions
        rep.dead_bytes_after = self.store.backend.dead_bytes
        self.total.segments += rep.segments
        self.total.bytes += rep.bytes
        self.total.chunks += rep.chunks
        self.total.chunk_bytes += rep.chunk_bytes
        return rep

    def stats(self) -> dict:
        return {
            "day": self.day,
            "cohorts": len(self._cohorts),
            "eroded_segments": self.total.segments,
            "eroded_bytes": self.total.bytes,
            "eroded_chunks": self.total.chunks,
            "eroded_chunk_bytes": self.total.chunk_bytes,
        }
