"""Deterministic simulated camera streams feeding the ingest scheduler.

A ``StreamSource`` renders synthetic street-scene segments
(``repro.analytics.scene``) on demand: segment ``i`` of stream ``s`` is a
pure function of ``(s, i, spec)``, so two processes (or an ingest run and
its later verification pass) see bit-identical footage.  ``interleave``
merges several sources into one arrival order — round-robin by segment
index, the way segments of concurrently recording cameras land at the
store — optionally paced against the wall clock at a realtime multiple.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Iterator

import numpy as np

from ..analytics.scene import generate_segment
from ..core.knobs import IngestSpec


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One segment arriving from a camera."""
    stream: str
    seg: int
    frames: np.ndarray          # uint8, ingest fidelity
    t_video: float              # stream time (s) at which the segment ends


class StreamSource:
    """One simulated camera: deterministic segments at the ingest spec."""

    def __init__(self, stream: str, spec: IngestSpec | None = None,
                 n_segments: int | None = None, start_seg: int = 0):
        self.stream = stream
        self.spec = spec or IngestSpec()
        self.n_segments = n_segments
        self.start_seg = start_seg

    def segment(self, seg: int) -> np.ndarray:
        frames, _truth = generate_segment(self.stream, seg, self.spec)
        return frames

    def __iter__(self) -> Iterator[Arrival]:
        seg = self.start_seg
        while self.n_segments is None or seg < self.start_seg + self.n_segments:
            yield Arrival(self.stream, seg, self.segment(seg),
                          (seg - self.start_seg + 1)
                          * self.spec.segment_seconds)
            seg += 1


def interleave(sources: list[StreamSource],
               pace_x: float | None = None) -> Iterator[Arrival]:
    """Round-robin arrival order across cameras: all streams' segment 0,
    then segment 1, ...  With ``pace_x`` set, sleeps so arrivals land at
    ``pace_x`` × realtime (1.0 = live cameras); None runs flat out."""
    iters = [iter(s) for s in sources]
    t0 = time.perf_counter()
    done = [False] * len(iters)
    while not all(done):
        for i, it in enumerate(iters):
            if done[i]:
                continue
            try:
                arr = next(it)
            except StopIteration:
                done[i] = True
                continue
            if pace_x:
                due = t0 + arr.t_video / pace_x
                delay = due - time.perf_counter()
                if delay > 0:
                    time.sleep(delay)
            yield arr
