"""Cross-segment batched consumption: one operator call over many segments'
activated frames.

The cascade executors historically called ``op.detect`` once per segment,
paying a jit dispatch + small-batch penalty for every 8-second segment even
when a late cascade stage has only a handful of activated frames per
segment.  ``BatchedConsumer`` gathers activated frames from many segments,
tags each frame with its segment via a *slot offset* on the position axis,
pads the concatenation to a small static set of batch shapes (so jit caches
stay warm), runs **one** ``op.detect`` per shape bucket, and scatters the
detected items back to per-segment results.

Bit-exactness with the per-segment path is by construction:

* Every operator is a per-frame program on the batch axis — conv, resize,
  per-frame reductions — so a frame's scores do not depend on which other
  frames share the batch.  The one exception is ``Diff``, which scores
  *consecutive-frame pairs*; see the slot-gap invariant below.
* Items carry their time bucket in position 1 (the cascade-wide invariant
  ``next_active = {it[1] ...}`` already relies on).  Offsetting a segment's
  positions by ``slot * stride`` (``stride`` a multiple of the bucket size)
  shifts its buckets by ``slot * buckets_per_slot`` exactly, so scattering
  is a ``divmod`` — no per-item bookkeeping rides through the operator.
* **Slot-gap invariant**: ``stride`` leaves a gap of at least
  ``_MIN_SLOT_GAP`` position ticks between consecutive segments' frames.
  ``Diff`` divides each pair score (``mean|Δ| <= 1.0`` on [0,1] pixels) by
  the positional gap, so a cross-segment pair can never reach its
  threshold — the batched path introduces no boundary detections.  Pairs
  *within* a segment see the same positions, hence the same gaps and the
  same scores, as the per-segment call.
* Shape buckets never split a segment (whole segments are packed greedily),
  so no within-segment ``Diff`` pair is lost to a chunk boundary.  Padding
  frames are zeros placed in a sentinel slot past every real segment; any
  item a padded frame could produce scatters to the sentinel and is
  dropped.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.knobs import IngestSpec
from ..obs.trace import span as _span
from .operators import Diff, Operator

# Minimum positional gap between consecutive slots' frames.  Diff's score
# for a frame pair is mean|Δ| / gap with mean|Δ| <= 1.0, so any gap
# >= ceil(1 / threshold) + 1 keeps every cross-segment pair strictly below
# threshold.  128 also gives headroom if the threshold is retuned downward.
_MIN_SLOT_GAP = max(128, int(np.ceil(1.0 / Diff.threshold)) + 1)

# The static batch shapes operator calls are padded to (plus the exact size
# for the rare batch larger than the top shape).  A small set keeps the
# per-(op, cf) jit cache warm across wildly varying activation counts.
DEFAULT_BATCH_SHAPES = (8, 16, 32, 64, 128, 256)


def derive_shapes(dispatch_overhead_s: float, per_frame_s: float, *,
                  min_shape: int = 8, max_shape: int = 256,
                  max_rungs: int = 10) -> tuple[int, ...]:
    """Static shape ladder sized from *measured* dispatch economics instead
    of the fixed power-of-two ladder.

    The ladder trades two costs.  Padding a batch of ``n`` frames up to the
    next rung ``r*n`` wastes ``n*(r-1)`` frames of operator compute (about
    ``n*(r-1)/2`` in expectation over uniform batch sizes).  Every extra
    rung costs one more jit entry per (op, cf) — a compile on first use
    plus a dispatch whose fixed overhead the profiler measures
    (``Profiler.dispatch_overhead``).  Let ``b = overhead / per_frame`` be
    the *breakeven batch*: the frame count whose compute equals one
    dispatch.  A rung at size ``s`` earns its keep only if the padding it
    saves (~``s*(ratio-1)/2`` frames per call) outweighs that fixed cost,
    so the step ratio leaving rung ``s`` is ``1 + 2*b/s`` — coarse where
    dispatch dominates (small rungs, or expensive dispatch), fine where
    per-frame compute dominates.  Clamped to [1.5, 4] so the ladder never
    degenerates (finer than 1.5 thrashes jit caches; coarser than 4 wastes
    >60% compute on padding), values snapped to multiples of 8 to match
    frame-batch alignment, and capped at ``max_rungs`` entries.

    Deterministic in its inputs; callers thread the result through
    ``run_query(batch_shapes=)`` / ``VStoreServer(batch_shapes=)``.
    """
    if per_frame_s <= 0:
        raise ValueError(f"per_frame_s must be > 0, got {per_frame_s}")
    if not 0 < min_shape <= max_shape:
        raise ValueError(f"bad shape bounds [{min_shape}, {max_shape}]")
    b = max(0.0, dispatch_overhead_s) / per_frame_s
    shapes = [min_shape]
    while shapes[-1] < max_shape and len(shapes) < max_rungs:
        s = shapes[-1]
        ratio = min(4.0, max(1.5, 1.0 + 2.0 * b / s))
        nxt = min(max_shape, max(s + 8, int(round(s * ratio / 8.0)) * 8))
        shapes.append(nxt)
    if shapes[-1] != max_shape:
        shapes[-1] = max_shape  # rung cap hit: top rung must cover max
    return tuple(shapes)


@dataclasses.dataclass
class ConsumeStats:
    """Accounting for one ``consume`` call (accumulated into StageStats)."""
    detect_calls: int = 0
    frames: int = 0          # real activated frames consumed
    batched_frames: int = 0  # rows fed to the operator, padding included

    def add(self, other: "ConsumeStats"):
        self.detect_calls += other.detect_calls
        self.frames += other.frames
        self.batched_frames += other.batched_frames


class BatchedConsumer:
    """Fuses many segments' activated frames into few operator calls.

    One instance per executor run; it is stateless between ``consume``
    calls (the jit caches it keeps warm live on the operators).
    """

    def __init__(self, spec: IngestSpec,
                 shapes: tuple[int, ...] = DEFAULT_BATCH_SHAPES):
        self.spec = spec
        self.shapes = tuple(sorted(shapes))
        bsz = max(1, spec.fps // 2)  # _bucket granularity in position ticks
        need = spec.frames_per_segment + _MIN_SLOT_GAP
        self._stride = -(-need // bsz) * bsz  # bucket-aligned slot stride
        self._spb = self._stride // bsz       # buckets per slot

    def _pad_to(self, n: int) -> int:
        for s in self.shapes:
            if s >= n:
                return s
        return n  # beyond the largest static shape: exact (compiles once)

    def consume(self, op: Operator, cf, batch: list[tuple]
                ) -> tuple[dict[int, set], ConsumeStats]:
        """Run ``op`` once per shape bucket over ``batch`` and scatter.

        ``batch`` is ``[(seg, frames_u8, positions), ...]`` with unique
        segments, each ``positions`` sorted ascending (the activated subset
        of the CF's consumed positions).  Returns ``({seg: items}, stats)``
        where every listed segment has an entry (possibly empty) — exactly
        the segments a per-segment loop would have called ``detect`` for.
        """
        batch = sorted(batch, key=lambda t: t[0])
        per_entry, stats = self.consume_entries(
            op, cf, [(f, p) for _seg, f, p in batch])
        per_seg = {seg: items
                   for (seg, f, _p), items in zip(batch, per_entry)
                   if len(f)}
        return per_seg, stats

    def consume_entries(self, op: Operator, cf, entries: list[tuple]
                        ) -> tuple[list[set], ConsumeStats]:
        """The slot-granular core of ``consume``: entries key on their list
        index, not a segment id, so the *same* segment may appear more than
        once (two queries' different activated subsets of one segment — the
        shared cross-query scheduler's case).  ``entries`` is
        ``[(frames_u8, positions), ...]``; returns a per-entry list of item
        sets in the entry's own (local) position coordinates.

        Bit-exactness carries over unchanged from the module invariants:
        every entry gets its own slot, slot offsets ascend with entry
        order, and consecutive slots keep the ``_MIN_SLOT_GAP`` positional
        gap — a ``Diff`` pair spanning two entries (even two copies of the
        same segment) can never reach threshold."""
        per_entry: list[set] = [set() for _ in entries]
        stats = ConsumeStats()
        todo = [(i, f, p) for i, (f, p) in enumerate(entries) if len(f)]
        if not todo:
            return per_entry, stats

        # Pack whole entries into chunks of at most the largest static
        # shape — a chunk boundary inside an entry would drop that
        # entry's Diff pairs straddling it.
        max_shape = self.shapes[-1]
        chunks: list[list[tuple[int, int, np.ndarray, np.ndarray]]] = []
        cur: list[tuple[int, int, np.ndarray, np.ndarray]] = []
        cur_n = 0
        for slot, (idx, frames, pos) in enumerate(todo):
            if cur and cur_n + len(frames) > max_shape:
                chunks.append(cur)
                cur, cur_n = [], 0
            cur.append((slot, idx, frames, pos))
            cur_n += len(frames)
        chunks.append(cur)

        sentinel = len(todo) * self._stride  # pad slot past every entry
        slot_idx = [idx for idx, _, _ in todo]
        for chunk in chunks:
            x = np.concatenate([f for _, _, f, _ in chunk])
            p = np.concatenate([np.asarray(pos, np.int64) + slot * self._stride
                                for slot, _, _, pos in chunk])
            n = len(x)
            target = self._pad_to(n)
            if target > n:
                x = np.concatenate(
                    [x, np.zeros((target - n,) + x.shape[1:], x.dtype)])
                p = np.concatenate(
                    [p, sentinel + np.arange(target - n, dtype=np.int64)])
            with _span("detect", op=type(op).__name__.lower(), cf=cf.name(),
                       frames=n, shape=target, segments=len(chunk)):
                items = op.detect(x, cf, self.spec, positions=p)
            stats.detect_calls += 1
            stats.frames += n
            stats.batched_frames += target
            for it in items:
                slot, local = divmod(int(it[1]), self._spb)
                if slot >= len(slot_idx):
                    continue  # produced by a padding frame
                per_entry[slot_idx[slot]].add((it[0], local) + tuple(it[2:]))
        return per_entry, stats
