"""The six analytics operators (paper Fig. 2), implemented as JAX tensor
programs over raw frames.

Query A (car detection):      Diff -> S-NN -> NN
Query B (license recognition): Motion -> License -> OCR

Each operator consumes frames at some consumption fidelity and emits a set of
hashable *items* in a fidelity-independent space (time buckets on the original
timeline; positions normalized to the uncropped full view).  Accuracy is the
paper's F1 of an operator's items against its own items on full-fidelity
video.  Consumption *cost* is measured wall time (the profiler times the
jitted compute); image quality affects items only (observation O2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..codec import transform as T
from ..core.knobs import FidelityOption, IngestSpec
from .scene import digit_glyphs

Item = tuple


def _bucket(pos: int, spec: IngestSpec) -> int:
    return int(pos) // max(1, spec.fps // 2)


def _positions(cf: FidelityOption, spec: IngestSpec) -> np.ndarray:
    """Original-timeline positions of the consumed frames."""
    return T.sample_indices(spec.frames_per_segment, cf.sampling)


def _to_norm(y, x, h, w, crop):
    """Map pixel coords in a cropped/resized frame to full-view [0,1]^2."""
    ny = (np.asarray(y) + 0.5) / h * crop + (1 - crop) / 2
    nx = (np.asarray(x) + 0.5) / w * crop + (1 - crop) / 2
    return ny, nx


def _conv(x, kernels, stride=1):
    """NHW x (o, kh, kw) -> (n, o, h', w') valid conv."""
    return jax.lax.conv_general_dilated(
        x[:, None], kernels[:, None].astype(x.dtype),
        window_strides=(stride, stride), padding="VALID")


# ---------------------------------------------------------------------------
# Operator base
# ---------------------------------------------------------------------------

class Operator:
    name: str = "op"

    def detect(self, frames_u8: np.ndarray, cf: FidelityOption,
               spec: IngestSpec, positions: np.ndarray | None = None
               ) -> set[Item]:
        """``positions`` gives the original-timeline index of each
        supplied frame (defaults to the full consumed set implied by
        ``cf.sampling``); cascades pass activated subsets."""
        raise NotImplementedError

    def __repr__(self):
        return f"<op {self.name}>"


# ---------------------------------------------------------------------------
# Diff: frame-difference event detector (cheapest)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=())
def _diff_scores(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(jnp.abs(x[1:] - x[:-1]), axis=(1, 2))


class Diff(Operator):
    name = "diff"
    threshold = 0.012  # mean-abs-diff rate per original-timeline frame

    def detect(self, frames_u8, cf, spec, positions=None):
        x = jnp.asarray(frames_u8, jnp.float32) / 255.0
        if x.shape[0] < 2:
            return set()
        pos = _positions(cf, spec) if positions is None else positions
        gaps = np.maximum(1, np.diff(pos))
        scores = np.asarray(_diff_scores(x)) / gaps  # per-frame change rate
        return {("evt", _bucket(pos[i + 1], spec))
                for i in np.nonzero(scores > self.threshold)[0]}


# ---------------------------------------------------------------------------
# Motion: tiled foreground/texture detector (works single-frame)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("ty", "tx"))
def _motion_tiles(x: jnp.ndarray, ty: int, tx: int) -> jnp.ndarray:
    gy = jnp.abs(x[:, 1:, :-1] - x[:, :-1, :-1])
    gx = jnp.abs(x[:, :-1, 1:] - x[:, :-1, :-1])
    e = gy + gx
    n, h, w = e.shape
    hh, ww = (h // ty) * ty, (w // tx) * tx
    e = e[:, :hh, :ww].reshape(n, ty, hh // ty, tx, ww // tx)
    return e.mean(axis=(2, 4))


class Motion(Operator):
    name = "motion"
    threshold = 0.06  # tile energy in excess of the frame's median tile
    grid = (4, 6)

    def detect(self, frames_u8, cf, spec, positions=None):
        ty, tx = self.grid
        x = jnp.asarray(frames_u8, jnp.float32) / 255.0
        n, h, w = x.shape
        if h < ty or w < tx:
            return set()
        tiles = np.asarray(_motion_tiles(x, ty, tx))
        # excess over the frame's median tile: robust to the uniform noise /
        # smoothing floor (quality knob), sensitive to car-specific edges
        med = np.median(tiles.reshape(n, -1), axis=1)[:, None, None]
        tiles = tiles - med
        pos = _positions(cf, spec) if positions is None else positions
        items = set()
        for t, iy, ix in zip(*np.nonzero(tiles > self.threshold)):
            cy, cx = _to_norm((iy + 0.5) * h / ty - 0.5, (ix + 0.5) * w / tx - 0.5,
                              h, w, cf.crop)
            items.add(("mot", _bucket(pos[t], spec),
                       int(cy * ty), int(cx * tx)))
        return items


# ---------------------------------------------------------------------------
# S-NN: small fixed convnet (shallow AlexNet stand-in)
# ---------------------------------------------------------------------------

@functools.cache
def _snn_kernels() -> np.ndarray:
    k = np.zeros((3, 5, 5), np.float32)
    k[0, 2, :] = 1.0; k[0, 0, :] = -0.5; k[0, 4, :] = -0.5       # horiz edge
    k[1, :, 2] = 1.0; k[1, :, 0] = -0.5; k[1, :, 4] = -0.5       # vert edge
    k[2] = -1 / 25.; k[2, 1:4, 1:4] = (25 - 9) / (25. * 9)       # center-surround
    return k


@functools.partial(jax.jit, static_argnames=("gy", "gx"))
def _snn_scores(x: jnp.ndarray, gy: int, gx: int) -> jnp.ndarray:
    a = jax.nn.relu(_conv(x, jnp.asarray(_snn_kernels())))
    a = (a * a).sum(axis=1)  # energy over channels
    n, h, w = a.shape
    hh, ww = (h // gy) * gy, (w // gx) * gx
    a = a[:, :hh, :ww].reshape(n, gy, hh // gy, gx, ww // gx)
    return a.mean(axis=(2, 4))


class SNN(Operator):
    name = "snn"
    threshold = 0.050
    grid = (3, 5)

    def detect(self, frames_u8, cf, spec, positions=None):
        gy, gx = self.grid
        x = jnp.asarray(frames_u8, jnp.float32) / 255.0
        n, h, w = x.shape
        if h < gy + 5 or w < gx + 5:
            return set()
        cells = np.asarray(_snn_scores(x, gy, gx))
        pos = _positions(cf, spec) if positions is None else positions
        items = set()
        for t, iy, ix in zip(*np.nonzero(cells > self.threshold)):
            cy, cx = _to_norm((iy + 0.5) * h / gy - 0.5, (ix + 0.5) * w / gx - 0.5,
                              h, w, cf.crop)
            items.add(("car", _bucket(pos[t], spec), int(cy * gy), int(cx * gx)))
        return items


# ---------------------------------------------------------------------------
# NN: multi-scale template detector (the expensive deep model stand-in)
# ---------------------------------------------------------------------------

@functools.cache
def _nn_templates() -> np.ndarray:
    """4 zero-mean 12x12 car-part templates."""
    t = np.zeros((4, 12, 12), np.float32)
    t[0, 2:10, 1:11] = 1.0                       # bright body
    t[1, 3:6, 1:11] = -1.0; t[1, 7:10, 1:11] = 1.0   # dark window over body
    t[2, :, 2:4] = 1.0; t[2, :, 8:10] = -1.0     # vertical edge pair
    t[3, 4:8, 2:10] = 1.0; t[3, 5:7, 3:9] = -1.2  # plate-ish ring
    t -= t.mean(axis=(1, 2), keepdims=True)
    t /= np.linalg.norm(t, axis=(1, 2), keepdims=True)
    return t


@functools.partial(jax.jit, static_argnames=("h2", "w2"))
def _nn_scale_scores(x: jnp.ndarray, h2: int, w2: int) -> jnp.ndarray:
    xs = jax.image.resize(x, (x.shape[0], h2, w2), "bilinear")
    a = _conv(xs - xs.mean(axis=(1, 2), keepdims=True),
              jnp.asarray(_nn_templates()))
    return a.max(axis=1)  # (n, h', w') best-template score


class NN(Operator):
    name = "nn"
    threshold = 1.7
    scales = (1.0, 2 / 3, 1 / 2)
    qgrid = 8

    def detect(self, frames_u8, cf, spec, positions=None):
        x = jnp.asarray(frames_u8, jnp.float32) / 255.0
        n, h, w = x.shape
        pos = _positions(cf, spec) if positions is None else positions
        items = set()
        for si, s in enumerate(self.scales):
            h2, w2 = max(14, int(h * s)), max(14, int(w * s))
            sc = np.asarray(_nn_scale_scores(x, h2, w2))
            for t, iy, ix in zip(*np.nonzero(sc > self.threshold)):
                cy, cx = _to_norm(iy + 6, ix + 6, h2, w2, cf.crop)
                q = self.qgrid
                items.add(("carbox", _bucket(pos[t], spec),
                           int(cy * q), int(cx * q), si))
        return items


# ---------------------------------------------------------------------------
# License: plate-region detector (bright box + dense dark edges)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=())
def _license_scores(x: jnp.ndarray) -> jnp.ndarray:
    bright = (x > 0.80).astype(x.dtype)
    gx = jnp.abs(jnp.diff(x, axis=2))
    edge = (gx > 0.25).astype(x.dtype)
    box = jnp.ones((1, 5, 11), x.dtype) / (5 * 11)
    b = _conv(bright, box)[:, 0]
    e = _conv(edge, box)[:, 0, :, :-1]
    hh = min(b.shape[1], e.shape[1]); ww = min(b.shape[2], e.shape[2])
    return b[:, :hh, :ww] * e[:, :hh, :ww]


class License(Operator):
    name = "license"
    threshold = 0.035
    qgrid = 12

    def score_map(self, frames_u8) -> np.ndarray:
        x = jnp.asarray(frames_u8, jnp.float32) / 255.0
        if x.shape[1] < 7 or x.shape[2] < 13:
            return np.zeros((x.shape[0], 1, 1), np.float32)
        return np.asarray(_license_scores(x))

    def detect(self, frames_u8, cf, spec, positions=None):
        sc = self.score_map(frames_u8)
        n, h, w = np.asarray(frames_u8).shape
        pos = _positions(cf, spec) if positions is None else positions
        items = set()
        for t in range(sc.shape[0]):
            ys, xs = np.nonzero(sc[t] > self.threshold)
            if len(ys) == 0:
                continue
            # cluster hits to cell grid
            cy, cx = _to_norm(ys + 2, xs + 5, h, w, cf.crop)
            q = self.qgrid
            for a, b in set(zip((cy * q).astype(int), (cx * q).astype(int))):
                items.add(("plate", _bucket(pos[t], spec), int(a), int(b)))
        return items


# ---------------------------------------------------------------------------
# OCR: digit reading inside detected plate regions
# ---------------------------------------------------------------------------

class OCR(Operator):
    name = "ocr"
    conf = 0.55
    _detector = License()

    def detect(self, frames_u8, cf, spec, positions=None):
        frames = np.asarray(frames_u8, np.float32) / 255.0
        sc = self._detector.score_map(frames_u8)
        n, h, w = frames.shape
        pos = _positions(cf, spec) if positions is None else positions
        glyphs = np.asarray(digit_glyphs())
        glyphs = glyphs - glyphs.mean(axis=(1, 2), keepdims=True)
        # plate canonical size at ingest scale
        items = set()
        for t in range(n):
            flat = sc[t].ravel()
            if flat.size == 0:
                continue
            order = np.argsort(flat)[::-1][:3]
            for o in order:
                if flat[o] <= self._detector.threshold:
                    break
                iy, ix = np.unravel_index(o, sc[t].shape)
                py, px = iy + 2, ix + 5  # plate center-ish in frame coords
                # extract patch scaled to canonical 9x26 plate
                ph = max(4, int(round(9 * h / 96)))
                pw = max(8, int(round(26 * w / 160)))
                y0, x0 = py - ph // 2, px - pw // 2
                if y0 < 0 or x0 < 0 or y0 + ph > h or x0 + pw > w:
                    continue
                patch = frames[t, y0:y0 + ph, x0:x0 + pw]
                patch = np.asarray(T.resize(jnp.asarray(patch[None]), 9, 26))[0]
                digits, confs = [], []
                for slot in range(4):
                    cell = patch[1:8, 1 + slot * 6:6 + slot * 6]
                    cell = 1.0 - cell  # digits are dark on white
                    cell = cell - cell.mean()
                    nrm = np.linalg.norm(cell) + 1e-6
                    corr = (glyphs * cell).sum(axis=(1, 2)) / (
                        nrm * (np.linalg.norm(glyphs, axis=(1, 2)) + 1e-6))
                    digits.append(int(np.argmax(corr)))
                    confs.append(float(np.max(corr)))
                if np.mean(confs) > self.conf:
                    items.add(("ocr", _bucket(pos[t], spec),
                               "".join(map(str, digits))))
        return items


OPERATORS: dict[str, Operator] = {
    op.name: op for op in (Diff(), Motion(), SNN(), NN(), License(), OCR())
}
