"""Cascade query execution over the video store.

A query is a cascade of ⟨operator, accuracy⟩ stages (paper Fig. 2): early
stages scan most of the queried timespan cheaply and *activate* later stages
only on the time buckets they flag.  Each stage consumes frames in its
consumption format, retrieved from the storage format its CF subscribes to.

Speed accounting follows the paper's model (§2.2): a stage streams data from
disk through the decoder to the operator, so its effective speed is the lower
of retrieval speed and consumption speed; we time both paths per stage and
report ``duration / max(retrieve_time, consume_time)`` (perfect pipelining)
as well as the strictly-sequential speed.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from ..core.knobs import FidelityOption, IngestSpec
from .batch import DEFAULT_BATCH_SHAPES, BatchedConsumer
from .operators import OPERATORS, _bucket, _positions

QUERY_A = ("diff", "snn", "nn")            # car detection
QUERY_B = ("motion", "license", "ocr")     # license-plate recognition
QUERIES = {"A": QUERY_A, "B": QUERY_B}


@dataclasses.dataclass
class StageStats:
    op: str
    cf: FidelityOption
    sf_id: str
    retrieve_s: float = 0.0
    consume_s: float = 0.0
    frames: int = 0
    items: int = 0
    segments_scanned: int = 0
    detect_calls: int = 0    # op.detect invocations (batching merges them)
    batched_frames: int = 0  # rows fed via the batched path, padding incl.

    def to_wire(self) -> dict:
        """Plain-scalar form (msgpack/json-safe) for cross-process serving."""
        d = dataclasses.asdict(self)
        d["cf"] = [self.cf.quality, self.cf.crop, self.cf.resolution,
                   self.cf.sampling]
        return d

    @staticmethod
    def from_wire(d: dict) -> "StageStats":
        d = dict(d)
        q, crop, res, samp = d["cf"]
        d["cf"] = FidelityOption(q, crop, res, samp)
        return StageStats(**d)


def _wire_scalar(x):
    """Numpy scalars -> plain Python so item tuples survive msgpack."""
    if isinstance(x, np.integer):
        return int(x)
    if isinstance(x, np.floating):
        return float(x)
    return x


@dataclasses.dataclass
class QueryCost:
    """Per-query resource attribution: what *this* query cost the system.

    The serving stack already tracks every one of these globally (planner
    decode counters, cache stats, scheduler leader shares); this ledger
    attributes them to the query that incurred them.  Fused-batch detect
    accounting follows the PR 8 leader-share convention — a dispatch is
    charged to the batch's leading unit's query — so summing the ledgers
    across a server's queries equals the true fused cost (per-query values
    are exact only in aggregate, like ``StageStats``).  Wall-clock fields:
    ``queue_wait_s`` is admission-to-start wait under the server,
    ``sched_wait_s`` is time blocked on shared-scheduler futures; deadline
    fields are filled when the query ran under a ``deadline_ms`` SLO."""
    decode_bytes: int = 0        # compressed bytes read off the store
    decode_chunks: int = 0
    decoded_frames: int = 0      # frames retrieval delivered
    detect_frames: int = 0       # operator rows consumed (leader share)
    detect_calls: int = 0        # fused op.detect dispatches (leader share)
    cache_hits: int = 0          # decoded-segment cache: exact hits
    cache_richer_hits: int = 0   # served bit-exactly from a richer CF
    cache_inflight_hits: int = 0  # joined another query's in-flight decode
    cache_misses: int = 0        # real decodes this query triggered
    queue_wait_s: float = 0.0
    sched_wait_s: float = 0.0
    deadline_ms: float = 0.0     # 0 = ran without a deadline
    deadline_slack_s: float = 0.0
    deadline_met: bool = True

    def to_wire(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_wire(d: dict) -> "QueryCost":
        return QueryCost(**d)

    def add(self, o: "QueryCost") -> None:
        """Roll another query's (or sub-query's) ledger into this one:
        counters and waits sum; deadline fields keep the worst case (the
        laxest deadline, the smallest slack, met only if all met)."""
        self.decode_bytes += o.decode_bytes
        self.decode_chunks += o.decode_chunks
        self.decoded_frames += o.decoded_frames
        self.detect_frames += o.detect_frames
        self.detect_calls += o.detect_calls
        self.cache_hits += o.cache_hits
        self.cache_richer_hits += o.cache_richer_hits
        self.cache_inflight_hits += o.cache_inflight_hits
        self.cache_misses += o.cache_misses
        self.queue_wait_s += o.queue_wait_s
        self.sched_wait_s += o.sched_wait_s
        if o.deadline_ms:
            if self.deadline_ms:
                self.deadline_ms = max(self.deadline_ms, o.deadline_ms)
                self.deadline_slack_s = min(self.deadline_slack_s,
                                            o.deadline_slack_s)
            else:
                self.deadline_ms = o.deadline_ms
                self.deadline_slack_s = o.deadline_slack_s
            self.deadline_met = self.deadline_met and o.deadline_met


@dataclasses.dataclass
class QueryResult:
    items: set
    stages: list[StageStats]
    video_seconds: float
    wall_s: float = 0.0  # measured end-to-end wall time of the execution
    # predicate pushdown (repro.index): segments the semantic index pruned
    # before retrieval — never read, never decoded.  ``pruned_conservative``
    # counts the subset pruned across a knob mismatch (conservative mode:
    # bounded recall loss); exact-match prunes never change items.
    pruned_segments: int = 0
    pruned_bytes: int = 0
    pruned_conservative: int = 0
    # per-query resource attribution (telemetry): filled by the executors,
    # deadline fields by the serving layer, rolled up by the router
    cost: QueryCost = dataclasses.field(default_factory=QueryCost)

    def to_wire(self) -> dict:
        """Plain-scalar form of the result (item tuples become lists; a
        shard worker ships this over the cluster wire protocol)."""
        return {
            "items": [[_wire_scalar(x) for x in it] for it in self.items],
            "stages": [s.to_wire() for s in self.stages],
            "video_seconds": float(self.video_seconds),
            "wall_s": float(self.wall_s),
            "pruned_segments": int(self.pruned_segments),
            "pruned_bytes": int(self.pruned_bytes),
            "pruned_conservative": int(self.pruned_conservative),
            "cost": self.cost.to_wire(),
        }

    @staticmethod
    def from_wire(d: dict) -> "QueryResult":
        return QueryResult(
            items={tuple(it) for it in d["items"]},
            stages=[StageStats.from_wire(s) for s in d["stages"]],
            video_seconds=d["video_seconds"], wall_s=d["wall_s"],
            pruned_segments=d.get("pruned_segments", 0),
            pruned_bytes=d.get("pruned_bytes", 0),
            pruned_conservative=d.get("pruned_conservative", 0),
            cost=(QueryCost.from_wire(d["cost"]) if d.get("cost")
                  else QueryCost()))

    @property
    def pipelined_speed(self) -> float:
        """x realtime with retrieval/consumption overlapped per stage."""
        t = sum(max(s.retrieve_s, s.consume_s) for s in self.stages)
        return self.video_seconds / max(t, 1e-9)

    @property
    def sequential_speed(self) -> float:
        t = sum(s.retrieve_s + s.consume_s for s in self.stages)
        return self.video_seconds / max(t, 1e-9)

    @property
    def measured_speed(self) -> float:
        """x realtime from the measured wall clock (the honest number; the
        two estimates above model perfect/no pipelining from stage timings)."""
        return self.video_seconds / max(self.wall_s, 1e-9)


def stage_specs(config, query: str, accuracy: float):
    """The cascade's resolved stages: [(op_name, operator, cf, sf_id)].

    Shared by the sequential path below and the pipelined executor
    (repro.serving.executor) so both run the identical cascade."""
    out = []
    for op_name in QUERIES[query]:
        cf = config.consumption_format(op_name, accuracy)
        out.append((op_name, OPERATORS[op_name], cf, config.subscription(cf)))
    return out


def apply_pushdown(store, index, stream: str, segments: list[int],
                   specs: list, accuracy: float, mode: str = "exact"):
    """Consult the semantic index (repro.index) before any retrieval:
    segments whose persisted cascade-head sketch shows zero activations
    at (or dominating) the query's knobs are dropped from the stage-0
    scan — no store read, no decode.  Returns ``(kept_segments,
    (pruned_segments, pruned_bytes, pruned_conservative))``.  Shared by
    ``run_query`` and the pipelined executor so both prune identically."""
    if index is None or mode == "off" or not segments:
        return segments, (0, 0, 0)
    op_name, _op, cf, sf_id = specs[0]
    if op_name not in getattr(index, "ops", ()):
        return segments, (0, 0, 0)
    dec = index.prune(stream, segments, op_name, cf, sf_id, accuracy,
                      mode=mode)
    if not dec.pruned:
        return segments, (0, 0, 0)
    nbytes = sum(store.segment_bytes(stream, s, sf_id) for s in dec.pruned)
    return dec.kept, (len(dec.pruned), nbytes, dec.conservative)


def _charge_fetch(cost: QueryCost, fcost: dict, n_frames: int,
                  n_fetches: int = 1) -> None:
    """Fold one retrieval's cost dict into a query ledger.  The cache
    kind tag (``"hit"``/``"richer"``/``"inflight"``/``"miss"``) comes from
    the serving planner's fetch; a raw store retrieve carries no tag and
    counts as misses — it decoded for real."""
    cost.decode_bytes += int(fcost.get("bytes", 0))
    cost.decode_chunks += int(fcost.get("chunks", 0))
    cost.decoded_frames += int(fcost.get("frames", n_frames))
    kind = fcost.get("cache")
    if kind == "hit":
        cost.cache_hits += n_fetches
    elif kind == "richer":
        cost.cache_richer_hits += n_fetches
    elif kind == "inflight":
        cost.cache_inflight_hits += n_fetches
    else:
        cost.cache_misses += n_fetches


def _active_frame_mask(frames_pos: np.ndarray, active_buckets: set | None,
                       spec: IngestSpec) -> np.ndarray:
    if active_buckets is None:
        return np.ones(len(frames_pos), bool)
    return np.array([_bucket(p, spec) in active_buckets for p in frames_pos],
                    dtype=bool)


def run_query(store, config, query: str, stream: str, segments: list[int],
              accuracy: float, retriever=None,
              batch_segments: int = 0,
              batch_shapes: tuple[int, ...] | None = None,
              index=None, pushdown: str = "exact") -> QueryResult:
    """Execute a cascade at one target accuracy for every stage.

    ``config`` is a DerivedConfig (repro.core.configure): maps consumer
    (op, accuracy) -> CF and CF -> storage format id.  ``retriever``
    substitutes the store's decode path — the serving layer passes its
    planner's cache-aware fetch here so all retrieval routes through the
    shared decoded-segment cache.

    ``batch_segments`` > 0 switches consumption to the cross-segment
    batched path (repro.analytics.batch): up to that many segments'
    activated frames are fused into one ``op.detect`` call per static
    shape bucket, and retrieval goes through ``store.retrieve_many`` so
    ``want_indices``/``convert`` amortize across the group.  Item sets are
    bit-exact with the per-segment path; ``StageStats.detect_calls`` shows
    the dispatch saving.  ``batch_shapes`` overrides the consumer's static
    shape ladder (see ``batch.derive_shapes`` for the profiler-derived one).

    ``index`` enables predicate pushdown (a ``repro.index.SemanticIndex``
    or compatible): sketched-inactive segments are pruned before the
    stage-0 scan (see ``apply_pushdown``).  In ``pushdown="exact"`` the
    result is bit-identical to the unpruned run; ``"conservative"`` also
    prunes across knob mismatches when the sketch's accuracy dominates.
    """
    if batch_segments < 0:
        raise ValueError(f"batch_segments must be >= 0, got {batch_segments}")
    spec = store.spec
    fetch = retriever or store.retrieve
    consumer = (BatchedConsumer(spec, shapes=batch_shapes or
                                DEFAULT_BATCH_SHAPES)
                if batch_segments else None)
    specs = stage_specs(config, query, accuracy)
    n_total = len(segments)  # video_seconds covers pruned segments too
    segments, (n_pruned, pruned_bytes, n_cons) = apply_pushdown(
        store, index, stream, segments, specs, accuracy, pushdown)
    stages: list[StageStats] = []
    active: dict[int, set] | None = None  # per segment active buckets
    items_all: set = set()
    cost = QueryCost()
    t_start = time.perf_counter()

    for op_name, op, cf, sf_id in specs:
        st = StageStats(op=op_name, cf=cf, sf_id=sf_id)
        stage_items: set = set()
        next_active: dict[int, set] = {}
        pos = _positions(cf, spec)

        if consumer is not None:
            segs = [s for s in segments
                    if active is None or active.get(s)]
            st.segments_scanned = len(segs)
            for g0 in range(0, len(segs), batch_segments):
                group = segs[g0:g0 + batch_segments]
                t0 = time.perf_counter()
                if retriever is None:
                    frames_list, gcost = store.retrieve_many(
                        stream, group, sf_id, cf)
                    _charge_fetch(cost, gcost,
                                  sum(len(f) for f in frames_list),
                                  n_fetches=len(group))
                else:
                    frames_list = []
                    for s in group:
                        frames, fcost = retriever(stream, s, sf_id, cf)
                        frames_list.append(frames)
                        _charge_fetch(cost, fcost, len(frames))
                st.retrieve_s += time.perf_counter() - t0
                pending = []
                for seg, frames in zip(group, frames_list):
                    mask = _active_frame_mask(pos, None if active is None
                                              else active.get(seg, set()),
                                              spec)
                    if not mask.any():
                        continue
                    sel = np.nonzero(mask)[0]
                    pending.append((seg, frames[sel], pos[sel]))
                t0 = time.perf_counter()
                per_seg, cstats = consumer.consume(op, cf, pending)
                st.consume_s += time.perf_counter() - t0
                st.detect_calls += cstats.detect_calls
                st.frames += cstats.frames
                st.batched_frames += cstats.batched_frames
                cost.detect_calls += cstats.detect_calls
                cost.detect_frames += cstats.frames
                for seg, items in per_seg.items():
                    stage_items |= {(seg,) + it for it in items}
                    next_active[seg] = {it[1] for it in items}
        else:
            for seg in segments:
                if active is not None and not active.get(seg):
                    continue  # early stage filtered this segment entirely
                st.segments_scanned += 1
                t0 = time.perf_counter()
                frames, fcost = fetch(stream, seg, sf_id, cf)
                st.retrieve_s += time.perf_counter() - t0
                _charge_fetch(cost, fcost, len(frames))

                mask = _active_frame_mask(pos, None if active is None
                                          else active.get(seg, set()), spec)
                if not mask.any():
                    continue
                t0 = time.perf_counter()
                # operators are batch programs; feed only activated frames
                sel = np.nonzero(mask)[0]
                items = op.detect(frames[sel], cf, spec, positions=pos[sel])
                st.consume_s += time.perf_counter() - t0
                st.detect_calls += 1
                st.frames += int(mask.sum())
                cost.detect_calls += 1
                cost.detect_frames += int(mask.sum())
                stage_items |= {(seg,) + it for it in items}
                next_active[seg] = {it[1] for it in items}

        st.items = len(stage_items)
        stages.append(st)
        active = next_active
        items_all = stage_items  # final stage's items are the answer

    dur = n_total * spec.segment_seconds
    return QueryResult(items=items_all, stages=stages, video_seconds=dur,
                       wall_s=time.perf_counter() - t_start,
                       pruned_segments=n_pruned, pruned_bytes=pruned_bytes,
                       pruned_conservative=n_cons, cost=cost)
