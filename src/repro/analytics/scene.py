"""Procedural traffic-camera scenes with ground truth.

Six streams mirror the paper's datasets: three surveillance cameras with
heavy/medium/light traffic (*jackson*, *miami*, *tucson*), a *dashcam* with
global camera motion, and two parking-lot cameras (*park*, *airport*).
Each segment is deterministic in (stream, segment_index): cars (textured
rectangles carrying digit license plates) translate across a static or
panning background, plus sensor noise.  Ground truth (car boxes, plate boxes,
digit strings per frame) is returned alongside the pixels for sanity tests —
operator *accuracy* is measured the paper's way, against the operator's own
output on full-fidelity video.
"""

from __future__ import annotations

import dataclasses
import functools
import zlib

import numpy as np

from ..core.knobs import IngestSpec


def _stream_seed(stream: str) -> int:
    """Stable per-stream seed.  Python's ``hash()`` is randomized per
    process (PYTHONHASHSEED), which silently made every process render
    different scenes — benchmarks comparing runs across processes (and the
    CI regression gate) need identical workloads, so use crc32."""
    return zlib.crc32(stream.encode())

# 7x5 digit glyph bitmaps.
_DIGITS_ROWS = {
    0: ("11111", "10001", "10001", "10001", "10001", "10001", "11111"),
    1: ("00100", "01100", "00100", "00100", "00100", "00100", "01110"),
    2: ("11111", "00001", "00001", "11111", "10000", "10000", "11111"),
    3: ("11111", "00001", "00001", "01111", "00001", "00001", "11111"),
    4: ("10001", "10001", "10001", "11111", "00001", "00001", "00001"),
    5: ("11111", "10000", "10000", "11111", "00001", "00001", "11111"),
    6: ("11111", "10000", "10000", "11111", "10001", "10001", "11111"),
    7: ("11111", "00001", "00010", "00100", "01000", "01000", "01000"),
    8: ("11111", "10001", "10001", "11111", "10001", "10001", "11111"),
    9: ("11111", "10001", "10001", "11111", "00001", "00001", "11111"),
}


@functools.cache
def digit_glyphs() -> np.ndarray:
    """(10, 7, 5) float32 in {0,1}."""
    out = np.zeros((10, 7, 5), np.float32)
    for d, rows in _DIGITS_ROWS.items():
        for i, row in enumerate(rows):
            for j, ch in enumerate(row):
                out[d, i, j] = float(ch == "1")
    return out


STREAMS = {
    #  name     : (cars/segment rate, car speed px/frame, global pan, plate prob)
    "jackson":   (3.0, 3.0, 0.0, 0.9),
    "miami":     (2.2, 2.5, 0.0, 0.9),
    "tucson":    (1.5, 2.0, 0.0, 0.9),
    "dashcam":   (2.0, 4.0, 1.5, 0.8),
    "park":      (1.0, 1.2, 0.0, 0.9),
    "airport":   (0.8, 1.0, 0.0, 0.9),
    "empty":     (0.0, 1.0, 0.0, 0.9),   # calibration / negative control
}


@dataclasses.dataclass
class CarTruth:
    car_id: int
    digits: str
    boxes: dict[int, tuple[int, int, int, int]]        # frame -> (y0,x0,y1,x1)
    plate_boxes: dict[int, tuple[int, int, int, int]]  # frame -> (y0,x0,y1,x1)


@dataclasses.dataclass
class SegmentTruth:
    stream: str
    seg: int
    cars: list[CarTruth]


def _background(stream: str, h: int, w: int) -> np.ndarray:
    rng = np.random.default_rng(_stream_seed(stream))
    y = np.linspace(0, 1, h)[:, None]
    x = np.linspace(0, 1, w)[None, :]
    bg = 90 + 50 * y + 15 * np.sin(x * 13) + 10 * np.cos(y * 21 + x * 7)
    bg += rng.normal(0, 6, (h, w))  # fixed texture
    # road band
    road0, road1 = int(h * 0.45), int(h * 0.95)
    bg[road0:road1] = 70 + 8 * np.sin(x * 31)
    return bg.clip(0, 255)


def _draw_car(frame: np.ndarray, y0: int, x0: int, ch: int, cw: int,
              shade: float, digits: str, with_plate: bool):
    h, w = frame.shape
    y1, x1 = y0 + ch, x0 + cw
    vy0, vx0 = max(0, y0), max(0, x0)
    vy1, vx1 = min(h, y1), min(w, x1)
    if vy1 <= vy0 or vx1 <= vx0:
        return None, None
    # body with simple shading + window band
    yy = np.arange(vy0, vy1)[:, None]
    frame[vy0:vy1, vx0:vx1] = shade + 12 * np.sin((yy - y0) / 4)
    wy0, wy1 = y0 + ch // 6, y0 + ch // 3
    frame[max(0, wy0):min(h, wy1), vx0:vx1] = shade * 0.4
    plate_box = None
    if with_plate:
        glyphs = digit_glyphs()
        ph, pw = 9, 2 + 4 * 6  # 7x5 glyphs + 1px spacing + 1px border
        py0 = y0 + (2 * ch) // 3
        px0 = x0 + (cw - pw) // 2
        py1, px1 = py0 + ph, px0 + pw
        if py0 >= 0 and px0 >= 0 and py1 <= h and px1 <= w:
            frame[py0:py1, px0:px1] = 235.0  # white plate
            for i, d in enumerate(digits):
                g = glyphs[int(d)]
                gy, gx = py0 + 1, px0 + 1 + i * 6
                frame[gy:gy + 7, gx:gx + 5] -= 215.0 * g  # dark digits
            plate_box = (py0, px0, py1, px1)
    return (vy0, vx0, vy1, vx1), plate_box


def generate_segment(stream: str, seg: int,
                     spec: IngestSpec | None = None
                     ) -> tuple[np.ndarray, SegmentTruth]:
    """Render one segment at ingest fidelity.  Deterministic."""
    spec = spec or IngestSpec()
    n, h, w = spec.frames_per_segment, spec.height, spec.width
    rate, speed, pan, plate_p = STREAMS.get(stream, STREAMS["tucson"])
    rng = np.random.default_rng(_stream_seed(stream) * 1000003 + seg)

    bg = _background(stream, h, w + int(abs(pan) * n) + 8)
    n_cars = rng.poisson(rate)
    cars = []
    for c in range(n_cars):
        ch = int(rng.integers(max(18, h // 4), max(24, h // 2)))
        cw = int(ch * rng.uniform(1.3, 1.7))
        lane_y = int(rng.uniform(0.45, max(0.451, 0.95 - ch / h)) * h)
        v = speed * rng.uniform(0.7, 1.4) * rng.choice([-1.0, 1.0])
        x_start = (-cw - rng.uniform(0, w * 0.5)) if v > 0 else \
            (w + rng.uniform(0, w * 0.5))
        shade = rng.uniform(140, 220)
        digits = "".join(str(d) for d in rng.integers(0, 10, 4))
        has_plate = rng.random() < plate_p
        cars.append((c, ch, cw, lane_y, v, x_start, shade, digits, has_plate))

    frames = np.empty((n, h, w), np.float32)
    truths = [CarTruth(c[0], c[7], {}, {}) for c in cars]
    noise = rng.normal(0, 2.0, (n, h, w)).astype(np.float32)
    for t in range(n):
        off = int(round(pan * t))
        frame = bg[:, off:off + w].copy()
        for (cid, ch, cw, ly, v, xs, shade, digits, has_plate), tr in \
                zip(cars, truths):
            x = int(round(xs + v * t))
            box, pbox = _draw_car(frame, ly, x, ch, cw, shade, digits, has_plate)
            if box is not None:
                tr.boxes[t] = box
            if pbox is not None:
                tr.plate_boxes[t] = pbox
        frames[t] = frame
    frames = (frames + noise).clip(0, 255)
    return frames.astype(np.uint8), SegmentTruth(stream, seg, truths)
