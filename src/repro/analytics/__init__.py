from .accuracy import f1_score
from .operators import OPERATORS, Operator
from .scene import STREAMS, generate_segment

__all__ = ["OPERATORS", "Operator", "f1_score", "generate_segment", "STREAMS"]
