from .accuracy import f1_score
from .batch import BatchedConsumer, ConsumeStats
from .operators import OPERATORS, Operator
from .scene import STREAMS, generate_segment

__all__ = ["BatchedConsumer", "ConsumeStats", "OPERATORS", "Operator",
           "f1_score", "generate_segment", "STREAMS"]
