"""F1 accuracy (paper §2.1): harmonic mean of precision and recall of an
operator's item set against ground truth = the same operator's items on
full-fidelity video (paper §6.1 methodology)."""

from __future__ import annotations


def f1_score(pred: set, truth: set) -> float:
    if not truth and not pred:
        return 1.0
    tp = len(pred & truth)
    precision = tp / len(pred) if pred else 0.0
    recall = tp / len(truth) if truth else 0.0
    if precision + recall == 0:
        return 0.0
    return 2 * precision * recall / (precision + recall)
