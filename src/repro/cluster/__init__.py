"""Sharded multi-process VStore: the first process boundary in the system.

The GIL caps thread-based serving at ~1.7x aggregate on small hosts, so
horizontal scale comes from *stream-sharded worker processes* (one full
SegmentStore -> VideoStore -> VStoreServer stack per shard, VSS-style
store-per-feed) behind a scatter-gather router:

* ``ShardWorker`` (``worker.shard_worker_main``) — a spawned process
  hosting one shard's stack over its own store directory, speaking the
  length-prefixed msgpack wire protocol (``wire``);
* ``ShardRouter`` — stable-hash stream placement, scatter-gather query
  fan-out with deterministic merge (bit-identical to single-process
  execution), cluster-wide stats rollup, and generation-checked worker
  restart on crash;
* ``ClusterIngest`` — owns every shard scheduler's ``BudgetLease``, splits
  the global transcode budget by observed backlog, and runs erosion passes
  cluster-wide so per-format debt and reclaimed bytes roll up in one
  place.

``python -m repro.launch.vcluster`` drives the whole thing end to end.
"""

from .ingest import ClusterIngest
from .router import (ShardError, ShardHost, ShardIdentityError, ShardRouter,
                     merge_results, stable_shard)
from .wire import (config_from_wire, config_to_wire, erosion_plan_from_wire,
                   erosion_plan_to_wire, pack, recv_msg, send_msg,
                   spec_from_wire, spec_to_wire, unpack)

__all__ = [
    "ClusterIngest", "ShardError", "ShardHost", "ShardIdentityError",
    "ShardRouter", "config_from_wire", "config_to_wire",
    "erosion_plan_from_wire", "erosion_plan_to_wire", "merge_results",
    "pack", "recv_msg", "send_msg", "spec_from_wire", "spec_to_wire",
    "stable_shard", "unpack",
]
