"""ClusterIngest: cluster-wide coordination of transcode budget and
erosion across shard workers.

Each shard worker runs its own ``IngestScheduler`` whose rate is held by a
``BudgetLease`` the *coordinator* owns (granted over the wire via the
``set_budget`` op).  The global budget is expressed the same way a single
scheduler's is — encode-seconds per arriving video-second — and the
coordinator keeps the cluster-wide invariant

    sum_i  rate_i * arrivals_i  ≈  global_rate * sum_i arrivals_i

while *skewing* the per-shard rates toward backlog: ``rebalance()`` reads
every shard's transcode debt and grants debt-weighted shares, so budget an
idle shard cannot spend flows to shards whose queues are behind (clamped
to ``max_skew`` x the global rate so one pathological shard can't starve
the rest).  With no debt anywhere the split degenerates to the uniform
grant, which is exactly the single-process semantics.

Erosion runs cluster-wide the same way: ``erode_advance`` moves every
shard's day clock in lockstep and merges the per-shard reports, so
per-format reclaimed bytes — like per-format transcode debt in
``stats()`` — roll up in one place.
"""

from __future__ import annotations

import threading

from ..obs.metrics import Histogram
from .router import ShardRouter


# per-format keys that are rankings/rates shared by every shard (carried
# through as-is), not additive quantities
_NON_ADDITIVE = {"recovery_cost"}


def _merge_per_format(slots: list[dict]) -> dict:
    out: dict[str, dict] = {}
    for per_format in slots:
        for sf_id, vals in per_format.items():
            slot = out.setdefault(sf_id, {})
            for k, v in vals.items():
                if k in _NON_ADDITIVE or not isinstance(v, (int, float)):
                    slot[k] = v
                else:
                    slot[k] = slot.get(k, 0) + v
    return out


class ClusterIngest:
    def __init__(self, router: ShardRouter, budget_x: float | None = None,
                 *, max_skew: float = 8.0):
        self.router = router
        self.max_skew = max_skew
        # rebalance() runs on whatever thread drives the coordinator
        # while on_reattach callbacks read grants from the router's pool
        # threads: grant state is one lock domain.  The grants list is
        # replaced wholesale under _mu and never mutated in place.
        self._mu = threading.Lock()
        self.budget_x = budget_x  # guarded-by: _mu
        self.rebalances = 0       # guarded-by: _mu
        # start every shard at the uniform grant (single-process semantics
        # until the first rebalance observes actual backlog)
        self.grants = [budget_x] * router.n_shards  # guarded-by: _mu
        self._apply_grants(self.grants_snapshot())
        for host in router.hosts:
            # a respawned worker reverts to its spawn-time budget; push
            # the coordinator's current grant back as soon as it reattaches
            host.on_reattach.append(
                lambda h: h.call("set_budget",
                                 budget_x=self.grant_for(h.idx)))

    def grants_snapshot(self) -> list[float | None]:
        """Consistent copy of the per-shard grants."""
        with self._mu:
            return list(self.grants)

    def grant_for(self, idx: int) -> float | None:
        with self._mu:
            return self.grants[idx]

    def _apply_grants(self, grants: list[float | None]):
        # RPCs happen outside _mu: a slow or respawning worker must not
        # stall grant reads (and the reattach callback path re-enters
        # grant_for, which would self-deadlock under a held _mu)
        for host, x in zip(self.router.hosts, grants):
            host.call_retry("set_budget", budget_x=x)

    # -- data path -------------------------------------------------------------
    def ingest(self, stream: str, seg: int, frames) -> float:
        return self.router.ingest(stream, seg, frames)

    def pump(self, max_tasks: int | None = None) -> int:
        """Deterministically run queued transcodes on every shard (budget
        credit permitting); returns total tasks completed."""
        return sum(self.router.broadcast("pump", max_tasks=max_tasks))

    def drain(self, include_shed: bool = True) -> int:
        """Run every shard's queue to empty, ignoring budget (the 'budget
        raised' path)."""
        return sum(self.router.broadcast("drain", include_shed=include_shed))

    # -- budget splitting ------------------------------------------------------
    def set_budget_x(self, budget_x: float | None) -> None:
        """Change the global rate; re-splits immediately."""
        with self._mu:
            self.budget_x = budget_x
        self.rebalance()

    def rebalance(self) -> list[float | None]:
        """Re-split the global budget by observed per-shard backlog.

        Shard i's grant is ``global_rate * total_arrivals * w_i /
        arrivals_i`` with debt-share weights ``w_i``; shards that have seen
        no arrivals yet get the uniform rate.  Conserves the cluster-wide
        encode-second rate (up to the ``max_skew`` clamp) while directing
        slack at the shards that are actually behind."""
        with self._mu:
            budget_x = self.budget_x
        if budget_x is None:  # unbounded: nothing to split
            grants: list[float | None] = [None] * self.router.n_shards
            with self._mu:
                self.grants = grants
            self._apply_grants(grants)
            return grants
        stats = self.router.broadcast("stats")
        ingests = [s.get("ingest") or {} for s in stats]
        arrivals = [float(ing.get("video_seconds", 0.0)) for ing in ingests]
        debts = [float(ing.get("debt_s", 0.0)) for ing in ingests]
        total_r = sum(arrivals)
        total_debt = sum(debts)
        grants = []
        for r_i, d_i in zip(arrivals, debts):
            if total_r <= 0 or r_i <= 0 or total_debt <= 0:
                grants.append(budget_x)
                continue
            w_i = d_i / total_debt
            x_i = budget_x * total_r * w_i / r_i
            grants.append(min(x_i, self.max_skew * budget_x))
        with self._mu:
            self.grants = grants
            self.rebalances += 1
        self._apply_grants(grants)
        return grants

    def requeue_shed(self) -> int:
        return sum(self.router.broadcast("requeue_shed"))

    # -- erosion ---------------------------------------------------------------
    def erode_advance(self, days: int = 1) -> dict:
        """Advance every shard's erosion day clock in lockstep; returns the
        merged report (segments/bytes/chunks summed, per-format rollup)."""
        reps = self.router.broadcast("erode_advance", days=days)
        merged = {
            "day": max(r["day"] for r in reps),
            "segments": sum(r["segments"] for r in reps),
            "bytes": sum(r["bytes"] for r in reps),
            "chunks": sum(r["chunks"] for r in reps),
            "chunk_bytes": sum(r["chunk_bytes"] for r in reps),
            "compactions": sum(r["compactions"] for r in reps),
            "dead_bytes_after": sum(r["dead_bytes_after"] for r in reps),
            "per_format": _merge_per_format([r["per_format"] for r in reps]),
            "per_shard": reps,
        }
        return merged

    # -- observability ---------------------------------------------------------
    def stats(self) -> dict:
        """One place for the whole cluster's ingest accounting: per-format
        pending/debt/shed rolled up across shards, global debt, write-back
        and erosion totals, plus the per-shard breakdown."""
        shard_stats = self.router.broadcast("stats")
        ingests = [s.get("ingest") or {} for s in shard_stats]
        erosions = [s.get("erosion") or {} for s in shard_stats]
        formats = _merge_per_format(
            [ing.get("formats", {}) for ing in ingests])
        sums = ("debt_s", "pending", "shed", "shed_total", "transcodes",
                "transcode_s", "video_seconds", "task_errors",
                "write_backs", "write_back_s", "write_backs_skipped")
        out = {k: sum(ing.get(k) or 0 for ing in ingests) for k in sums}
        out["formats"] = formats
        # latency distributions merge by histogram buckets, never by
        # averaging the per-shard percentiles (a skewed shard's tail would
        # vanish into the mean)
        for key in ("golden_hist", "transcode_hist"):
            snaps = [ing[key] for ing in ingests if ing.get(key)]
            if snaps:
                out[key] = Histogram.merge(snaps)
        with self._mu:
            out["grants"] = list(self.grants)
            out["budget_x"] = self.budget_x
            out["rebalances"] = self.rebalances
        out["erosion"] = {
            "eroded_segments": sum(e.get("eroded_segments", 0)
                                   for e in erosions),
            "eroded_bytes": sum(e.get("eroded_bytes", 0) for e in erosions),
            "eroded_chunks": sum(e.get("eroded_chunks", 0)
                                 for e in erosions),
            "eroded_chunk_bytes": sum(e.get("eroded_chunk_bytes", 0)
                                      for e in erosions),
        }
        out["per_shard"] = ingests
        return out
