"""ShardWorker: one OS process hosting a full per-shard VStore stack.

Each worker owns a *stream shard* — a disjoint subset of camera streams
assigned by the router's stable hash — behind its own store directory:

    SegmentStore -> VideoStore -> VStoreServer (+ optional IngestScheduler
    + ErosionExecutor), all private to the process.

The process listens on a unix-domain socket and answers length-prefixed
msgpack frames (``repro.cluster.wire``); one thread per accepted
connection, so a router holding several connections gets concurrent
queries into the server's worker pool.  Workers are started with the
``spawn`` method by default (``REPRO_CLUSTER_START_METHOD`` overrides):
jax state, thread pools and open sockets must never be inherited over
``fork``, and spawn keeps the worker honest about what actually crosses
the process boundary — everything arrives through the wire forms.

Protocol ops (request ``{"op": ..., **args}`` -> ``{"ok": True, "value":
...}`` or ``{"ok": False, "error": ..., "trace": ...}``):

``hello``          identity: store_id, generation, pid, formats, mono clock
``query``          a ``QueryRequest`` wire form -> ``QueryResult`` wire form
``ingest``         one segment's frames -> golden durability latency
``pump``/``drain``/``requeue_shed``  background-transcode control
``set_budget``     grant a new budget share to the worker's lease
``erode_advance``  move the erosion day clock; returns the report
``stats``          the server's aggregate stats (+ shard identity)
``telemetry``      one telemetry frame body (metrics + SLO + alerts);
                   ``sample_telemetry`` forces a durable local sample
``spans``          drain the worker's trace ring (wire-form span dicts)
``flush``/``shutdown``

Tracing: ``opts["trace"]`` enables the worker's ``repro.obs`` tracer.  Any
request frame may carry ``"_trace": [trace_id, span_id]`` (the router's
rpc span); the serve loop activates it around the handler so worker-side
spans parent under the caller's timeline.  Query responses ship the
query's spans back inline; everything else (background transcodes,
erosion) stays in the ring until a ``spans`` drain."""

from __future__ import annotations

import os
import socket
import sys
import threading
import time
import traceback

from . import wire


def _existing_streams(store) -> list[str]:
    """Stream names with any stored segment (used to re-adopt footage into
    erosion cohorts after a worker restart)."""
    return sorted({k.split(":", 1)[0] for k in store.backend.keys()})


def runtime_env_overrides(opts: dict, environ=None) -> dict[str, str]:
    """The env a worker's numeric runtime needs, as a pure key -> value
    map relative to ``environ`` (default ``os.environ``).

    One worker per core is the cluster's parallelism model, so each
    worker's runtime must stay single-threaded — letting one shard's
    XLA/BLAS pools fan across cores other shards own turns N processes
    into mutual oversubscription instead of scale-out (Redis/Seastar-style
    process-per-core discipline).  Explicit ``opts["env"]`` entries
    override the isolation defaults.

    Consumed on BOTH sides of the spawn: the router applies (and then
    restores — the parent's own runtime must not be silently
    single-threaded) these around ``Process.start()``, because BLAS sizes
    its pools while numpy is imported during the child's module
    resolution, before any worker code runs; the worker re-asserts them
    for jax — not imported until the stack builds — covering direct
    callers that spawn without the router."""
    env = os.environ if environ is None else environ
    out: dict[str, str] = {}
    if opts.get("isolate_runtime", True):
        if "OMP_NUM_THREADS" not in env:
            out["OMP_NUM_THREADS"] = "1"
        if "OPENBLAS_NUM_THREADS" not in env:
            out["OPENBLAS_NUM_THREADS"] = "1"
        flags = env.get("XLA_FLAGS", "")
        if "--xla_cpu_multi_thread_eigen" not in flags:
            out["XLA_FLAGS"] = (
                flags + " --xla_cpu_multi_thread_eigen=false").strip()
    for k, v in opts.get("env", {}).items():
        out[k] = str(v)
    return out


def apply_runtime_isolation(opts: dict) -> None:
    """Worker-side: export the runtime knobs into this process's env."""
    os.environ.update(runtime_env_overrides(opts))


class _ShardStack:
    """The per-shard object graph, built once per worker process."""

    def __init__(self, shard_dir: str, generation: int, cfg_wire: dict,
                 spec_wire: dict, opts: dict):
        from ..ingest import ErosionExecutor, IngestScheduler
        from ..obs import trace as obst
        from ..serving import QueryRequest, VStoreServer
        from ..videostore import VideoStore

        self.tracing = bool(opts.get("trace"))
        if self.tracing:
            obst.TRACER.enabled = True
        self._tracer = obst.TRACER
        self.generation = generation
        self.QueryRequest = QueryRequest
        self.config = wire.config_from_wire(cfg_wire)
        if self.config.dct_backend:
            # the frontend's profiler-measured codec backend choice
            # applies cluster-wide, not just in the deriving process
            from ..codec.transform import set_dct_backend
            set_dct_backend(self.config.dct_backend)
        spec = wire.spec_from_wire(spec_wire)
        self.store = VideoStore(shard_dir, spec)
        self.store.set_formats(self.config.storage_formats())
        # shard-local semantic index (repro.index): sketches live beside
        # the shard's segment store and are built/served by this process
        # only — the router never sees sketch bytes, just rolled-up stats
        self.index = None
        if self.config.index_ops and opts.get("index", True):
            from ..index import SemanticIndex
            self.index = SemanticIndex(os.path.join(shard_dir, "index"),
                                       spec, self.config)
        self.server = VStoreServer(
            self.store, self.config,
            workers=opts.get("workers", 1),
            max_inflight=opts.get("max_inflight", 16),
            cache_bytes=opts.get("cache_bytes", 256 << 20),
            prefetch_depth=opts.get("prefetch_depth", 1),
            batch_segments=opts.get("batch_segments", 4),
            cache_policy=opts.get("cache_policy", "lru"),
            cross_query_batching=opts.get("cross_query_batching", False),
            batch_max_wait_ms=opts.get("batch_max_wait_ms", 4.0),
            index=self.index,
            pushdown=opts.get("pushdown", "exact"))
        # SLO classes registered cluster-wide: the router forwards them in
        # opts so every shard derives the identical deadline for a class
        for name, kw in (opts.get("slo_classes") or {}).items():
            self.server.register_slo(name, **kw)
        # continuous telemetry (repro.obs.telemetry): the sampler snapshots
        # this shard's registry into an append-only crash-safe log beside
        # the others in the cluster's telemetry dir; the router's merged
        # series is scraped via op_telemetry
        self.telemetry = None
        tpath = opts.get("telemetry_path")
        if tpath:
            from ..obs import telemetry as tel
            self.telemetry = tel.TelemetrySampler(
                self.server.telemetry_body, tel.TelemetryLog(tpath),
                interval_s=float(opts.get("telemetry_interval_s", 1.0)))
            self.telemetry.start()
        self.scheduler = None
        self.erosion = None
        if opts.get("ingest"):
            self.scheduler = IngestScheduler(
                self.store, self.config,
                budget_x=opts.get("budget_x"),
                shed_debt_s=opts.get("shed_debt_s"),
                materialize_on_read=opts.get("materialize_on_read", False))
            if self.index is not None:
                # before adopt_missing, so the backlog sweep also queues
                # sketch backfill for segments that predate the index (or
                # whose sketch a crash lost before the flush ack)
                self.scheduler.attach_sketcher(self.index)
            # a restart lost the in-memory transcode queue; re-adopt the
            # backlog for acked-but-unmaterialized formats so debt stays
            # visible and drainable (no-op on a fresh store)
            self.scheduler.adopt_missing(_existing_streams(self.store))
            plan_wire = opts.get("erosion_plan")
            if plan_wire is not None:
                self.erosion = ErosionExecutor(
                    self.store, wire.erosion_plan_from_wire(plan_wire),
                    list(opts.get("node_ids", [])),
                    golden_id=self.scheduler.golden_id,
                    seed=opts.get("erosion_seed", 0))
                self.scheduler.on_ingest(self.erosion.note_ingested)
                # a restarted worker re-adopts already-stored footage so
                # cohort targets keep covering it (day granularity is the
                # ledger's resolution; the store itself is durable)
                self.erosion.register_existing(_existing_streams(self.store))
            self.server.attach_ingest(self.scheduler, self.erosion)
            if opts.get("start_worker", False):
                self.scheduler.start()

    # -- op handlers ---------------------------------------------------------
    def op_hello(self, req: dict) -> dict:
        # "mono" lets the router align this process's span timestamps
        # with its own perf_counter clock (offset measured around hello)
        return {"store_id": self.store.store_id,
                "generation": self.generation,
                "pid": os.getpid(),
                "formats": sorted(self.store.formats),
                "mono": time.perf_counter()}

    def op_query(self, req: dict) -> dict:
        r = self.QueryRequest.from_wire(req["request"])
        r.block = True  # the connection thread is the natural queue
        if self.tracing:
            # the serve loop activated the frame's _trace context on this
            # thread; hand it to the server pool thread via the request
            tid, sid = self._tracer.current()
            if tid:
                r.trace_id, r.parent_span = tid, sid
        out = self.server.submit_request(r).result().to_wire()
        if self.tracing and r.trace_id:
            # the query span closed before the future resolved, so the
            # trace's spans are all ringed; ship them with the result
            out["spans"] = self._tracer.take(r.trace_id)
        return out

    def op_spans(self, req: dict) -> list:
        """Drain every ringed span (background ingest/erosion work that no
        query response carried home)."""
        return [sp.to_wire() for sp in self._tracer.drain()]

    def op_ingest(self, req: dict) -> dict:
        stream, seg, frames = req["stream"], int(req["seg"]), req["frames"]
        # at-least-once delivery: the router retries an ingest whose ack a
        # crash swallowed, and the respawned stack's adopt_missing already
        # accounted the durable segment — re-running scheduler.ingest
        # would double-count arrivals, mint duplicate bucket credit and
        # enqueue duplicate tasks.  Cluster streams are append-only camera
        # feeds, so a present segment IS the duplicate case.
        if self.scheduler is not None:
            if self.store.has_segment(stream, seg, self.scheduler.golden_id):
                return {"golden_s": 0.0, "duplicate": True}
            golden_s = self.scheduler.ingest(stream, seg, frames)
        else:
            if all(self.store.has_segment(stream, seg, sid)
                   for sid in self.store.formats):
                return {"golden_s": 0.0, "duplicate": True}
            t0 = time.perf_counter()
            self.store.ingest_segment(stream, seg, frames)
            golden_s = time.perf_counter() - t0
        # the ack below is the router's durability receipt: the store index
        # must hit disk before it, or a SIGKILL'd worker would restart
        # without the segment (the shard bytes would be orphan-swept)
        self.store.flush()
        self._flush_index()
        return {"golden_s": golden_s}

    def _flush_index(self) -> None:
        """Make the semantic index durable alongside the store: the
        IndexStore's ack point is its flush (recovery truncates the log
        tail back to the last flushed index), so sketches built or
        invalidated under this op become crash-durable with the same ack
        that makes the segments durable.  A sketch lost anyway (SIGKILL
        between build and flush) is re-queued by ``adopt_missing`` on
        restart — never served torn."""
        if self.index is not None:
            self.index.flush()

    def _sched(self):
        if self.scheduler is None:
            raise RuntimeError("worker built without ingest scheduler")
        return self.scheduler

    def op_pump(self, req: dict) -> int:
        done = self._sched().pump(req.get("max_tasks"))
        if done:
            self.store.flush()  # background materializations now durable
            self._flush_index()
        return done

    def op_drain(self, req: dict) -> int:
        done = self._sched().drain(req.get("include_shed", True))
        if done:
            self.store.flush()
            self._flush_index()
        return done

    def op_requeue_shed(self, req: dict) -> int:
        return self._sched().requeue_shed()

    def op_set_budget(self, req: dict) -> None:
        self._sched().lease.grant(req.get("budget_x"))

    def op_erode_advance(self, req: dict) -> dict:
        if self.erosion is None:
            raise RuntimeError("worker built without erosion executor")
        import dataclasses
        return dataclasses.asdict(self.erosion.advance(req.get("days", 1)))

    def op_stats(self, req: dict) -> dict:
        st = self.server.stats()
        st["store_id"] = self.store.store_id
        st["generation"] = self.generation
        return st

    def op_telemetry(self, req: dict) -> dict:
        """One telemetry frame body (metrics snapshot + SLO state +
        drained alerts) — the router scrapes every shard with this and
        writes the cluster-merged series."""
        return self.server.telemetry_body()

    def op_sample_telemetry(self, req: dict) -> int | None:
        """Force one synchronous durable sample into the shard's own log
        (deterministic test/bench hook; the interval loop is the normal
        path).  Returns the acked seq, or None without a sampler."""
        if self.telemetry is None:
            return None
        return self.telemetry.sample_now()

    def op_flush(self, req: dict) -> None:
        self.store.flush()
        self._flush_index()

    def close(self):
        if self.scheduler is not None:
            self.scheduler.stop()
        if self.telemetry is not None:
            # final synchronous sample while the server is still up, so a
            # clean shutdown's last counters reach the durable series
            self.telemetry.stop(final=True)
        self.server.close()
        self.store.flush()
        self._flush_index()


def shard_worker_main(shard_dir: str, sock_path: str, generation: int,
                      cfg_wire: dict, spec_wire: dict, opts: dict) -> None:
    """Process entry point (importable top-level, as ``spawn`` requires)."""
    if os.environ.get("REPRO_ANALYSIS") == "1":
        # trace lock acquisition orders inside the worker too; the
        # store/scheduler/erosion locks below are created after this
        from ..analysis import runtime as _analysis_runtime
        _analysis_runtime.install()
    else:
        _analysis_runtime = None
    apply_runtime_isolation(opts)
    pin = opts.get("pin_core")
    if pin is not None and hasattr(os, "sched_setaffinity"):
        # one core per shard: the shard process is the unit of parallelism,
        # so its runtime's spin/intra-op threads must not bleed onto cores
        # other shards own (two unpinned workers on a 2-core host slow each
        # other ~1.5x through oversubscription)
        try:
            os.sched_setaffinity(0, {pin % (os.cpu_count() or 1)})
        except OSError:
            pass  # restricted environment; run unpinned
    stack = _ShardStack(shard_dir, generation, cfg_wire, spec_wire, opts)
    stop = threading.Event()

    listener = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    if os.path.exists(sock_path):
        os.remove(sock_path)  # stale socket from a previous generation
    listener.bind(sock_path)
    listener.listen(16)

    def serve(conn: socket.socket):
        try:
            while not stop.is_set():
                try:
                    req = wire.recv_msg(conn)
                except (wire.WireError, OSError):
                    return  # peer went away; not our problem
                op = req.get("op")
                if op == "shutdown":
                    wire.send_msg(conn, {"ok": True, "value": None})
                    stop.set()
                    # connecting to ourselves unblocks accept() below
                    try:
                        poke = socket.socket(socket.AF_UNIX,
                                             socket.SOCK_STREAM)
                        poke.connect(sock_path)
                        poke.close()
                    except OSError:
                        pass
                    return
                handler = getattr(stack, f"op_{op}", None)
                if handler is None:
                    resp = {"ok": False, "error": f"unknown op {op!r}",
                            "trace": ""}
                else:
                    try:
                        ctx = req.pop("_trace", None)
                        if stack.tracing and ctx:
                            # parent this connection thread's spans under
                            # the router's rpc span for the op's duration
                            with stack._tracer.activate(int(ctx[0]),
                                                        int(ctx[1])):
                                resp = {"ok": True, "value": handler(req)}
                        else:
                            resp = {"ok": True, "value": handler(req)}
                    except BaseException as e:  # noqa: BLE001
                        resp = {"ok": False,
                                "error": f"{type(e).__name__}: {e}",
                                "trace": traceback.format_exc()}
                wire.send_msg(conn, resp)
        finally:
            conn.close()

    threads = []
    try:
        while not stop.is_set():
            try:
                conn, _ = listener.accept()
            except OSError:
                break
            t = threading.Thread(target=serve, args=(conn,), daemon=True)
            t.start()
            threads.append(t)
    finally:
        listener.close()
        try:
            os.remove(sock_path)
        except OSError:
            pass
        stack.close()
        if _analysis_runtime is not None:
            # worker-side lock orders can't cross the process exit, so
            # validate them here; stderr reaches the harness/CI log
            for v in _analysis_runtime.check():
                print(f"REPRO_ANALYSIS[worker {shard_dir}]: {v}",
                      file=sys.stderr)
