"""ShardRouter: the scatter-gather frontend of the sharded VStore.

Streams are assigned to shard worker processes by stable hashing
(``crc32(stream) % n_shards`` — the same process-stable idiom the scene
generator uses for stream seeds), so a stream's segments always live in
exactly one worker's store directory and ingest never crosses shards.

Queries scatter: a multi-stream submission fans one sub-query per stream
out to the owning workers over the wire protocol, and the per-stream
``QueryResult``s are gathered and merged deterministically (streams in
sorted order, items tagged with their stream) — bit-identical to running
the same cascades in one process, because each shard runs the unmodified
single-process executor over the unmodified per-stream store.

Workers crash; the router reattaches.  Every RPC that fails at the
connection level triggers a *generation-checked* restart: the router first
re-reads the shard's persisted ``store_id`` through a read-only store
attach (never mutating a directory another process might still own), spawns
a replacement worker with a bumped generation, and verifies the new
worker's ``hello`` reports the same ``store_id`` before retrying the call.
Queries are pure reads over a durable store (golden is written
synchronously), so the retry is safe; a half-finished background transcode
is simply redone by the restarted scheduler's fallback-equivalent paths.
"""

from __future__ import annotations

import dataclasses
import os
import socket
import tempfile
import threading
import time
import zlib
from concurrent.futures import ThreadPoolExecutor

from ..analysis.runtime import allow_block as _allow_block
from ..analytics.query import QueryCost, QueryResult
from ..obs import drift as obs_drift
from ..obs import telemetry as obs_telemetry
from ..obs import trace as obs
from ..obs.metrics import Histogram
from ..serving.server import QueryRequest
from . import wire
from .worker import runtime_env_overrides, shard_worker_main

_CONNECT_TIMEOUT_S = 180.0  # spawn + jax import + store load can be slow

# spawn-time env changes are applied-then-restored around Process.start();
# the lock keeps concurrent spawns from restoring each other's overrides
# out from under an in-flight start
_SPAWN_ENV_MU = threading.Lock()

# the directory containing the repro package (".../src")
_SRC_DIR = os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))))


class ShardError(RuntimeError):
    """An op failed *inside* a worker (the worker itself is healthy)."""


class ShardIdentityError(RuntimeError):
    """A (re)spawned worker is not serving the store we expected."""


def stable_shard(stream: str, n_shards: int) -> int:
    """crc32-based stream -> shard assignment (process-stable, like
    ``scene._stream_seed``)."""
    return zlib.crc32(stream.encode()) % n_shards


def merge_results(per_stream: dict[str, QueryResult]) -> QueryResult:
    """Deterministic gather: combine per-stream results of one logical
    query in sorted-stream order.  Items are tagged with their stream
    (``(stream, seg, ...)``); stage timings/counters sum positionally
    (every sub-query ran the identical cascade); ``wall_s`` is the max —
    the scatter ran them concurrently."""
    items: set = set()
    stages = None
    vsec, wall = 0.0, 0.0
    pruned_segs = pruned_bytes = pruned_cons = 0
    cost = None
    for stream in sorted(per_stream):
        r = per_stream[stream]
        items |= {(stream,) + tuple(it) for it in r.items}
        vsec += r.video_seconds
        wall = max(wall, r.wall_s)
        pruned_segs += r.pruned_segments
        pruned_bytes += r.pruned_bytes
        pruned_cons += r.pruned_conservative
        if cost is None:
            cost = dataclasses.replace(r.cost)
        else:
            cost.add(r.cost)
        if stages is None:
            stages = [dataclasses.replace(s) for s in r.stages]
        else:
            for agg, s in zip(stages, r.stages):
                agg.retrieve_s += s.retrieve_s
                agg.consume_s += s.consume_s
                agg.frames += s.frames
                agg.items += s.items
                agg.segments_scanned += s.segments_scanned
                agg.detect_calls += s.detect_calls
                agg.batched_frames += s.batched_frames
    return QueryResult(items=items, stages=stages or [],
                       video_seconds=vsec, wall_s=wall,
                       pruned_segments=pruned_segs,
                       pruned_bytes=pruned_bytes,
                       pruned_conservative=pruned_cons,
                       cost=cost or QueryCost())


class ShardHost:
    """Parent-side handle of one worker process: spawn, connection pool,
    RPC, and identity-checked restart."""

    def __init__(self, idx: int, shard_dir: str, sock_dir: str,
                 cfg_wire: dict, spec_wire: dict, opts: dict, ctx):
        self.idx = idx
        self.shard_dir = shard_dir
        self.sock_dir = sock_dir
        self.cfg_wire = cfg_wire
        self.spec_wire = spec_wire
        self.opts = opts
        self.ctx = ctx
        self.generation = 0
        self.store_id: str | None = None
        self.restarts = 0
        # worker perf_counter -> router perf_counter (measured at hello);
        # absorbed spans are re-based by this so one timeline lines up
        self.clock_offset = 0.0
        # callbacks(host) run after a successful reattach — a respawned
        # worker reverts to its spawn-time opts, so owners of dynamic
        # state (the cluster ingest coordinator's budget grants) re-apply
        # it here
        self.on_reattach: list = []
        self.process = None
        self.sock_path = ""
        self._idle: list[socket.socket] = []  # guarded-by: _mu
        self._mu = threading.Lock()
        self._restart_mu = threading.Lock()

    # -- lifecycle -----------------------------------------------------------
    def spawn(self) -> None:
        self.sock_path = os.path.join(
            self.sock_dir, f"s{self.idx}-g{self.generation}.sock")
        self.process = self.ctx.Process(
            target=shard_worker_main,
            args=(self.shard_dir, self.sock_path, self.generation,
                  self.cfg_wire, self.spec_wire, self.opts),
            name=f"vstore-shard-{self.idx}", daemon=True)
        # the child's numpy/BLAS initializes during module resolution,
        # before shard_worker_main runs — the isolation knobs must be in
        # the env it inherits, but the *parent's* runtime must not keep
        # them, so apply-then-restore around start()
        overrides = runtime_env_overrides(self.opts)
        # spawned workers re-import repro by name; make sure the package's
        # parent dir reaches them even when this process got it onto
        # sys.path without PYTHONPATH (scoped to the spawn, like the rest)
        paths = os.environ.get("PYTHONPATH", "").split(os.pathsep)
        if _SRC_DIR not in paths:
            overrides["PYTHONPATH"] = os.pathsep.join(
                [_SRC_DIR] + [p for p in paths if p])
        with _SPAWN_ENV_MU:
            saved = {k: os.environ.get(k) for k in overrides}
            os.environ.update(overrides)
            try:
                self.process.start()
            finally:
                for k, v in saved.items():
                    if v is None:
                        os.environ.pop(k, None)
                    else:
                        os.environ[k] = v
        hello = self.call("hello")
        if "mono" in hello:
            # clock alignment must not use the first hello: its round-trip
            # includes worker boot (connect retries), which skews the
            # midpoint by up to half the boot time.  Resample on clean
            # RPCs and keep the lowest-RTT sample — the worker reads its
            # clock roughly mid-flight, so half that round-trip is the
            # best alignment available
            best_rtt = best_off = None
            for _ in range(3):
                s0 = time.perf_counter()
                mono = self.call("hello")["mono"]
                s1 = time.perf_counter()
                if best_rtt is None or s1 - s0 < best_rtt:
                    best_rtt, best_off = s1 - s0, (s0 + s1) / 2 - mono
            self.clock_offset = best_off
        problem = None
        if self.store_id is not None and hello["store_id"] != self.store_id:
            problem = (f"worker serves store {hello['store_id']} but "
                       f"router expected {self.store_id}")
        elif hello["generation"] != self.generation:
            problem = (f"worker generation {hello['generation']} != "
                       f"expected {self.generation}")
        if problem is not None:
            # don't orphan the imposter: it would keep holding the socket
            # and the store directory while the error propagates
            self._drop_connections()
            self.process.terminate()
            self.process.join(timeout=10)
            raise ShardIdentityError(f"shard {self.idx}: {problem}")
        if self.store_id is None:
            self.store_id = hello["store_id"]

    def _dial(self) -> socket.socket:
        deadline = time.monotonic() + _CONNECT_TIMEOUT_S
        while True:
            try:
                s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
                s.connect(self.sock_path)
                return s
            except OSError:
                s.close()
                if self.process is None or not self.process.is_alive():
                    raise ConnectionError(
                        f"shard {self.idx} worker died before accepting "
                        f"connections") from None
                if time.monotonic() > deadline:
                    raise ConnectionError(
                        f"shard {self.idx} worker did not come up within "
                        f"{_CONNECT_TIMEOUT_S:.0f}s") from None
                time.sleep(0.05)

    # -- RPC -----------------------------------------------------------------
    def call(self, op: str, **kw):
        """One request/response over a pooled connection.  Raises
        ``ConnectionError`` when the worker is unreachable (caller decides
        whether to reattach) and ``ShardError`` for in-worker failures.

        With tracing enabled the exchange runs inside an ``rpc:<op>`` span
        whose context rides the frame as ``"_trace"`` — the worker
        activates it, so both sides of the wire share one timeline."""
        if not obs.TRACER.enabled:
            return self._call(op, kw)
        with obs.span(f"rpc:{op}", shard=self.idx):
            kw["_trace"] = list(obs.TRACER.current())
            return self._call(op, kw)

    def _call(self, op: str, kw: dict):
        with self._mu:
            sock = self._idle.pop() if self._idle else None
        if sock is None:
            sock = self._dial()
        try:
            wire.send_msg(sock, {"op": op, **kw})
            resp = wire.recv_msg(sock)
        except (wire.WireError, OSError) as e:
            sock.close()
            raise ConnectionError(f"shard {self.idx}: {e}") from e
        with self._mu:
            self._idle.append(sock)
        if not resp.get("ok"):
            raise ShardError(
                f"shard {self.idx} op {op!r} failed: {resp.get('error')}\n"
                f"{resp.get('trace', '')}")
        return resp.get("value")

    def _drop_connections(self):
        with self._mu:
            idle, self._idle = self._idle, []
        for s in idle:
            s.close()

    # -- restart -------------------------------------------------------------
    def reattach(self) -> None:
        """Identity-checked worker restart after a connection failure.

        Before spawning over the shard directory, the persisted store_id is
        re-read through a *read-only* store attach and checked against the
        identity recorded at first hello — the router must never hand a
        replacement worker a directory that isn't the shard it lost.  The
        replacement runs generation+1; its hello must echo both."""
        with self._restart_mu, _allow_block(
                "reattach is deliberately serialized: the probe and "
                "respawn RPCs (with their connect-retry sleeps) run "
                "under _restart_mu so concurrent callers can't "
                "double-spawn; _restart_mu is never on the query path"):
            # a concurrent caller may have already restarted it
            if self.process is not None and self.process.is_alive():
                try:
                    # analysis: allow[block] reattach is deliberately
                    # serialized: the liveness-probe RPC must happen under
                    # _restart_mu so concurrent callers can't double-spawn;
                    # _restart_mu is never taken on the query path
                    self.call("hello")
                    return
                except ConnectionError:
                    pass
            self._drop_connections()
            if self.process is not None:
                self.process.terminate()
                self.process.join(timeout=10)
            if self.store_id is not None:
                from ..videostore import VideoStore
                disk_id = VideoStore(self.shard_dir, readonly=True).store_id
                if disk_id != self.store_id:
                    raise ShardIdentityError(
                        f"shard {self.idx}: on-disk store_id {disk_id} != "
                        f"recorded {self.store_id}; refusing to respawn")
            self.generation += 1
            self.restarts += 1
            self.spawn()
            for cb in self.on_reattach:
                cb(self)

    def call_retry(self, op: str, **kw):
        """RPC with one identity-checked restart+retry on connection
        failure.  Safe for the router's ops: queries/stats are pure reads
        and ingest rewrites the same deterministic bytes."""
        try:
            return self.call(op, **kw)
        except ConnectionError:
            self.reattach()
            return self.call(op, **kw)

    def kill(self) -> None:
        """Hard-kill the worker (crash injection for tests/benches)."""
        if self.process is not None:
            self.process.kill()
            self.process.join(timeout=10)

    def close(self) -> None:
        try:
            self.call("shutdown")
        except (ConnectionError, ShardError):
            pass
        self._drop_connections()
        if self.process is not None:
            self.process.join(timeout=15)
            if self.process.is_alive():
                self.process.terminate()
                self.process.join(timeout=5)


class ShardRouter:
    """Scatter-gather frontend over ``n_shards`` worker processes."""

    def __init__(self, root: str, config, n_shards: int, *, spec=None,
                 opts: dict | None = None, start_method: str | None = None):
        """``opts`` is forwarded to every worker's stack (workers,
        batch_segments, cache_policy, ingest/budget_x/erosion_plan, ...).
        ``start_method`` defaults to ``$REPRO_CLUSTER_START_METHOD`` or
        ``spawn`` — fork would duplicate jax/thread state into workers."""
        import multiprocessing as mp

        from ..core.knobs import IngestSpec
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        method = start_method or os.environ.get(
            "REPRO_CLUSTER_START_METHOD", "spawn")
        ctx = mp.get_context(method)
        self.root = root
        self.n_shards = n_shards
        self.spec = spec or IngestSpec()
        cfg_wire = wire.config_to_wire(config)
        spec_wire = wire.spec_to_wire(self.spec)
        self.opts = dict(opts or {})
        os.makedirs(root, exist_ok=True)
        # unix-socket paths must stay short (108-byte sun_path limit), so
        # sockets live in their own tmpdir, not under arbitrary roots
        self._sock_dir = tempfile.mkdtemp(prefix="vcluster-")
        # pin_cores=True gives each worker its own core (shard i -> core
        # i mod ncpu): the per-shard process is the unit of parallelism,
        # and unpinned runtimes' spin threads oversubscribe small hosts
        pin = self.opts.pop("pin_cores", False)
        # opts["telemetry_dir"]: every worker samples its own crash-safe
        # series into <dir>/shard-NN.vtl (a respawn reopens the same log,
        # truncating any torn tail); attach_telemetry adds the router's
        # cluster-merged <dir>/cluster.vtl beside them
        self._telemetry_dir = self.opts.pop("telemetry_dir", None)
        self._telemetry: obs_telemetry.TelemetrySampler | None = None
        if self._telemetry_dir:
            os.makedirs(self._telemetry_dir, exist_ok=True)

        def host_opts(i: int) -> dict:
            extra: dict = {"pin_core": i} if pin else {}
            if self._telemetry_dir:
                extra["telemetry_path"] = os.path.join(
                    self._telemetry_dir, f"shard-{i:02d}.vtl")
            return self.opts | extra if extra else self.opts

        self.hosts = [
            ShardHost(i, os.path.join(root, f"shard-{i:02d}"),
                      self._sock_dir, cfg_wire, spec_wire, host_opts(i), ctx)
            for i in range(n_shards)]
        self._pool = ThreadPoolExecutor(
            max_workers=max(2 * n_shards, 8),
            thread_name_prefix="vstore-router")
        self._started = False
        self._t_up = time.perf_counter()

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "ShardRouter":
        if self._started:
            return self
        # spawn all workers concurrently — startup cost is one worker's
        # import time, not the sum
        futs = [self._pool.submit(h.spawn) for h in self.hosts]
        for f in futs:
            f.result()
        self._started = True
        self._t_up = time.perf_counter()
        return self

    def close(self) -> None:
        if self._telemetry is not None:
            # final merged sample while workers can still answer a scrape
            self._telemetry.stop(final=True)
            self._telemetry = None
        futs = [self._pool.submit(h.close) for h in self.hosts]
        for f in futs:
            f.result()
        self._pool.shutdown(wait=True)
        try:
            for name in os.listdir(self._sock_dir):
                os.remove(os.path.join(self._sock_dir, name))
            os.rmdir(self._sock_dir)
        except OSError:
            pass

    def __enter__(self) -> "ShardRouter":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- placement -----------------------------------------------------------
    def shard_of(self, stream: str) -> int:
        return stable_shard(stream, self.n_shards)

    def host_of(self, stream: str) -> ShardHost:
        return self.hosts[self.shard_of(stream)]

    # -- data path ------------------------------------------------------------
    def ingest(self, stream: str, seg: int, frames) -> float:
        """Route one arriving segment to its stream's shard; returns the
        golden durability latency measured in the worker."""
        v = self.host_of(stream).call_retry(
            "ingest", stream=stream, seg=int(seg), frames=frames)
        return v["golden_s"]

    def _sub_query(self, query: str, stream: str, segments, accuracy,
                   ctx: tuple[int, int] | None = None,
                   deadline_ms: float | None = None,
                   slo_class: str = "") -> QueryResult:
        """One per-stream sub-query.  ``ctx`` is the scatter root's trace
        context — runs on pool threads, so it is passed explicitly and
        activated here; the worker ships the sub-query's spans back and
        they are absorbed into the router's ring re-based onto its clock
        (pid = shard idx + 1; pid 0 is the router itself)."""
        req = QueryRequest(query, stream, list(segments), accuracy,
                           deadline_ms=deadline_ms or 0.0,
                           slo_class=slo_class)
        host = self.host_of(stream)
        with obs.TRACER.activate(*(ctx or (0, 0))):
            v = host.call_retry("query", request=req.to_wire())
        spans = v.pop("spans", None)
        if spans and obs.TRACER.enabled:
            obs.TRACER.absorb(spans, pid=host.idx + 1,
                              offset=host.clock_offset)
        return QueryResult.from_wire(v)

    def query(self, query: str, streams, segments: list[int],
              accuracy: float, deadline_ms: float | None = None,
              slo_class: str = "") -> QueryResult:
        """Execute one cascade.  ``streams`` may be a single stream name
        (routed to its shard; result identical to single-process
        ``run_query``) or a list (scatter one sub-query per stream to the
        owning shards, gather, merge deterministically — see
        ``merge_results`` for the tagging).  ``deadline_ms``/``slo_class``
        ride the request to the owning shards: each sub-query runs under
        the deadline (EDF in the shard's consumption queues, hit/miss
        accounted in the shard's SLO telemetry); a class without an
        explicit deadline derives one shard-side from the profiled speeds
        (classes come from ``opts["slo_classes"]``, so every shard derives
        identically)."""
        with obs.span("query", query=query, accuracy=accuracy):
            ctx = obs.TRACER.current() if obs.TRACER.enabled else None
            if isinstance(streams, str):
                return self._sub_query(query, streams, segments, accuracy,
                                       ctx, deadline_ms, slo_class)
            futs = {s: self._pool.submit(self._sub_query, query, s, segments,
                                         accuracy, ctx, deadline_ms,
                                         slo_class) for s in streams}
            return merge_results({s: f.result() for s, f in futs.items()})

    def query_many(self, submissions: list[tuple]) -> list[QueryResult]:
        """Scatter a batch of ``(query, stream(s), segments, accuracy)``
        submissions across the cluster concurrently; gather results in
        submission order.  A submission may carry a fifth element — a dict
        with ``deadline_ms`` and/or ``slo_class`` — to run under an SLO.
        Multi-stream submissions are flattened into per-stream sub-queries
        *here* — pool tasks never submit into their own (bounded) pool,
        which would deadlock once every worker thread held an outer task
        blocked on queued inner ones."""
        tracing = obs.TRACER.enabled
        plans = []  # per submission: (single, [(stream, future)], root span)
        for sub in submissions:
            q, streams, segments, acc = sub[:4]
            slo = sub[4] if len(sub) > 4 else {}
            root = obs.TRACER.start_span("query", query=q,
                                         accuracy=acc) if tracing else None
            ctx = (root.trace_id, root.span_id) if root else None
            names = [streams] if isinstance(streams, str) else list(streams)
            futs = [(s, self._pool.submit(self._sub_query, q, s, segments,
                                          acc, ctx,
                                          slo.get("deadline_ms"),
                                          slo.get("slo_class", "")))
                    for s in names]
            plans.append((isinstance(streams, str), futs, root))
        out = []
        for single, futs, root in plans:
            if single:
                out.append(futs[0][1].result())
            else:
                out.append(merge_results({s: f.result() for s, f in futs}))
            if root is not None:
                obs.TRACER.finish(root)
        return out

    # -- control / observability ----------------------------------------------
    def broadcast(self, op: str, **kw) -> list:
        """Run one op on every shard concurrently (gathered in shard
        order)."""
        futs = [self._pool.submit(h.call_retry, op, **kw)
                for h in self.hosts]
        return [f.result() for f in futs]

    def stats(self) -> dict:
        """Cluster-wide stats: per-shard breakdown plus counters rolled up
        across shards, with the aggregate x-realtime measured against the
        router's own uptime (shards serve concurrently, so their
        video-seconds add but their wall clocks don't).

        Distribution-valued stats roll up distribution-correctly: the
        per-shard latency histograms are bucket-merged (never averaged —
        two skewed shards yield the true cluster p95) and drift reports
        keep each knob's worst observation across shards."""
        per_shard = self.broadcast("stats")
        rollup_keys = ("completed", "rejected", "failed", "collapsed",
                       "deadline_hits", "deadline_misses",
                       "sched_deadline_hits", "sched_deadline_misses",
                       "inflight", "video_seconds", "query_wall_s",
                       "decodes", "coalesced_cfs", "inflight_hits",
                       "decode_bytes", "decode_chunks", "cache_bytes",
                       "sched_enqueued", "sched_deduped",
                       "sched_dispatches", "sched_units",
                       "sched_detect_calls", "sched_frames",
                       "sched_batched_frames", "sched_queue_depth",
                       # shard-local semantic indexes (repro.index): raw
                       # counts sum across shards; every worker emits the
                       # keys (zeros without an index) so this stays total
                       "index_sketches", "index_builds", "index_build_s",
                       "index_lookups", "index_invalidated", "index_bytes",
                       "index_pruned_segments", "index_pruned_bytes",
                       "index_pruned_conservative")
        total = {k: sum(s[k] for s in per_shard) for k in rollup_keys}
        # shared-scheduler ratios recomputed from the summed counters
        # (never averaged across shards — an idle shard's 0.0 would skew
        # a mean), mirrored into a merged gauge view alongside the live
        # admission/queue occupancy sums
        total["sched_fusion_ratio"] = (
            total["sched_deduped"]
            / max(1, total["sched_enqueued"] + total["sched_deduped"]))
        total["sched_batch_occupancy"] = (
            total["sched_frames"] / max(1, total["sched_batched_frames"]))
        gauges = {
            "inflight": total["inflight"],
            "queue_depth": total["sched_queue_depth"],
            "fusion_ratio": total["sched_fusion_ratio"],
            "batch_occupancy": total["sched_batch_occupancy"],
        }
        cache = {k: sum(s["cache"][k] for s in per_shard)
                 for k in ("hits", "richer_hits", "misses", "evictions",
                           "oversize", "inserted_bytes", "lookups")}
        cache["hit_rate"] = ((cache["hits"] + cache["richer_hits"])
                             / max(1, cache["lookups"]))
        latency = Histogram.merge([s["latency"] for s in per_shard
                                   if s.get("latency")])
        drift = obs_drift.merge_reports([s.get("drift") or {}
                                         for s in per_shard])
        uptime = time.perf_counter() - self._t_up
        return {
            "shards": per_shard,
            "n_shards": self.n_shards,
            "generations": [h.generation for h in self.hosts],
            "restarts": sum(h.restarts for h in self.hosts),
            "uptime_s": uptime,
            "aggregate_x_realtime": total["video_seconds"]
            / max(uptime, 1e-9),
            "cache": cache,
            "latency": latency,
            "drift": drift,
            "gauges": gauges,
            **total,
        }

    def telemetry_scrape(self) -> dict:
        """One cluster-merged telemetry frame body: every *live* shard's
        ``telemetry`` op answer merged with ``obs.telemetry.merge_frames``
        (counters sum, histogram buckets sum — percentiles recomputed,
        never averaged), plus per-shard health rows.  Dead shards are
        skipped, not respawned — a monitoring read must never mutate the
        cluster (``call``, not ``call_retry``)."""
        parts: list[dict | None] = []
        shards = []
        for h in self.hosts:
            alive = h.process is not None and h.process.is_alive()
            body = None
            if alive:
                try:
                    body = h.call("telemetry")
                except (ConnectionError, ShardError):
                    alive = False
            parts.append(body)
            shards.append({"shard": h.idx, "alive": alive,
                           "generation": h.generation,
                           "restarts": h.restarts})
        merged = obs_telemetry.merge_frames([p for p in parts if p])
        merged["shards"] = shards
        return merged

    def attach_telemetry(self, interval_s: float = 1.0
                         ) -> obs_telemetry.TelemetrySampler:
        """Start the router's cluster-merged series: a sampler scraping
        every shard each interval into ``<telemetry_dir>/cluster.vtl``
        (the workers' own per-shard logs already run — this is the merged
        view ``vtop`` leads with).  Requires ``opts["telemetry_dir"]``;
        stopped (with a final sample) by ``close``."""
        if not self._telemetry_dir:
            raise RuntimeError("router built without opts['telemetry_dir']")
        if self._telemetry is None:
            log = obs_telemetry.TelemetryLog(
                os.path.join(self._telemetry_dir, "cluster.vtl"))
            self._telemetry = obs_telemetry.TelemetrySampler(
                self.telemetry_scrape, log, interval_s=interval_s)
            self._telemetry.start()
        return self._telemetry

    def harvest_spans(self) -> int:
        """Pull every worker's remaining ringed spans (background
        transcode/erosion work no query response carried) into the
        router's tracer, clock-aligned; returns the number absorbed."""
        n = 0
        for h in self.hosts:
            try:
                spans = h.call_retry("spans")
            except (ShardError, ConnectionError):
                continue  # worker without tracing support/reachability
            n += obs.TRACER.absorb(spans, pid=h.idx + 1,
                                   offset=h.clock_offset)
        return n
