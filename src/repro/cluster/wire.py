"""Cluster wire protocol: length-prefixed msgpack frames + explicit wire
forms for every object that crosses the process boundary.

The sharded store is the first place VStore objects leave their process, so
each layer's payload gets a deliberate serialized form here instead of
pickle: pickle would silently couple the worker to the router's class
layout (and break under ``spawn`` for locally-defined config stand-ins like
the launchers' ``_Log``).  Frames are ``4-byte big-endian length +
msgpack(payload)``; payloads are plain scalars/lists/dicts plus one tagged
extension for numpy arrays (``{_ND_TAG: [shape, dtype, bytes]}``, used to
ship ingest frames without a base64 detour).

Wire forms provided here:

* ``pack``/``unpack`` + ``send_msg``/``recv_msg`` — framing;
* ``config_to_wire``/``config_from_wire`` — a ``DerivedConfig``'s consumer
  plans and SF nodes (knob values only; the receiving worker rebuilds the
  dataclasses and lookup tables);
* ``spec_to_wire``/``spec_from_wire`` — the ``IngestSpec`` grid;
* ``erosion_plan_to_wire``/``..from_wire`` — an ``ErosionPlan`` so workers
  can run cluster-coordinated erosion passes;
* ``QueryResult.to_wire``/``from_wire`` live with the dataclass itself
  (``repro.analytics.query``).
"""

from __future__ import annotations

import dataclasses
import struct

import msgpack
import numpy as np

from ..core.coalesce import SFNode
# analysis: allow[wire-field] DerivedConfig.erosion is deliberately not
# in the config frame: the erosion plan ships separately (opts
# ["erosion_plan"], erosion_plan_to_wire) so workers can rebuild their
# ErosionExecutor without re-deriving the whole config
from ..core.configure import DerivedConfig
from ..core.consumption import Consumer, ConsumerPlan
from ..core.erosion import ErosionPlan
from ..core.knobs import CodingOption, FidelityOption, IngestSpec

_LEN = struct.Struct(">I")
_ND_TAG = "__nd__"
MAX_FRAME = 256 << 20  # corrupt-length guard, not a real payload limit


class WireError(ConnectionError):
    """Framing-level failure (peer closed mid-frame, oversized frame)."""


# -- numpy passthrough -------------------------------------------------------

def _default(obj):
    if isinstance(obj, np.ndarray):
        arr = np.ascontiguousarray(obj)
        return {_ND_TAG: [list(arr.shape), arr.dtype.str, arr.tobytes()]}
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    raise TypeError(f"not wire-serializable: {type(obj).__name__}")


def _object_hook(d):
    nd = d.get(_ND_TAG)
    if nd is not None:
        shape, dtype, raw = nd
        return np.frombuffer(raw, dtype=np.dtype(dtype)).reshape(shape).copy()
    return d


def pack(obj) -> bytes:
    return msgpack.packb(obj, default=_default, use_bin_type=True)


def unpack(blob: bytes):
    return msgpack.unpackb(blob, object_hook=_object_hook, raw=False,
                           strict_map_key=False)


# -- framing over a stream socket -------------------------------------------

def send_msg(sock, obj) -> None:
    blob = pack(obj)
    sock.sendall(_LEN.pack(len(blob)) + blob)


def _recv_exact(sock, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise WireError("peer closed mid-frame")
        buf.extend(chunk)
    return bytes(buf)


def recv_msg(sock):
    n = _LEN.unpack(_recv_exact(sock, _LEN.size))[0]
    if n > MAX_FRAME:
        raise WireError(f"frame of {n} bytes exceeds MAX_FRAME")
    return unpack(_recv_exact(sock, n))


# -- IngestSpec --------------------------------------------------------------

def spec_to_wire(spec: IngestSpec) -> dict:
    return dataclasses.asdict(spec)


def spec_from_wire(d: dict) -> IngestSpec:
    return IngestSpec(**d)


# -- DerivedConfig -----------------------------------------------------------

def _fidelity_to_wire(f: FidelityOption) -> list:
    return [f.quality, f.crop, f.resolution, f.sampling]


def _fidelity_from_wire(v) -> FidelityOption:
    q, crop, res, samp = v
    return FidelityOption(q, crop, res, samp)


def _coding_to_wire(c: CodingOption) -> list:
    return [c.speed, c.keyframe, c.bypass]


def _coding_from_wire(v) -> CodingOption:
    speed, keyframe, bypass = v
    return CodingOption(speed, keyframe, bypass)


@dataclasses.dataclass
class _WireCoalesceLog:
    """Minimal coalesce-log stand-in for a config rebuilt from the wire
    (the coalescing transcript itself stays on the frontend)."""
    nodes: list
    ingest_cost: float = 0.0
    storage_cost: float = 0.0
    rounds: list = dataclasses.field(default_factory=list)
    budget_met: bool = True


def config_to_wire(config: DerivedConfig) -> dict:
    """Serialize the parts of a ``DerivedConfig`` query execution reads:
    consumer plans and SF nodes.  Plans are indexed so node membership
    round-trips as shared references."""
    plan_idx = {id(p): i for i, p in enumerate(config.plans)}
    return {
        "plans": [{
            "op": p.consumer.op, "target": p.consumer.target,
            "cf": _fidelity_to_wire(p.cf), "accuracy": p.accuracy,
            "speed": p.speed,
        } for p in config.plans],
        "nodes": [{
            "fidelity": _fidelity_to_wire(n.fidelity),
            "coding": _coding_to_wire(n.coding),
            "plans": [plan_idx[id(p)] for p in n.plans],
            "golden": n.golden,
        } for n in config.nodes],
        "dct_backend": config.dct_backend,
        "index_ops": (list(config.index_ops)
                      if config.index_ops is not None else None),
    }


def config_from_wire(d: dict) -> DerivedConfig:
    plans = [ConsumerPlan(Consumer(p["op"], p["target"]),
                          _fidelity_from_wire(p["cf"]),
                          p["accuracy"], p["speed"]) for p in d["plans"]]
    nodes = [SFNode(_fidelity_from_wire(n["fidelity"]),
                    _coding_from_wire(n["coding"]),
                    [plans[i] for i in n["plans"]],
                    golden=n["golden"]) for n in d["nodes"]]
    index_ops = d.get("index_ops")
    return DerivedConfig(plans=plans, nodes=nodes,
                         coalesce_log=_WireCoalesceLog(nodes=nodes),
                         dct_backend=d.get("dct_backend"),
                         index_ops=(tuple(index_ops)
                                    if index_ops is not None else None))


# -- ErosionPlan -------------------------------------------------------------

def erosion_plan_to_wire(plan: ErosionPlan) -> dict:
    d = dataclasses.asdict(plan)
    # msgpack maps stringify nothing here (strict_map_key=False lets int
    # keys through), but normalize to lists of [idx, frac] pairs anyway so
    # the wire form is self-describing
    d["fractions"] = [sorted(f.items()) for f in plan.fractions]
    return d


def erosion_plan_from_wire(d: dict) -> ErosionPlan:
    d = dict(d)
    d["fractions"] = [{int(i): float(v) for i, v in pairs}
                      for pairs in d["fractions"]]
    return ErosionPlan(**d)
