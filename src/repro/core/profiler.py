"""Profiling harness (paper §4.2/§4.3): measures, per fidelity option, an
operator's accuracy and consumption speed, and per storage format, its
ingestion cost, storage cost, and retrieval speed for a downstream consumer.

All results are memoized — the paper's configuration overhead reductions
(Fig. 13, §6.4) come from (a) profiling only boundary fidelity options and
(b) memoizing storage-format profiles across coalescing rounds.  The counters
here feed the overhead benchmark.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from ..codec import segment as codec
from ..codec import transform as T
from .knobs import FidelityOption, IngestSpec, StorageFormat


def _analytics():
    """Deferred import: analytics depends on core.knobs, so importing it at
    module scope would cycle through the package inits."""
    from ..analytics.accuracy import f1_score
    from ..analytics.operators import OPERATORS
    from ..analytics.scene import generate_segment
    return f1_score, OPERATORS, generate_segment

GOLDEN_F = FidelityOption("best", 1.0, 720, 1.0)

# Paper §6.1: ops of query A profiled on jackson, query B on dashcam.
DEFAULT_PROFILE_STREAMS = {
    "diff": "jackson", "snn": "jackson", "nn": "jackson",
    "motion": "dashcam", "license": "dashcam", "ocr": "dashcam",
}


@dataclasses.dataclass
class ProfilerStats:
    consumption_runs: int = 0
    storage_runs: int = 0
    memo_hits: int = 0
    wall_seconds: float = 0.0


class Profiler:
    """Measured profiling over procedurally generated sample segments."""

    def __init__(self, spec: IngestSpec | None = None, n_segments: int = 3,
                 streams: dict[str, str] | None = None, repeats: int = 2):
        self.spec = spec or IngestSpec()
        self.n_segments = n_segments
        self.streams = streams or dict(DEFAULT_PROFILE_STREAMS)
        self.repeats = repeats
        self.stats = ProfilerStats()
        self._samples: dict[str, list[np.ndarray]] = {}
        self._golden: dict[tuple, set] = {}
        self._consume: dict[tuple, tuple[float, float]] = {}
        self._storage: dict[tuple, tuple[float, float]] = {}
        self._retrieve: dict[tuple, float] = {}
        self._blob_cache: dict[tuple, list[bytes]] = {}

    # -- samples -------------------------------------------------------------
    def _segments(self, stream: str) -> list[np.ndarray]:
        if stream not in self._samples:
            _, _, generate_segment = _analytics()
            self._samples[stream] = [
                generate_segment(stream, i, self.spec)[0]
                for i in range(self.n_segments)]
        return self._samples[stream]

    def _golden_items(self, op_name: str, stream: str, i: int) -> set:
        key = (op_name, stream, i)
        if key not in self._golden:
            _, OPERATORS, _ = _analytics()
            seg = self._segments(stream)[i]
            self._golden[key] = OPERATORS[op_name].detect(seg, GOLDEN_F,
                                                          self.spec)
        return self._golden[key]

    # -- consumer profile (accuracy + consumption speed) ----------------------
    def consumer_profile(self, op_name: str, f: FidelityOption
                         ) -> tuple[float, float]:
        """Returns (accuracy F1, consumption speed in x-realtime)."""
        key = (op_name, f)
        if key in self._consume:
            self.stats.memo_hits += 1
            return self._consume[key]
        t_start = time.perf_counter()
        f1_score, OPERATORS, _ = _analytics()
        op = OPERATORS[op_name]
        stream = self.streams.get(op_name, "jackson")
        accs, best_t = [], []
        for i, seg in enumerate(self._segments(stream)):
            frames = np.asarray(T.materialize(seg, f, self.spec))
            times = []
            pred = None
            for _ in range(self.repeats):
                t0 = time.perf_counter()
                pred = op.detect(frames, f, self.spec)
                times.append(time.perf_counter() - t0)
            accs.append(f1_score(pred, self._golden_items(op_name, stream, i)))
            best_t.append(min(times))
        acc = float(np.mean(accs))
        speed = self.spec.segment_seconds * len(accs) / max(sum(best_t), 1e-9)
        self._consume[key] = (acc, speed)
        self.stats.consumption_runs += 1
        self.stats.wall_seconds += time.perf_counter() - t_start
        return acc, speed

    def accuracy(self, op_name: str, f: FidelityOption) -> float:
        return self.consumer_profile(op_name, f)[0]

    def consumption_speed(self, op_name: str, f: FidelityOption) -> float:
        return self.consumer_profile(op_name, f)[1]

    # -- storage-format profile ------------------------------------------------
    def _blobs(self, sf: StorageFormat) -> tuple[list[bytes], float]:
        """Encoded sample blobs for a storage format + encode seconds."""
        key = (sf.fidelity, sf.coding)
        if key in self._blob_cache:
            return self._blob_cache[key]
        stream = "jackson"
        blobs, enc_t = [], 0.0
        for seg in self._segments(stream):
            frames = np.asarray(
                T.convert_fidelity(frames_u8=seg, f_from=GOLDEN_F,
                                   f_to=sf.fidelity, spec=self.spec))
            t0 = time.perf_counter()
            if sf.coding.bypass:
                blob = codec.encode_raw(frames)
            else:
                blob = codec.encode_segment(
                    frames, quant_scale=sf.fidelity.quant_scale,
                    keyframe_interval=sf.coding.keyframe,
                    zstd_level=sf.coding.zstd_level)
            enc_t += time.perf_counter() - t0
            blobs.append(blob)
        self._blob_cache[key] = (blobs, enc_t)
        return blobs, enc_t

    def storage_profile(self, sf: StorageFormat) -> tuple[float, float]:
        """Returns (ingest cost: encode-seconds per video-second,
        storage cost: bytes per video-second)."""
        key = (sf.fidelity, sf.coding)
        if key in self._storage:
            self.stats.memo_hits += 1
            return self._storage[key]
        t_start = time.perf_counter()
        blobs, enc_t = self._blobs(sf)
        dur = self.n_segments * self.spec.segment_seconds
        res = (enc_t / dur, sum(len(b) for b in blobs) / dur)
        self._storage[key] = res
        self.stats.storage_runs += 1
        self.stats.wall_seconds += time.perf_counter() - t_start
        return res

    def dispatch_overhead(self, op_name: str = "diff",
                          f: FidelityOption | None = None,
                          n_big: int = 64) -> tuple[float, float]:
        """Measured ``(dispatch_overhead_s, per_frame_s)`` of one operator
        call: the fixed cost of an ``op.detect`` invocation (jit dispatch,
        host<->device staging, Python glue) versus the marginal per-frame
        compute.  Fit from two batch sizes — a single frame (all fixed
        cost) and ``n_big`` frames — with the best of ``repeats`` runs
        after a warm-up, so compile time is excluded.  Feeds
        ``repro.analytics.batch.derive_shapes``: the batched consumer's
        static shape ladder is coarse when dispatch dominates and fine
        when per-frame compute does.  Memoized like the other profiles."""
        if n_big < 2:
            raise ValueError(f"n_big must be >= 2, got {n_big}")
        f = f or GOLDEN_F
        key = ("dispatch", op_name, f, n_big)
        if key in self._consume:
            self.stats.memo_hits += 1
            return self._consume[key]
        t_start = time.perf_counter()
        _, OPERATORS, _ = _analytics()
        op = OPERATORS[op_name]
        stream = self.streams.get(op_name, "jackson")
        seg = self._segments(stream)[0]
        frames = np.asarray(T.materialize(seg, f, self.spec))
        big = frames[np.arange(n_big) % len(frames)]
        times = {1: [], n_big: []}
        for n, batch in ((1, big[:1]), (n_big, big)):
            op.detect(batch, f, self.spec)  # warm the jit cache
            for _ in range(max(2, self.repeats)):
                t0 = time.perf_counter()
                op.detect(batch, f, self.spec)
                times[n].append(time.perf_counter() - t0)
        t1, tn = min(times[1]), min(times[n_big])
        per_frame = max((tn - t1) / (n_big - 1), 1e-9)
        overhead = max(t1 - per_frame, 0.0)
        self._consume[key] = (overhead, per_frame)
        self.stats.consumption_runs += 1
        self.stats.wall_seconds += time.perf_counter() - t_start
        return overhead, per_frame

    def dct_dispatch_cost(self, n_frames: int = 8,
                          resolution: int = 360) -> tuple[float, float]:
        """Measured wall seconds of one fused dct8 dequantize dispatch per
        codec backend: ``(jnp_s, pallas_s)``.  The probe shape defaults to
        a decode-representative chunk (a handful of mid-res frames), NOT a
        tiny one: off-TPU the Pallas kernels run in interpret mode, whose
        per-element cost only shows at realistic sizes — a dispatch-only
        micro-probe would crown the backend that then crawls on real
        segments (interpret-mode Pallas wins 2-frame/64px probes but loses
        >10x at 8-frame/360px).  Best-of-``repeats`` after a warm call per
        backend so compile time is excluded.  Memoized like the other
        profiles; feeds ``derive_config``'s ``DerivedConfig.dct_backend``."""
        key = ("dct_dispatch", n_frames, resolution)
        if key in self._consume:
            self.stats.memo_hits += 1
            return self._consume[key]
        t_start = time.perf_counter()
        from ..kernels.dct8.ops import dct_dequantize
        hb = wb = resolution // 8
        rng = np.random.default_rng(0)
        sym = rng.integers(-32, 32, (n_frames, hb, wb, 8, 8), dtype=np.int16)
        best = {}
        for use_pallas in (False, True):
            np.asarray(dct_dequantize(sym, 2.0, use_pallas=use_pallas))
            times = []
            for _ in range(max(2, self.repeats)):
                t0 = time.perf_counter()
                np.asarray(dct_dequantize(sym, 2.0, use_pallas=use_pallas))
                times.append(time.perf_counter() - t0)
            best[use_pallas] = min(times)
        res = (best[False], best[True])
        self._consume[key] = res
        self.stats.consumption_runs += 1
        self.stats.wall_seconds += time.perf_counter() - t_start
        return res

    def retrieval_speed(self, sf: StorageFormat, cf: FidelityOption) -> float:
        """x-realtime speed of decoding SF (with chunk-skip for the CF's
        sampling) and converting to CF."""
        key = (sf.fidelity, sf.coding, cf)
        if key in self._retrieve:
            self.stats.memo_hits += 1
            return self._retrieve[key]
        t_start = time.perf_counter()
        blobs, _ = self._blobs(sf)
        want = T.temporal_indices(sf.fidelity, cf, self.spec)
        times = []
        for blob in blobs:
            for _ in range(self.repeats):
                t0 = time.perf_counter()
                frames = codec.decode_segment(blob, want)
                np.asarray(T.spatial_convert(frames, sf.fidelity, cf, self.spec))
                times.append(time.perf_counter() - t0)
        per_seg = np.median(np.asarray(times).reshape(len(blobs), -1).min(axis=1))
        speed = self.spec.segment_seconds / max(float(per_seg), 1e-9)
        self._retrieve[key] = speed
        self.stats.storage_runs += 1
        self.stats.wall_seconds += time.perf_counter() - t_start
        return speed


class TableProfiler:
    """Profiler backed by explicit tables — used by unit/property tests and
    by exhaustive-vs-search validation (deterministic, no wall clock)."""

    def __init__(self, acc: dict, cost: dict, storage: dict | None = None,
                 retrieve: dict | None = None):
        self._acc, self._cost = acc, cost
        self._storage = storage or {}
        self._retrieve = retrieve or {}
        self.stats = ProfilerStats()
        self._seen_consumer = set()
        self._seen_storage = set()

    def consumer_profile(self, op, f):
        if (op, f) in self._seen_consumer:
            self.stats.memo_hits += 1
        else:
            self._seen_consumer.add((op, f))
            self.stats.consumption_runs += 1
        return self._acc[(op, f)], self._cost[(op, f)]

    def accuracy(self, op, f):
        return self.consumer_profile(op, f)[0]

    def consumption_speed(self, op, f):
        return self.consumer_profile(op, f)[1]

    def storage_profile(self, sf):
        key = (sf.fidelity, sf.coding)
        if key in self._seen_storage:
            self.stats.memo_hits += 1
        else:
            self._seen_storage.add(key)
            self.stats.storage_runs += 1
        return self._storage[key]

    def retrieval_speed(self, sf, cf):
        return self._retrieve[(sf.fidelity, sf.coding, cf)]
