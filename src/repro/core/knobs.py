"""Video-format knob spaces (paper Table 1).

Fidelity knobs (4): image quality, crop factor, resolution, frame sampling.
Coding knobs (3): speed step, keyframe interval, coding bypass.

A ``FidelityOption`` is a point in the 4D fidelity space F; a ``CodingOption``
is a point in the coding space C.  Storage formats live in F x C; consumption
formats live in F.  The *richer-than* relation is a partial order over F
(knob-wise >=, strict on at least one knob).

Knob values keep the paper's names (e.g. resolution "720p") but map onto a
configurable ``IngestSpec`` pixel grid so the whole system scales from
laptop-size tests to full-resolution runs without touching any algorithm.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Iterable

# ---------------------------------------------------------------------------
# Knob value ladders (paper Table 1). Order = poorest ... richest.
# ---------------------------------------------------------------------------

# Image quality -> quantization scale of the codec (CRF-like).  "best" is
# near-lossless.  Paper: CRF = 50, 40, 23, 0.
QUALITY_VALUES = ("worst", "bad", "good", "best")
QUALITY_QUANT_SCALE = {"worst": 16.0, "bad": 6.0, "good": 2.0, "best": 1.0}

# Crop factor: retain the central crop of this fraction (both axes).
CROP_VALUES = (0.50, 0.75, 1.00)

# Resolution ladder: 10 rungs, paper 60x60 ... 720p.  Stored as the paper's
# nominal vertical resolution; resolved against IngestSpec proportionally.
RESOLUTION_VALUES = (60, 100, 144, 180, 200, 270, 360, 400, 540, 720)

# Frame sampling: fraction of frames consumed.
SAMPLING_VALUES = (1 / 30, 1 / 5, 1 / 2, 2 / 3, 1.0)

# Coding speed step: slowest ... fastest (paper: x264 presets veryslow ...
# ultrafast).  Mapped to zstd level + transform effort in the codec.
SPEED_VALUES = ("slowest", "slow", "med", "fast", "fastest")
SPEED_ZSTD_LEVEL = {"slowest": 19, "slow": 12, "med": 7, "fast": 3, "fastest": 1}

# Keyframe interval (frames per independently-decodable chunk).
KEYFRAME_VALUES = (5, 10, 50, 100, 250)

# Coding bypass: True => store RAW frames (no coding knobs apply).
BYPASS_VALUES = (False, True)

FIDELITY_KNOBS = ("quality", "crop", "resolution", "sampling")
CODING_KNOBS = ("speed", "keyframe", "bypass")

# Index ladders for ordering comparisons.
_LADDER = {
    "quality": QUALITY_VALUES,
    "crop": CROP_VALUES,
    "resolution": RESOLUTION_VALUES,
    "sampling": SAMPLING_VALUES,
}


@dataclasses.dataclass(frozen=True, order=True)
class FidelityOption:
    """A point f in the 4D fidelity space."""

    quality: str = "best"
    crop: float = 1.0
    resolution: int = 720
    sampling: float = 1.0

    def __post_init__(self):
        if self.quality not in QUALITY_VALUES:
            raise ValueError(f"bad quality {self.quality!r}")
        if self.crop not in CROP_VALUES:
            raise ValueError(f"bad crop {self.crop!r}")
        if self.resolution not in RESOLUTION_VALUES:
            raise ValueError(f"bad resolution {self.resolution!r}")
        if self.sampling not in SAMPLING_VALUES:
            raise ValueError(f"bad sampling {self.sampling!r}")

    # -- ordering ----------------------------------------------------------
    def rank(self) -> tuple[int, int, int, int]:
        """Per-knob ladder indices (higher = richer)."""
        return (
            QUALITY_VALUES.index(self.quality),
            CROP_VALUES.index(self.crop),
            RESOLUTION_VALUES.index(self.resolution),
            SAMPLING_VALUES.index(self.sampling),
        )

    def richer_eq(self, other: "FidelityOption") -> bool:
        """True iff self is knob-wise >= other (the richer-than-or-equal
        partial order)."""
        a, b = self.rank(), other.rank()
        return all(x >= y for x, y in zip(a, b))

    def richer(self, other: "FidelityOption") -> bool:
        return self.richer_eq(other) and self != other

    def join(self, other: "FidelityOption") -> "FidelityOption":
        """Knob-wise maximum (least upper bound) — used by SF coalescing."""
        return FidelityOption(
            quality=_max_on(QUALITY_VALUES, self.quality, other.quality),
            crop=_max_on(CROP_VALUES, self.crop, other.crop),
            resolution=_max_on(RESOLUTION_VALUES, self.resolution, other.resolution),
            sampling=_max_on(SAMPLING_VALUES, self.sampling, other.sampling),
        )

    def with_knob(self, knob: str, value) -> "FidelityOption":
        return dataclasses.replace(self, **{knob: value})

    def name(self) -> str:
        q = self.quality
        return f"{q}-{self.resolution}p-{_frac(self.sampling)}-{int(self.crop * 100)}%"

    # quantization scale used by the codec for this quality value
    @property
    def quant_scale(self) -> float:
        return QUALITY_QUANT_SCALE[self.quality]


@dataclasses.dataclass(frozen=True, order=True)
class CodingOption:
    """A point c in the coding space.  ``bypass=True`` means RAW storage; the
    other knobs are then irrelevant and normalized to canonical values so RAW
    is a single point in the space."""

    speed: str = "med"
    keyframe: int = 50
    bypass: bool = False

    def __post_init__(self):
        if self.speed not in SPEED_VALUES:
            raise ValueError(f"bad speed {self.speed!r}")
        if self.keyframe not in KEYFRAME_VALUES:
            raise ValueError(f"bad keyframe {self.keyframe!r}")
        if self.bypass:
            # Normalize: RAW is one canonical point.
            object.__setattr__(self, "speed", "fastest")
            object.__setattr__(self, "keyframe", KEYFRAME_VALUES[0])

    @property
    def zstd_level(self) -> int:
        return SPEED_ZSTD_LEVEL[self.speed]

    def name(self) -> str:
        if self.bypass:
            return "RAW"
        return f"{self.keyframe}-{self.speed}"

    def cheaper_steps(self) -> list["CodingOption"]:
        """Successively cheaper-to-code options (used by budget adaptation):
        faster speed steps first, then RAW."""
        out = []
        i = SPEED_VALUES.index(self.speed)
        for s in SPEED_VALUES[i + 1:]:
            out.append(CodingOption(speed=s, keyframe=self.keyframe))
        out.append(CodingOption(bypass=True))
        return out


RAW = CodingOption(bypass=True)
GOLDEN_CODING = CodingOption(speed="slowest", keyframe=max(KEYFRAME_VALUES))


@dataclasses.dataclass(frozen=True, order=True)
class StorageFormat:
    """SF<f, c>: an on-disk video version."""

    fidelity: FidelityOption
    coding: CodingOption

    def name(self) -> str:
        return f"{self.fidelity.name()}|{self.coding.name()}"


# A consumption format CF<f> is just a FidelityOption; consumers subscribe to
# one.  We alias for readability.
ConsumptionFormat = FidelityOption


# ---------------------------------------------------------------------------
# Spaces
# ---------------------------------------------------------------------------

def fidelity_space() -> list[FidelityOption]:
    """The full 4D fidelity space F (600 options in the paper's ladders)."""
    return [
        FidelityOption(q, c, r, s)
        for q, c, r, s in itertools.product(
            QUALITY_VALUES, CROP_VALUES, RESOLUTION_VALUES, SAMPLING_VALUES
        )
    ]


def coding_space() -> list[CodingOption]:
    """Coding space C: 25 encoded options + RAW."""
    opts = [
        CodingOption(s, k)
        for s, k in itertools.product(SPEED_VALUES, KEYFRAME_VALUES)
    ]
    opts.append(RAW)
    return opts


def storage_space_size() -> int:
    return len(fidelity_space()) * len(coding_space())


def _max_on(ladder: tuple, a, b):
    return ladder[max(ladder.index(a), ladder.index(b))]


def _frac(x: float) -> str:
    for num, den in ((1, 30), (1, 5), (1, 2), (2, 3), (1, 1)):
        if abs(x - num / den) < 1e-9:
            return "1" if den == 1 else f"{num}/{den}"
    return f"{x:.3f}"


# ---------------------------------------------------------------------------
# Ingest spec: resolves paper-ladder knob values onto a concrete pixel grid.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class IngestSpec:
    """The format in which camera streams arrive (paper: 720p30 h264).

    ``height``/``width``/``fps`` define the concrete grid of the *richest*
    fidelity; the paper-named resolution ladder maps proportionally onto it.
    Dimensions snap to multiples of 8 (DCT block size).
    """

    height: int = 96
    width: int = 160
    fps: int = 8
    segment_seconds: int = 4
    nominal: int = 720  # paper-name of the richest rung

    @property
    def frames_per_segment(self) -> int:
        return self.fps * self.segment_seconds

    def resolve(self, f: FidelityOption) -> tuple[int, int, int]:
        """(frames, height, width) of a segment in fidelity ``f``."""
        scale = f.resolution / self.nominal
        h = _snap8(self.height * scale * f.crop)
        w = _snap8(self.width * scale * f.crop)
        n = max(1, round(self.frames_per_segment * f.sampling))
        return n, h, w

    def frame_stride(self, f: FidelityOption) -> int:
        """Temporal stride implied by the sampling knob."""
        n = max(1, round(self.frames_per_segment * f.sampling))
        return max(1, self.frames_per_segment // n)

    def raw_bytes_per_segment(self, f: FidelityOption) -> int:
        n, h, w = self.resolve(f)
        return n * h * w  # uint8 grayscale


def _snap8(x: float) -> int:
    return max(8, int(round(x / 8)) * 8)


# Default reduced-scale spec used by tests & benches (laptop-affordable);
# examples may pass larger specs.
DEFAULT_INGEST = IngestSpec()


def unique_formats(formats: Iterable) -> list:
    """Stable de-dup preserving first-seen order."""
    seen, out = set(), []
    for f in formats:
        if f not in seen:
            seen.add(f)
            out.append(f)
    return out
