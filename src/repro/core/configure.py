"""Backward derivation of the global video-format configuration (paper §4).

    consumers --(§4.2)--> consumption formats
              --(§4.3)--> storage formats (+ ingestion budget)
              --(§4.4)--> data erosion plan (+ storage budget)

`derive_config` runs the three steps and returns a `DerivedConfig` that the
video store installs and query execution reads.
"""

from __future__ import annotations

import dataclasses

from .coalesce import CoalesceResult, SFNode, coalesce
from .consumption import Consumer, ConsumerPlan, derive_all
from .erosion import ErosionPlan, plan_erosion
from .knobs import FidelityOption, StorageFormat

DEFAULT_ACCURACIES = (0.95, 0.90, 0.80, 0.70)
DEFAULT_OPS = ("diff", "snn", "nn", "motion", "license", "ocr")


@dataclasses.dataclass
class DerivedConfig:
    plans: list[ConsumerPlan]
    nodes: list[SFNode]
    coalesce_log: CoalesceResult
    erosion: ErosionPlan | None = None
    # codec transform backend ("jnp" | "pallas") chosen from the profiler's
    # measured dispatch cost (derive_config), not a platform guess; None
    # means "not profiled" and leaves the codec-wide default untouched
    dct_backend: str | None = None
    # cascade-head ops to sketch at ingest (repro.index); None disables
    # ingest-time indexing — queries then never consult a semantic index
    index_ops: tuple[str, ...] | None = None

    # -- derived lookup tables -------------------------------------------------
    def __post_init__(self):
        self._sf_ids: dict[int, str] = {}
        n = 1
        for i, node in enumerate(self.nodes):
            if node.golden:
                self._sf_ids[i] = "sf_g"
            else:
                self._sf_ids[i] = f"sf{n}"
                n += 1
        self._cf_to_node: dict[FidelityOption, int] = {}
        for i, node in enumerate(self.nodes):
            for p in node.plans:
                self._cf_to_node[p.cf] = i
        self._consumer_plan: dict[tuple[str, float], ConsumerPlan] = {
            (p.consumer.op, round(p.consumer.target, 4)): p for p in self.plans}

    # -- public API ---------------------------------------------------------
    def _plan_for(self, op: str, accuracy: float) -> "ConsumerPlan":
        plan = self._consumer_plan.get((op, round(accuracy, 4)))
        if plan is None:
            ops = sorted({o for o, _ in self._consumer_plan})
            accs = sorted({a for _, a in self._consumer_plan}, reverse=True)
            raise KeyError(
                f"no consumer plan for op={op!r} at accuracy={accuracy}; "
                f"this configuration profiled ops {ops} "
                f"at accuracies {accs}")
        return plan

    def consumption_format(self, op: str, accuracy: float) -> FidelityOption:
        return self._plan_for(op, accuracy).cf

    def consumer_speed(self, op: str, accuracy: float) -> float:
        return self._plan_for(op, accuracy).speed

    def subscription(self, cf: FidelityOption) -> str:
        return self._sf_ids[self._cf_to_node[cf]]

    def storage_formats(self) -> dict[str, StorageFormat]:
        return {self._sf_ids[i]: n.sf for i, n in enumerate(self.nodes)}

    def node_id(self, idx: int) -> str:
        return self._sf_ids[idx]

    def subscriptions_by_node(self) -> dict[str, list[ConsumerPlan]]:
        return {self._sf_ids[i]: list(n.plans)
                for i, n in enumerate(self.nodes)}

    def table(self) -> str:
        """Human-readable Table-2-style snapshot."""
        lines = ["== consumption formats =="]
        for p in sorted(self.plans, key=lambda p: (p.consumer.op,
                                                   -p.consumer.target)):
            lines.append(
                f"  {p.consumer.name():14s} cf={p.cf.name():24s} "
                f"acc={p.accuracy:.2f} speed={p.speed:9.1f}x "
                f"-> {self.subscription(p.cf)}")
        lines.append("== storage formats ==")
        for i, n in enumerate(self.nodes):
            lines.append(f"  {self._sf_ids[i]:5s} {n.sf.name()}"
                         f"{'  [golden]' if n.golden else ''}")
        return "\n".join(lines)


def derive_config(profiler,
                  ops: tuple[str, ...] = DEFAULT_OPS,
                  accuracies: tuple[float, ...] = DEFAULT_ACCURACIES,
                  ingest_budget: float | None = None,
                  storage_budget_bytes: float | None = None,
                  lifespan_days: int = 10,
                  daily_video_seconds: float = 86400.0) -> DerivedConfig:
    """Run the full backward derivation."""
    consumers = [Consumer(op, a) for op in ops for a in accuracies]

    # 1. consumption formats (optimize consumption speed)
    plans = derive_all(profiler, consumers)

    # 2. storage formats (optimize storage, respect ingestion budget)
    result = coalesce(profiler, plans, ingest_budget=ingest_budget)
    cfg = DerivedConfig(plans=plans, nodes=result.nodes, coalesce_log=result)

    # 2b. codec kernel backend: pick jnp vs Pallas from the profiler's
    # *measured* dct8 dispatch cost instead of the platform-guessing
    # default ("auto" -> pallas iff TPU), and install it codec-wide so the
    # configuration's decode/encode estimates match what serving runs.
    # Table-backed profilers (tests) have no wall clock and skip this.
    if hasattr(profiler, "dct_dispatch_cost"):
        from ..codec.transform import set_dct_backend
        jnp_s, pallas_s = profiler.dct_dispatch_cost()
        cfg.dct_backend = "pallas" if pallas_s < jnp_s else "jnp"
        set_dct_backend(cfg.dct_backend)

    # 3. erosion plan (respect storage budget)
    if storage_budget_bytes is not None:
        subs = {}
        for i, node in enumerate(result.nodes):
            for p in node.plans:
                subs[p] = i
        daily = []
        for node in result.nodes:
            _, bytes_per_sec = profiler.storage_profile(node.sf)
            daily.append(bytes_per_sec * daily_video_seconds)
        cfg.erosion = plan_erosion(
            profiler, result.nodes, subs, daily, lifespan_days,
            storage_budget_bytes)
    return cfg
