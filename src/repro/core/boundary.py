"""Accuracy-boundary search in a monotone 2D space (paper §4.2, Fig. 8).

Accuracy is (assumed) monotone non-decreasing along both axes of a
(sampling x resolution) grid.  The *accuracy boundary* is, per row, the
poorest column whose accuracy is adequate.  A staircase walk starting at the
richest row probes O(rows + cols) cells instead of rows x cols: as the row
gets poorer, the minimal adequate column can only move richer, so the column
pointer never moves left.

Unlike the classic saddleback search for a single element, VStore must
traverse the *entire* boundary: every minimal adequate point is a candidate,
because adequacy does not imply minimal consumption cost (paper §4.2).
"""

from __future__ import annotations

from typing import Callable


def boundary_search(n_rows: int, n_cols: int,
                    adequate: Callable[[int, int], bool]
                    ) -> tuple[list[tuple[int, int]], int]:
    """Walk the accuracy boundary of a monotone grid.

    ``adequate(r, c)`` probes the cell with row ``r`` (poorest row = 0) and
    column ``c`` (poorest col = 0); both axes are monotone: if (r, c) is
    adequate then any (r', c') with r' >= r, c' >= c is adequate.

    Returns (boundary points, number of probes).  Boundary points are the
    per-row minimal adequate cells (for rows that have any adequate cell).
    """
    probes = 0
    points: list[tuple[int, int]] = []
    c = 0  # minimal adequate column so far, scanning rows richest -> poorest
    for r in range(n_rows - 1, -1, -1):
        # advance c to the minimal adequate column for this row
        found = None
        while c < n_cols:
            probes += 1
            if adequate(r, c):
                found = (r, c)
                break
            c += 1
        if found is None:
            break  # no adequate cell in this row; poorer rows can't have any
        points.append(found)
    return points, probes
