"""Storage-format derivation by iterative pairwise coalescing (paper §4.3).

Start from one SF per unique CF (identical fidelity) plus the *golden* SF
(knob-wise max fidelity of all CFs, slowest coding).  Repeatedly coalesce
pairs: the coalesced fidelity is the knob-wise max (R1); its coding is the
cheapest-storage option whose retrieval speed still exceeds every downstream
consumer's consumption speed (R2), falling back to RAW.  Phase 1 merges pairs
that cut ingestion cost without increasing storage cost; if an ingestion
budget is exceeded, phase 2 first cheapens coding (faster speed steps, then
RAW) and then keeps coalescing at the expense of storage (paper Table 3).
"""

from __future__ import annotations

import dataclasses
import itertools

from .consumption import ConsumerPlan
from .knobs import (GOLDEN_CODING, KEYFRAME_VALUES, RAW, SPEED_VALUES,
                    CodingOption, FidelityOption, StorageFormat)


@dataclasses.dataclass
class SFNode:
    fidelity: FidelityOption
    coding: CodingOption
    plans: list[ConsumerPlan]          # downstream consumers
    golden: bool = False

    @property
    def sf(self) -> StorageFormat:
        return StorageFormat(self.fidelity, self.coding)

    def cfs(self) -> list[FidelityOption]:
        return sorted({p.cf for p in self.plans})


@dataclasses.dataclass
class CoalesceResult:
    nodes: list[SFNode]
    ingest_cost: float      # encode-seconds per video-second (all SFs)
    storage_cost: float     # bytes per video-second (all SFs)
    rounds: list[dict]      # log for benchmarks
    budget_met: bool = True


def _coding_candidates():
    """Coding options in (approximately) ascending storage cost: slower
    speed steps compress better; larger keyframe intervals store fewer intra
    frames.  RAW is the terminal fallback."""
    for speed in SPEED_VALUES:                       # slowest ... fastest
        for k in sorted(KEYFRAME_VALUES, reverse=True):
            yield CodingOption(speed, k)
    yield RAW


def choose_coding(profiler, fidelity: FidelityOption,
                  plans: list[ConsumerPlan],
                  min_speed_idx: int = 0) -> CodingOption | None:
    """Cheapest-storage coding whose retrieval speed exceeds every
    subscribed consumer's consumption speed.  ``min_speed_idx`` restricts to
    speed steps at least that cheap (used by budget adaptation)."""
    for coding in _coding_candidates():
        if not coding.bypass and SPEED_VALUES.index(coding.speed) < min_speed_idx:
            continue
        sf = StorageFormat(fidelity, coding)
        ok = all(profiler.retrieval_speed(sf, p.cf) > p.speed for p in plans)
        if ok:
            return coding
    return None


def _unique_nodes(plans: list[ConsumerPlan], profiler) -> list[SFNode]:
    by_cf: dict[FidelityOption, list[ConsumerPlan]] = {}
    for p in plans:
        by_cf.setdefault(p.cf, []).append(p)
    nodes = []
    for cf, ps in sorted(by_cf.items()):
        coding = choose_coding(profiler, cf, ps) or RAW
        nodes.append(SFNode(cf, coding, ps))
    return nodes


def _golden_node(plans: list[ConsumerPlan]) -> SFNode:
    fg = plans[0].cf
    for p in plans[1:]:
        fg = fg.join(p.cf)
    return SFNode(fg, GOLDEN_CODING, [], golden=True)


def _costs(profiler, nodes: list[SFNode]) -> tuple[float, float]:
    ing = sto = 0.0
    for n in nodes:
        i, s = profiler.storage_profile(n.sf)
        ing += i
        sto += s
    return ing, sto


def _merge(profiler, a: SFNode, b: SFNode, min_speed_idx: int = 0
           ) -> SFNode | None:
    fidelity = a.fidelity.join(b.fidelity)
    plans = a.plans + b.plans
    coding = (GOLDEN_CODING if (a.golden or b.golden) and not plans else
              choose_coding(profiler, fidelity, plans, min_speed_idx))
    if coding is None:
        return None
    if (a.golden or b.golden):
        # merging into golden keeps golden status; coding must still serve
        # the union's consumers (checked above)
        node = SFNode(fidelity, coding, plans, golden=True)
        if not plans:
            node.coding = GOLDEN_CODING
        return node
    return SFNode(fidelity, coding, plans)


def coalesce(profiler, plans: list[ConsumerPlan],
             ingest_budget: float | None = None,
             min_speed_idx: int = 0) -> CoalesceResult:
    nodes = _unique_nodes(plans, profiler) + [_golden_node(plans)]
    rounds: list[dict] = []

    # Phase 1: merge while some pair cuts ingest without growing storage.
    while True:
        ing0, sto0 = _costs(profiler, nodes)
        best = None
        for i, j in itertools.combinations(range(len(nodes)), 2):
            m = _merge(profiler, nodes[i], nodes[j], min_speed_idx)
            if m is None:
                continue
            mi, ms = profiler.storage_profile(m.sf)
            ai, as_ = profiler.storage_profile(nodes[i].sf)
            bi, bs = profiler.storage_profile(nodes[j].sf)
            d_ing, d_sto = mi - ai - bi, ms - as_ - bs
            if d_ing < 0 and d_sto <= 0:
                if best is None or (d_ing, d_sto) < (best[0], best[1]):
                    best = (d_ing, d_sto, i, j, m)
        if best is None:
            break
        _, _, i, j, m = best
        rounds.append({"phase": 1, "merged": (nodes[i].sf.name(),
                                              nodes[j].sf.name()),
                       "into": m.sf.name()})
        nodes = [n for k, n in enumerate(nodes) if k not in (i, j)] + [m]

    # Phase 2: respect the ingestion budget.
    budget_met = True
    if ingest_budget is not None:
        guard = 0
        while True:
            ing, sto = _costs(profiler, nodes)
            if ing <= ingest_budget:
                break
            guard += 1
            if guard > 200:
                budget_met = False
                break
            step = _cheapen_step(profiler, nodes) or \
                _forced_merge_step(profiler, nodes, min_speed_idx)
            if step is None:
                budget_met = False
                break
            kind, payload = step
            if kind == "cheapen":
                idx, coding = payload
                rounds.append({"phase": 2, "cheapen": nodes[idx].sf.name(),
                               "to": coding.name()})
                nodes[idx].coding = coding
            else:
                i, j, m = payload
                rounds.append({"phase": 2,
                               "merged": (nodes[i].sf.name(),
                                          nodes[j].sf.name()),
                               "into": m.sf.name()})
                nodes = [n for k, n in enumerate(nodes) if k not in (i, j)] + [m]

    ing, sto = _costs(profiler, nodes)
    return CoalesceResult(nodes=nodes, ingest_cost=ing, storage_cost=sto,
                          rounds=rounds, budget_met=budget_met)


def _cheapen_step(profiler, nodes):
    """Best single-SF coding cheapening: max ingest reduction, tie-break min
    storage increase.  Keeps R2 satisfied (verified per candidate)."""
    best = None
    for idx, n in enumerate(nodes):
        if n.coding.bypass:
            continue
        i0, s0 = profiler.storage_profile(n.sf)
        for coding in n.coding.cheaper_steps():
            sf2 = StorageFormat(n.fidelity, coding)
            if not all(profiler.retrieval_speed(sf2, p.cf) > p.speed
                       for p in n.plans):
                continue
            i1, s1 = profiler.storage_profile(sf2)
            d_ing, d_sto = i1 - i0, s1 - s0
            if d_ing < 0:
                key = (d_ing, d_sto)
                if best is None or key < best[0]:
                    best = (key, idx, coding)
            break  # only the next cheaper feasible step per node
    if best is None:
        return None
    _, idx, coding = best
    return "cheapen", (idx, coding)


def _forced_merge_step(profiler, nodes, min_speed_idx):
    """Coalesce the pair with the smallest storage growth that reduces
    ingestion cost (budget pressure: storage is traded for ingest)."""
    best = None
    for i, j in itertools.combinations(range(len(nodes)), 2):
        m = _merge(profiler, nodes[i], nodes[j], min_speed_idx)
        if m is None:
            continue
        mi, ms = profiler.storage_profile(m.sf)
        ai, as_ = profiler.storage_profile(nodes[i].sf)
        bi, bs = profiler.storage_profile(nodes[j].sf)
        d_ing, d_sto = mi - ai - bi, ms - as_ - bs
        if d_ing < 0:
            key = (d_sto, d_ing)
            if best is None or key < best[0]:
                best = (key, i, j, m)
    if best is None:
        return None
    _, i, j, m = best
    return "merge", (i, j, m)
