"""The paper's primary contribution: VStore's backward derivation of the
video-format configuration (consumption formats -> storage formats ->
erosion plan), plus the knob spaces and profiling harness it runs on."""

from .boundary import boundary_search
from .coalesce import CoalesceResult, SFNode, choose_coding, coalesce
from .configure import (DEFAULT_ACCURACIES, DEFAULT_OPS, DerivedConfig,
                        derive_config)
from .consumption import Consumer, ConsumerPlan, derive_all
from .erosion import ErosionPlan, plan_erosion, recovery_cost
from .knobs import (CodingOption, FidelityOption, IngestSpec, StorageFormat,
                    coding_space, fidelity_space)
from .profiler import Profiler, TableProfiler

__all__ = [
    "boundary_search", "coalesce", "choose_coding", "CoalesceResult",
    "SFNode", "derive_config", "DerivedConfig", "DEFAULT_ACCURACIES",
    "DEFAULT_OPS", "Consumer", "ConsumerPlan", "derive_all", "ErosionPlan",
    "plan_erosion", "recovery_cost", "FidelityOption", "CodingOption",
    "StorageFormat",
    "IngestSpec", "fidelity_space", "coding_space", "Profiler",
    "TableProfiler",
]
