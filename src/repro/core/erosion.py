"""Age-based data erosion planning (paper §4.4).

Storage formats form a *richer-than* tree rooted at the golden format (never
eroded).  A consumer whose format lost a segment falls back to the nearest
ancestor that still holds it — accuracy is preserved (richer fidelity, R1)
but effective speed decays.  The planner:

  * computes each consumer's relative speed under per-format erosion
    fractions (generalized  α/((1-p)α+p)  across a fallback chain),
  * defines overall speed as the max-min-fair minimum across consumers,
  * sets per-age targets with the power law  P(x) = (1-Pmin)·x^(-k) + Pmin,
  * erodes, per age, whichever format least hurts the currently-slowest
    consumer until the age's target is reached (fair-scheduler style),
  * binary-searches the smallest decay factor k whose accumulated storage
    cost over the lifespan fits the storage budget.
"""

from __future__ import annotations

import dataclasses

from .coalesce import SFNode
from .consumption import ConsumerPlan

STEP = 0.05  # erosion-fraction quantum
K_MAX = 8.0


@dataclasses.dataclass
class ErosionPlan:
    k: float
    ages: list[int]
    fractions: list[dict[int, float]]   # per age: node index -> eroded frac
    overall_speed: list[float]          # per age
    daily_bytes: list[float]            # per age, after erosion
    total_bytes: float
    feasible: bool


class _Chains:
    """Fallback chains + speed math shared by planning and evaluation."""

    def __init__(self, profiler, nodes: list[SFNode],
                 subscriptions: dict[ConsumerPlan, int]):
        self.nodes = nodes
        self.golden_idx = next(i for i, n in enumerate(nodes) if n.golden)
        self.parent = self._build_tree()
        # consumer -> (chain of node indices, speeds along chain)
        self.chains: list[tuple[ConsumerPlan, list[int], list[float]]] = []
        for plan, idx in subscriptions.items():
            chain = [idx]
            while chain[-1] != self.golden_idx:
                chain.append(self.parent[chain[-1]])
            speeds = []
            for ni in chain:
                ret = profiler.retrieval_speed(self.nodes[ni].sf, plan.cf)
                speeds.append(min(ret, plan.speed))
            self.chains.append((plan, chain, speeds))

    def _build_tree(self) -> dict[int, int]:
        parent = {}
        for i, n in enumerate(self.nodes):
            if n.golden:
                continue
            cands = [j for j, m in enumerate(self.nodes)
                     if j != i and m.fidelity.richer_eq(n.fidelity)]
            # nearest ancestor: minimal fidelity among richer candidates
            def _key(j):
                return (sum(self.nodes[j].fidelity.rank()), j)
            parent[i] = min(cands, key=_key)
        return parent

    def relative_speed(self, plan_i: int, e: dict[int, float]) -> float:
        _, chain, speeds = self.chains[plan_i]
        t, survive = 0.0, 1.0
        for ni, v in zip(chain, speeds):
            frac_here = survive * (1.0 - e.get(ni, 0.0))
            t += frac_here / max(v, 1e-12)
            survive *= e.get(ni, 0.0)
        v0 = speeds[0]
        return 1.0 / max(v0 * t, 1e-12)

    def overall(self, e: dict[int, float]) -> float:
        if not self.chains:
            return 1.0
        return min(self.relative_speed(i, e) for i in range(len(self.chains)))

    def p_min(self) -> float:
        e_full = {i: 1.0 for i, n in enumerate(self.nodes) if not n.golden}
        return self.overall(e_full)


def recovery_cost(profiler, nodes: list[SFNode],
                  subscriptions: dict[ConsumerPlan, int]) -> dict[int, float]:
    """Per-node fleet slowdown if that node is entirely absent and every
    read is served over its fallback chain: ``1 - overall({i: 1.0})``.

    This is the same chain math the erosion planner optimizes with, reused
    by the ingest scheduler to rank transcode work: a format whose absence
    barely slows the fleet is cheap to recover (its ancestor serves reads
    nearly as fast), so under transcode-budget pressure it is shed first.
    Golden is never shed and scores +inf."""
    chains = _Chains(profiler, nodes, subscriptions)
    out: dict[int, float] = {}
    for i, n in enumerate(nodes):
        if n.golden:
            out[i] = float("inf")
        else:
            out[i] = max(0.0, 1.0 - chains.overall({i: 1.0}))
    return out


def _erode_to_target(chains: _Chains, e: dict[int, float], target: float
                     ) -> dict[int, float]:
    """Fair-scheduler erosion: repeatedly erode the format that least hurts
    the currently slowest consumer, until overall speed <= target."""
    e = dict(e)
    while chains.overall(e) > target + 1e-9:
        cands = [i for i, n in enumerate(chains.nodes)
                 if not n.golden and e.get(i, 0.0) < 1.0 - 1e-9]
        if not cands:
            break
        q = min(range(len(chains.chains)),
                key=lambda i: chains.relative_speed(i, e))
        best = None
        for f in cands:
            e2 = dict(e)
            e2[f] = min(1.0, e2.get(f, 0.0) + STEP)
            hurt_q = chains.relative_speed(q, e) - chains.relative_speed(q, e2)
            freed = 1.0  # tie-break below uses storage weight
            key = (hurt_q, -freed)
            if best is None or key < best[0]:
                best = (key, f, e2)
        e = best[2]
    return e


def plan_erosion(profiler, nodes: list[SFNode],
                 subscriptions: dict[ConsumerPlan, int],
                 daily_bytes_per_node: list[float],
                 lifespan_days: int,
                 storage_budget_bytes: float) -> ErosionPlan:
    chains = _Chains(profiler, nodes, subscriptions)
    p_min = chains.p_min()
    ages = list(range(1, lifespan_days + 1))

    def build(k: float) -> ErosionPlan:
        e: dict[int, float] = {}
        fractions, speeds, daily = [], [], []
        for x in ages:
            target = (1.0 - p_min) * (x ** -k) + p_min if k > 0 else 1.0
            e = _erode_to_target(chains, e, target)
            fractions.append(dict(e))
            speeds.append(chains.overall(e))
            daily.append(sum(b * (1.0 - e.get(i, 0.0))
                             for i, b in enumerate(daily_bytes_per_node)))
        total = sum(daily)
        return ErosionPlan(k=k, ages=ages, fractions=fractions,
                           overall_speed=speeds, daily_bytes=daily,
                           total_bytes=total,
                           feasible=total <= storage_budget_bytes)

    flat = build(0.0)
    if flat.feasible:
        return flat

    lo, hi = 0.0, K_MAX
    best = build(K_MAX)
    if not best.feasible:
        return best  # even max decay cannot fit the budget
    for _ in range(24):
        mid = (lo + hi) / 2
        plan = build(mid)
        if plan.feasible:
            best, hi = plan, mid
        else:
            lo = mid
    return best
