"""Consumption-format derivation (paper §4.2).

For each consumer ⟨operator, target accuracy⟩ find the fidelity f0 with
adequate accuracy and minimum consumption cost:

  i)   fix image quality at its richest value (O2: quality does not affect
       consumption cost),
  ii)  partition the remaining 3D space along the shortest dimension (crop),
  iii) in each 2D (sampling x resolution) plane walk the accuracy boundary
       (boundary_search) profiling only probed cells,
  iv)  among all adequate boundary points pick the minimum consumption cost,
  v)   then lower image quality as far as accuracy stays adequate (reduces
       storage-side costs opportunistically without touching consumption
       cost).
"""

from __future__ import annotations

import dataclasses

from .boundary import boundary_search
from .knobs import (CROP_VALUES, QUALITY_VALUES, RESOLUTION_VALUES,
                    SAMPLING_VALUES, FidelityOption)


@dataclasses.dataclass(frozen=True)
class Consumer:
    op: str
    target: float

    def name(self) -> str:
        return f"{self.op}@{self.target:.2f}"


@dataclasses.dataclass(eq=False)  # identity hash: plans key subscriptions
class ConsumerPlan:
    consumer: Consumer
    cf: FidelityOption
    accuracy: float
    speed: float  # consumption speed, x-realtime


def derive_consumption_format(profiler, consumer: Consumer) -> ConsumerPlan:
    op, target = consumer.op, consumer.target
    best_q = QUALITY_VALUES[-1]

    candidates: list[tuple[float, FidelityOption]] = []
    for crop in CROP_VALUES:
        def adequate(r: int, c: int, _crop=crop) -> bool:
            f = FidelityOption(best_q, _crop, RESOLUTION_VALUES[c],
                               SAMPLING_VALUES[r])
            return profiler.accuracy(op, f) >= target

        points, _ = boundary_search(len(SAMPLING_VALUES),
                                    len(RESOLUTION_VALUES), adequate)
        for r, c in points:
            f = FidelityOption(best_q, crop, RESOLUTION_VALUES[c],
                               SAMPLING_VALUES[r])
            acc, speed = profiler.consumer_profile(op, f)
            candidates.append((speed, f))

    if not candidates:  # golden fidelity is adequate by construction
        f = FidelityOption()
        acc, speed = profiler.consumer_profile(op, f)
        return ConsumerPlan(consumer, f, acc, speed)

    # max consumption speed = min consumption cost; tie-break to the poorest
    # fidelity (lower storage-side cost downstream)
    speed0, f0 = max(candidates, key=lambda t: (t[0], -sum(t[1].rank())))

    # v) lower image quality to the minimum that stays adequate
    chosen = f0
    for q in reversed(QUALITY_VALUES[:-1]):  # good, bad, worst
        f_try = chosen.with_knob("quality", q)
        if profiler.accuracy(op, f_try) >= target:
            chosen = f_try
        else:
            break

    acc, speed = profiler.consumer_profile(op, chosen)
    return ConsumerPlan(consumer, chosen, acc, speed)


def derive_all(profiler, consumers: list[Consumer]) -> list[ConsumerPlan]:
    """Derive CFs for every consumer.  Profiling results are memoized inside
    the profiler, so one operator's multiple accuracy levels share runs
    (paper §4.2 'further optimization')."""
    return [derive_consumption_format(profiler, c) for c in consumers]
