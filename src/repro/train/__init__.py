from .optimizer import AdamWConfig, apply_updates, init_opt_state
from .train_step import (compress_int8, init_feedback, make_serve_step,
                         make_train_step)

__all__ = [
    "AdamWConfig", "init_opt_state", "apply_updates", "make_train_step",
    "make_serve_step", "compress_int8", "init_feedback",
]
