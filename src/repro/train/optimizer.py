"""AdamW with linear-warmup cosine decay, hand-rolled on pytrees.

Moments can be kept in bf16 (halves optimizer HBM — used for arctic-480b);
update math is always fp32.  Moment sharding (ZeRO-1) is applied by the
train-step's out_shardings, not here: the optimizer is sharding-agnostic.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    moment_dtype: Any = jnp.float32


def init_opt_state(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def _schedule(step, cfg: AdamWConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else 1.0
    lr = _schedule(step, cfg)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mh, vh = m32 / b1c, v32 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m32.astype(m.dtype),
                v32.astype(v.dtype))

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, metrics
