"""Training and serving step functions (the units the dry-run lowers).

``make_train_step`` builds a jit-able  (params, opt_state, batch) ->
(params, opt_state, metrics)  closure with:

* microbatching — ``lax.scan`` over gradient-accumulation slices,
* remat — handled inside the model's layer scan,
* optional gradient compression (int8 + error feedback) before the
  data-parallel mean (the all-reduce itself is expressed by sharding).

``make_serve_step`` builds (params, batch, cache) -> (next_token, cache).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models import decode_step, lm_loss
from ..models.config import ArchConfig
from .optimizer import AdamWConfig, apply_updates


def _split_micro(batch: dict, n_micro: int):
    def f(x):
        if x.ndim >= 2 and x.shape[0] == 3:  # mrope (3, B, S)
            b = x.shape[1]
            return x.reshape((3, n_micro, b // n_micro) + x.shape[2:]) \
                .swapaxes(0, 1)
        b = x.shape[0]
        return x.reshape((n_micro, b // n_micro) + x.shape[1:])
    return jax.tree.map(f, batch)


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig,
                    n_micro: int = 1, moe_dispatch: str = "scatter",
                    compress: str | None = None):
    """Returns train_step(params, opt_state, batch) -> (p, s, metrics).

    ``compress='int8'`` quantizes gradients (per-leaf scale, error feedback
    carried in ``opt_state['fb']``) before the optimizer; together with the
    data-parallel mean this cuts gradient-reduction bytes 4x.
    """

    def loss_fn(params, micro_batch):
        return lm_loss(params, cfg, micro_batch, moe_dispatch=moe_dispatch)

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            micro = _split_micro(batch, n_micro)

            def acc_step(carry, mb):
                g_acc, l_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss), _ = jax.lax.scan(acc_step, (g0, 0.0), micro)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss / n_micro
        if compress == "int8":
            grads, fb = compress_int8(grads, opt_state["fb"])
            opt_state = dict(opt_state, fb=fb)
        params, new_state, metrics = apply_updates(
            params, grads, {k: v for k, v in opt_state.items() if k != "fb"},
            opt_cfg)
        if compress == "int8":
            new_state["fb"] = opt_state["fb"]
        metrics["loss"] = loss
        return params, new_state, metrics

    return train_step


def make_serve_step(cfg: ArchConfig, moe_dispatch: str = "dense",
                    greedy: bool = True):
    """Returns serve_step(params, batch, cache) -> (token (B,), cache).
    This is the function lowered for decode_* / long_* dry-run shapes."""

    def serve_step(params, batch, cache):
        logits, cache = decode_step(params, cfg, batch, cache,
                                    moe_dispatch=moe_dispatch)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return token, cache

    return serve_step


# ---------------------------------------------------------------------------
# Gradient compression (beyond-paper distributed-optimization trick)
# ---------------------------------------------------------------------------

def init_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_int8(grads, feedback):
    """Quantize gradients to int8 with per-leaf scale and error feedback.

    The quantize -> (data-parallel reduce) -> dequantize route cuts
    gradient-reduction bytes 4x (fp32) / 2x (bf16); error feedback keeps the
    bias bounded by adding each round's residual to the next round's
    gradient.  Returns (dequantized grads, new feedback).
    """

    def q(g, e):
        g32 = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        q8 = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        deq = q8.astype(jnp.float32) * scale
        return deq.astype(g.dtype), g32 - deq

    out = jax.tree.map(q, grads, feedback)
    deq = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    fb = jax.tree.map(lambda t: t[1], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    return deq, fb
