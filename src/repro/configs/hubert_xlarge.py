"""HuBERT-XLarge [arXiv:2106.07447]: encoder-only audio transformer
(w2v2-style backbone), bidirectional attention, masked-prediction head over
504 cluster targets.  Audio frontend is a stub: input_specs() supplies
precomputed frame embeddings."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge", family="audio", n_layers=48, d_model=1280,
    n_heads=16, n_kv_heads=16, d_ff=5120, vocab_size=504,
    act="gelu", causal=False, frontend="frames", supports_decode=False,
)
