"""StarCoder2-3B [arXiv:2402.19173]: dense decoder, GQA (kv=2), RoPE,
GeLU MLP (non-gated), learned... (we use RoPE per config block)."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b", family="dense", n_layers=30, d_model=3072,
    n_heads=24, n_kv_heads=2, d_ff=12288, vocab_size=49152,
    rope_theta=1e5, act="gelu", qkv_bias=True,
)
