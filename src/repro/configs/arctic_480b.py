"""Snowflake Arctic [hf:Snowflake/snowflake-arctic-base]: dense-MoE hybrid —
128 experts top-2 in parallel with a dense residual FFN; GQA kv=8."""
from ..models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="arctic-480b", family="moe", n_layers=35, d_model=7168,
    n_heads=56, n_kv_heads=8, d_ff=4864, vocab_size=32000,
    act="silu",
    moe=MoEConfig(n_experts=128, top_k=2, dense_residual=True,
                  dense_ff=4864),
)
