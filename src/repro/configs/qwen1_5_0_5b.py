"""Qwen1.5-0.5B [hf:Qwen/Qwen1.5-0.5B]: dense, MHA (kv=16), QKV bias,
SiLU-gated MLP, tied embeddings."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-0.5b", family="dense", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=2816, vocab_size=151936,
    act="silu", qkv_bias=True, tie_embeddings=True,
)
