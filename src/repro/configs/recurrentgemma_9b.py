"""RecurrentGemma-9B [arXiv:2402.19427 Griffin]: RG-LRU + local attention,
2:1 pattern, window 2048, GQA kv=1 on the attention layers."""
from ..models.config import ArchConfig, RGLRUConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid", n_layers=38, d_model=4096,
    n_heads=16, n_kv_heads=1, d_ff=12288, vocab_size=256000, head_dim=256,
    act="geglu", tie_embeddings=True,
    rglru=RGLRUConfig(lru_width=4096, conv_width=4,
                      block_pattern=("rglru", "rglru", "attn"), window=2048),
    subquadratic=True,
)
