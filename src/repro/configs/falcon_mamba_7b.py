"""Falcon-Mamba-7B [arXiv:2410.05355]: attention-free mamba-1 architecture,
64 layers, ssm_state=16, expand=2 (inner 8192)."""
from ..models.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm", n_layers=64, d_model=4096,
    n_heads=1, n_kv_heads=1, d_ff=0, vocab_size=65024,
    ssm=SSMConfig(state_dim=16, conv_width=4, expand=2),
    subquadratic=True,
)
