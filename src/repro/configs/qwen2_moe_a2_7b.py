"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B]: 60 routed experts top-4 +
4 shared experts (sigmoid-gated), fine-grained expert d_ff=1408."""
from ..models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab_size=151936,
    act="silu", qkv_bias=True,
    moe=MoEConfig(n_experts=60, top_k=4, n_shared_experts=4,
                  shared_gated=True),
)
