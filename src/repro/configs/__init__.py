"""Assigned-architecture registry: ``get_config(arch_id)``.

Each module holds the exact published configuration; ``reduced()`` copies
are used by CPU smoke tests.  The paper's own analytics operators live in
``repro.analytics`` (they are image programs, not LM configs).
"""
from . import (arctic_480b, falcon_mamba_7b, gemma2_2b, hubert_xlarge,
               qwen1_5_0_5b, qwen2_moe_a2_7b, qwen2_vl_72b,
               recurrentgemma_9b, smollm_135m, starcoder2_3b)

ARCHS = {
    "starcoder2-3b": starcoder2_3b.CONFIG,
    "smollm-135m": smollm_135m.CONFIG,
    "gemma2-2b": gemma2_2b.CONFIG,
    "qwen1.5-0.5b": qwen1_5_0_5b.CONFIG,
    "recurrentgemma-9b": recurrentgemma_9b.CONFIG,
    "qwen2-moe-a2.7b": qwen2_moe_a2_7b.CONFIG,
    "arctic-480b": arctic_480b.CONFIG,
    "qwen2-vl-72b": qwen2_vl_72b.CONFIG,
    "falcon-mamba-7b": falcon_mamba_7b.CONFIG,
    "hubert-xlarge": hubert_xlarge.CONFIG,
}


def get_config(arch_id: str):
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(ARCHS)}")
    return ARCHS[arch_id]
