"""Qwen2-VL-72B [arXiv:2409.12191]: VLM backbone — M-RoPE (t,h,w) rotary,
GQA kv=8, QKV bias.  Vision frontend is a stub: input_specs() supplies
precomputed patch embeddings + mrope position triples."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b", family="vlm", n_layers=80, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=29568, vocab_size=152064,
    act="silu", qkv_bias=True, mrope=True, frontend="patches",
)
