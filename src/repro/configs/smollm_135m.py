"""SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M]: llama-architecture small
model — GQA (kv=3), RoPE, SiLU-gated MLP, tied embeddings."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="smollm-135m", family="dense", n_layers=30, d_model=576,
    n_heads=9, n_kv_heads=3, d_ff=1536, vocab_size=49152,
    act="silu", tie_embeddings=True,
)
