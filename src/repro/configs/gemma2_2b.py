"""Gemma2-2B [arXiv:2408.00118]: local(4096)+global alternating attention,
logit softcapping (attn 50, final 30), post-norms, GeGLU, head_dim=256."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b", family="dense", n_layers=26, d_model=2304,
    n_heads=8, n_kv_heads=4, d_ff=9216, vocab_size=256000, head_dim=256,
    act="geglu", logit_softcap=50.0, final_softcap=30.0,
    local_window=4096, local_global_alternate=True, post_norm=True,
    tie_embeddings=True,
)
