from .segment import (decode_segment, decoded_chunks, encode_raw,
                      encode_segment, segment_info)
from .transform import convert_fidelity, resize, sample_indices

__all__ = [
    "encode_segment", "encode_raw", "decode_segment", "segment_info",
    "decoded_chunks", "convert_fidelity", "resize", "sample_indices",
]
