from .segment import (decode_many, decode_segment, decode_segment_ex,
                      decode_segment_scan, decoded_chunks, encode_raw,
                      encode_segment, segment_info)
from .transform import (convert_fidelity, dct_backend, resize, sample_indices,
                        set_dct_backend)

__all__ = [
    "encode_segment", "encode_raw", "decode_segment", "decode_segment_ex",
    "decode_segment_scan", "decode_many", "segment_info", "decoded_chunks",
    "convert_fidelity", "resize", "sample_indices",
    "dct_backend", "set_dct_backend",
]
