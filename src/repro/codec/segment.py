"""Segment-level encode / decode.

An encoded segment is a sequence of *chunks* ("group of pictures"): each chunk
begins with an intra-coded frame (predicted from mid-gray) followed by
delta-coded frames (predicted from the previous *reconstructed* frame, DPCM
style, so there is no drift between encoder and decoder).  Chunks decode
independently — sparse frame sampling therefore skips whole chunks
(paper Fig. 3b).  Quantized DCT symbols are entropy-coded with zstd whose
level realizes the *speed step* knob (paper Fig. 3a); when the optional
``zstandard`` module is absent we fall back to stdlib ``zlib`` and record
the entropy coder in the blob header (``"ec"``), so blobs stay
self-describing and either coder can read its own output.

Blob layout (common): ``[u32 header_len][msgpack header][payload bytes]``.

Two header-versioned payload formats coexist (``"v"`` field; absent = v1):

* **v1** — one entropy-coded stream over the whole segment's symbols.
  Any decode, however sparse, must decompress the entire payload.
* **v2** (default) — each chunk is entropy-coded *independently* and the
  header records per-chunk compressed byte lengths (``"spans"``), VSS-style
  chunk-granular physical layout.  Chunk-skip then skips decompression and
  payload *bytes*, not just transform work: a 1/30-sparse read touches
  ``header + spans[c]`` bytes for the one chunk ``c`` it needs.  Symbols of
  a short tail chunk are stored unpadded (``n`` and ``k`` determine each
  chunk's frame count).

Decoding is *batched*: all wanted chunks' residuals are reconstructed in a
single jit dispatch (``_decode_chunks`` — dequantize + IDCT over every
frame at once, zero-padded to the keyframe interval), then a cheap
sequential add+clip scan runs over the precomputed residuals.  Per-frame
float ops and their order are identical to the per-chunk reference scan
(``decode_segment_scan``), so results are bit-exact by construction.  The
dequantize+IDCT (and the encoder's forward DCT) route through the fused
Pallas kernels in ``repro.kernels.dct8`` when the transform backend
resolves to ``"pallas"`` (see ``transform.set_dct_backend``); the pure-jnp
path is the oracle and the CPU default.
"""

from __future__ import annotations

import functools
import struct
import zlib

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:  # pragma: no cover - exercised on bare interpreters
    zstandard = None

from . import transform as T
from ..kernels.dct8.dct8 import dct8_dequantize, dct8_quantize
from ..obs.trace import span as _span

_MAGIC = "tpucodec-v1"

#: Blob format written by :func:`encode_segment` when ``version`` is None.
DEFAULT_VERSION = 2


def _compress(payload: bytes, level: int) -> tuple[str, bytes]:
    """Entropy-code with zstd when available, else zlib.  Returns the coder
    tag recorded in the header alongside the compressed payload."""
    if zstandard is not None:
        return "zstd", zstandard.ZstdCompressor(level=level).compress(payload)
    return "zlib", zlib.compress(payload, min(9, max(1, level)))


def _decompress(coder: str, payload: bytes) -> bytes:
    if coder == "zstd":
        if zstandard is None:
            raise RuntimeError(
                "blob was zstd-coded but the zstandard module is unavailable")
        return zstandard.ZstdDecompressor().decompress(payload)
    if coder == "zlib":
        return zlib.decompress(payload)
    raise ValueError(f"unknown entropy coder {coder!r}")


# ---------------------------------------------------------------------------
# Chunk coding (jitted; tail chunks are padded to the keyframe interval
# before encode and sliced after, so there is ONE compile per (k, hb, wb)
# regardless of segment length)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("backend", "interpret"))
def _encode_chunk(frames_f32: jnp.ndarray, quant_scale: jnp.ndarray,
                  backend: str = "jnp", interpret: bool = True):
    """frames (k, h, w) float32 -> (symbols (k, hb, wb, 8, 8) int16)."""

    def step(pred, frame):
        resid = (frame - pred)[None]
        if backend == "pallas":
            sym = dct8_quantize(resid, quant_scale, interpret=interpret)[0]
            recon_resid = dct8_dequantize(sym[None], quant_scale,
                                          interpret=interpret)[0]
        else:
            sym = T.frames_to_symbols(resid, quant_scale)[0]
            recon_resid = T.symbols_to_residuals(sym[None], quant_scale)[0]
        recon = jnp.clip(pred + recon_resid, 0.0, 255.0)
        return recon, sym

    init = jnp.full(frames_f32.shape[1:], 128.0, frames_f32.dtype)
    _, symbols = jax.lax.scan(step, init, frames_f32)
    return symbols


@functools.partial(jax.jit, static_argnames=())
def _decode_chunk(symbols: jnp.ndarray, quant_scale: jnp.ndarray):
    """Per-chunk reference decoder (k, hb, wb, 8, 8) int16 -> (k, h, w) f32.

    The seed decode path: dequantize+IDCT trapped inside the DPCM scan, one
    dispatch per chunk.  Kept as the bit-exactness oracle for the batched
    ``_decode_chunks`` and as the baseline of the ``decode_path`` bench."""

    def step(pred, sym):
        recon_resid = T.symbols_to_residuals(sym[None], quant_scale)[0]
        recon = jnp.clip(pred + recon_resid, 0.0, 255.0)
        return recon, recon

    k, hb, wb, _, _ = symbols.shape
    init = jnp.full((hb * T.BLOCK, wb * T.BLOCK), 128.0, jnp.float32)
    _, frames = jax.lax.scan(step, init, symbols)
    return frames


@functools.partial(jax.jit, static_argnames=("backend", "interpret"))
def _chunk_residuals(symbols: jnp.ndarray, quant_scale: jnp.ndarray,
                     backend: str = "jnp", interpret: bool = True):
    """One-dispatch batched residual IDCT: (C, k, hb, wb, 8, 8) int16 ->
    (k, C, h, w) float32 residuals for ALL wanted chunks' frames at once
    (one fused Pallas dispatch or one pair of big GEMMs), hoisted out of
    the DPCM recursion.  k-major layout so the downstream scan consumes
    its leading axis with no float32 transposes."""
    C, k, hb, wb, _, _ = symbols.shape
    kmajor = symbols.transpose(1, 0, 2, 3, 4, 5).reshape(
        k * C, hb, wb, T.BLOCK, T.BLOCK)
    if backend == "pallas":
        resid = dct8_dequantize(kmajor, quant_scale, interpret=interpret)
    else:
        resid = T.symbols_to_residuals(kmajor, quant_scale)
    return resid.reshape(k, C, hb * T.BLOCK, wb * T.BLOCK)


@jax.jit
def _residuals_scan(resid: jnp.ndarray) -> jnp.ndarray:
    """The cheap sequential DPCM tail over precomputed residuals:
    (k, C, h, w) f32 -> (k, C, h, w) u8.  Each step adds+clips and emits
    rounded uint8 directly, so the float32 frame stack never materializes
    in memory (only the (C, h, w) carry stays float)."""

    def step(pred, r):
        recon = jnp.clip(pred + r, 0.0, 255.0)
        return recon, jnp.round(recon).astype(jnp.uint8)

    init = jnp.full(resid.shape[1:], 128.0, jnp.float32)
    _, frames = jax.lax.scan(step, init, resid)
    return frames


def _decode_chunks(symbols: jnp.ndarray, quant_scale: jnp.ndarray,
                   backend: str = "jnp", interpret: bool = True):
    """Batched chunk decode: (C, k, hb, wb, 8, 8) int16 -> (k, C, h, w) u8.

    Two jit dispatches regardless of chunk count — the batched residual
    IDCT and the add+clip scan (kept as separate programs: XLA:CPU fuses
    the GEMM chain into the scan body when compiled together, which is
    measurably slower).  Per-frame float ops and their order match
    ``_decode_chunk`` exactly, so reconstruction is bit-exact with the
    per-chunk path; callers index ``[frame_in_chunk, chunk_row]``."""
    return _residuals_scan(_chunk_residuals(symbols, quant_scale,
                                            backend=backend,
                                            interpret=interpret))


def _pad_chunk_count(c: int) -> int:
    """Next power of two >= c: the static chunk-batch shapes ``_decode_chunks``
    compiles for, so arbitrary want-sets reuse a small ladder of jit entries."""
    return 1 << max(0, c - 1).bit_length()


def _k_eff(k: int, n: int) -> int:
    """The chunk-stack frame dimension: ``min(k, n)``.  A keyframe interval
    larger than the segment yields a single chunk of n frames — padding to
    the full interval would scan k-n ghost frames per chunk."""
    return min(k, n)


def _pad_tail(chunk: np.ndarray, k_eff: int) -> np.ndarray:
    """Edge-pad a short tail chunk to the (effective) keyframe interval
    (DPCM is causal, so padded frames cannot affect the real frames'
    symbols)."""
    if len(chunk) == k_eff:
        return chunk
    return np.concatenate(
        [chunk, np.repeat(chunk[-1:], k_eff - len(chunk), axis=0)])


# ---------------------------------------------------------------------------
# Public segment API
# ---------------------------------------------------------------------------

def encode_segment(frames_u8: np.ndarray, *, quant_scale: float,
                   keyframe_interval: int, zstd_level: int,
                   version: int | None = None) -> bytes:
    """Encode (n, h, w) uint8 frames.  n need not divide the interval; the
    final chunk is simply shorter (padded for the jit call, sliced before
    serialization).  ``version`` selects the blob format (default
    ``DEFAULT_VERSION``); v1 is retained for back-compat tests/benches."""
    version = DEFAULT_VERSION if version is None else version
    if version not in (1, 2):
        raise ValueError(f"unknown blob format version {version}")
    frames = np.asarray(frames_u8)
    n, h, w = frames.shape
    k = keyframe_interval
    backend, interp = T.dct_backend(), T.dct_interpret()
    parts = []
    for start in range(0, n, k):
        kc = min(k, n - start)
        chunk = jnp.asarray(_pad_tail(frames[start:start + kc], _k_eff(k, n)),
                            jnp.float32)
        sym = _encode_chunk(chunk, jnp.float32(quant_scale),
                            backend=backend, interpret=interp)
        parts.append(np.asarray(sym)[:kc])
    header = {
        "magic": _MAGIC, "raw": False, "n": n, "h": h, "w": w,
        "k": k, "qs": float(quant_scale), "lvl": zstd_level,
    }
    if version == 1:
        coder, comp = _compress(b"".join(p.tobytes() for p in parts),
                                zstd_level)
        header["ec"] = coder
        payload = comp
    else:
        spans, blobs = [], []
        coder = None
        for p in parts:
            coder, comp = _compress(p.tobytes(), zstd_level)
            spans.append(len(comp))
            blobs.append(comp)
        header["v"] = 2
        header["ec"] = coder or _compress(b"", zstd_level)[0]
        header["spans"] = spans
        payload = b"".join(blobs)
    packed = msgpack.packb(header)
    return struct.pack("<I", len(packed)) + packed + payload


def encode_raw(frames_u8: np.ndarray) -> bytes:
    """Coding bypass: store raw frames (true random access, no decode)."""
    frames = np.ascontiguousarray(np.asarray(frames_u8, np.uint8))
    n, h, w = frames.shape
    header = msgpack.packb({"magic": _MAGIC, "raw": True, "n": n, "h": h, "w": w})
    return struct.pack("<I", len(header)) + header + frames.tobytes()


def _parse(blob: bytes):
    (hlen,) = struct.unpack_from("<I", blob, 0)
    header = msgpack.unpackb(blob[4:4 + hlen])
    if header.get("magic") != _MAGIC:
        raise ValueError("not a tpucodec blob")
    return header, blob[4 + hlen:]


def segment_info(blob: bytes) -> dict:
    header, _ = _parse(blob)
    return header


def _chunk_symbols(header: dict, payload: bytes, chunks: np.ndarray,
                   pad_to: int) -> tuple[np.ndarray, int]:
    """Entropy-decode the selected ``chunks`` into a zero-padded
    (pad_to, k, hb, wb, 8, 8) int16 stack.  Returns (symbols,
    payload_bytes_touched): v2 touches only the selected chunks' spans, v1
    must decompress the whole stream."""
    n, h, w, k = header["n"], header["h"], header["w"], header["k"]
    hb, wb = h // T.BLOCK, w // T.BLOCK
    ec = header.get("ec", "zstd")
    out = np.zeros((pad_to, _k_eff(k, n), hb, wb, T.BLOCK, T.BLOCK),
                   np.int16)
    if header.get("v", 1) >= 2:
        offsets = np.concatenate([[0], np.cumsum(header["spans"])])
        touched = 0
        for i, c in enumerate(chunks):
            c = int(c)
            raw = _decompress(ec, payload[offsets[c]:offsets[c + 1]])
            kc = min(k, n - c * k)
            out[i, :kc] = np.frombuffer(raw, np.int16).reshape(
                kc, hb, wb, T.BLOCK, T.BLOCK)
            touched += int(header["spans"][c])
        return out, touched
    sym_all = np.frombuffer(_decompress(ec, payload), np.int16).reshape(
        n, hb, wb, T.BLOCK, T.BLOCK)
    for i, c in enumerate(chunks):
        start = int(c) * k
        kc = min(k, n - start)
        out[i, :kc] = sym_all[start:start + kc]
    return out, len(payload)


def _decode_cost(header: dict, header_bytes: int, payload_bytes: int,
                 chunks: int, frames: int) -> dict:
    """The header dict augmented with bytes/chunks/frames actually touched —
    what ``VideoStore.decode_for`` reports, from the single parse that the
    decode itself performed."""
    return dict(header) | {
        "bytes": header_bytes + payload_bytes,
        "chunks": chunks,
        "frames": frames,
    }


def decode_segment_ex(blob: bytes,
                      want: np.ndarray | None = None
                      ) -> tuple[np.ndarray, dict]:
    """Decode stored frames and return ``(frames, info)`` from one parse.

    ``want`` (sorted indices into the stored frame sequence) enables
    chunk-skip: only chunks containing wanted frames are entropy-decoded
    (v2: only their payload bytes are even touched) and reconstructed, all
    in a single batched jit dispatch.  ``info`` is the blob header plus
    ``bytes``/``chunks``/``frames`` actually touched, so callers need no
    second ``segment_info`` parse."""
    with _span("codec.parse", bytes=len(blob)):
        header, payload = _parse(blob)
    hlen = len(blob) - len(payload)
    n, h, w = header["n"], header["h"], header["w"]
    if header["raw"]:
        return _decode_raw(header, payload, hlen, want)

    k = header["k"]
    want = np.arange(n) if want is None else np.asarray(want, np.int64)
    if want.size == 0:
        return (np.empty((0, h, w), np.uint8),
                _decode_cost(header, hlen, 0, 0, 0))
    chunk_of = want // k
    chunks = np.unique(chunk_of)
    with _span("codec.entropy", chunks=len(chunks)) as esp:
        sym, touched = _chunk_symbols(header, payload, chunks,
                                      _pad_chunk_count(len(chunks)))
        esp.set(bytes=touched)
    with _span("codec.residuals", chunks=len(chunks), frames=len(want)):
        decoded = _run_decode(sym, header)  # (k_eff, C_padded, h, w)
    out = _scatter_rows(decoded, want, k, chunks)
    return out, _decode_cost(header, hlen, touched, len(chunks), len(want))


def _decode_raw(header: dict, payload: bytes, hlen: int,
                want: np.ndarray | None) -> tuple[np.ndarray, dict]:
    """Coding-bypass read: slice (or, for a dense read, copy — frombuffer
    views are read-only and callers may mutate) the raw frame array."""
    n, h, w = header["n"], header["h"], header["w"]
    frames = np.frombuffer(payload, np.uint8).reshape(n, h, w)
    out = frames[want] if want is not None else frames.copy()
    return out, _decode_cost(header, hlen, out.nbytes, 0, len(out))


def _run_decode(sym_padded: np.ndarray, header: dict) -> np.ndarray:
    """One ``_decode_chunks`` dispatch on the resolved transform backend."""
    return np.asarray(_decode_chunks(
        jnp.asarray(sym_padded), jnp.float32(header["qs"]),
        backend=T.dct_backend(), interpret=T.dct_interpret()))


def _scatter_rows(decoded: np.ndarray, want: np.ndarray, k: int,
                  chunks: np.ndarray, row0: int = 0) -> np.ndarray:
    """Select ``want`` frames from a decoded (k_eff, C, h, w) chunk stack
    whose rows ``row0 .. row0+len(chunks)`` hold ``chunks`` (sorted unique).
    The single scatter-math implementation shared by the one-segment and
    grouped decoders, so their indexing cannot diverge."""
    chunk_of = want // k
    rows = row0 + np.searchsorted(chunks, chunk_of)
    return decoded[want - chunk_of * k, rows]


def decode_segment(blob: bytes, want: np.ndarray | None = None) -> np.ndarray:
    """Decode stored frames (see ``decode_segment_ex``; this drops the cost
    info).  Returns (len(want) or n, h, w) uint8, always writable."""
    return decode_segment_ex(blob, want)[0]


def decode_many(blobs: list[bytes],
                want: np.ndarray | None = None
                ) -> tuple[list[np.ndarray], dict]:
    """Decode several segments' ``want`` frames with ONE batched dispatch.

    All coded blobs sharing a transform shape (h, w, k, qs) — which every
    segment of one storage format does — contribute their wanted chunks to
    a single stacked ``_decode_chunks`` call; raw or odd-shaped blobs fall
    back to per-blob decode.  Returns ``(frames_per_blob, cost)`` where
    cost aggregates bytes/chunks/frames touched plus the jit ``dispatches``
    issued (one per distinct coded shape group; raw blobs need none)."""
    outs: list[np.ndarray | None] = [None] * len(blobs)
    cost = {"bytes": 0, "chunks": 0, "frames": 0, "dispatches": 0}
    groups: dict[tuple, list] = {}
    for i, blob in enumerate(blobs):
        header, payload = _parse(blob)
        hlen = len(blob) - len(payload)
        if header["raw"]:
            outs[i], info = _decode_raw(header, payload, hlen, want)
            for key in ("bytes", "chunks", "frames"):
                cost[key] += info[key]
            continue
        key = (header["h"], header["w"], header["k"], header["qs"],
               _k_eff(header["k"], header["n"]))
        groups.setdefault(key, []).append((i, header, payload, hlen))

    for (_h, _w, k, _qs, k_eff), members in groups.items():
        per_member = []
        total_chunks = 0
        for i, header, payload, hlen in members:
            n = header["n"]
            w_i = (np.arange(n) if want is None
                   else np.asarray(want, np.int64))
            chunks = np.unique(w_i // k) if w_i.size else np.empty(0, np.int64)
            per_member.append((i, header, payload, hlen, w_i, chunks))
            total_chunks += len(chunks)
        if total_chunks == 0:
            for i, header, payload, hlen, w_i, _c in per_member:
                outs[i] = np.empty((0, header["h"], header["w"]), np.uint8)
                cost["bytes"] += hlen
            continue
        pad = _pad_chunk_count(total_chunks)
        header0 = per_member[0][1]
        hb, wb = header0["h"] // T.BLOCK, header0["w"] // T.BLOCK
        sym = np.zeros((pad, k_eff, hb, wb, T.BLOCK, T.BLOCK), np.int16)
        row = 0
        rowspans = []
        with _span("codec.entropy", chunks=total_chunks,
                   segments=len(per_member)) as esp:
            for i, header, payload, hlen, w_i, chunks in per_member:
                part, touched = _chunk_symbols(header, payload, chunks,
                                               len(chunks))
                sym[row:row + len(chunks)] = part
                rowspans.append(row)
                row += len(chunks)
                cost["bytes"] += hlen + touched
                cost["chunks"] += len(chunks)
                cost["frames"] += len(w_i)
            esp.set(bytes=cost["bytes"])
        with _span("codec.residuals", chunks=total_chunks,
                   frames=cost["frames"]):
            decoded = _run_decode(sym, header0)
        cost["dispatches"] += 1
        for (i, header, payload, hlen, w_i, chunks), r0 in zip(per_member,
                                                              rowspans):
            if w_i.size == 0:
                outs[i] = np.empty((0, header["h"], header["w"]), np.uint8)
                continue
            outs[i] = _scatter_rows(decoded, w_i, k, chunks, row0=r0)
    return outs, cost


def decode_segment_scan(blob: bytes,
                        want: np.ndarray | None = None) -> np.ndarray:
    """The seed decode path, kept as oracle and bench baseline: one
    ``_decode_chunk`` jit dispatch + one float32 host transfer per wanted
    chunk, with the dequantize+IDCT inside the DPCM scan, and (for v1
    blobs) a whole-payload entropy decode."""
    header, payload = _parse(blob)
    n, h, w = header["n"], header["h"], header["w"]
    if header["raw"]:
        frames = np.frombuffer(payload, np.uint8).reshape(n, h, w)
        return frames[want] if want is not None else frames.copy()
    k, qs = header["k"], np.float32(header["qs"])
    want = np.arange(n) if want is None else np.asarray(want, np.int64)
    out = np.empty((len(want), h, w), np.uint8)
    chunk_of = want // k
    chunks = np.unique(chunk_of)
    sym_all, _ = _chunk_symbols(header, payload, chunks, len(chunks))
    for row, c in enumerate(chunks):
        kc = min(k, n - int(c) * k)
        # analysis: allow[jit-shape] per-chunk reference oracle, not a
        # serving path: decode_segment_scan exists to cross-check the
        # batched decoder bit-for-bit, and the tail chunk's kc<k shape
        # is the exact semantics it must replicate
        frames = np.asarray(_decode_chunk(jnp.asarray(sym_all[row, :kc]), qs))
        sel = np.nonzero(chunk_of == c)[0]
        out[sel] = np.clip(np.round(frames[want[sel] - int(c) * k]),
                           0, 255).astype(np.uint8)
    return out


def decoded_chunks(n: int, k: int, want: np.ndarray) -> int:
    """How many chunks a decode of ``want`` touches (cost accounting)."""
    return len(np.unique(np.asarray(want) // k))
