"""Segment-level encode / decode.

An encoded segment is a sequence of *chunks* ("group of pictures"): each chunk
begins with an intra-coded frame (predicted from mid-gray) followed by
delta-coded frames (predicted from the previous *reconstructed* frame, DPCM
style, so there is no drift between encoder and decoder).  Chunks decode
independently — sparse frame sampling therefore skips whole chunks
(paper Fig. 3b).  Quantized DCT symbols are entropy-coded with zstd whose
level realizes the *speed step* knob (paper Fig. 3a); when the optional
``zstandard`` module is absent we fall back to stdlib ``zlib`` and record
the entropy coder in the blob header (``"ec"``), so blobs stay
self-describing and either coder can read its own output.

Blob layout: [u32 header_len][msgpack header][payload bytes].
"""

from __future__ import annotations

import functools
import struct
import zlib

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:  # pragma: no cover - exercised on bare interpreters
    zstandard = None

from . import transform as T

_MAGIC = "tpucodec-v1"


def _compress(payload: bytes, level: int) -> tuple[str, bytes]:
    """Entropy-code with zstd when available, else zlib.  Returns the coder
    tag recorded in the header alongside the compressed payload."""
    if zstandard is not None:
        return "zstd", zstandard.ZstdCompressor(level=level).compress(payload)
    return "zlib", zlib.compress(payload, min(9, max(1, level)))


def _decompress(coder: str, payload: bytes) -> bytes:
    if coder == "zstd":
        if zstandard is None:
            raise RuntimeError(
                "blob was zstd-coded but the zstandard module is unavailable")
        return zstandard.ZstdDecompressor().decompress(payload)
    if coder == "zlib":
        return zlib.decompress(payload)
    raise ValueError(f"unknown entropy coder {coder!r}")


# ---------------------------------------------------------------------------
# Chunk coding (jitted; one compile per (chunk_len, hb, wb))
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=())
def _encode_chunk(frames_f32: jnp.ndarray, quant_scale: jnp.ndarray):
    """frames (k, h, w) float32 -> (symbols (k, hb, wb, 8, 8) int16)."""

    def step(pred, frame):
        resid = T.to_blocks((frame - pred)[None])[0]
        sym = T.quantize(T.dct2(resid), quant_scale)
        recon_resid = T.from_blocks(T.idct2(T.dequantize(sym, quant_scale))[None])[0]
        recon = jnp.clip(pred + recon_resid, 0.0, 255.0)
        return recon, sym

    init = jnp.full(frames_f32.shape[1:], 128.0, frames_f32.dtype)
    _, symbols = jax.lax.scan(step, init, frames_f32)
    return symbols


@functools.partial(jax.jit, static_argnames=())
def _decode_chunk(symbols: jnp.ndarray, quant_scale: jnp.ndarray):
    """Inverse of _encode_chunk: (k, hb, wb, 8, 8) int16 -> (k, h, w) f32."""

    def step(pred, sym):
        recon_resid = T.from_blocks(T.idct2(T.dequantize(sym, quant_scale))[None])[0]
        recon = jnp.clip(pred + recon_resid, 0.0, 255.0)
        return recon, recon

    k, hb, wb, _, _ = symbols.shape
    init = jnp.full((hb * T.BLOCK, wb * T.BLOCK), 128.0, jnp.float32)
    _, frames = jax.lax.scan(step, init, symbols)
    return frames


# ---------------------------------------------------------------------------
# Public segment API
# ---------------------------------------------------------------------------

def encode_segment(frames_u8: np.ndarray, *, quant_scale: float,
                   keyframe_interval: int, zstd_level: int) -> bytes:
    """Encode (n, h, w) uint8 frames.  n need not divide the interval; the
    final chunk is simply shorter."""
    frames = np.asarray(frames_u8)
    n, h, w = frames.shape
    parts = []
    for start in range(0, n, keyframe_interval):
        chunk = jnp.asarray(frames[start:start + keyframe_interval], jnp.float32)
        sym = _encode_chunk(chunk, jnp.float32(quant_scale))
        parts.append(np.asarray(sym))
    payload = b"".join(p.tobytes() for p in parts)
    coder, comp = _compress(payload, zstd_level)
    header = msgpack.packb({
        "magic": _MAGIC, "raw": False, "n": n, "h": h, "w": w,
        "k": keyframe_interval, "qs": float(quant_scale), "lvl": zstd_level,
        "ec": coder,
    })
    return struct.pack("<I", len(header)) + header + comp


def encode_raw(frames_u8: np.ndarray) -> bytes:
    """Coding bypass: store raw frames (true random access, no decode)."""
    frames = np.ascontiguousarray(np.asarray(frames_u8, np.uint8))
    n, h, w = frames.shape
    header = msgpack.packb({"magic": _MAGIC, "raw": True, "n": n, "h": h, "w": w})
    return struct.pack("<I", len(header)) + header + frames.tobytes()


def _parse(blob: bytes):
    (hlen,) = struct.unpack_from("<I", blob, 0)
    header = msgpack.unpackb(blob[4:4 + hlen])
    if header.get("magic") != _MAGIC:
        raise ValueError("not a tpucodec blob")
    return header, blob[4 + hlen:]


def segment_info(blob: bytes) -> dict:
    header, _ = _parse(blob)
    return header


def decode_segment(blob: bytes, want: np.ndarray | None = None) -> np.ndarray:
    """Decode stored frames.  ``want`` (sorted indices into the stored frame
    sequence) enables chunk-skip: only chunks containing wanted frames are
    reconstructed.  Returns (len(want) or n, h, w) uint8."""
    header, payload = _parse(blob)
    n, h, w = header["n"], header["h"], header["w"]
    if header["raw"]:
        frames = np.frombuffer(payload, np.uint8).reshape(n, h, w)
        return frames[want] if want is not None else frames

    k, qs = header["k"], np.float32(header["qs"])
    hb, wb = h // T.BLOCK, w // T.BLOCK
    sym_all = np.frombuffer(
        _decompress(header.get("ec", "zstd"), payload), np.int16
    ).reshape(n, hb, wb, T.BLOCK, T.BLOCK)

    if want is None:
        want = np.arange(n)
    want = np.asarray(want)
    out = np.empty((len(want), h, w), np.uint8)

    # Group wanted indices by chunk; skip chunks with no wanted frame.
    chunk_of = want // k
    for c in np.unique(chunk_of):
        start = int(c) * k
        sym = jnp.asarray(sym_all[start:start + k])
        frames = np.asarray(_decode_chunk(sym, qs))
        sel = np.nonzero(chunk_of == c)[0]
        out[sel] = np.clip(np.round(frames[want[sel] - start]), 0, 255).astype(np.uint8)
    return out


def decoded_chunks(n: int, k: int, want: np.ndarray) -> int:
    """How many chunks a decode of ``want`` touches (cost accounting)."""
    return len(np.unique(np.asarray(want) // k))
