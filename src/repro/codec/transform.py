"""Transform-coding primitives: 8x8 block DCT on the MXU, quantization,
fidelity conversion (crop / resize / temporal sampling).

The DCT of an 8x8 block X is D @ X @ D.T with the orthonormal DCT-II basis D —
i.e. batched 8x8 matmuls, the native shape of the TPU MXU.  The Pallas kernel
(src/repro/kernels/dct8) tiles frames into VMEM and fuses quantization; this
module is the pure-jnp implementation used as its oracle and as the CPU path.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 8

# ---------------------------------------------------------------------------
# DCT backend selection: which implementation the codec's hot transforms
# (batched residual IDCT, encoder forward DCT) run on.  "auto" resolves to
# the fused Pallas kernels (repro.kernels.dct8) on TPU and the pure-jnp
# oracle elsewhere; "pallas" forces the kernels (interpret mode off-TPU,
# slow but bit-faithful — used by oracle tests), "jnp" forces the oracle.
# ---------------------------------------------------------------------------

_DCT_BACKENDS = ("auto", "jnp", "pallas")
_dct_backend = os.environ.get("REPRO_DCT_BACKEND", "auto")
if _dct_backend not in _DCT_BACKENDS:  # pragma: no cover - env misuse
    raise ValueError(f"REPRO_DCT_BACKEND must be one of {_DCT_BACKENDS}, "
                     f"got {_dct_backend!r}")


def set_dct_backend(name: str) -> None:
    """Select the codec transform backend: 'auto' | 'jnp' | 'pallas'."""
    global _dct_backend
    if name not in _DCT_BACKENDS:
        raise ValueError(f"backend must be one of {_DCT_BACKENDS}, got {name!r}")
    _dct_backend = name


def dct_backend() -> str:
    """The resolved backend ('jnp' or 'pallas') for the current platform."""
    if _dct_backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return _dct_backend


def dct_interpret() -> bool:
    """Whether a Pallas dispatch must run in interpret mode (off-TPU)."""
    return jax.default_backend() != "tpu"


@functools.cache
def dct_basis() -> np.ndarray:
    """Orthonormal 8x8 DCT-II basis matrix D (D @ D.T = I)."""
    k = np.arange(BLOCK)[:, None]
    n = np.arange(BLOCK)[None, :]
    d = np.cos(np.pi * (2 * n + 1) * k / (2 * BLOCK))
    d[0] *= 1.0 / np.sqrt(2)
    d *= np.sqrt(2.0 / BLOCK)
    return d.astype(np.float32)


@functools.cache
def quant_table() -> np.ndarray:
    """JPEG-like base quantization table scaled to unit DC step: higher
    frequencies quantized more coarsely."""
    i = np.arange(BLOCK)[:, None]
    j = np.arange(BLOCK)[None, :]
    return (1.0 + (i + j) * 1.5).astype(np.float32)


def to_blocks(frames: jnp.ndarray) -> jnp.ndarray:
    """(n, h, w) -> (n, h//8, w//8, 8, 8)."""
    n, h, w = frames.shape
    x = frames.reshape(n, h // BLOCK, BLOCK, w // BLOCK, BLOCK)
    return x.transpose(0, 1, 3, 2, 4)


def from_blocks(blocks: jnp.ndarray) -> jnp.ndarray:
    """(n, hb, wb, 8, 8) -> (n, h, w)."""
    n, hb, wb, _, _ = blocks.shape
    return blocks.transpose(0, 1, 3, 2, 4).reshape(n, hb * BLOCK, wb * BLOCK)


def dct2(blocks: jnp.ndarray) -> jnp.ndarray:
    """Forward 2D DCT over trailing (8, 8) dims: D @ X @ D.T.

    Formulated as two large (M*8, 8) @ (8, 8) GEMMs instead of an einsum
    over per-block 8x8 matmuls — XLA:CPU runs one big GEMM several times
    faster than 10^5 tiny batched dots, and the contraction order (j then
    k, each an in-order 8-term dot) is identical, so results are bit-exact
    with the einsum ``ij,...jk,lk->...il`` form."""
    d = jnp.asarray(dct_basis())
    shp = blocks.shape
    x = blocks.reshape(-1, BLOCK, BLOCK)
    tmp = x.transpose(0, 2, 1).reshape(-1, BLOCK) @ d.T   # rows (b,k) cols i
    tmp = tmp.reshape(-1, BLOCK, BLOCK).transpose(0, 2, 1)  # (b, i, k)
    return (tmp.reshape(-1, BLOCK) @ d.T).reshape(shp)    # rows (b,i) cols l


def idct2(coefs: jnp.ndarray) -> jnp.ndarray:
    """Inverse 2D DCT over trailing (8, 8) dims: D.T @ C @ D (same two-GEMM
    formulation and contraction order as ``dct2`` — see its docstring)."""
    d = jnp.asarray(dct_basis())
    shp = coefs.shape
    x = coefs.reshape(-1, BLOCK, BLOCK)
    tmp = x.transpose(0, 2, 1).reshape(-1, BLOCK) @ d     # rows (b,k) cols i
    tmp = tmp.reshape(-1, BLOCK, BLOCK).transpose(0, 2, 1)  # (b, i, k)
    return (tmp.reshape(-1, BLOCK) @ d).reshape(shp)      # rows (b,i) cols l


def quantize(coefs: jnp.ndarray, quant_scale: float) -> jnp.ndarray:
    q = jnp.asarray(quant_table()) * quant_scale
    return jnp.round(coefs / q).astype(jnp.int16)


def dequantize(symbols: jnp.ndarray, quant_scale: float) -> jnp.ndarray:
    q = jnp.asarray(quant_table()) * quant_scale
    return symbols.astype(jnp.float32) * q


def symbols_to_residuals(symbols: jnp.ndarray,
                         quant_scale: float) -> jnp.ndarray:
    """Fused dequantize + IDCT + de-blocking for a frame stack:
    (n, hb, wb, 8, 8) int16 -> (n, h, w) float32.

    The decode hot path.  Equivalent to
    ``from_blocks(idct2(dequantize(symbols, qs)))`` — per-element dot
    products and their order are identical (bit-exact) — but the
    de-blocking transpose is folded into the second GEMM's batch layout so
    the frame stack is materialized once, not three times."""
    n, hb, wb = symbols.shape[:3]
    d = jnp.asarray(dct_basis())
    coef = dequantize(symbols, quant_scale)
    tmp = coef.reshape(-1, BLOCK, BLOCK).transpose(0, 2, 1)
    tmp = (tmp.reshape(-1, BLOCK) @ d).reshape(n, hb, wb, BLOCK, BLOCK)
    tmp = tmp.transpose(0, 1, 4, 2, 3)                    # (n, hb, i, wb, k)
    out = tmp.reshape(-1, BLOCK) @ d                      # rows (n,hb,i,wb)
    return out.reshape(n, hb * BLOCK, wb * BLOCK)


def frames_to_symbols(frames: jnp.ndarray, quant_scale: float) -> jnp.ndarray:
    """Fused blocking + DCT + quantize for a frame stack:
    (n, h, w) float32 -> (n, hb, wb, 8, 8) int16 — the encode-side twin of
    ``symbols_to_residuals`` (bit-exact with
    ``quantize(dct2(to_blocks(frames)), qs)``)."""
    n, h, w = frames.shape
    hb, wb = h // BLOCK, w // BLOCK
    d = jnp.asarray(dct_basis())
    x = frames.reshape(n, hb, BLOCK, wb, BLOCK)
    tmp = x.transpose(0, 1, 3, 4, 2)                      # (n, hb, wb, k, j)
    tmp = (tmp.reshape(-1, BLOCK) @ d.T).reshape(n, hb, wb, BLOCK, BLOCK)
    tmp = tmp.transpose(0, 1, 2, 4, 3)                    # (n, hb, wb, i, k)
    coef = (tmp.reshape(-1, BLOCK) @ d.T).reshape(n, hb, wb, BLOCK, BLOCK)
    return quantize(coef, quant_scale)


def frame_to_symbols(frame_f32: jnp.ndarray, quant_scale: float) -> jnp.ndarray:
    """(h, w) float32 -> quantized DCT symbols (hb, wb, 8, 8) int16."""
    blocks = to_blocks(frame_f32[None])[0]
    return quantize(dct2(blocks), quant_scale)


def symbols_to_frame(symbols: jnp.ndarray, quant_scale: float) -> jnp.ndarray:
    """Inverse of frame_to_symbols (reconstruction, float32)."""
    return from_blocks(idct2(dequantize(symbols, quant_scale))[None])[0]


# ---------------------------------------------------------------------------
# Fidelity conversion
# ---------------------------------------------------------------------------

def sample_indices(n_total: int, sampling: float) -> np.ndarray:
    """Deterministic frame-sampling index set (monotone in ``sampling``:
    richer sampling consumes a superset-density of the timeline)."""
    n_keep = max(1, round(n_total * sampling))
    return np.floor(np.arange(n_keep) * (n_total / n_keep)).astype(np.int64)


def center_crop(frames: jnp.ndarray, crop: float) -> jnp.ndarray:
    """Central crop to ``crop`` fraction on both axes, snapped to x8."""
    if crop >= 1.0:
        return frames
    n, h, w = frames.shape
    ch = max(8, int(round(h * crop / 8)) * 8)
    cw = max(8, int(round(w * crop / 8)) * 8)
    top, left = (h - ch) // 2, (w - cw) // 2
    return frames[:, top:top + ch, left:left + cw]


@functools.partial(jax.jit, static_argnames=("h", "w"))
def _resize(frames: jnp.ndarray, h: int, w: int) -> jnp.ndarray:
    return jax.image.resize(frames, (frames.shape[0], h, w), method="bilinear")


def resize(frames: jnp.ndarray, h: int, w: int) -> jnp.ndarray:
    if frames.shape[1:] == (h, w):
        return frames
    return _resize(frames.astype(jnp.float32), h, w)


@jax.jit
def _quality_roundtrip(frames_f32: jnp.ndarray, quant_scale: jnp.ndarray):
    blocks = to_blocks(frames_f32)
    sym = quantize(dct2(blocks), quant_scale)
    return from_blocks(idct2(dequantize(sym, quant_scale)))


def apply_quality(frames_u8, quant_scale: float):
    """Intra-frame quantization roundtrip — the image-quality knob's effect on
    pixels, used when materializing consumption-fidelity samples for
    profiling (full DPCM coding adds only second-order differences)."""
    if quant_scale <= 1.0:
        return jnp.asarray(frames_u8, jnp.uint8)
    x = _quality_roundtrip(jnp.asarray(frames_u8, jnp.float32),
                           jnp.float32(quant_scale))
    return jnp.clip(jnp.round(x), 0, 255).astype(jnp.uint8)


def materialize(frames_u8, cf, spec, src=None):
    """Ingest-fidelity frames -> consumption-fidelity frames (sampling, crop,
    resolution, then image-quality loss)."""
    from ..core.knobs import FidelityOption
    src = src or FidelityOption()
    out = convert_fidelity(frames_u8, src, cf, spec)
    return apply_quality(out, cf.quant_scale)


def temporal_indices(f_from, f_to, spec) -> np.ndarray:
    """Indices into a segment stored at fidelity ``f_from`` that realize the
    (sparser) sampling of ``f_to`` — the stored frames nearest to the target
    timeline points.  These drive chunk-skip decoding."""
    n_from, _, _ = spec.resolve(f_from)
    n_to, _, _ = spec.resolve(f_to)
    if n_to == n_from:
        return np.arange(n_from)
    src_pos = sample_indices(spec.frames_per_segment, f_from.sampling)
    dst_pos = sample_indices(spec.frames_per_segment, f_to.sampling)
    nearest = np.searchsorted(src_pos, dst_pos, side="right") - 1
    return np.clip(nearest, 0, n_from - 1)


def spatial_convert(frames, f_from, f_to, spec):
    """Crop + resize a (already temporally sampled) frame stack from
    ``f_from``'s grid to ``f_to``'s.  Returns uint8."""
    _, h_to, w_to = spec.resolve(f_to)
    rel_crop = f_to.crop / f_from.crop
    x = center_crop(jnp.asarray(frames, jnp.float32), min(1.0, rel_crop))
    x = resize(x, h_to, w_to)
    return jnp.clip(jnp.round(x), 0, 255).astype(jnp.uint8)


def convert_fidelity(frames_u8, f_from, f_to, spec):
    """Convert a segment from fidelity ``f_from`` to ``f_to``.

    ``f_from`` must be richer-than-or-equal ``f_to`` (R1).  Applies temporal
    re-sampling, central re-crop and spatial resize.  Image-quality loss is a
    coding-time effect and needs no conversion here (a higher-quality source
    simply over-delivers).  Returns uint8 frames shaped per spec.resolve(f_to).
    """
    if not f_from.richer_eq(f_to):
        raise ValueError(f"fidelity {f_from.name()} cannot serve {f_to.name()}")
    n_from, _, _ = spec.resolve(f_from)
    frames = jnp.asarray(frames_u8)
    if frames.shape[0] != n_from:
        raise ValueError(f"segment has {frames.shape[0]} frames, spec says {n_from}")
    frames = frames[temporal_indices(f_from, f_to, spec)]
    return spatial_convert(frames, f_from, f_to, spec)
