"""Semantic sketches: build at ingest, prune at query time.

A *sketch* is the cascade-head operator's activation set over one
segment, computed at the op's profiled consumption knobs and persisted
in the ``IndexStore``.  ``run_query``'s cascade already drops a segment
after stage 0 when the head op returns no items for it — stage 0 sets
that segment's active-bucket set empty and every later stage skips it —
so a segment whose *persisted* sketch shows zero activations at the
query's exact head knobs can be pruned before retrieval without
changing a single item: the pruned run is bit-identical to the unpruned
run (held as a hypothesis property in tests/test_index.py).

Two engagement modes:

* ``exact`` — prune only when the sketch's (cf, sf) equal the query
  head's resolved (cf, sf).  ``op.detect`` is deterministic, so equal
  knobs imply the sketch *is* the stage-0 result: zero information loss.
* ``conservative`` — additionally prune across a knob mismatch when the
  sketch was built at accuracy >= the query's target: the sketch op
  dominates the query's head on the accuracy ladder, so an empty sketch
  bounds the recall loss by the accuracy gap.  Engaged only when asked
  for explicitly; pruned-under-mismatch counts are surfaced separately
  (``QueryResult.pruned_conservative``).

Sketches are keyed by (stream, op, seg) and carry the sf they were
computed from; erosion does not invalidate them — fallback-chain
reconstruction of an eroded format is bit-exact, so the sketch of a
reconstructed segment equals the sketch of the original.  Re-ingesting
a segment *does* invalidate (the footage itself may differ).
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time

import msgpack
import numpy as np

from ..analytics.operators import OPERATORS, _bucket
from ..core.knobs import FidelityOption, IngestSpec
from ..obs.trace import span as _span
from .store import IndexStore

SKETCH_VERSION = 1


def _key(stream: str, op: str, seg: int) -> str:
    return f"{stream}:{op}:{seg:06d}"


@dataclasses.dataclass
class SketchRecord:
    """One persisted sketch: which time buckets of one segment the op
    activated, at which knobs, plus per-bucket item-count quantiles
    (selectivity metadata for planners; only zero-activation prunes)."""
    op: str
    cf: FidelityOption
    sf_id: str
    accuracy: float
    n_buckets: int                 # buckets per segment at build time
    buckets: tuple[int, ...]       # activated buckets, sorted
    items: int                     # total items the op emitted
    quantiles: tuple[float, ...]   # (p25, p50, p75, max) items/activated bucket
    version: int = SKETCH_VERSION

    def to_wire(self) -> dict:
        d = dataclasses.asdict(self)
        d["cf"] = [self.cf.quality, self.cf.crop, self.cf.resolution,
                   self.cf.sampling]
        d["buckets"] = list(self.buckets)
        d["quantiles"] = [float(q) for q in self.quantiles]
        return d

    @staticmethod
    def from_wire(d: dict) -> "SketchRecord":
        d = dict(d)
        q, crop, res, samp = d["cf"]
        d["cf"] = FidelityOption(q, crop, res, samp)
        d["buckets"] = tuple(int(b) for b in d["buckets"])
        d["quantiles"] = tuple(float(x) for x in d["quantiles"])
        return SketchRecord(**d)


@dataclasses.dataclass
class PruneDecision:
    """Outcome of one pushdown lookup over a query's segment list."""
    kept: list[int]
    pruned: list[int]
    conservative: int = 0   # of pruned: across a knob mismatch
    missing: int = 0        # segments with no sketch (always kept)


def sketch_specs(config, ops: tuple[str, ...] | None = None
                 ) -> dict[str, tuple]:
    """op -> (operator, cf, sf_id, accuracy): the knobs sketches are
    built at.  Each indexed op uses its highest-accuracy profiled plan —
    the most conservative sketch, and (configurations like the demo's,
    where one CF serves every accuracy of an op) usually the *exact*
    knobs every query resolves to."""
    ops = tuple(ops if ops is not None else
                (getattr(config, "index_ops", None) or ()))
    out = {}
    for op_name in ops:
        plans = [p for p in config.plans if p.consumer.op == op_name]
        if not plans:
            raise KeyError(f"no consumer plan for indexed op {op_name!r}")
        p = max(plans, key=lambda p: p.consumer.target)
        out[op_name] = (OPERATORS[op_name], p.cf,
                        config.subscription(p.cf), p.consumer.target)
    return out


def segment_buckets(spec: IngestSpec) -> int:
    """Time buckets per segment (the item-space granularity)."""
    return _bucket(spec.frames_per_segment - 1, spec) + 1


class SemanticIndex:
    """Facade over the ``IndexStore``: builds sketches and answers
    pruning lookups.  One per store root (or per shard); thread-safe."""

    def __init__(self, root: str, spec: IngestSpec, config,
                 ops: tuple[str, ...] | None = None,
                 readonly: bool = False):
        self.spec = spec
        self.store = IndexStore(root, readonly=readonly)
        self.specs = sketch_specs(config, ops)
        self.ops = tuple(self.specs)
        self._mu = threading.Lock()
        self._builds = 0      # guarded-by: _mu
        self._build_s = 0.0   # guarded-by: _mu
        self._lookups = 0     # guarded-by: _mu
        self._invalidated = 0  # guarded-by: _mu

    # -- build ---------------------------------------------------------------
    def has_sketch(self, stream: str, seg: int, op_name: str) -> bool:
        return _key(stream, op_name, seg) in self.store

    def get(self, stream: str, seg: int, op_name: str) -> SketchRecord | None:
        try:
            blob = self.store.get(_key(stream, op_name, seg))
        except KeyError:
            return None
        return SketchRecord.from_wire(msgpack.unpackb(blob))

    def build(self, store, stream: str, seg: int, op_name: str) -> float:
        """Run the op over the segment at its sketch knobs and persist
        the activation record.  Returns the wall seconds spent (what the
        ingest scheduler debits from the transcode budget).  Durable
        only after ``flush()``."""
        operator, cf, sf_id, accuracy = self.specs[op_name]
        t0 = time.perf_counter()
        with _span("index.build", stream=stream, seg=seg, op=op_name) as sp:
            # the direct decode path: sketch building must not churn the
            # serving cache, and its input must equal what stage 0 of a
            # query would consume (retrieve/retrieve_direct are bit-exact)
            frames, _cost = store.retrieve_direct(stream, seg, sf_id, cf)
            items = operator.detect(frames, cf, self.spec)
            per_bucket = collections.Counter(it[1] for it in items)
            counts = sorted(per_bucket.values())
            if counts:
                qs = np.quantile(np.asarray(counts, float),
                                 (0.25, 0.5, 0.75, 1.0))
                quantiles = tuple(float(q) for q in qs)
            else:
                quantiles = (0.0, 0.0, 0.0, 0.0)
            rec = SketchRecord(
                op=op_name, cf=cf, sf_id=sf_id, accuracy=accuracy,
                n_buckets=segment_buckets(self.spec),
                buckets=tuple(sorted(per_bucket)), items=len(items),
                quantiles=quantiles)
            self.store.put(_key(stream, op_name, seg),
                           msgpack.packb(rec.to_wire()))
            sp.set(buckets=len(rec.buckets), items=rec.items)
        dt = time.perf_counter() - t0
        with self._mu:
            self._builds += 1
            self._build_s += dt
        return dt

    def invalidate(self, stream: str, seg: int) -> int:
        """Drop every op's sketch of a segment (re-ingest: the footage
        may have changed).  Returns how many records were dropped."""
        n = 0
        for op_name in self.ops:
            if self.store.delete(_key(stream, op_name, seg)):
                n += 1
        if n:
            with self._mu:
                self._invalidated += n
        return n

    def missing(self, stream: str, segments: list[int]
                ) -> list[tuple[int, str]]:
        """(seg, op) pairs that still need a sketch — the backfill list
        for footage ingested before the index existed."""
        return [(seg, op_name) for seg in segments for op_name in self.ops
                if not self.has_sketch(stream, seg, op_name)]

    # -- lookup --------------------------------------------------------------
    def prune(self, stream: str, segments: list[int], op_name: str,
              cf: FidelityOption, sf_id: str, accuracy: float,
              mode: str = "exact") -> PruneDecision:
        """Partition ``segments`` by the persisted sketches: a segment
        whose sketch shows zero activations is pruned when the sketch's
        knobs exactly match the query head's, or — in ``conservative``
        mode only — when the sketch's accuracy dominates the query's.
        Unsketched segments and any activation keep the segment."""
        if mode not in ("exact", "conservative"):
            raise ValueError(f"unknown pushdown mode {mode!r}")
        dec = PruneDecision(kept=[], pruned=[])
        with _span("index.lookup", stream=stream, op=op_name,
                   segments=len(segments), mode=mode) as sp:
            for seg in segments:
                rec = None if op_name not in self.specs else \
                    self.get(stream, seg, op_name)
                if rec is None:
                    dec.missing += 1
                    dec.kept.append(seg)
                    continue
                if rec.buckets:
                    dec.kept.append(seg)
                    continue
                exact = rec.sf_id == sf_id and rec.cf == cf
                if exact:
                    dec.pruned.append(seg)
                elif (mode == "conservative"
                        and rec.accuracy >= accuracy - 1e-9):
                    dec.pruned.append(seg)
                    dec.conservative += 1
                else:
                    dec.kept.append(seg)
            sp.set(pruned=len(dec.pruned), kept=len(dec.kept),
                   conservative=dec.conservative)
        with self._mu:
            self._lookups += 1
        return dec

    # -- lifecycle -----------------------------------------------------------
    def flush(self):
        self.store.flush()

    def stats(self) -> dict:
        with self._mu:
            builds, build_s = self._builds, self._build_s
            lookups, invalidated = self._lookups, self._invalidated
        return {
            "index_sketches": len(self.store),
            "index_builds": builds,
            "index_build_s": build_s,
            "index_lookups": lookups,
            "index_invalidated": invalidated,
            "index_bytes": self.store.total_bytes(),
        }
