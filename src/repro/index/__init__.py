"""repro.index: ingest-time semantic indexing with predicate pushdown.

VStore spends ingest/storage resources so queries beat realtime; this
subsystem spends a little more of the same ingest budget to run the
cascade-head operators *at ingest* and persist their per-segment
activation sketches, so repeated and standing queries consult an index
and skip inactive segments before ever touching disk or the decoder.

* ``IndexStore`` — append-only, crash-safe on-disk store for sketch
  records beside the segment store (versioned log headers, atomic index
  flush, torn-tail truncation + orphan sweep on load, readonly attach);
* ``SemanticIndex`` — builds sketches (``op.detect`` at the op's
  profiled knobs) and answers pruning lookups: exact-match pushdown is
  bit-identical to the unpruned query, conservative mode additionally
  prunes across knob mismatches when the sketch's accuracy dominates;
* ``SketchRecord`` — the wire-safe persisted record (activation buckets
  + per-bucket item-count quantiles).

Sketch tasks ride the ingest scheduler's token bucket (priced like
transcodes, shed the same way); queries report pruning in
``QueryResult`` and the cluster rolls ``index_*`` counters up.
"""

from .sketch import PruneDecision, SemanticIndex, SketchRecord, sketch_specs
from .store import IndexStore

__all__ = [
    "IndexStore", "PruneDecision", "SemanticIndex", "SketchRecord",
    "sketch_specs",
]
