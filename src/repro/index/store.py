"""On-disk sketch store: small append-only logs behind a keyed index.

Mirrors the segment store's crash-safety contract at sketch scale
(records are ~100 bytes, not MB): ``root/log-XXXX.bin`` append-only
record logs + ``root/index.msgpack`` mapping key -> (log, offset,
length).  Every log file starts with a versioned magic header, so an
attach can reject a foreign or corrupt directory instead of decoding
garbage.  ``flush()`` is the durability ack point: a sketch is only
acknowledged (and only survives a crash) once the index referencing it
has been atomically replaced on disk.

Crash recovery on a writable load:

* the active log is truncated back to the length the durable index
  recorded — a torn or unacked record tail (the bytes a crash mid-append
  left behind) is discarded, never half-read;
* log files the index no longer references are swept (the garbage a
  crash may leave on either side of a compaction).

``readonly=True`` attaches without any mutation — no truncation, no
sweep, writes raise — safe for inspecting an index another process owns.
"""

from __future__ import annotations

import os
import threading

import msgpack

_MAGIC = b"VIDX0001"          # 8-byte versioned log header
_LOG_LIMIT = 4 * 1024 * 1024


class IndexStore:
    def __init__(self, root: str, auto_compact_frac: float | None = 0.5,
                 auto_compact_min_bytes: int = 1 << 14,
                 readonly: bool = False):
        if auto_compact_frac is not None and not 0 < auto_compact_frac <= 1:
            raise ValueError(f"auto_compact_frac must be in (0, 1], "
                             f"got {auto_compact_frac}")
        self.root = root
        self.readonly = readonly
        if not readonly:
            os.makedirs(root, exist_ok=True)
        self.auto_compact_frac = None if readonly else auto_compact_frac
        self.auto_compact_min_bytes = auto_compact_min_bytes
        self._mu = threading.Lock()
        self._index: dict[str, tuple[int, int, int]] = {}  # guarded-by: _mu
        self._log_id = 0    # guarded-by: _mu
        self._log_size = 0  # guarded-by: _mu (0 = log not created yet)
        self._live_bytes = 0  # guarded-by: _mu (sum of indexed lengths)
        self._dead_bytes = 0  # guarded-by: _mu (unreferenced log bytes)
        self._gen = 0  # guarded-by: _mu (compact() bump; detects rewrites)
        self.compactions = 0  # guarded-by: _mu
        self.truncated_bytes = 0  # guarded-by: _mu (torn tail cut at load)
        self._load()

    # -- persistence --------------------------------------------------------
    def _index_path(self) -> str:
        return os.path.join(self.root, "index.msgpack")

    def _log_path(self, lid: int) -> str:
        return os.path.join(self.root, f"log-{lid:04d}.bin")

    def _check_header(self, lid: int):
        with open(self._log_path(lid), "rb") as f:
            head = f.read(len(_MAGIC))
        if head != _MAGIC:
            raise ValueError(f"not an index log (bad header): "
                             f"{self._log_path(lid)}")

    def _load(self):
        if not os.path.exists(self._index_path()):
            return
        with open(self._index_path(), "rb") as f:
            raw = msgpack.unpackb(f.read())
        self._index = {k: tuple(v) for k, v in raw["index"].items()}
        self._log_id = raw["log_id"]
        self._log_size = raw["log_size"]
        self._live_bytes = sum(v[2] for v in self._index.values())
        self._dead_bytes = raw.get("dead_bytes", 0)
        for lid in {v[0] for v in self._index.values()}:
            self._check_header(lid)
        if self.readonly:
            return  # truncation and the orphan sweep mutate; owner's job
        # discard the torn/unacked tail of the active log: bytes past the
        # length the durable index recorded were never acknowledged (the
        # ack is the index flush), so cutting them loses nothing and
        # guarantees no half-written record is ever addressable
        path = self._log_path(self._log_id)
        if os.path.exists(path):
            self._check_header(self._log_id)
            actual = os.path.getsize(path)
            if actual > self._log_size:
                with open(path, "r+b") as f:
                    f.truncate(self._log_size)
                self.truncated_bytes += actual - self._log_size
        live = {v[0] for v in self._index.values()} | {self._log_id}
        for name in os.listdir(self.root):
            if name.startswith("log-") and name.endswith(".bin"):
                lid = int(name[4:-4])
                if lid not in live:
                    os.remove(os.path.join(self.root, name))

    def flush(self):
        """Make every put durable — the sketch ack point."""
        if self.readonly:
            return  # nothing of ours to persist
        with self._mu:
            self._flush_locked()

    def _flush_locked(self):
        blob = msgpack.packb({
            "index": {k: list(v) for k, v in self._index.items()},
            "log_id": self._log_id, "log_size": self._log_size,
            "dead_bytes": self._dead_bytes,
        })
        tmp = self._index_path() + ".tmp"
        with open(tmp, "wb") as f:
            f.write(blob)
        os.replace(tmp, self._index_path())  # atomic

    def _check_writable(self):
        if self.readonly:
            raise RuntimeError(f"read-only IndexStore at {self.root}")

    # -- KV API --------------------------------------------------------------
    def put(self, key: str, value: bytes):
        self._check_writable()
        with self._mu:
            if self._log_size + len(value) > _LOG_LIMIT and self._log_size:
                self._log_id += 1
                self._log_size = 0
            lid = self._log_id
            path = self._log_path(lid)
            with open(path, "ab") as f:
                if f.tell() == 0:
                    f.write(_MAGIC)
                offset = f.tell()
                f.write(value)
            self._log_size = offset + len(value)
            old = self._index.get(key)
            if old is not None:
                self._dead_bytes += old[2]
                self._live_bytes -= old[2]
            self._index[key] = (lid, offset, len(value))
            self._live_bytes += len(value)
            self._maybe_compact_locked()

    def get(self, key: str) -> bytes:
        # optimistic read (the segment store's idiom): snapshot the entry
        # under the lock, read the log without it, verify no compaction
        # rewrote the layout mid-read
        while True:
            with self._mu:
                gen = self._gen
                lid, offset, length = self._index[key]
                path = self._log_path(lid)
            try:
                with open(path, "rb") as f:
                    f.seek(offset)
                    blob = f.read(length)
            except FileNotFoundError:
                with self._mu:
                    if self._gen != gen:
                        continue  # compacted away mid-read; retry
                raise
            with self._mu:
                if self._gen == gen:
                    return blob

    def delete(self, key: str) -> bool:
        self._check_writable()
        with self._mu:
            entry = self._index.pop(key, None)
            if entry is None:
                return False
            self._dead_bytes += entry[2]
            self._live_bytes -= entry[2]
            self._maybe_compact_locked()
            return True

    def __contains__(self, key: str) -> bool:
        with self._mu:
            return key in self._index

    def __len__(self) -> int:
        with self._mu:
            return len(self._index)

    def keys(self, prefix: str = "") -> list[str]:
        with self._mu:
            return sorted(k for k in self._index if k.startswith(prefix))

    def total_bytes(self) -> int:
        with self._mu:
            return self._live_bytes

    # -- compaction ----------------------------------------------------------
    def _maybe_compact_locked(self):
        if self.auto_compact_frac is None:
            return
        if (self._dead_bytes >= self.auto_compact_min_bytes
                and self._dead_bytes > self.auto_compact_frac
                * max(1, self._live_bytes + self._dead_bytes)):
            self._compact_locked()

    def compact(self):
        self._check_writable()
        with self._mu:
            self._compact_locked()

    def _compact_locked(self):
        """Crash-safe rewrite into *fresh* log ids: the new index is made
        durable pointing at the new logs before the old logs are deleted,
        so a crash at any point leaves a readable store (new logs are
        orphans before the flush; old logs after it)."""
        old_lids = {v[0] for v in self._index.values()} | {self._log_id}
        base = self._log_id + 1
        items = sorted(self._index.items())
        new_index, li, size = {}, 0, 0
        out = open(self._log_path(base), "wb")
        out.write(_MAGIC)
        size = len(_MAGIC)
        for key, (olid, off, ln) in items:
            with open(self._log_path(olid), "rb") as f:
                f.seek(off)
                blob = f.read(ln)
            if size + ln > _LOG_LIMIT and size > len(_MAGIC):
                out.close()
                li += 1
                out = open(self._log_path(base + li), "wb")
                out.write(_MAGIC)
                size = len(_MAGIC)
            new_index[key] = (base + li, size, ln)
            out.write(blob)
            size += ln
        out.close()
        self._index = new_index
        self._log_id, self._log_size = base + li, size
        self._live_bytes = sum(v[2] for v in new_index.values())
        self._dead_bytes = 0
        self._gen += 1
        self.compactions += 1
        self._flush_locked()  # durable before the destructive deletes
        for lid in old_lids:
            path = self._log_path(lid)
            if os.path.exists(path):
                os.remove(path)
