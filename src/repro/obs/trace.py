"""Structured trace spans over a bounded per-process ring buffer.

The data path (store read -> blob parse -> entropy/residual decode ->
spatial convert -> batched detect), the serving layers above it, and the
ingest machinery all emit *spans*: named intervals with a parent link and
a small dict of scalar attributes (bytes, chunks, cf name, hit kind ...).
Spans form per-thread stacks (``threading.local``) so nesting needs no
plumbing, and finished spans land in a fixed-capacity ring — tracing a
long-running server bounds memory by construction, at the cost of losing
the oldest spans.

Disabled cost is one attribute read plus a shared no-op context manager:
``span()`` returns the ``_NOOP`` singleton without allocating, so leaving
instrumentation in hot paths is free enough to keep everywhere (the
``obs_overhead`` bench gates this, < 3% on the full query path).

Cross-process timelines: span/trace ids embed a per-process random salt,
so ids minted on different shard workers never collide; workers ship
finished spans as plain dicts next to their ``QueryResult`` wire forms and
the router ``absorb``s them — re-based onto the router's clock via the
per-host offset measured at ``hello`` — into its own ring.  One
``export_trace`` then writes a single Chrome trace-event JSON
(Perfetto-loadable) covering the whole cluster.
"""

from __future__ import annotations

import itertools
import json
import os
import threading
import time
from collections import deque


class Span:
    """One finished interval.  ``t0`` is ``time.perf_counter()`` seconds
    (re-based by ``Tracer.absorb`` when crossing processes); ids are
    64-bit ints (32-bit per-process salt << 32 | counter)."""

    __slots__ = ("name", "trace_id", "span_id", "parent_id", "t0", "dur",
                 "pid", "tid", "attrs")

    def __init__(self, name, trace_id, span_id, parent_id, t0, dur,
                 pid, tid, attrs):
        self.name = name
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.t0 = t0
        self.dur = dur
        self.pid = pid
        self.tid = tid
        self.attrs = attrs

    def to_wire(self) -> dict:
        """Msgpack-safe dict (short keys; attrs coerced to scalars)."""
        return {"n": self.name, "t": self.trace_id, "s": self.span_id,
                "p": self.parent_id, "t0": self.t0, "d": self.dur,
                "pid": self.pid, "tid": self.tid,
                "a": {k: (v if isinstance(v, (str, int, float, bool))
                          else str(v))
                      for k, v in self.attrs.items()}}

    @staticmethod
    def from_wire(d: dict) -> "Span":
        return Span(d["n"], int(d["t"]), int(d["s"]), int(d["p"]),
                    float(d["t0"]), float(d["d"]), int(d["pid"]),
                    int(d["tid"]), dict(d.get("a") or {}))


class _Noop:
    """Shared do-nothing span handle (the disabled-path return value)."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


_NOOP = _Noop()


class _SpanCM:
    """Live span handle: context manager that resolves its parent from the
    thread's span stack (falling back to an ``activate``d remote context)
    on enter and records into the tracer's ring on exit.  ``set`` adds
    attributes discovered mid-span (hit kind, bytes touched ...)."""

    __slots__ = ("_tr", "name", "attrs", "trace_id", "span_id",
                 "parent_id", "_t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tr = tracer
        self.name = name
        self.attrs = attrs

    def set(self, **attrs):
        self.attrs.update(attrs)
        return self

    def __enter__(self):
        tr = self._tr
        tls = tr._tls
        stack = getattr(tls, "stack", None)
        if stack is None:
            stack = tls.stack = []
        if stack:
            self.trace_id, self.parent_id = stack[-1]
        else:
            ctx = getattr(tls, "ctx", None)
            if ctx is not None:
                self.trace_id, self.parent_id = ctx
            else:
                self.trace_id, self.parent_id = tr.new_id(), 0
        self.span_id = tr.new_id()
        stack.append((self.trace_id, self.span_id))
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        tr = self._tr
        # pop up to and including our own entry: an exception between an
        # explicitly paired enter/exit deeper in may have orphaned inner
        # entries, and a reused pool thread must not inherit them
        stack = tr._tls.stack
        while stack:
            if stack.pop()[1] == self.span_id:
                break
        tr.record(Span(self.name, self.trace_id, self.span_id,
                       self.parent_id, self._t0, t1 - self._t0, tr.pid,
                       threading.get_ident(), self.attrs))
        return False


class _Activate:
    """Adopt a remote (or otherwise explicit) trace context as this
    thread's root: spans opened on an empty stack parent under it instead
    of starting fresh traces.  A falsy trace id makes this a no-op, so
    callers can pass through unconditionally."""

    __slots__ = ("_tr", "_ctx", "_saved")

    def __init__(self, tracer: "Tracer", trace_id: int, parent_id: int):
        self._tr = tracer
        self._ctx = (trace_id, parent_id) if trace_id else None

    def __enter__(self):
        tls = self._tr._tls
        self._saved = getattr(tls, "ctx", None)
        if self._ctx is not None:
            tls.ctx = self._ctx
        return self

    def __exit__(self, *exc):
        self._tr._tls.ctx = self._saved
        return False


class Tracer:
    """Per-process span collector.  All public methods are thread-safe;
    ``enabled`` is a plain attribute read on the hot path."""

    def __init__(self, capacity: int = 16384, pid: int | None = None):
        self.enabled = False
        self.capacity = int(capacity)
        self.pid = os.getpid() if pid is None else pid
        self._mu = threading.Lock()
        self._spans: deque[Span] = deque(maxlen=self.capacity)  # guarded-by: _mu
        self._tls = threading.local()
        # ids unique across processes without coordination: a random
        # 32-bit per-process salt above a monotone counter
        self._salt = int.from_bytes(os.urandom(4), "big") | 1
        self._ids = itertools.count(1)

    # -- id / context --------------------------------------------------------
    def new_id(self) -> int:
        return (self._salt << 32) | (next(self._ids) & 0xFFFFFFFF)

    def current(self) -> tuple[int, int]:
        """(trace_id, span_id) of the innermost open span on this thread,
        falling back to an ``activate``d context, else ``(0, 0)``."""
        stack = getattr(self._tls, "stack", None)
        if stack:
            return stack[-1]
        return getattr(self._tls, "ctx", None) or (0, 0)

    def activate(self, trace_id: int, parent_id: int) -> _Activate:
        return _Activate(self, int(trace_id), int(parent_id))

    # -- span creation -------------------------------------------------------
    def span(self, name: str, **attrs):
        if not self.enabled:
            return _NOOP
        return _SpanCM(self, name, attrs)

    def start_span(self, name: str, **attrs) -> _SpanCM:
        """Open a span *without* pushing the thread stack — for intervals
        whose begin/end straddle threads (scatter-gather roots).  Close
        with ``finish()``; read ``trace_id``/``span_id`` for child ctx."""
        cm = _SpanCM(self, name, attrs)
        stack = getattr(self._tls, "stack", None)
        if stack:
            cm.trace_id, cm.parent_id = stack[-1]
        else:
            ctx = getattr(self._tls, "ctx", None)
            if ctx is not None:
                cm.trace_id, cm.parent_id = ctx
            else:
                cm.trace_id, cm.parent_id = self.new_id(), 0
        cm.span_id = self.new_id()
        cm._t0 = time.perf_counter()
        return cm

    def finish(self, cm: _SpanCM) -> None:
        """Record a ``start_span`` handle."""
        self.record(Span(cm.name, cm.trace_id, cm.span_id, cm.parent_id,
                         cm._t0, time.perf_counter() - cm._t0, self.pid,
                         threading.get_ident(), cm.attrs))

    # -- ring buffer ---------------------------------------------------------
    def record(self, span: Span) -> None:
        with self._mu:
            self._spans.append(span)

    def spans(self) -> list[Span]:
        """Non-destructive snapshot of the ring (oldest first)."""
        with self._mu:
            return list(self._spans)

    def drain(self) -> list[Span]:
        with self._mu:
            out = list(self._spans)
            self._spans.clear()
        return out

    def clear(self) -> None:
        with self._mu:
            self._spans.clear()

    def take(self, trace_id: int) -> list[dict]:
        """Remove and return (as wire dicts) every ringed span of one
        trace — what a shard worker ships back with a query response."""
        with self._mu:
            keep, out = [], []
            for sp in self._spans:
                (out if sp.trace_id == trace_id else keep).append(sp)
            self._spans.clear()
            self._spans.extend(keep)
        return [sp.to_wire() for sp in out]

    def absorb(self, span_dicts: list[dict], pid: int | None = None,
               offset: float = 0.0) -> int:
        """Merge wire-form spans from another process into this ring,
        re-based onto this process's clock by ``offset`` (seconds to add
        to each ``t0``) and re-labelled with ``pid`` for display.  Ids are
        kept verbatim — the per-process salt guarantees no collisions, and
        parents minted router-side stay resolvable."""
        spans = [Span.from_wire(d) for d in span_dicts]
        for sp in spans:
            sp.t0 += offset
            if pid is not None:
                sp.pid = pid
        with self._mu:
            self._spans.extend(spans)
        return len(spans)


#: process-wide default tracer; instrumentation goes through the module
#: helpers below so call sites stay one short name
TRACER = Tracer()


def span(name: str, **attrs):
    if not TRACER.enabled:
        return _NOOP
    return _SpanCM(TRACER, name, attrs)


def enable(on: bool = True) -> None:
    TRACER.enabled = on


# -- Chrome trace-event export ------------------------------------------------

def chrome_trace_events(spans: list[Span],
                        process_names: dict[int, str] | None = None,
                        base: float | None = None) -> list[dict]:
    """Spans -> Chrome trace-event dicts (complete events, microseconds
    relative to the earliest span).  Span/parent/trace ids ride in
    ``args`` so tooling can rebuild the tree; visual nesting in
    Perfetto/chrome://tracing comes from ts/dur containment per track."""
    if not spans:
        return []
    if base is None:
        base = min(sp.t0 for sp in spans)
    events = []
    for p in sorted({sp.pid for sp in spans}):
        name = (process_names or {}).get(p, f"pid {p}")
        events.append({"name": "process_name", "ph": "M", "pid": p,
                       "tid": 0, "args": {"name": name}})
    for sp in spans:
        events.append({
            "name": sp.name, "cat": "repro", "ph": "X",
            "ts": (sp.t0 - base) * 1e6, "dur": sp.dur * 1e6,
            "pid": sp.pid, "tid": sp.tid % (1 << 31),
            "args": {"trace": format(sp.trace_id, "x"),
                     "span": format(sp.span_id, "x"),
                     "parent": format(sp.parent_id, "x"),
                     **sp.attrs}})
    return events


def export_trace(path: str, tracer: Tracer | None = None,
                 process_names: dict[int, str] | None = None) -> int:
    """Write the tracer's ring (non-destructively) as Chrome trace-event
    JSON; returns the number of spans exported."""
    tr = tracer or TRACER
    spans = tr.spans()
    doc = {"traceEvents": chrome_trace_events(spans, process_names),
           "displayTimeUnit": "ms"}
    with open(path, "w") as f:
        json.dump(doc, f)
    return len(spans)
