"""repro.obs: tracing, metrics, drift detection, and telemetry.

One observability layer for the whole data path — see ``trace`` (span
facility + Chrome trace-event export), ``metrics`` (counters, gauges,
mergeable latency histograms), ``drift`` (observed-vs-profiled speed
ratios), and ``telemetry`` (crash-safe on-disk metric time-series, SLO
classes/burn rates, deduplicated alerts — see README.md).  The package
``__init__``'s import cost is stdlib-only; the rest of the tree imports
it freely, including from inside codec hot paths.  ``telemetry`` needs
msgpack (the on-disk frame codec, same as the cluster wire), so it stays
a submodule import: ``from repro.obs import telemetry``.
"""

from .drift import DriftDetector, merge_reports, retrieval_expectations
from .metrics import DEFAULT_BOUNDS, Histogram, MetricsRegistry
from .trace import (TRACER, Span, Tracer, chrome_trace_events, enable,
                    export_trace, span)

__all__ = [
    "TRACER", "Span", "Tracer", "chrome_trace_events", "enable",
    "export_trace", "span",
    "DEFAULT_BOUNDS", "Histogram", "MetricsRegistry",
    "DriftDetector", "merge_reports", "retrieval_expectations",
]
