"""repro.obs: tracing, metrics, and profile-drift detection.

One observability layer for the whole data path — see ``trace`` (span
facility + Chrome trace-event export), ``metrics`` (counters, gauges,
mergeable latency histograms), and ``drift`` (observed-vs-profiled speed
ratios).  Import cost is stdlib-only; the rest of the tree imports this
package freely, including from inside codec hot paths.
"""

from .drift import DriftDetector, merge_reports, retrieval_expectations
from .metrics import DEFAULT_BOUNDS, Histogram, MetricsRegistry
from .trace import (TRACER, Span, Tracer, chrome_trace_events, enable,
                    export_trace, span)

__all__ = [
    "TRACER", "Span", "Tracer", "chrome_trace_events", "enable",
    "export_trace", "span",
    "DEFAULT_BOUNDS", "Histogram", "MetricsRegistry",
    "DriftDetector", "merge_reports", "retrieval_expectations",
]
