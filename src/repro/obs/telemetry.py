"""Continuous telemetry: a crash-safe metric time-series plus SLO
accounting primitives.

PR 6 gave the tree point-in-time ``stats()`` snapshots; this module makes
them *continuous*.  A ``TelemetrySampler`` snapshots a source (the
server's metrics registry, or the router's cluster-merged scrape) on an
interval into a ``TelemetryLog`` — an append-only on-disk series with the
same durability discipline as ``repro.index.IndexStore``:

* magic + version header (``VTEL0001``), then length-prefixed msgpack
  frames;
* every append is flushed and fsync'd before it returns — an acked frame
  survives SIGKILL;
* a writable reopen scans the log and truncates the torn tail (a frame a
  crash cut short) back to the last intact frame, then continues the
  sequence; readers stop at the tail without ever mutating the file.

Frames are plain dicts (``{"t", "seq", "metrics", "slo", "alerts", ...}``)
so the ``vtop`` dashboard, tests, and offline tooling all read the same
bytes.  Cluster merging reuses ``Histogram.merge`` bucket-sum semantics —
counters add, bucket vectors add, percentiles are recomputed from the
merged buckets, never averaged across processes.

SLO accounting: an ``SLOClass`` names an error budget
(``target_miss_frac`` over ``window_s``) and a deadline-derivation slack;
``derive_deadline_ms`` turns the class into a concrete ``deadline_ms``
from the derived config's *profiled* per-knob speeds (the ROADMAP item:
admission control translating an SLO class into per-stage deadline
budgets); ``BurnRate`` tracks the windowed miss rate against the budget;
``AlertDeduper`` turns persistent conditions (SLO burn, profile drift)
into one alert per key per window instead of one per query.
"""

from __future__ import annotations

import os
import struct
import threading
import time

import msgpack

from .metrics import Histogram

_MAGIC = b"VTEL0001"
_LEN = struct.Struct(">I")
#: sanity bound on one frame's payload — a length prefix beyond this is
#: torn/corrupt tail, not a real frame
MAX_FRAME = 16 << 20


class TelemetryError(RuntimeError):
    """The file is not a telemetry log (bad magic / wrong version)."""


def _scan(buf: bytes):
    """Walk ``buf`` (everything after the header) yielding
    ``(end_offset, frame)`` for each intact frame; stops at the first
    torn or undecodable tail."""
    off, n = 0, len(buf)
    while off + _LEN.size <= n:
        (ln,) = _LEN.unpack_from(buf, off)
        if ln > MAX_FRAME or off + _LEN.size + ln > n:
            return  # torn length or torn payload
        payload = buf[off + _LEN.size:off + _LEN.size + ln]
        try:
            frame = msgpack.unpackb(payload, raw=False,
                                    strict_map_key=False)
        except Exception:  # noqa: BLE001 — any decode failure = torn tail
            return
        if not isinstance(frame, dict):
            return
        off += _LEN.size + ln
        yield off, frame


def read_frames(path: str) -> list[dict]:
    """Read every intact frame of a telemetry log (read-only: a torn tail
    is skipped, never truncated — safe against a live writer and on
    read-only media)."""
    with open(path, "rb") as f:
        head = f.read(len(_MAGIC))
        if head != _MAGIC:
            raise TelemetryError(f"{path}: not a telemetry log "
                                 f"(magic {head!r})")
        buf = f.read()
    return [frame for _off, frame in _scan(buf)]


class TelemetryLog:
    """Append-only crash-safe frame log (one per process).

    ``append`` stamps a monotone ``seq``, writes one length-prefixed
    msgpack frame, and fsyncs before returning — the returned seq is the
    durability ack.  Reopening an existing log truncates any torn tail
    (``truncated_bytes`` records how much) and resumes the sequence.
    """

    def __init__(self, path: str):
        self.path = path
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._mu = threading.Lock()
        self._closed = False        # guarded-by: _mu
        self.truncated_bytes = 0    # torn tail dropped at open (read-only)
        self.frames_recovered = 0   # intact frames found at open
        if not os.path.exists(path) or os.path.getsize(path) == 0:
            with open(path, "wb") as f:
                f.write(_MAGIC)
                f.flush()
                os.fsync(f.fileno())
        self._f = open(path, "r+b")  # guarded-by: _mu (after init)
        head = self._f.read(len(_MAGIC))
        if head != _MAGIC:
            self._f.close()
            raise TelemetryError(f"{path}: not a telemetry log "
                                 f"(magic {head!r})")
        buf = self._f.read()
        good, last_seq = 0, 0
        for off, frame in _scan(buf):
            good = off
            last_seq = int(frame.get("seq", last_seq))
            self.frames_recovered += 1
        if good < len(buf):
            # a crash tore the tail mid-frame: drop it so the next append
            # lands on a frame boundary (IndexStore's recovery discipline)
            self.truncated_bytes = len(buf) - good
            self._f.truncate(len(_MAGIC) + good)
        self._f.seek(0, os.SEEK_END)
        self._seq = last_seq  # guarded-by: _mu

    def append(self, body: dict) -> int:
        """Durably append one frame; returns its seq (the ack).  ``body``
        is copied — the caller's dict is never mutated."""
        with self._mu:
            if self._closed:
                raise TelemetryError(f"{self.path}: log is closed")
            seq = self._seq + 1
            frame = dict(body)
            frame["seq"] = seq
            payload = msgpack.packb(frame, use_bin_type=True)
            self._f.write(_LEN.pack(len(payload)))
            self._f.write(payload)
            self._f.flush()
            os.fsync(self._f.fileno())
            self._seq = seq
            return seq

    @property
    def seq(self) -> int:
        with self._mu:
            return self._seq

    def close(self) -> None:
        with self._mu:
            if not self._closed:
                self._closed = True
                self._f.close()

    def __enter__(self) -> "TelemetryLog":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class TelemetrySampler:
    """Samples ``source()`` (a frame-body callable) into a
    ``TelemetryLog`` every ``interval_s``.  ``sample_now()`` takes one
    synchronous sample — tests and shutdown paths use it for a
    deterministic final frame.  The source runs outside every lock (it
    takes the registry/scheduler locks itself)."""

    def __init__(self, source, log: TelemetryLog, interval_s: float = 1.0,
                 clock=time.time):
        if interval_s <= 0:
            raise ValueError(f"interval_s must be > 0, got {interval_s}")
        self.source = source
        self.log = log
        self.interval_s = float(interval_s)
        self._clock = clock
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._mu = threading.Lock()
        self._samples = 0   # guarded-by: _mu
        self._errors = 0    # guarded-by: _mu

    def start(self) -> "TelemetrySampler":
        if self._thread is None:
            self._thread = threading.Thread(target=self._loop,
                                            name="vstore-telemetry",
                                            daemon=True)
            self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            self.sample_now()

    def sample_now(self) -> int | None:
        """One sample: build a frame body from the source, stamp the wall
        clock, durably append.  Returns the acked seq, or None if the
        source or the append failed (failures are counted, not raised —
        telemetry must never take the data path down)."""
        try:
            body = self.source()
            body["t"] = float(self._clock())
            seq = self.log.append(body)
        except Exception:  # noqa: BLE001
            with self._mu:
                self._errors += 1
            return None
        with self._mu:
            self._samples += 1
        return seq

    @property
    def samples(self) -> int:
        with self._mu:
            return self._samples

    @property
    def errors(self) -> int:
        with self._mu:
            return self._errors

    def stop(self, final: bool = True) -> None:
        """Stop the loop; ``final`` takes one last synchronous sample (so
        a clean shutdown's counters reach the log) before closing it."""
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join()
            self._thread = None
        if final:
            self.sample_now()
        self.log.close()

    close = stop


# -- SLO classes / deadline derivation ---------------------------------------

SLO_FIELDS = ("slack_x", "target_miss_frac", "window_s")


class SLOClass:
    """A named latency SLO: deadline slack over the *expected* cascade
    time, and an error budget (miss fraction over a rolling window)."""

    __slots__ = ("name", "slack_x", "target_miss_frac", "window_s")

    def __init__(self, name: str, slack_x: float = 3.0,
                 target_miss_frac: float = 0.01, window_s: float = 60.0):
        if slack_x <= 0:
            raise ValueError(f"slack_x must be > 0, got {slack_x}")
        if not 0 < target_miss_frac <= 1:
            raise ValueError("target_miss_frac must be in (0, 1], got "
                             f"{target_miss_frac}")
        self.name = name
        self.slack_x = float(slack_x)
        self.target_miss_frac = float(target_miss_frac)
        self.window_s = float(window_s)


def derive_deadline_ms(config, spec, ops, accuracy: float,
                       n_segments: int, slack_x: float = 3.0) -> float:
    """Translate an SLO class into a concrete per-query deadline from the
    derived config's *profiled* per-knob speeds: each cascade stage's
    expected consume time is ``video_seconds / consumer_speed(op, acc)``
    (a conservative full-scan bound — early stages prune later ones, so
    the real cascade is faster), summed over the stages and scaled by the
    class's slack.  Returns milliseconds, ``submit(deadline_ms=...)``
    ready."""
    video_s = n_segments * spec.segment_seconds
    expected = sum(video_s / config.consumer_speed(op, accuracy)
                   for op in ops)
    return slack_x * expected * 1e3


class BurnRate:
    """Windowed SLO burn: the observed miss fraction over the class's
    rolling window divided by its error budget.  Burn > 1 means the
    budget is being consumed faster than allotted — the alerting
    threshold."""

    def __init__(self, slo: SLOClass, clock=time.monotonic):
        self.slo = slo
        self._clock = clock
        self._mu = threading.Lock()
        self._events: list = []  # guarded-by: _mu — (t, missed) in window
        self._hits = 0           # guarded-by: _mu (lifetime)
        self._misses = 0         # guarded-by: _mu (lifetime)

    def _prune_locked(self, now: float) -> None:
        horizon = now - self.slo.window_s
        i = 0
        for i, (t, _m) in enumerate(self._events):
            if t >= horizon:
                break
        else:
            i = len(self._events)
        if i:
            del self._events[:i]

    def record(self, missed: bool) -> None:
        now = self._clock()
        with self._mu:
            self._events.append((now, bool(missed)))
            if missed:
                self._misses += 1
            else:
                self._hits += 1
            self._prune_locked(now)

    def snapshot(self) -> dict:
        now = self._clock()
        with self._mu:
            self._prune_locked(now)
            total = len(self._events)
            misses = sum(1 for _t, m in self._events if m)
            hits_life, misses_life = self._hits, self._misses
        rate = misses / total if total else 0.0
        return {"hits": hits_life, "misses": misses_life,
                "window_total": total, "window_misses": misses,
                "window_miss_rate": rate,
                "burn": rate / self.slo.target_miss_frac,
                "target_miss_frac": self.slo.target_miss_frac,
                "window_s": self.slo.window_s}


class AlertDeduper:
    """Deduplicated alert events: ``emit`` records at most one alert per
    key per ``window_s`` (a persistently-drifted knob or burning SLO
    produces one alert per window, not one per sample); ``drain`` hands
    the accumulated events to the telemetry frame."""

    def __init__(self, window_s: float = 30.0, clock=time.monotonic,
                 wall=time.time):
        self.window_s = float(window_s)
        self._clock = clock
        self._wall = wall
        self._mu = threading.Lock()
        self._last: dict[str, float] = {}  # guarded-by: _mu
        self._pending: list[dict] = []     # guarded-by: _mu

    def emit(self, key: str, severity: str, message: str, **attrs) -> bool:
        """Returns True if the alert was recorded, False if deduplicated
        (the same key fired within the window)."""
        now = self._clock()
        with self._mu:
            last = self._last.get(key)
            if last is not None and now - last < self.window_s:
                return False
            self._last[key] = now
            self._pending.append({"key": key, "severity": severity,
                                  "message": message,
                                  "t": float(self._wall()), **attrs})
            return True

    def drain(self) -> list[dict]:
        with self._mu:
            out, self._pending = self._pending, []
            return out


def drift_alert_candidates(report: dict) -> list[tuple[str, str, dict]]:
    """Flatten a ``DriftDetector.report()`` into ``(key, message, attrs)``
    per *drifted* knob — the deduper decides which actually emit."""
    out = []
    for section in ("consumption", "retrieval"):
        for knob, row in (report.get(section) or {}).items():
            if not row.get("drifted"):
                continue
            msg = (f"{section} knob {knob}: observed "
                   f"{row.get('observed_x', 0.0):.1f}x vs expected "
                   f"{row.get('expected_x', 0.0):.1f}x "
                   f"(ratio {row.get('ratio', 0.0):.2f})")
            out.append((f"drift:{section}:{knob}", msg,
                        {"section": section, "knob": knob,
                         "ratio": float(row.get("ratio", 0.0))}))
    return out


# -- cluster merge ------------------------------------------------------------

def merge_frames(parts: list[dict]) -> dict:
    """Merge per-process telemetry frame bodies into one cluster body.

    Counters and gauges sum; histogram snapshots bucket-merge via
    ``Histogram.merge`` (percentiles recomputed from the union buckets —
    never averaged across shards); per-queue SLO hit/miss counts sum and
    lateness histograms merge; per-class burn keeps the *worst* shard
    (the drift-report convention: a cluster is burning if any shard is);
    alerts concatenate tagged with their source index."""
    parts = [p for p in parts if p]
    counters: dict[str, float] = {}
    gauges: dict[str, float] = {}
    hists: dict[str, list] = {}
    queues: dict[str, dict] = {}
    classes: dict[str, dict] = {}
    alerts: list[dict] = []
    for i, p in enumerate(parts):
        m = p.get("metrics") or {}
        for k, v in (m.get("counters") or {}).items():
            counters[k] = counters.get(k, 0) + v
        for k, v in (m.get("gauges") or {}).items():
            gauges[k] = gauges.get(k, 0) + v
        for k, snap in (m.get("histograms") or {}).items():
            hists.setdefault(k, []).append(snap)
        slo = p.get("slo") or {}
        for qk, row in (slo.get("queues") or {}).items():
            agg = queues.setdefault(qk, {"hits": 0, "misses": 0,
                                         "lateness": []})
            agg["hits"] += row.get("hits", 0)
            agg["misses"] += row.get("misses", 0)
            if row.get("lateness"):
                agg["lateness"].append(row["lateness"])
        for name, row in (slo.get("classes") or {}).items():
            agg = classes.get(name)
            if agg is None:
                classes[name] = dict(row)
            else:
                for k in ("hits", "misses", "window_total",
                          "window_misses"):
                    agg[k] = agg.get(k, 0) + row.get(k, 0)
                # worst shard's burn is the cluster's burn
                for k in ("burn", "window_miss_rate"):
                    agg[k] = max(agg.get(k, 0.0), row.get(k, 0.0))
        for a in (p.get("alerts") or []):
            alerts.append({**a, "source": i})
    return {
        "metrics": {
            "counters": counters,
            "gauges": gauges,
            "histograms": {k: Histogram.merge(v) for k, v in hists.items()},
        },
        "slo": {
            "queues": {qk: {"hits": row["hits"], "misses": row["misses"],
                            "lateness": Histogram.merge(row["lateness"])}
                       for qk, row in queues.items()},
            "classes": classes,
        },
        "alerts": alerts,
        "sources": len(parts),
    }
