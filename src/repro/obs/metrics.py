"""Counters, gauges, and mergeable latency histograms.

The cluster's observability problem is distribution-shaped: per-shard
``stats()`` dicts used to carry scalar means, and the router's rollup
could only sum or average them — averaging per-shard p95s (or worse,
means) erases exactly the skew a tail-latency question asks about.  So
the primitive here is a fixed-bound bucketed ``Histogram`` whose
``snapshot()`` is a plain dict that crosses the wire, and whose ``merge``
adds bucket counts — percentiles of the merged distribution are then
recomputed from the combined buckets, which is correct to bucket
resolution no matter how skewed the shards are.

Bucket bounds are shared by construction (every histogram defaults to
``DEFAULT_BOUNDS``); ``merge`` refuses mismatched bounds rather than
guessing a re-bucketing.
"""

from __future__ import annotations

import bisect
import math
import threading

#: log-spaced latency bounds in seconds, ~2-2.5x apart: sub-ms decode
#: dispatches through multi-second cluster drains land mid-range
DEFAULT_BOUNDS = (0.0005, 0.001, 0.002, 0.005, 0.01, 0.02, 0.05,
                  0.1, 0.2, 0.5, 1.0, 2.0, 5.0, 10.0, 30.0)


def _percentiles(bounds, counts, count, mn, mx, qs):
    """Percentile estimates from bucket counts (linear interpolation
    inside the winning bucket; min/max clamp the open-ended buckets)."""
    if count <= 0:
        return {q: 0.0 for q in qs}
    out = {}
    for q in qs:
        rank = q * (count - 1)
        c = 0
        val = mx
        for i, n in enumerate(counts):
            if n == 0:
                continue
            if c + n > rank:
                lo = bounds[i - 1] if i > 0 else min(mn, bounds[0])
                hi = bounds[i] if i < len(bounds) else max(mx, bounds[-1])
                lo = max(lo, mn)
                hi = min(hi, mx)
                if hi < lo:
                    lo = hi
                val = lo + (hi - lo) * ((rank - c + 0.5) / n)
                break
            c += n
        out[q] = val
    return out


def _snapshot_dict(bounds, counts, count, total, mn, mx):
    ps = _percentiles(bounds, counts, count, mn, mx, (0.5, 0.95, 0.99))
    return {"count": count, "sum": total,
            "mean": total / count if count else 0.0,
            "min": mn if count else 0.0, "max": mx if count else 0.0,
            "p50": ps[0.5], "p95": ps[0.95], "p99": ps[0.99],
            "bounds": list(bounds), "counts": list(counts)}


class Histogram:
    """Thread-safe bucketed histogram of nonnegative floats (latencies in
    seconds by convention).  ``snapshot()`` is wire-safe; ``merge`` is the
    cluster rollup."""

    __slots__ = ("bounds", "_counts", "_count", "_sum", "_min", "_max",
                 "_mu")

    def __init__(self, bounds=None):
        self.bounds = tuple(bounds if bounds is not None else DEFAULT_BOUNDS)
        self._counts = [0] * (len(self.bounds) + 1)  # guarded-by: _mu
        self._count = 0        # guarded-by: _mu
        self._sum = 0.0        # guarded-by: _mu
        self._min = math.inf   # guarded-by: _mu
        self._max = -math.inf  # guarded-by: _mu
        self._mu = threading.Lock()

    def observe(self, value: float) -> None:
        v = float(value)
        i = bisect.bisect_left(self.bounds, v)
        with self._mu:
            self._counts[i] += 1
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    @property
    def count(self) -> int:
        return self._count

    def percentile(self, q: float) -> float:
        if q in (0.5, 0.95, 0.99):
            return self.snapshot()[f"p{int(q * 100)}"]
        with self._mu:
            counts, count = list(self._counts), self._count
            lo, hi = self._min, self._max
        return _percentiles(self.bounds, counts, count, lo, hi, (q,))[q]

    def snapshot(self) -> dict:
        with self._mu:
            return _snapshot_dict(self.bounds, self._counts, self._count,
                                  self._sum, self._min, self._max)

    @staticmethod
    def merge(snapshots: list[dict]) -> dict:
        """Combine ``snapshot()`` dicts from many histograms (e.g. one per
        shard) into one snapshot of the union distribution.  Bucket counts
        add; percentiles are recomputed from the merged buckets — never
        averaged across sources."""
        snaps = [s for s in snapshots if s and s.get("count", 0) >= 0]
        if not snaps:
            return _snapshot_dict(DEFAULT_BOUNDS,
                                  [0] * (len(DEFAULT_BOUNDS) + 1),
                                  0, 0.0, math.inf, -math.inf)
        bounds = tuple(snaps[0]["bounds"])
        counts = [0] * (len(bounds) + 1)
        count, total = 0, 0.0
        mn, mx = math.inf, -math.inf
        for s in snaps:
            if tuple(s["bounds"]) != bounds:
                raise ValueError("cannot merge histograms with different "
                                 f"bounds: {s['bounds']} vs {list(bounds)}")
            for i, n in enumerate(s["counts"]):
                counts[i] += n
            count += s["count"]
            total += s["sum"]
            if s["count"]:
                mn = min(mn, s["min"])
                mx = max(mx, s["max"])
        return _snapshot_dict(bounds, counts, count, total, mn, mx)


class MetricsRegistry:
    """Named counters / gauges / histograms behind one lock, with a
    wire-safe ``snapshot()``.  Counters are monotone (float-capable:
    video-seconds and wall-clock accumulators live here too); gauges are
    last-write-wins."""

    def __init__(self):
        self._mu = threading.Lock()
        self._counters: dict[str, float] = {}   # guarded-by: _mu
        self._gauges: dict[str, float] = {}     # guarded-by: _mu
        self._hists: dict[str, Histogram] = {}  # guarded-by: _mu

    # -- counters / gauges ---------------------------------------------------
    def inc(self, name: str, n: float = 1) -> None:
        with self._mu:
            self._counters[name] = self._counters.get(name, 0) + n

    def value(self, name: str, default: float = 0):
        with self._mu:
            if name in self._counters:
                return self._counters[name]
            return self._gauges.get(name, default)

    def set_gauge(self, name: str, v: float) -> None:
        with self._mu:
            self._gauges[name] = v

    # -- histograms ----------------------------------------------------------
    def histogram(self, name: str, bounds=None) -> Histogram:
        with self._mu:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = Histogram(bounds)
            return h

    def observe(self, name: str, v: float) -> None:
        self.histogram(name).observe(v)

    # -- export --------------------------------------------------------------
    def snapshot(self) -> dict:
        with self._mu:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = dict(self._hists)
        return {"counters": counters, "gauges": gauges,
                "histograms": {k: h.snapshot() for k, h in hists.items()}}
