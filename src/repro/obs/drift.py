"""Profile-drift detection: observed stage speeds vs the derived config.

Backward derivation (``core.configure``) chooses every knob from profiled
costs — consumption x-realtime per (op, accuracy), retrieval x-realtime
per (sf, cf).  Those profiles go stale: a detector library update, a
different host, thermal throttling, a storage tier change.  Nothing in
the data path fails when that happens; the accuracy/speed tradeoff the
user asked for just silently stops being the one they get.

``DriftDetector`` closes the loop: every completed ``QueryResult``
carries per-stage timings and scanned-segment counts, from which the
*observed* x-realtime of each knob falls out.  Observations are folded
into an EMA per knob and compared against the expected value; a knob
whose ratio leaves ``[1/tolerance, tolerance]`` is flagged in
``report()`` (surfaced through ``VStoreServer.stats()["drift"]``), so a
stale profile is visible long before anyone re-runs the profiler.

Retrieval is judged slow-only: the pipelined executor's ``retrieve_s`` is
time *blocked waiting* on retrieval, so over-performing (cache hits,
good overlap) is expected and only under-performing signals drift.
"""

from __future__ import annotations

import math
import threading


def _fold(table: dict, key, observed: float, alpha: float) -> None:
    prev, n = table.get(key, (observed, 0))
    table[key] = (prev + alpha * (observed - prev), n + 1)


class DriftDetector:
    """EMA-based per-knob speed tracker.

    ``retrieval_speeds`` optionally maps ``(sf_id, cf_name) -> expected
    retrieval x-realtime`` (e.g. from ``Profiler.retrieval_speed``); when
    absent only consumption knobs are tracked — consumption expectations
    travel with the wire-rebuilt config, retrieval profiles do not.
    """

    def __init__(self, config, spec, retrieval_speeds: dict | None = None,
                 tolerance: float = 3.0, ema_alpha: float = 0.3):
        if tolerance <= 1.0:
            raise ValueError(f"tolerance must be > 1, got {tolerance}")
        self.segment_seconds = float(spec.segment_seconds)
        self.tolerance = float(tolerance)
        self.alpha = float(ema_alpha)
        self._expect_consume = {
            (p.consumer.op, round(p.consumer.target, 4)): float(p.speed)
            for p in config.plans}
        self._expect_retrieve = {
            (sf_id, cf_name): float(x)
            for (sf_id, cf_name), x in (retrieval_speeds or {}).items()}
        self._mu = threading.Lock()
        self._consume: dict[tuple, tuple[float, int]] = {}   # guarded-by: _mu
        self._retrieve: dict[tuple, tuple[float, int]] = {}  # guarded-by: _mu

    def observe(self, accuracy: float, result) -> None:
        """Fold one completed query's per-stage speeds in."""
        for st in result.stages:
            video_s = st.segments_scanned * self.segment_seconds
            if video_s <= 0:
                continue
            ckey = (st.op, round(accuracy, 4))
            if st.consume_s > 1e-9 and ckey in self._expect_consume:
                with self._mu:
                    _fold(self._consume, ckey, video_s / st.consume_s,
                          self.alpha)
            rkey = (st.sf_id, st.cf.name())
            if st.retrieve_s > 1e-9 and rkey in self._expect_retrieve:
                with self._mu:
                    _fold(self._retrieve, rkey, video_s / st.retrieve_s,
                          self.alpha)

    def report(self) -> dict:
        """Wire-safe per-knob drift table.  ``ratio = observed/expected``;
        consumption drifts in either direction, retrieval only when slow
        (see module docstring)."""
        tol = self.tolerance
        with self._mu:
            consume = dict(self._consume)
            retrieve = dict(self._retrieve)
        out: dict = {"consumption": {}, "retrieval": {}, "drifted": False}
        for (op, acc), (obs, n) in sorted(consume.items()):
            exp = self._expect_consume[(op, acc)]
            ratio = obs / exp if exp > 0 else math.inf
            drifted = not (1.0 / tol <= ratio <= tol)
            out["consumption"][f"{op}@{acc:g}"] = {
                "expected_x": exp, "observed_x": obs, "ratio": ratio,
                "samples": n, "drifted": drifted}
            out["drifted"] |= drifted
        for (sf_id, cf_name), (obs, n) in sorted(retrieve.items()):
            exp = self._expect_retrieve[(sf_id, cf_name)]
            ratio = obs / exp if exp > 0 else math.inf
            drifted = ratio < 1.0 / tol
            out["retrieval"][f"{sf_id}:{cf_name}"] = {
                "expected_x": exp, "observed_x": obs, "ratio": ratio,
                "samples": n, "drifted": drifted}
            out["drifted"] |= drifted
        return out


def merge_reports(reports: list[dict]) -> dict:
    """Cluster rollup of per-shard drift reports: per knob, keep the
    observation farthest from its expectation (max ``|log ratio|``) —
    drift on any shard is drift, and averaging shards would let a healthy
    shard mask a throttled one."""
    merged: dict = {"consumption": {}, "retrieval": {}, "drifted": False}
    for rep in reports:
        if not rep:
            continue
        for section in ("consumption", "retrieval"):
            for knob, row in rep.get(section, {}).items():
                cur = merged[section].get(knob)
                if cur is None or (abs(math.log(max(row["ratio"], 1e-12)))
                                   > abs(math.log(max(cur["ratio"],
                                                      1e-12)))):
                    merged[section][knob] = dict(row)
        merged["drifted"] |= bool(rep.get("drifted"))
    return merged


def retrieval_expectations(profiler, config) -> dict:
    """``(sf_id, cf_name) -> expected retrieval x-realtime`` for every
    subscription in a derived config — the optional retrieval side of a
    ``DriftDetector``, for callers that still hold the profiler."""
    out = {}
    for i, node in enumerate(config.nodes):
        sf_id = config.node_id(i)
        for p in node.plans:
            out[(sf_id, p.cf.name())] = float(
                profiler.retrieval_speed(node.sf, p.cf))
    return out
