"""Pallas TPU kernel: fused 8x8 block DCT + quantization (codec hot spot).

The 2D DCT of an 8x8 block is D @ X @ D.T — per frame row-band this is a
pair of small matmuls that map straight onto the MXU.  The kernel tiles a
frame stack (n, h, w) into VMEM row-bands of 8 rows x the full width
(<= 8 x 1280 f32 = 40 KiB, comfortably inside the ~16 MiB VMEM), computes
the transform for all w/8 blocks of the band at once, fuses the
quantization (divide by table, round), and writes int16 symbols.

Grid: (n, h//8) — both parallel.  The inverse kernel fuses dequantize+IDCT.
The DCT basis and quantization table are passed as (tiny, replicated) VMEM
inputs — Pallas kernels cannot close over host constants.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...codec.transform import dct_basis, quant_table

BLOCK = 8


def _dct_kernel(x_ref, d_ref, q_ref, qs_ref, out_ref, *, width: int):
    wb = width // BLOCK
    x = x_ref[0]                               # (8, W)
    d = d_ref[...]                             # (8, 8)
    q = q_ref[...] * qs_ref[0]                 # (8, 8)
    blocks = x.reshape(BLOCK, wb, BLOCK).transpose(1, 0, 2)   # (wb, 8, 8)
    coef = jnp.einsum("ij,wjk,lk->wil", d, blocks, d,
                      preferred_element_type=jnp.float32)
    out_ref[0, 0] = jnp.round(coef / q).astype(jnp.int16)     # fused quant


def _idct_kernel(sym_ref, d_ref, q_ref, qs_ref, out_ref, *, width: int):
    wb = width // BLOCK
    d = d_ref[...]
    q = q_ref[...] * qs_ref[0]
    coef = sym_ref[0, 0].astype(jnp.float32) * q              # (wb, 8, 8)
    blocks = jnp.einsum("ji,wjk,kl->wil", d, coef, d,
                        preferred_element_type=jnp.float32)
    out_ref[0] = blocks.transpose(1, 0, 2).reshape(BLOCK, wb * BLOCK)


def _consts(quant_scale):
    d = jnp.asarray(dct_basis())
    q = jnp.asarray(quant_table())
    qs = jnp.broadcast_to(jnp.asarray(quant_scale, jnp.float32), (1,))
    return d, q, qs


_CONST_SPECS = [
    pl.BlockSpec((BLOCK, BLOCK), lambda i, j: (0, 0)),
    pl.BlockSpec((BLOCK, BLOCK), lambda i, j: (0, 0)),
    pl.BlockSpec((1,), lambda i, j: (0,)),
]


@functools.partial(jax.jit, static_argnames=("interpret",))
def dct8_quantize(frames: jnp.ndarray, quant_scale: jnp.ndarray,
                  interpret: bool = True) -> jnp.ndarray:
    """(n, h, w) f32 -> (n, h//8, w//8, 8, 8) int16 quantized symbols."""
    n, h, w = frames.shape
    assert h % BLOCK == 0 and w % BLOCK == 0
    d, q, qs = _consts(quant_scale)
    kernel = functools.partial(_dct_kernel, width=w)
    return pl.pallas_call(
        kernel,
        grid=(n, h // BLOCK),
        in_specs=[pl.BlockSpec((1, BLOCK, w), lambda i, j: (i, j, 0))]
        + _CONST_SPECS,
        out_specs=pl.BlockSpec((1, 1, w // BLOCK, BLOCK, BLOCK),
                               lambda i, j: (i, j, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h // BLOCK, w // BLOCK,
                                        BLOCK, BLOCK), jnp.int16),
        interpret=interpret,
    )(frames, d, q, qs)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dct8_dequantize(symbols: jnp.ndarray, quant_scale: jnp.ndarray,
                    interpret: bool = True) -> jnp.ndarray:
    """(n, hb, wb, 8, 8) int16 -> (n, h, w) f32 reconstruction."""
    n, hb, wb, _, _ = symbols.shape
    h, w = hb * BLOCK, wb * BLOCK
    d, q, qs = _consts(quant_scale)
    kernel = functools.partial(_idct_kernel, width=w)
    return pl.pallas_call(
        kernel,
        grid=(n, hb),
        in_specs=[pl.BlockSpec((1, 1, wb, BLOCK, BLOCK),
                               lambda i, j: (i, j, 0, 0, 0))]
        + _CONST_SPECS,
        out_specs=pl.BlockSpec((1, BLOCK, w), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h, w), jnp.float32),
        interpret=interpret,
    )(symbols, d, q, qs)
