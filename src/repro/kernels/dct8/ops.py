"""Jit'd dispatch for the dct8 kernel: Pallas on TPU, interpret-mode Pallas
or the jnp oracle elsewhere.  ``use_pallas=None`` defers to the codec-wide
transform backend (``repro.codec.transform.set_dct_backend`` /
``REPRO_DCT_BACKEND``), which is the same flag the batched segment decoder
(``repro.codec.segment._decode_chunks``) and the encoder's forward DCT
route through — one switch flips the whole codec."""

from ...codec.transform import dct_backend, dct_interpret
from .dct8 import dct8_dequantize, dct8_quantize
from .ref import dct8_dequantize_ref, dct8_quantize_ref


def dct_quantize(frames, quant_scale, use_pallas: bool | None = None):
    use = (dct_backend() == "pallas") if use_pallas is None else use_pallas
    if use:
        return dct8_quantize(frames, quant_scale, interpret=dct_interpret())
    return dct8_quantize_ref(frames, quant_scale)


def dct_dequantize(symbols, quant_scale, use_pallas: bool | None = None):
    use = (dct_backend() == "pallas") if use_pallas is None else use_pallas
    if use:
        return dct8_dequantize(symbols, quant_scale, interpret=dct_interpret())
    return dct8_dequantize_ref(symbols, quant_scale)
