"""Jit'd dispatch for the dct8 kernel: Pallas on TPU, interpret-mode Pallas
or the jnp oracle elsewhere."""
import jax

from .dct8 import dct8_dequantize, dct8_quantize
from .ref import dct8_dequantize_ref, dct8_quantize_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def dct_quantize(frames, quant_scale, use_pallas: bool | None = None):
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return dct8_quantize(frames, quant_scale, interpret=not _on_tpu())
    return dct8_quantize_ref(frames, quant_scale)


def dct_dequantize(symbols, quant_scale, use_pallas: bool | None = None):
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return dct8_dequantize(symbols, quant_scale, interpret=not _on_tpu())
    return dct8_dequantize_ref(symbols, quant_scale)
