"""Pure-jnp oracle for the dct8 kernel (the codec's own transform path)."""
import jax.numpy as jnp

from ...codec import transform as T


def dct8_quantize_ref(frames: jnp.ndarray, quant_scale) -> jnp.ndarray:
    blocks = T.to_blocks(frames.astype(jnp.float32))
    return T.quantize(T.dct2(blocks), quant_scale)


def dct8_dequantize_ref(symbols: jnp.ndarray, quant_scale) -> jnp.ndarray:
    return T.from_blocks(T.idct2(T.dequantize(symbols, quant_scale)))
