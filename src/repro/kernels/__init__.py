"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel subpackage has: <name>.py (pl.pallas_call + BlockSpec VMEM
tiling), ops.py (jit'd wrapper with backend dispatch), ref.py (pure-jnp
oracle).  On this CPU container kernels run in interpret mode; on TPU the
same pallas_call compiles natively.
"""
