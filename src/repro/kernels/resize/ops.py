"""Backend dispatch for bilinear resize."""
import jax

from .ref import resize_ref
from .resize import resize_bilinear


def _on_tpu():
    return jax.default_backend() == "tpu"


def resize(frames, h2: int, w2: int, use_pallas: bool | None = None):
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return resize_bilinear(frames, h2, w2, interpret=not _on_tpu())
    return resize_ref(frames, h2, w2)
