"""jnp oracle: jax.image.resize bilinear (the codec's conversion path)."""
import jax
import jax.numpy as jnp


def resize_ref(frames, h2: int, w2: int):
    return jax.image.resize(frames.astype(jnp.float32),
                            (frames.shape[0], h2, w2), method="bilinear")
