"""Pallas TPU kernel: bilinear resize as two MXU matmuls (retrieval hot
spot: storage-fidelity -> consumption-fidelity conversion).

A GPU/CPU bilinear resize is a gather — hostile to the TPU's vector memory.
Bilinear interpolation is separable and linear, so we re-express it as
   out = R_y @ X @ R_x^T
with sparse-but-dense-stored interpolation matrices built host-side.  The
kernel tiles the frame stack over a (n,) grid; each step runs two small
matmuls entirely in VMEM.  (Roughly 2x the FLOPs of a gather formulation —
and far faster on the MXU than strided gathers on the VPU.)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


@functools.cache
def interp_matrix(n_out: int, n_in: int) -> np.ndarray:
    """(n_out, n_in) interpolation weights matching jax.image.resize
    'bilinear' (anti-aliased triangle filter: support widens by the
    downscale factor; rows normalized with edge weights dropped)."""
    m = np.zeros((n_out, n_in), np.float32)
    if n_out == n_in:
        np.fill_diagonal(m, 1.0)
        return m
    scale = n_in / n_out
    support = max(1.0, scale)
    for i in range(n_out):
        pos = (i + 0.5) * scale - 0.5
        lo = int(np.ceil(pos - support))
        hi = int(np.floor(pos + support))
        for j in range(lo, hi + 1):
            if 0 <= j < n_in:
                m[i, j] = max(0.0, 1.0 - abs(j - pos) / support)
        s = m[i].sum()
        if s > 0:
            m[i] /= s
    return m


def _resize_kernel(x_ref, ry_ref, rx_ref, o_ref):
    x = x_ref[0]                                   # (H1, W1)
    ry = ry_ref[...]                               # (H2, H1)
    rx = rx_ref[...]                               # (W2, W1)
    tmp = jax.lax.dot_general(ry, x, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    o_ref[0] = jax.lax.dot_general(tmp, rx, (((1,), (1,)), ((), ())),
                                   preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("h2", "w2", "interpret"))
def resize_bilinear(frames: jnp.ndarray, h2: int, w2: int,
                    interpret: bool = True) -> jnp.ndarray:
    """(n, h1, w1) f32 -> (n, h2, w2) f32."""
    n, h1, w1 = frames.shape
    ry = jnp.asarray(interp_matrix(h2, h1))
    rx = jnp.asarray(interp_matrix(w2, w1))
    return pl.pallas_call(
        _resize_kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, h1, w1), lambda i: (i, 0, 0)),
            pl.BlockSpec((h2, h1), lambda i: (0, 0)),
            pl.BlockSpec((w2, w1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h2, w2), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h2, w2), jnp.float32),
        interpret=interpret,
    )(frames.astype(jnp.float32), ry, rx)
