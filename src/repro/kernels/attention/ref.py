"""Pure-jnp oracle: naive full-matrix attention."""
import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=0, logit_cap=0.0):
    """q, k, v: (B, H, S, hd) equal head counts."""
    b, h, sq, hd = q.shape
    sk = k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    if logit_cap:
        s = logit_cap * jnp.tanh(s / logit_cap)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    ok = jnp.ones((sq, sk), bool)
    if causal:
        ok &= q_pos >= k_pos
    if window:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)) \
        .astype(v.dtype)
