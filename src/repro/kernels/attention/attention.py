"""Pallas TPU kernel: flash attention (online-softmax, VMEM-tiled).

Grid: (B*H, num_q_blocks, num_k_blocks) — the k dimension is sequential
("arbitrary"): running max / sum / accumulator live in VMEM scratch and
persist across k steps; the output block is written on the last k step.

Block shapes default to (q=512, k=512) x head_dim — MXU-aligned (multiples
of 128 in the contracted/lane dims when head_dim is 64/128/256) and well
inside VMEM: q,k,v,acc tiles at 512x256 f32 are 0.5 MiB each.

Supports causal masking, sliding windows (gemma2 local layers), and logit
soft-capping.  GQA is handled by the ops.py wrapper (kv head broadcast).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_COMPILER_PARAMS = getattr(pltpu, 'CompilerParams', None) or \
    pltpu.TPUCompilerParams

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
                 sq: int, sk: int, q_block: int, k_block: int,
                 causal: bool, window: int, logit_cap: float, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0].astype(jnp.float32) * scale          # (qb, hd)
    k = k_ref[0].astype(jnp.float32)                  # (kb, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (qb, kb)
    if logit_cap:
        s = logit_cap * jnp.tanh(s / logit_cap)

    q_pos = qi * q_block + jax.lax.broadcasted_iota(jnp.int32,
                                                    (q_block, k_block), 0)
    k_pos = ki * k_block + jax.lax.broadcasted_iota(jnp.int32,
                                                    (q_block, k_block), 1)
    ok = k_pos < sk
    if causal:
        ok &= q_pos >= k_pos
    if window:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * alpha + p.sum(axis=1)
    v = v_ref[0].astype(jnp.float32)
    acc_s[...] = acc_s[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_s[...] /
                    jnp.maximum(l_s[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "logit_cap", "q_block",
                              "k_block", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    logit_cap: float = 0.0, q_block: int = 512,
                    k_block: int = 512, interpret: bool = True
                    ) -> jnp.ndarray:
    """q, k, v: (B, H, S, hd) with equal head counts (GQA pre-broadcast).
    Returns (B, H, Sq, hd)."""
    b, h, sq, hd = q.shape
    sk = k.shape[2]
    scale = hd ** -0.5
    q_block = min(q_block, sq)
    k_block = min(k_block, sk)
    nq, nk = -(-sq // q_block), -(-sk // k_block)

    qp = jnp.pad(q, ((0, 0), (0, 0), (0, nq * q_block - sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, nk * k_block - sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, nk * k_block - sk), (0, 0)))
    qf = qp.reshape(b * h, nq * q_block, hd)
    kf = kp.reshape(b * h, nk * k_block, hd)
    vf = vp.reshape(b * h, nk * k_block, hd)

    kernel = functools.partial(
        _attn_kernel, sq=sq, sk=sk, q_block=q_block, k_block=k_block,
        causal=causal, window=window, logit_cap=logit_cap, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, q_block, hd), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, k_block, hd), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, k_block, hd), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, hd), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, nq * q_block, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block, hd), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, nq * q_block, hd)[:, :, :sq]
