"""Jit'd wrapper: model layout (B, S, H, hd) + GQA -> kernel layout, with
backend dispatch (Pallas on TPU; interpret-mode / jnp-blocked elsewhere)."""
import jax
import jax.numpy as jnp

from .attention import flash_attention
from .ref import attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def gqa_attention(q, k, v, *, causal=True, window=0, logit_cap=0.0,
                  use_pallas: bool | None = None, interpret=None):
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd).  Returns (B, Sq, H, hd)."""
    b, sq, hct, hd = q.shape
    kv = k.shape[2]
    groups = hct // kv
    qt = q.transpose(0, 2, 1, 3)
    kt = jnp.repeat(k.transpose(0, 2, 1, 3), groups, axis=1)
    vt = jnp.repeat(v.transpose(0, 2, 1, 3), groups, axis=1)
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        interp = (not _on_tpu()) if interpret is None else interpret
        o = flash_attention(qt, kt, vt, causal=causal, window=window,
                            logit_cap=logit_cap, interpret=interp)
    else:
        o = attention_ref(qt, kt, vt, causal=causal, window=window,
                          logit_cap=logit_cap)
    return o.transpose(0, 2, 1, 3)
