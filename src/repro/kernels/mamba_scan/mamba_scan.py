"""Pallas TPU kernel: Mamba-1 selective-scan with fused C-contraction.

    h_t = da_t ⊙ h_{t-1} + dbx_t          h: (inner, n)
    y_t = h_t @ c_t                        y: (inner,)

The pointwise state h (inner x n, i.e. up to 8192 x 16) is never
materialized in HBM — exactly the insight of the original fused CUDA
selective-scan, re-expressed for the TPU memory hierarchy: the state lives
in VMEM scratch, the sequence streams through in chunks, and only y (the
size of the activations anyway) plus the final state (for decode handoff)
are written back.

Grid: (batch, inner_tiles, seq_chunks); seq is sequential ("arbitrary").
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_COMPILER_PARAMS = getattr(pltpu, 'CompilerParams', None) or \
    pltpu.TPUCompilerParams


def _mamba_kernel(da_ref, dbx_ref, c_ref, y_ref, hT_ref, state):
    si = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(si == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    da = da_ref[0]                   # (sc, it, n)
    dbx = dbx_ref[0]
    c = c_ref[0]                     # (sc, n)
    sc = da.shape[0]

    def step(t, h):
        h = da[t] * h + dbx[t]                        # (it, n)
        y_ref[0, t, :] = jnp.sum(h * c[t][None, :], axis=1)
        return h

    state[...] = jax.lax.fori_loop(0, sc, step, state[...])

    @pl.when(si == ns - 1)
    def _final():
        hT_ref[0] = state[...]


@functools.partial(jax.jit,
                   static_argnames=("inner_tile", "seq_chunk", "interpret"))
def mamba_scan(da: jnp.ndarray, dbx: jnp.ndarray, c: jnp.ndarray, *,
               inner_tile: int = 128, seq_chunk: int = 256,
               interpret: bool = True):
    """da, dbx: (B, S, inner, n); c: (B, S, n).
    Returns (y (B, S, inner), h_final (B, inner, n))."""
    bsz, s, inner, n = da.shape
    it = min(inner_tile, inner)
    sc = min(seq_chunk, s)
    ni, ns = -(-inner // it), -(-s // sc)
    pad_i, pad_s = ni * it - inner, ns * sc - s
    if pad_i or pad_s:
        # pad decay with 1 (identity) so the final state survives padding
        da = jnp.pad(da, ((0, 0), (0, pad_s), (0, pad_i), (0, 0)),
                     constant_values=1.0)
        dbx = jnp.pad(dbx, ((0, 0), (0, pad_s), (0, pad_i), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad_s), (0, 0)))
    y, hT = pl.pallas_call(
        _mamba_kernel,
        grid=(bsz, ni, ns),
        in_specs=[
            pl.BlockSpec((1, sc, it, n), lambda i, j, t: (i, t, j, 0)),
            pl.BlockSpec((1, sc, it, n), lambda i, j, t: (i, t, j, 0)),
            pl.BlockSpec((1, sc, n), lambda i, j, t: (i, t, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, sc, it), lambda i, j, t: (i, t, j)),
            pl.BlockSpec((1, it, n), lambda i, j, t: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, ns * sc, ni * it), jnp.float32),
            jax.ShapeDtypeStruct((bsz, ni * it, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((it, n), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(da.astype(jnp.float32), dbx.astype(jnp.float32), c.astype(jnp.float32))
    return y[:, :s, :inner], hT[:, :inner]
