"""jnp oracle for the fused selective scan: chunked associative scan +
explicit C-contraction (the math used by models.recurrent.mamba_mix)."""
import jax.numpy as jnp

from ...models.recurrent import linear_scan


def mamba_scan_ref(da, dbx, c):
    """da, dbx: (B, S, inner, n); c: (B, S, n) ->
    (y (B, S, inner), h_final (B, inner, n))."""
    h = linear_scan(da.astype(jnp.float32), dbx.astype(jnp.float32), axis=1)
    y = jnp.einsum("bsin,bsn->bsi", h, c.astype(jnp.float32))
    return y, h[:, -1]
