"""Backend dispatch for the fused selective scan."""
import jax

from .mamba_scan import mamba_scan
from .ref import mamba_scan_ref


def _on_tpu():
    return jax.default_backend() == "tpu"


def selective_scan(da, dbx, c, use_pallas: bool | None = None):
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return mamba_scan(da, dbx, c, interpret=not _on_tpu())
    return mamba_scan_ref(da, dbx, c)
