"""Pallas TPU kernel: RG-LRU gated linear recurrence.

    h_t = a_t ⊙ h_{t-1} + b_t        (elementwise over the LRU width)

Grid: (batch, width_tiles, seq_chunks).  Batch and width are parallel; the
sequence dimension is sequential ("arbitrary") with the running state h in
VMEM scratch, so arbitrarily long sequences stream through fixed VMEM
(chunk x tile = 512 x 128 f32 = 256 KiB per operand).  The sequential inner
loop matches the recurrence's data dependence; parallelism comes from
width x batch (the associative-scan formulation in repro.models.recurrent
is the jnp oracle).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_COMPILER_PARAMS = getattr(pltpu, 'CompilerParams', None) or \
    pltpu.TPUCompilerParams


def _rglru_kernel(a_ref, b_ref, h_ref, state):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    a = a_ref[0]                    # (sc, wt)
    b = b_ref[0]
    sc = a.shape[0]

    def step(t, h):
        h = a[t] * h + b[t]
        h_ref[0, t, :] = h
        return h

    state[...] = jax.lax.fori_loop(0, sc, step, state[...])


@functools.partial(jax.jit,
                   static_argnames=("width_tile", "seq_chunk", "interpret"))
def rglru_scan(a: jnp.ndarray, b: jnp.ndarray, *, width_tile: int = 128,
               seq_chunk: int = 512, interpret: bool = True) -> jnp.ndarray:
    """a, b: (B, S, W) -> h: (B, S, W) with h_t = a_t*h_{t-1} + b_t."""
    bsz, s, w = a.shape
    wt = min(width_tile, w)
    sc = min(seq_chunk, s)
    nw, ns = -(-w // wt), -(-s // sc)
    pad_w, pad_s = nw * wt - w, ns * sc - s
    if pad_w or pad_s:
        a = jnp.pad(a, ((0, 0), (0, pad_s), (0, pad_w)))
        b = jnp.pad(b, ((0, 0), (0, pad_s), (0, pad_w)))
    out = pl.pallas_call(
        _rglru_kernel,
        grid=(bsz, nw, ns),
        in_specs=[
            pl.BlockSpec((1, sc, wt), lambda i, j, t: (i, t, j)),
            pl.BlockSpec((1, sc, wt), lambda i, j, t: (i, t, j)),
        ],
        out_specs=pl.BlockSpec((1, sc, wt), lambda i, j, t: (i, t, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, ns * sc, nw * wt), jnp.float32),
        scratch_shapes=[pltpu.VMEM((wt,), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a.astype(jnp.float32), b.astype(jnp.float32))
    return out[:, :s, :w]
