"""Backend dispatch for the RG-LRU scan."""
import jax

from .ref import rglru_scan_ref
from .rglru import rglru_scan


def _on_tpu():
    return jax.default_backend() == "tpu"


def lru_scan(a, b, use_pallas: bool | None = None):
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return rglru_scan(a, b, interpret=not _on_tpu())
    return rglru_scan_ref(a, b)
