"""jnp oracle: associative-scan linear recurrence (models.recurrent)."""
from ...models.recurrent import linear_scan


def rglru_scan_ref(a, b):
    return linear_scan(a, b, axis=-2)
