from .config import ArchConfig, MoEConfig, RGLRUConfig, SSMConfig
from .serving import decode_step, init_cache, prefill
from .transformer import forward, init_params, lm_loss

__all__ = [
    "ArchConfig", "MoEConfig", "SSMConfig", "RGLRUConfig", "init_params",
    "forward", "lm_loss", "init_cache", "prefill", "decode_step",
]
