"""Mixture-of-Experts layers.

Two dispatch modes:

* ``dense``  — compute every expert for every token and combine with router
  weights.  Exact, simple; used by reduced smoke tests and as the oracle for
  the scatter path.
* ``scatter`` — capacity-based sparse dispatch (GShard-style, but built from
  sort-free scatter/gather so no (T, E, C) one-hot is ever materialized):
  tokens are ranked per expert via a cumulative sum over the top-k mask,
  dropped beyond capacity, scattered into an (E, C, d) buffer, processed as
  a batched expert matmul (E as a leading batch dim — shardable over the
  model axis = expert parallelism), and gathered back.

Covers qwen2-moe (shared experts + sigmoid-gated shared output) and
arctic (dense FFN residual in parallel with the MoE).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import truncated_normal


def init_moe(key, cfg, dtype) -> dict:
    m = cfg.moe
    d, ff = cfg.d_model, cfg.d_ff
    keys = jax.random.split(key, 6)
    s_in, s_out = d ** -0.5, ff ** -0.5
    p = {
        "router": truncated_normal(keys[0], (d, m.n_experts), s_in,
                                   jnp.float32),
        "wi": truncated_normal(keys[1], (m.n_experts, d, ff), s_in, dtype),
        "wg": truncated_normal(keys[2], (m.n_experts, d, ff), s_in, dtype),
        "wo": truncated_normal(keys[3], (m.n_experts, ff, d), s_out, dtype),
    }
    if m.n_shared_experts:
        sf = ff * m.n_shared_experts
        p["shared"] = {
            "wi": truncated_normal(keys[4], (d, sf), s_in, dtype),
            "wg": truncated_normal(keys[5], (d, sf), s_in, dtype),
            "wo": truncated_normal(keys[4], (sf, d), (sf) ** -0.5, dtype),
        }
        if m.shared_gated:
            p["shared_gate"] = truncated_normal(keys[5], (d, 1), s_in, dtype)
    return p


def _expert_ffn(wi, wg, wo, x):
    """x: (E, C, d) -> (E, C, d); batched over experts."""
    h = jnp.einsum("ecd,edf->ecf", x, wi)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, wg))
    return jnp.einsum("ecf,efd->ecd", h * g, wo)


def moe_dense(params, x, cfg):
    """Reference dispatch: all experts on all tokens."""
    m = cfg.moe
    b, s, d = x.shape
    logits = (x.astype(jnp.float32) @ params["router"])
    weights, idx = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), m.top_k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    gates = _scatter_gates(weights, idx, m.n_experts)
    h = jnp.einsum("bsd,edf->bsef", x, params["wi"])
    g = jax.nn.silu(jnp.einsum("bsd,edf->bsef", x, params["wg"]))
    y = jnp.einsum("bsef,efd->bsed", h * g, params["wo"])
    out = jnp.einsum("bsed,bse->bsd", y, gates.astype(y.dtype))
    return out + _shared(params, x, cfg)


def _scatter_gates(weights, idx, n_experts):
    oh = jax.nn.one_hot(idx, n_experts, dtype=weights.dtype)  # (b,s,k,E)
    return jnp.einsum("bske,bsk->bse", oh, weights)


def moe_scatter(params, x, cfg, capacity_factor: float = 1.25):
    """Capacity-based sparse dispatch; compute scales with top_k, not E."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    logits = xf.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, m.top_k)          # (t, k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)

    capacity = max(1, int(t * m.top_k * capacity_factor / m.n_experts))
    # position of each (token, k) within its expert: cumsum over flat order
    oh = jax.nn.one_hot(idx, m.n_experts, dtype=jnp.int32)   # (t, k, E)
    flat = oh.reshape(t * m.top_k, m.n_experts)
    pos_in_e = jnp.cumsum(flat, axis=0) - 1                  # (t*k, E)
    pos = jnp.sum(pos_in_e * flat, axis=-1)                  # (t*k,)
    e_idx = idx.reshape(t * m.top_k)
    keep = pos < capacity
    w_flat = weights.reshape(t * m.top_k) * keep

    buf = jnp.zeros((m.n_experts, capacity, d), x.dtype)
    safe_pos = jnp.where(keep, pos, capacity - 1)
    contrib = jnp.repeat(xf, m.top_k, axis=0) * keep[:, None].astype(x.dtype)
    buf = buf.at[e_idx, safe_pos].add(contrib, mode="drop")

    out_buf = _expert_ffn(params["wi"], params["wg"], params["wo"], buf)

    gathered = out_buf[e_idx, safe_pos]                      # (t*k, d)
    y = (gathered * w_flat[:, None].astype(gathered.dtype))
    y = y.reshape(t, m.top_k, d).sum(axis=1).reshape(b, s, d)
    return y + _shared(params, x, cfg)


def _shared(params, x, cfg):
    m = cfg.moe
    if not m.n_shared_experts:
        return jnp.zeros_like(x)
    p = params["shared"]
    h = (x @ p["wi"]) * jax.nn.silu(x @ p["wg"])
    y = h @ p["wo"]
    if m.shared_gated:
        y = y * jax.nn.sigmoid(x @ params["shared_gate"])
    return y


# ---------------------------------------------------------------------------
# Expert-parallel dispatch (shard_map): §Perf — the scalable formulation
# ---------------------------------------------------------------------------

def moe_ep(params, x, cfg, capacity_factor: float = 1.25):
    """Expert parallelism via shard_map over the 'model' axis.

    Tokens stay sharded over the data axes (replicated across model ranks);
    each model rank routes *locally* and dispatches only the (token, k)
    pairs bound for its own E/ep experts, with capacity sized from the
    local token count.  The only cross-device communication is one psum of
    the (B_loc, S, d) output over 'model' — versus the global-view scatter
    whose (E, C_global, d) buffer the SPMD partitioner reshards across the
    data axis (the dominant collective term of the arctic-480b baseline).
    """
    try:
        from jax import shard_map
    except ImportError:  # moved in newer jax; experimental home in 0.4.x
        from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from ..distributed import context

    mesh = context.get_mesh()
    if mesh is None or mesh.shape.get("model", 1) == 1 or \
            cfg.moe.n_experts % mesh.shape.get("model", 1) != 0:
        return moe_scatter(params, x, cfg, capacity_factor)

    m = cfg.moe
    ep = mesh.shape["model"]
    e_loc = m.n_experts // ep
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def local(x_l, router, wi, wg, wo):
        b, s, d = x_l.shape
        t = b * s
        xf = x_l.reshape(t, d)
        logits = xf.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        weights, idx = jax.lax.top_k(probs, m.top_k)
        weights = weights / jnp.sum(weights, axis=-1, keepdims=True)

        rank = jax.lax.axis_index("model")
        local_ids = idx - rank * e_loc                       # (t, k)
        in_range = (local_ids >= 0) & (local_ids < e_loc)
        capacity = max(1, int(t * m.top_k * capacity_factor / m.n_experts))

        safe_ids = jnp.where(in_range, local_ids, 0)
        oh = jax.nn.one_hot(safe_ids, e_loc, dtype=jnp.int32) * \
            in_range[..., None]
        flat = oh.reshape(t * m.top_k, e_loc)
        pos_in_e = jnp.cumsum(flat, axis=0) - 1
        pos = jnp.sum(pos_in_e * flat, axis=-1)
        keep = in_range.reshape(-1) & (pos < capacity)
        w_flat = weights.reshape(-1) * keep

        buf = jnp.zeros((e_loc, capacity, d), x_l.dtype)
        safe_pos = jnp.where(keep, pos, capacity - 1)
        e_idx = jnp.where(keep, safe_ids.reshape(-1), 0)
        contrib = jnp.repeat(xf, m.top_k, axis=0) * \
            keep[:, None].astype(x_l.dtype)
        buf = buf.at[e_idx, safe_pos].add(contrib, mode="drop")

        out_buf = _expert_ffn(wi, wg, wo, buf)
        gathered = out_buf[e_idx, safe_pos]
        y = (gathered * w_flat[:, None].astype(gathered.dtype))
        y = y.reshape(t, m.top_k, d).sum(axis=1).reshape(b, s, d)
        return jax.lax.psum(y, "model")

    import inspect
    no_check = {"check_vma": False} \
        if "check_vma" in inspect.signature(shard_map).parameters \
        else {"check_rep": False}  # pre-rename jax spells it check_rep
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(batch_axes, None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=P(batch_axes, None, None),
        **no_check)
    y = fn(x, params["router"], params["wi"], params["wg"], params["wo"])
    return y + _shared(params, x, cfg)


def moe_layer(params, x, cfg, dispatch: str = "scatter"):
    if dispatch == "dense":
        return moe_dense(params, x, cfg)
    if dispatch == "ep":
        return moe_ep(params, x, cfg)
    return moe_scatter(params, x, cfg)
