"""Shared neural layers: RMSNorm, embeddings, RoPE / M-RoPE, gated MLPs.

Pure-functional: parameters are pytrees of jnp arrays created by ``init_*``
helpers; forward passes are plain functions.  Layer parameters are *stacked*
on a leading layer axis by the transformer so the layer loop is a
``lax.scan`` (compile time stays flat in depth).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def truncated_normal(key, shape, scale, dtype=jnp.float32):
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) \
        .astype(dtype)


def rms_norm(x, weight, eps: float = 1e-6, zero_centered: bool = True):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    w = (1.0 + weight) if zero_centered else weight
    return (y * w).astype(x.dtype)


def softcap(x, cap: float):
    """Gemma-2 style logit soft-capping."""
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
               ) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    freqs = rope_freqs(x.shape[-1], theta)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
                sections: tuple[int, ...]) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE.  positions: (3, ..., seq) for (t, h, w);
    ``sections`` splits the rotary half-dim across the three axes."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    # build per-frequency position selector: first sections[0] freqs use t,
    # next sections[1] use h, rest use w
    sel = jnp.concatenate([
        jnp.full((sections[0],), 0), jnp.full((sections[1],), 1),
        jnp.full((hd // 2 - sections[0] - sections[1],), 2)])
    pos = _mrope_positions(positions, sel)
    ang = pos * freqs  # (..., seq, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _mrope_positions(positions: jnp.ndarray, sel: jnp.ndarray) -> jnp.ndarray:
    """positions (3, ..., seq), sel (hd/2,) in {0,1,2} ->
    per-frequency positions (..., seq, hd/2)."""
    stacked = jnp.moveaxis(positions.astype(jnp.float32), 0, -1)  # (..., seq, 3)
    return jnp.take(stacked, sel, axis=-1)  # (..., seq, hd/2)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, act: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = d_model ** -0.5
    scale_out = d_ff ** -0.5
    p = {"wi": truncated_normal(k1, (d_model, d_ff), scale_in, dtype),
         "wo": truncated_normal(k2, (d_ff, d_model), scale_out, dtype)}
    if act in ("silu", "geglu"):
        p["wg"] = truncated_normal(k3, (d_model, d_ff), scale_in, dtype)
    return p


def mlp(params: dict, x: jnp.ndarray, act: str) -> jnp.ndarray:
    h = x @ params["wi"]
    if act == "silu":
        h = jax.nn.silu(x @ params["wg"]) * h
    elif act == "geglu":
        h = jax.nn.gelu(x @ params["wg"]) * h
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(act)
    return h @ params["wo"]
