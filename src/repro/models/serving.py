"""Serving: prefill + single-token decode with explicit caches.

Cache layouts (stacked over layers where homogeneous):

* full-attention archs: ``kv`` (L, B, S_max, KV, hd) x2 + scalar ``len``
* gemma2 alternation:    same (local layers mask inside the window)
* hybrid (recurrentgemma): attention layers keep a **ring buffer** of the
  local window only (constant memory — this is why hybrid/ssm archs run the
  long_500k shape); RG-LRU layers carry (conv, h) states
* ssm (mamba): (conv, h) states only — no KV at all

``decode_step`` consumes one new token per sequence and returns updated
caches; it is the function lowered by the ``decode_*`` / ``long_*`` dry-run
shapes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as A
from . import recurrent as R
from .config import ArchConfig
from .layers import mlp, rms_norm, softcap
from .transformer import _ffn, _rope_fn

# ---------------------------------------------------------------------------
# cache init
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    kinds = [cfg.layer_kind(i) for i in range(cfg.n_layers)]
    cache: dict = {"len": jnp.zeros((), jnp.int32)}
    if cfg.family == "hybrid":
        n_attn = sum(k == "local_attn" for k in kinds)
        n_rec = sum(k == "rglru" for k in kinds)
        w = min(cfg.rglru.window, max_len)
        cache["k"] = jnp.zeros((n_attn, batch, w, kv, hd), dtype)
        cache["v"] = jnp.zeros((n_attn, batch, w, kv, hd), dtype)
        st = R.rglru_init_state(cfg, batch, dtype)
        cache["rec"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_rec,) + x.shape), st)
    elif cfg.family == "ssm":
        st = R.mamba_init_state(cfg, batch, dtype)
        cache["rec"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), st)
    else:
        cache["k"] = jnp.zeros((cfg.n_layers, batch, max_len, kv, hd), dtype)
        cache["v"] = jnp.zeros((cfg.n_layers, batch, max_len, kv, hd), dtype)
    return cache


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------

def decode_step(params, cfg: ArchConfig, batch: dict, cache: dict,
                moe_dispatch: str = "dense") -> tuple[jnp.ndarray, dict]:
    """batch: tokens (B, 1) (or embeds (B, 1, d)); optional mrope_positions
    (3, B, 1).  Returns (logits (B, vocab), updated cache)."""
    if cfg.frontend == "tokens":
        x = params["embed"][batch["tokens"]]
        if cfg.tie_embeddings:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    else:
        x = batch["embeds"]
    b = x.shape[0]
    pos = cache["len"]
    positions = jnp.broadcast_to(pos, (b, 1))
    rope_fn = _rope_fn(cfg, batch.get("mrope_positions"))

    if cfg.family == "hybrid":
        x, cache = _hybrid_decode(params, cfg, x, positions, rope_fn, cache)
    elif cfg.family == "ssm":
        x, cache = _ssm_decode(params, cfg, x, cache)
    else:
        x, cache = _stacked_decode(params, cfg, x, positions, rope_fn, cache,
                                   moe_dispatch)

    cache = dict(cache, len=cache["len"] + 1)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = softcap((x @ head)[:, 0], cfg.final_softcap)
    return logits, cache


def _write_kv(k_cache, v_cache, k_new, v_new, idx):
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), idx, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), idx, axis=1)
    return k_cache, v_cache


def _stacked_decode(params, cfg, x, positions, rope_fn, cache,
                    moe_dispatch="dense"):
    kinds = [cfg.layer_kind(i) for i in range(cfg.n_layers)]
    is_local = jnp.asarray([k == "local_attn" for k in kinds])
    pos = cache["len"]

    def body(x, scanned):
        bp, kc, vc, loc = scanned
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        q, k, v = A.qkv_project(bp["attn"], h, cfg, positions, rope_fn)
        kc, vc = _write_kv(kc, vc, k, v, pos)
        window = jnp.where(loc, cfg.local_window, 0) if \
            cfg.local_global_alternate else 0
        if cfg.local_global_alternate and cfg.local_window:
            out_g = A.decode_attention(q, kc, vc, pos + 1, window=0,
                                       logit_cap=cfg.logit_softcap)
            out_l = A.decode_attention(q, kc, vc, pos + 1,
                                       window=cfg.local_window,
                                       logit_cap=cfg.logit_softcap)
            attn_out = jnp.where(loc, out_l, out_g)
        else:
            attn_out = A.decode_attention(q, kc, vc, pos + 1, window=0,
                                          logit_cap=cfg.logit_softcap)
        o = A.out_project(bp["attn"], attn_out)
        if cfg.post_norm:
            o = rms_norm(o, bp["pn1"], cfg.norm_eps)
        x = x + o
        y = _ffn(bp, rms_norm(x, bp["ln2"], cfg.norm_eps), cfg,
                 moe_dispatch=moe_dispatch)
        if cfg.post_norm:
            y = rms_norm(y, bp["pn2"], cfg.norm_eps)
        return x + y, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["blocks"], cache["k"], cache["v"], is_local))
    return x, dict(cache, k=k_new, v=v_new)


def _ssm_decode(params, cfg, x, cache):
    def body(x, scanned):
        bp, st = scanned
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        out, st_new = R.mamba_mix(bp["ssm"], h, cfg, state=st)
        return x + out, st_new

    x, rec = jax.lax.scan(body, x, (params["blocks"], cache["rec"]))
    return x, dict(cache, rec=rec)


def _hybrid_decode(params, cfg, x, positions, rope_fn, cache):
    kinds = [cfg.layer_kind(i) for i in range(cfg.n_layers)]
    pos = cache["len"]
    w = cache["k"].shape[2]
    ring_idx = jnp.mod(pos, w)
    ri = ai = 0
    ks, vs, recs = [], [], []
    bp_r, bp_a = params["blocks"]["rglru"], params["blocks"]["attn"]
    for kind in kinds:
        if kind == "rglru":
            bp = jax.tree.map(lambda p, j=ri: p[j], bp_r)
            st = jax.tree.map(lambda p, j=ri: p[j], cache["rec"])
            h = rms_norm(x, bp["ln1"], cfg.norm_eps)
            out, st_new = R.rglru_mix(bp["rglru"], h, cfg, state=st)
            x = x + out
            y = mlp(bp["mlp"], rms_norm(x, bp["ln2"], cfg.norm_eps), cfg.act)
            x = x + y
            recs.append(st_new)
            ri += 1
        else:
            bp = jax.tree.map(lambda p, j=ai: p[j], bp_a)
            kc = cache["k"][ai]
            vc = cache["v"][ai]
            h = rms_norm(x, bp["ln1"], cfg.norm_eps)
            q, k, v = A.qkv_project(bp["attn"], h, cfg, positions, rope_fn)
            kc, vc = _write_kv(kc, vc, k, v, ring_idx)
            # ring holds exactly the last min(pos+1, w) tokens
            attn_out = A.decode_attention(q, kc, vc, jnp.minimum(pos + 1, w),
                                          window=0,
                                          logit_cap=cfg.logit_softcap)
            o = A.out_project(bp["attn"], attn_out)
            x = x + o
            y = mlp(bp["mlp"], rms_norm(x, bp["ln2"], cfg.norm_eps), cfg.act)
            x = x + y
            ks.append(kc)
            vs.append(vc)
            ai += 1
    new_cache = dict(cache,
                     k=jnp.stack(ks), v=jnp.stack(vs),
                     rec=jax.tree.map(lambda *xs: jnp.stack(xs), *recs))
    return x, new_cache


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def prefill(params, cfg: ArchConfig, batch: dict, max_len: int,
            cache_dtype=jnp.bfloat16, moe_dispatch: str = "scatter"):
    """Run the full-sequence forward while building a decode cache.
    batch: tokens (B, S).  Returns (logits (B, S, vocab), cache)."""
    if cfg.frontend == "tokens":
        b, s = batch["tokens"].shape
    else:
        b, s, _ = batch["embeds"].shape
    cache = init_cache(cfg, b, max_len, cache_dtype)

    if cfg.family in ("hybrid", "ssm"):
        # build recurrent states by replaying decode steps is O(S) — instead
        # run the sequence form capturing final states
        logits, cache = _prefill_recurrent(params, cfg, batch, cache)
        return logits, cache

    # capture per-layer roped k/v by re-running projections inside a scan
    if cfg.frontend == "tokens":
        x = params["embed"][batch["tokens"]]
        if cfg.tie_embeddings:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    else:
        x = batch["embeds"]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    rope_fn = _rope_fn(cfg, batch.get("mrope_positions"))
    kinds = [cfg.layer_kind(i) for i in range(cfg.n_layers)]
    is_local = jnp.asarray([k == "local_attn" for k in kinds])

    def body(x, scanned):
        bp, loc = scanned
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        q, k, v = A.qkv_project(bp["attn"], h, cfg, positions, rope_fn)
        if cfg.local_global_alternate and cfg.local_window:
            out_g = A.attention(q, k, v, causal=cfg.causal, window=0,
                                logit_cap=cfg.logit_softcap)
            out_l = A.attention(q, k, v, causal=cfg.causal,
                                window=cfg.local_window,
                                logit_cap=cfg.logit_softcap)
            attn_out = jnp.where(loc, out_l, out_g)
        else:
            attn_out = A.attention(q, k, v, causal=cfg.causal, window=0,
                                   logit_cap=cfg.logit_softcap)
        o = A.out_project(bp["attn"], attn_out)
        if cfg.post_norm:
            o = rms_norm(o, bp["pn1"], cfg.norm_eps)
        x = x + o
        y = _ffn(bp, rms_norm(x, bp["ln2"], cfg.norm_eps), cfg,
                 moe_dispatch=moe_dispatch)
        if cfg.post_norm:
            y = rms_norm(y, bp["pn2"], cfg.norm_eps)
        return x + y, (k, v)

    x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], is_local))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = softcap(x @ head, cfg.final_softcap)

    pad = max_len - s
    ks = jnp.pad(ks.astype(cache_dtype), ((0, 0), (0, 0), (0, pad),
                                          (0, 0), (0, 0)))
    vs = jnp.pad(vs.astype(cache_dtype), ((0, 0), (0, 0), (0, pad),
                                          (0, 0), (0, 0)))
    cache = dict(cache, k=ks, v=vs, len=jnp.asarray(s, jnp.int32))
    return logits, cache


def _prefill_recurrent(params, cfg, batch, cache):
    """Sequence-form prefill for ssm/hybrid: capture final states."""
    if cfg.frontend == "tokens":
        x = params["embed"][batch["tokens"]]
        if cfg.tie_embeddings:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        b, s = batch["tokens"].shape
    else:
        x = batch["embeds"]
        b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    rope_fn = _rope_fn(cfg, batch.get("mrope_positions"))
    kinds = [cfg.layer_kind(i) for i in range(cfg.n_layers)]

    if cfg.family == "ssm":
        def body(x, scanned):
            bp, st0 = scanned
            h = rms_norm(x, bp["ln1"], cfg.norm_eps)
            out, st = R.mamba_mix(bp["ssm"], h, cfg)
            return x + out, st
        x, rec = jax.lax.scan(body, x, (params["blocks"], cache["rec"]))
        cache = dict(cache, rec=rec, len=jnp.asarray(s, jnp.int32))
    else:
        ri = ai = 0
        ks, vs, recs = [], [], []
        w = cache["k"].shape[2]
        bp_r, bp_a = params["blocks"]["rglru"], params["blocks"]["attn"]
        for kind in kinds:
            if kind == "rglru":
                bp = jax.tree.map(lambda p, j=ri: p[j], bp_r)
                h = rms_norm(x, bp["ln1"], cfg.norm_eps)
                out, st = R.rglru_mix(bp["rglru"], h, cfg)
                x = x + out
                x = x + mlp(bp["mlp"], rms_norm(x, bp["ln2"], cfg.norm_eps),
                            cfg.act)
                recs.append(st)
                ri += 1
            else:
                bp = jax.tree.map(lambda p, j=ai: p[j], bp_a)
                h = rms_norm(x, bp["ln1"], cfg.norm_eps)
                q, k, v = A.qkv_project(bp["attn"], h, cfg, positions,
                                        rope_fn)
                attn_out = A.attention(q, k, v, causal=True,
                                       window=cfg.rglru.window)
                x = x + A.out_project(bp["attn"], attn_out)
                x = x + mlp(bp["mlp"], rms_norm(x, bp["ln2"], cfg.norm_eps),
                            cfg.act)
                # ring: last w tokens in ring order (pos % w)
                take = jnp.arange(w) + jnp.maximum(s - w, 0)
                kc = jnp.zeros_like(cache["k"][0]).at[
                    :, jnp.mod(take, w)].set(
                        k[:, jnp.clip(take, 0, s - 1)].astype(
                            cache["k"].dtype))
                vc = jnp.zeros_like(cache["v"][0]).at[
                    :, jnp.mod(take, w)].set(
                        v[:, jnp.clip(take, 0, s - 1)].astype(
                            cache["v"].dtype))
                ks.append(kc)
                vs.append(vc)
                ai += 1
        cache = dict(cache, k=jnp.stack(ks), v=jnp.stack(vs),
                     rec=jax.tree.map(lambda *xs: jnp.stack(xs), *recs),
                     len=jnp.asarray(s, jnp.int32))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = softcap(x @ head, cfg.final_softcap)
    return logits, cache
