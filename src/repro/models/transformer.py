"""Unified transformer model covering all supported families.

Functional API:

    params          = init_params(cfg, rng, dtype)
    logits          = forward(params, cfg, batch)             # train/prefill
    logits, cache   = prefill(params, cfg, batch)             # builds cache
    logits, cache   = decode_step(params, cfg, token, cache)  # 1 new token

Layer parameters of homogeneous stacks are *stacked* on a leading layer axis
and the layer loop is a ``lax.scan`` (flat compile time in depth); the hybrid
family (recurrentgemma) has two interleaved structures and uses a python
loop over its short macro-pattern groups.

Per-layer static variation (gemma2's local/global alternation) is encoded as
a scanned boolean so one scan body covers both.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import attention as A
from . import moe as M
from . import recurrent as R
from .config import ArchConfig
from .layers import (apply_mrope, apply_rope, init_mlp, mlp, rms_norm,
                     softcap, truncated_normal)

Params = Any


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ArchConfig, kind: str, dtype) -> dict:
    ka, kf, kn = jax.random.split(key, 3)
    p: dict = {"ln1": jnp.zeros((cfg.d_model,), jnp.float32),
               "ln2": jnp.zeros((cfg.d_model,), jnp.float32)}
    if cfg.post_norm:
        p["pn1"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["pn2"] = jnp.zeros((cfg.d_model,), jnp.float32)
    if kind in ("attn", "local_attn"):
        p["attn"] = A.init_attention(ka, cfg, dtype)
    elif kind == "rglru":
        p["rglru"] = R.init_rglru(ka, cfg, dtype)
    elif kind == "ssm":
        p["ssm"] = R.init_mamba(ka, cfg, dtype)
    if kind != "ssm":
        if cfg.family == "moe":
            p["moe"] = M.init_moe(kf, cfg, dtype)
            if cfg.moe.dense_residual:
                p["dense_mlp"] = init_mlp(kf, cfg.d_model, cfg.moe.dense_ff,
                                          cfg.act, dtype)
        else:
            p["mlp"] = init_mlp(kf, cfg.d_model, cfg.d_ff, cfg.act, dtype)
    return p


def _stack(trees: list) -> Any:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(cfg: ArchConfig, rng, dtype=jnp.float32) -> Params:
    keys = jax.random.split(rng, cfg.n_layers + 3)
    params: dict = {}
    if cfg.frontend == "tokens":
        # tied embeddings are read back through the sqrt(d) input scaling, so
        # init at d^-0.5 to keep initial logits O(1)
        emb_scale = cfg.d_model ** -0.5 if cfg.tie_embeddings else 1.0
        params["embed"] = truncated_normal(
            keys[-1], (cfg.vocab_size, cfg.d_model), emb_scale, dtype)
    params["ln_f"] = jnp.zeros((cfg.d_model,), jnp.float32)
    if not cfg.tie_embeddings:
        params["lm_head"] = truncated_normal(
            keys[-2], (cfg.d_model, cfg.vocab_size), cfg.d_model ** -0.5,
            dtype)

    kinds = [cfg.layer_kind(i) for i in range(cfg.n_layers)]
    if cfg.family == "hybrid":
        # two stacked groups: rglru layers and attn layers, interleaved at
        # run time by the block pattern
        params["blocks"] = {
            "rglru": _stack([_init_block(keys[i], cfg, "rglru", dtype)
                             for i, k in enumerate(kinds) if k == "rglru"]),
            "attn": _stack([_init_block(keys[i], cfg, "local_attn", dtype)
                            for i, k in enumerate(kinds) if k == "local_attn"]),
        }
    else:
        params["blocks"] = _stack([_init_block(keys[i], cfg, kinds[i], dtype)
                                   for i in range(cfg.n_layers)])
    return params


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _rope_fn(cfg: ArchConfig, mrope_positions=None):
    if cfg.mrope and mrope_positions is not None:
        hd = cfg.resolved_head_dim
        third = hd // 2 // 3
        sections = (hd // 2 - 2 * third, third, third)
        return lambda x, pos: apply_mrope(x, mrope_positions, cfg.rope_theta,
                                          sections)
    return lambda x, pos: apply_rope(x, pos, cfg.rope_theta)


def _attn_block(bp, x, cfg, positions, is_local, rope_fn, moe_dispatch):
    h = rms_norm(x, bp["ln1"], cfg.norm_eps)
    q, k, v = A.qkv_project(bp["attn"], h, cfg, positions, rope_fn)
    window = jnp.where(is_local, cfg.local_window or cfg.rglru.window
                       if cfg.family == "hybrid" else cfg.local_window, 0) \
        if isinstance(is_local, jnp.ndarray) else (
            (cfg.local_window or (cfg.rglru.window if cfg.family == "hybrid"
                                  else 0)) if is_local else 0)
    attn_out = _run_attention(q, k, v, cfg, window)
    o = A.out_project(bp["attn"], attn_out)
    if cfg.post_norm:
        o = rms_norm(o, bp["pn1"], cfg.norm_eps)
    x = x + o
    y = _ffn(bp, rms_norm(x, bp["ln2"], cfg.norm_eps), cfg, moe_dispatch)
    if cfg.post_norm:
        y = rms_norm(y, bp["pn2"], cfg.norm_eps)
    return x + y


def _run_attention(q, k, v, cfg, window):
    # window is static (int) everywhere we call full attention
    return A.attention(q, k, v, causal=cfg.causal, window=int(window),
                       logit_cap=cfg.logit_softcap)


def _ffn(bp, h, cfg, moe_dispatch):
    if "moe" in bp:
        y = M.moe_layer(bp["moe"], h, cfg, dispatch=moe_dispatch)
        if "dense_mlp" in bp:
            y = y + mlp(bp["dense_mlp"], h, cfg.act)
        return y
    return mlp(bp["mlp"], h, cfg.act)


# ---------------------------------------------------------------------------
# forward (train / no-cache prefill)
# ---------------------------------------------------------------------------

def forward(params: Params, cfg: ArchConfig, batch: dict,
            moe_dispatch: str = "scatter", remat: bool = True) -> jnp.ndarray:
    """batch: tokens (B, S) int32 | embeds (B, S, d); optional
    mrope_positions (3, B, S).  Returns logits (B, S, vocab)."""
    if cfg.frontend == "tokens":
        x = params["embed"][batch["tokens"]]
        if cfg.tie_embeddings:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        bsz, seq = batch["tokens"].shape
    else:
        x = batch["embeds"]
        bsz, seq, _ = x.shape
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(seq), (bsz, seq))
    rope_fn = _rope_fn(cfg, batch.get("mrope_positions"))

    if cfg.family == "hybrid":
        x = _hybrid_forward(params, cfg, x, positions, rope_fn, remat)
    else:
        x = _stacked_forward(params, cfg, x, positions, rope_fn,
                             moe_dispatch, remat)

    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return softcap(logits, cfg.final_softcap)


def _stacked_forward(params, cfg, x, positions, rope_fn, moe_dispatch, remat):
    kinds = [cfg.layer_kind(i) for i in range(cfg.n_layers)]
    is_local = jnp.asarray([k == "local_attn" for k in kinds])

    def body(x, scanned):
        bp, loc = scanned
        if kinds[0] == "ssm":
            h = rms_norm(x, bp["ln1"], cfg.norm_eps)
            out, _ = R.mamba_mix(bp["ssm"], h, cfg)
            y = x + out
        else:
            # local/global via static-per-arch window selected by `loc`
            if cfg.local_global_alternate and cfg.local_window:
                y = _dual_window_block(bp, x, cfg, positions, loc, rope_fn,
                                       moe_dispatch)
            else:
                y = _attn_block(bp, x, cfg, positions, False, rope_fn,
                                moe_dispatch)
        return y, None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, (params["blocks"], is_local))
    return x


def _dual_window_block(bp, x, cfg, positions, loc, rope_fn, moe_dispatch):
    """Gemma2-style alternation: compute QKV once, run attention with both
    masks, select by the scanned ``loc`` flag (both masks share one scan
    body; XLA folds the select)."""
    h = rms_norm(x, bp["ln1"], cfg.norm_eps)
    q, k, v = A.qkv_project(bp["attn"], h, cfg, positions, rope_fn)
    out_g = A.attention(q, k, v, causal=cfg.causal, window=0,
                        logit_cap=cfg.logit_softcap)
    out_l = A.attention(q, k, v, causal=cfg.causal, window=cfg.local_window,
                        logit_cap=cfg.logit_softcap)
    attn_out = jnp.where(loc, out_l, out_g)
    o = A.out_project(bp["attn"], attn_out)
    if cfg.post_norm:
        o = rms_norm(o, bp["pn1"], cfg.norm_eps)
    x = x + o
    y = _ffn(bp, rms_norm(x, bp["ln2"], cfg.norm_eps), cfg, moe_dispatch)
    if cfg.post_norm:
        y = rms_norm(y, bp["pn2"], cfg.norm_eps)
    return x + y


def _hybrid_forward(params, cfg, x, positions, rope_fn, remat):
    kinds = [cfg.layer_kind(i) for i in range(cfg.n_layers)]
    ri = ai = 0
    bp_r, bp_a = params["blocks"]["rglru"], params["blocks"]["attn"]
    for i, kind in enumerate(kinds):
        if kind == "rglru":
            bp = jax.tree.map(lambda p, j=ri: p[j], bp_r)
            x = _rglru_block(bp, x, cfg)
            ri += 1
        else:
            bp = jax.tree.map(lambda p, j=ai: p[j], bp_a)
            fn = functools.partial(_attn_block, cfg=cfg, positions=positions,
                                   is_local=True, rope_fn=rope_fn,
                                   moe_dispatch="dense")
            x = jax.checkpoint(lambda b, y: fn(b, y))(bp, x) if remat \
                else fn(bp, x)
            ai += 1
    return x


def _rglru_block(bp, x, cfg, state=None):
    h = rms_norm(x, bp["ln1"], cfg.norm_eps)
    out, new_state = R.rglru_mix(bp["rglru"], h, cfg, state)
    x = x + out
    y = mlp(bp["mlp"], rms_norm(x, bp["ln2"], cfg.norm_eps), cfg.act)
    return (x + y) if state is None else (x + y, new_state)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def lm_loss(params: Params, cfg: ArchConfig, batch: dict,
            moe_dispatch: str = "scatter", remat: bool = True) -> jnp.ndarray:
    logits = forward(params, cfg, batch, moe_dispatch, remat)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(nll)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
