"""Architecture configuration schema for the model zoo.

One ``ArchConfig`` describes any of the supported families:

  dense | moe | hybrid (RG-LRU + local attn) | ssm (mamba1) | vlm | audio

The assigned architectures (``repro.configs``) instantiate this schema with
exact published hyperparameters; smoke tests use ``reduced()`` copies.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "hybrid", "ssm", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 2
    n_shared_experts: int = 0      # qwen2-moe: shared experts run for all tokens
    shared_gated: bool = True      # qwen2-moe gates shared output by a sigmoid
    dense_residual: bool = False   # arctic: dense FFN in parallel with MoE
    dense_ff: int = 0              # width of the parallel dense FFN
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16            # mamba1 N
    conv_width: int = 4
    expand: int = 2                # inner = expand * d_model
    dt_rank: int = 0               # 0 => ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0             # 0 => d_model
    conv_width: int = 4
    block_pattern: tuple[str, ...] = ("rglru", "rglru", "attn")  # 2:1
    window: int = 2048             # local attention window


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 => d_model // n_heads
    # attention flavor
    causal: bool = True            # False: encoder-only (hubert)
    rope_theta: float = 10000.0
    mrope: bool = False            # qwen2-vl: multimodal 3D rope (t, h, w)
    qkv_bias: bool = False         # qwen1.5 / qwen2
    logit_softcap: float = 0.0     # gemma2: attention logit soft-capping
    final_softcap: float = 0.0     # gemma2: final logit soft-capping
    local_window: int = 0          # gemma2: sliding window for local layers
    local_global_alternate: bool = False  # gemma2: even layers local
    # norms / misc
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    act: str = "silu"              # silu | gelu
    post_norm: bool = False        # gemma2 uses post-ffw/post-attn norms too
    # family extras
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    rglru: RGLRUConfig | None = None
    # modality frontend stubs
    frontend: str = "tokens"       # tokens | patches (vlm) | frames (audio)
    # shapes this arch supports (decode steps need causal LM)
    supports_decode: bool = True
    subquadratic: bool = False     # can run long_500k

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def layer_kind(self, i: int) -> str:
        """Block type of layer i: attn | local_attn | rglru | ssm."""
        if self.family == "ssm":
            return "ssm"
        if self.family == "hybrid":
            pat = self.rglru.block_pattern
            return "local_attn" if pat[i % len(pat)] == "attn" else "rglru"
        if self.local_global_alternate:
            return "local_attn" if i % 2 == 0 else "attn"
        return "attn"

    def is_moe_layer(self, i: int) -> bool:
        return self.family == "moe" and self.moe is not None

    def reduced(self, n_layers: int = 2, d_model: int = 64, n_heads: int = 4,
                n_kv_heads: int | None = None, d_ff: int = 128,
                vocab: int = 512, n_experts: int | None = None
                ) -> "ArchConfig":
        """A tiny same-family copy for CPU smoke tests."""
        kv = n_kv_heads if n_kv_heads is not None else max(
            1, n_heads * self.n_kv_heads // max(self.n_heads, 1) or 1)
        kv = max(1, min(kv, n_heads))
        while n_heads % kv:
            kv -= 1
        moe = self.moe
        if moe is not None:
            moe = dataclasses.replace(
                moe, n_experts=n_experts or min(8, moe.n_experts),
                top_k=min(moe.top_k, n_experts or 8),
                n_shared_experts=min(1, moe.n_shared_experts),
                dense_ff=d_ff if moe.dense_residual else 0)
        rglru = self.rglru
        if rglru is not None:
            rglru = dataclasses.replace(rglru, lru_width=d_model, window=32)
            n_layers = max(n_layers, len(rglru.block_pattern))  # >=1 attn
        ssm = self.ssm
        if ssm is not None:
            ssm = dataclasses.replace(ssm, state_dim=8)
        return dataclasses.replace(
            self, name=self.name + "-reduced", n_layers=n_layers,
            d_model=d_model, n_heads=n_heads, n_kv_heads=kv, d_ff=d_ff,
            vocab_size=vocab, head_dim=0, moe=moe, rglru=rglru, ssm=ssm,
            local_window=min(self.local_window, 16) if self.local_window else 0)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks); used for roofline
        MODEL_FLOPS = 6·N·D."""
        d, L, V = self.d_model, self.n_layers, self.vocab_size
        hd = self.resolved_head_dim
        emb = V * d * (1 if self.tie_embeddings else 2)
        total = emb
        for i in range(L):
            kind = self.layer_kind(i)
            if kind in ("attn", "local_attn"):
                q = d * self.n_heads * hd
                kv = 2 * d * self.n_kv_heads * hd
                o = self.n_heads * hd * d
                total += q + kv + o
            elif kind == "rglru":
                w = self.rglru.lru_width or d
                total += 2 * d * w + w * d + 2 * w * self.rglru.conv_width \
                    + 2 * w * w  # in/out proj + conv + gates
            elif kind == "ssm":
                inner = self.ssm.expand * d
                dt_rank = self.ssm.dt_rank or -(-d // 16)
                total += 2 * d * inner + inner * d \
                    + inner * self.ssm.conv_width \
                    + inner * (dt_rank + 2 * self.ssm.state_dim) \
                    + dt_rank * inner + inner * self.ssm.state_dim
            # FFN / MoE
            if kind == "ssm":
                continue  # mamba blocks have no separate FFN
            if self.is_moe_layer(i):
                m = self.moe
                total += 3 * d * self.d_ff * (m.n_experts + m.n_shared_experts)
                total += d * m.n_experts  # router
                if m.dense_residual:
                    total += 3 * d * m.dense_ff
            else:
                n_mats = 3 if self.act in ("silu", "geglu") else 2
                total += n_mats * d * self.d_ff
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k + shared)."""
        if self.family != "moe" or self.moe is None:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        m = self.moe
        full = self.param_count()
        all_experts = L * 3 * d * self.d_ff * m.n_experts
        active_experts = L * 3 * d * self.d_ff * m.top_k
        return full - all_experts + active_experts
