"""Recurrent sequence-mixing blocks.

* RG-LRU (Griffin / RecurrentGemma): gated linear recurrence
      a_t = exp(c * softplus-free log a ∘ r_t),  r_t = σ(W_a x_t)
      h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)
  computed with an associative scan (parallel over sequence — the Pallas
  kernel repro.kernels/rglru tiles the same recurrence).

* Mamba-1 selective SSM: input-dependent (Δ, B, C) discretization of a
  diagonal state space, scanned over time per chunk.

Both expose a full-sequence form (train / prefill) and a single-step form
carrying explicit state (decode) — constant memory per token, which is why
these archs run the long_500k shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import truncated_normal

C_RGLRU = 8.0


# ---------------------------------------------------------------------------
# Linear recurrence h_t = a_t * h_{t-1} + b_t via associative scan
# ---------------------------------------------------------------------------

def linear_scan(a, b, axis: int = -2):
    """h_t = a_t * h_{t-1} + b_t with h_{-1} = 0, scanned along ``axis``."""

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, b1 * a2 + b2

    a_, b_ = jax.lax.associative_scan(combine, (a, b), axis=axis)
    return b_


# ---------------------------------------------------------------------------
# RG-LRU block
# ---------------------------------------------------------------------------

def init_rglru(key, cfg, dtype) -> dict:
    d = cfg.d_model
    w = cfg.rglru.lru_width or d
    cw = cfg.rglru.conv_width
    ks = jax.random.split(key, 7)
    s = d ** -0.5
    return {
        "wx": truncated_normal(ks[0], (d, w), s, dtype),     # recurrent branch
        "wy": truncated_normal(ks[1], (d, w), s, dtype),     # gate branch
        "conv": truncated_normal(ks[2], (cw, w), w ** -0.5, dtype),
        "w_input_gate": truncated_normal(ks[3], (w, w), w ** -0.5, dtype),
        "w_rec_gate": truncated_normal(ks[4], (w, w), w ** -0.5, dtype),
        "a_param": jnp.log(jnp.expm1(  # softplus^-1 so a ≈ 0.95^c at init
            jnp.full((w,), 0.65, jnp.float32))),
        "wo": truncated_normal(ks[5], (w, d), w ** -0.5, dtype),
    }


def _causal_conv(x, w, state=None):
    """x: (B, S, W) depthwise causal conv with kernel (cw, W).
    ``state``: (B, cw-1, W) history for decode; returns (y, new_state)."""
    cw = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(cw))
    new_state = xp[:, -(cw - 1):] if cw > 1 else pad
    return y, new_state


def rglru_mix(params, x, cfg, state=None):
    """x: (B, S, d).  state: None (fresh) or dict(conv, h) for decode.
    Returns (out (B, S, d), new_state)."""
    xb = x @ params["wx"]
    yb = jax.nn.gelu(x @ params["wy"])
    conv_state = None if state is None else state["conv"]
    xc, conv_state = _causal_conv(xb, params["conv"], conv_state)

    r = jax.nn.sigmoid(xc @ params["w_rec_gate"])
    i = jax.nn.sigmoid(xc @ params["w_input_gate"])
    log_a = -C_RGLRU * r * jax.nn.softplus(params["a_param"])
    a = jnp.exp(log_a.astype(jnp.float32))
    gated = (i * xc).astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated

    if state is None:
        h = linear_scan(a, b)
    else:
        h0 = state["h"]
        # sequential within the (usually length-1) step
        def step(carry, ab):
            at, bt = ab
            hn = at * carry + bt
            return hn, hn
        hT, hs = jax.lax.scan(step, h0, (jnp.moveaxis(a, 1, 0),
                                         jnp.moveaxis(b, 1, 0)))
        h = jnp.moveaxis(hs, 0, 1)
        h0 = hT
    new_state = {"conv": conv_state,
                 "h": h[:, -1].astype(jnp.float32) if state is None
                 else h0}
    out = (h.astype(x.dtype) * yb) @ params["wo"]
    return out, new_state


def rglru_init_state(cfg, batch, dtype):
    w = cfg.rglru.lru_width or cfg.d_model
    cw = cfg.rglru.conv_width
    return {"conv": jnp.zeros((batch, cw - 1, w), dtype),
            "h": jnp.zeros((batch, w), jnp.float32)}


# ---------------------------------------------------------------------------
# Mamba-1 block
# ---------------------------------------------------------------------------

def init_mamba(key, cfg, dtype) -> dict:
    d = cfg.d_model
    ssm = cfg.ssm
    inner = ssm.expand * d
    n = ssm.state_dim
    dt_rank = ssm.dt_rank or -(-d // 16)
    ks = jax.random.split(key, 8)
    s = d ** -0.5
    return {
        "in_proj": truncated_normal(ks[0], (d, 2 * inner), s, dtype),
        "conv": truncated_normal(ks[1], (ssm.conv_width, inner),
                                 inner ** -0.5, dtype),
        "x_proj": truncated_normal(ks[2], (inner, dt_rank + 2 * n),
                                   inner ** -0.5, dtype),
        "dt_proj": truncated_normal(ks[3], (dt_rank, inner),
                                    dt_rank ** -0.5, dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(
                ks[4], (inner,), jnp.float32,
                jnp.log(1e-3), jnp.log(1e-1))))),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32),
                                  (inner, 1))),
        "d": jnp.ones((inner,), jnp.float32),
        "out_proj": truncated_normal(ks[5], (inner, d), inner ** -0.5, dtype),
    }


def mamba_mix(params, x, cfg, state=None, scan_impl: str | None = None):
    """x: (B, S, d) -> (B, S, d).  state: None or dict(conv, h) for decode.

    ``scan_impl``:
      * "step"  — per-timestep scan with the discretization computed inside
        the body: nothing of shape (B, S, inner, n) is ever materialized
        (the state h is the only (B, inner, n) tensor, carried in-place).
        This is the HBM-traffic shape of the fused Pallas kernel
        (repro.kernels/mamba_scan) and is ~30x lighter than "chunk"
        (§Perf iteration 1).
      * "chunk" — chunked associative scan (parallel over time, but each of
        the log2(chunk) combine levels re-materializes (B, ck, inner, n)).
    """
    if scan_impl is None:
        import os
        scan_impl = os.environ.get("REPRO_MAMBA_SCAN", "chunk")
    ssm = cfg.ssm
    d = cfg.d_model
    inner = ssm.expand * d
    n = ssm.state_dim
    dt_rank = ssm.dt_rank or -(-d // 16)

    xz = x @ params["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)
    conv_state = None if state is None else state["conv"]
    xc, conv_state = _causal_conv(xi, params["conv"], conv_state)
    xc = jax.nn.silu(xc)

    # projections as full-sequence matmuls (small outputs: (B,S,inner) and
    # (B,S,n)); discretization happens inside the scan
    proj = xc @ params["x_proj"]
    dt, bmat, cmat = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    delta = jax.nn.softplus(dt @ params["dt_proj"] + params["dt_bias"])
    a = -jnp.exp(params["a_log"])                       # (inner, n)

    h0 = (jnp.zeros((x.shape[0], inner, n), jnp.float32)
          if state is None else state["h"])

    if scan_impl == "step":
        def step(h, t_in):
            delta_t, xc_t, b_t, c_t = t_in              # (B,inner) ... (B,n)
            da_t = jnp.exp(delta_t[..., None].astype(jnp.float32) * a)
            dbx_t = (delta_t * xc_t).astype(jnp.float32)[..., None] * \
                b_t.astype(jnp.float32)[..., None, :]
            h = da_t * h + dbx_t                        # (B, inner, n)
            y_t = jnp.einsum("bin,bn->bi", h, c_t.astype(jnp.float32))
            return h, y_t

        hT, ys = jax.lax.scan(
            step, h0, (jnp.moveaxis(delta, 1, 0), jnp.moveaxis(xc, 1, 0),
                       jnp.moveaxis(bmat, 1, 0), jnp.moveaxis(cmat, 1, 0)))
        y = jnp.moveaxis(ys, 0, 1)
    else:
        y, hT = _chunked_scan(delta, xc, bmat, cmat, a, h0)
        y = y.astype(jnp.float32)

    y = y + params["d"] * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ params["out_proj"]
    new_state = {"conv": conv_state, "h": hT}
    return out, new_state


def _chunked_scan(delta, xc, bmat, cmat, a, h0, chunk: int = 16):
    """Chunked scan with discretization AND the C-contraction fused INSIDE
    the (checkpointed) chunk body — the fused-kernel structure:

    * nothing of shape (B, S, inner, n) ever exists in HBM: the scan's
      inputs are delta/xc (B, S, inner) and bmat/cmat (B, S, n), its output
      is y (B, S, inner) — all n x smaller than the state sequence;
    * crucially the *backward* cotangents are likewise for the small
      tensors (the naive formulation stacks two full-size (B, S, inner, n)
      cotangents for da / dbx — the dominant HBM term of §Perf i1-i3);
    * each chunk's (B, ck, inner, n) internals are rematerialized in the
      backward pass (jax.checkpoint) instead of stored.
    """
    bsz, s_len, inner = delta.shape
    n = bmat.shape[-1]

    def chunk_step(h, xs):
        d_c, x_c, b_c, c_c = xs                 # (B,ck,inner) x2, (B,ck,n) x2
        da_c = jnp.exp(d_c[..., None].astype(jnp.float32) * a)
        db_c = (d_c * x_c).astype(jnp.float32)[..., None] * \
            b_c.astype(jnp.float32)[..., None, :]
        h_in = linear_scan(da_c, db_c, axis=1)
        cum_a = jnp.cumprod(da_c, axis=1)
        h_full = h_in + cum_a * h[:, None]
        y_c = jnp.einsum("bkin,bkn->bki", h_full,
                         c_c.astype(jnp.float32)).astype(delta.dtype)
        return h_full[:, -1], y_c

    chunk_step = jax.checkpoint(chunk_step)

    ck = min(chunk, s_len)
    n_chunks = -(-s_len // ck)
    pad = n_chunks * ck - s_len
    if pad:
        delta = jnp.pad(delta, ((0, 0), (0, pad), (0, 0)))
        xc = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))

    def chunks(t, feat):
        return jnp.moveaxis(t.reshape(bsz, n_chunks, ck, feat), 1, 0)

    hT, ys = jax.lax.scan(chunk_step, h0,
                          (chunks(delta, inner), chunks(xc, inner),
                           chunks(bmat, n), chunks(cmat, n)))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, n_chunks * ck, inner)[:, :s_len]
    return y, hT


def mamba_init_state(cfg, batch, dtype):
    inner = cfg.ssm.expand * cfg.d_model
    return {"conv": jnp.zeros((batch, cfg.ssm.conv_width - 1, inner), dtype),
            "h": jnp.zeros((batch, inner, cfg.ssm.state_dim), jnp.float32)}
