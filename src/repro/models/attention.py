"""Grouped-query attention: full-sequence (train / prefill) and single-token
decode with a KV cache.

The full-sequence path computes attention in query/key *blocks* with an
online softmax (the flash-attention recurrence in pure jnp) so that 32k+
sequences never materialize an S x S score matrix in HBM.  The Pallas kernel
(repro.kernels.attention) implements the same recurrence with VMEM tiling;
``repro.kernels.attention.ops`` switches between them by backend.

Supports: GQA (kv heads broadcast over query groups), causal and
bidirectional masks, sliding local windows (gemma2 / recurrentgemma), logit
soft-capping (gemma2), and QKV bias (qwen).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import softcap, truncated_normal

NEG_INF = -1e30


def init_attention(key, cfg, dtype) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kq, kk, kv, ko, kb = jax.random.split(key, 5)
    s = d ** -0.5
    p = {
        "wq": truncated_normal(kq, (d, cfg.n_heads, hd), s, dtype),
        "wk": truncated_normal(kk, (d, cfg.n_kv_heads, hd), s, dtype),
        "wv": truncated_normal(kv, (d, cfg.n_kv_heads, hd), s, dtype),
        "wo": truncated_normal(ko, (cfg.n_heads, hd, d),
                               (cfg.n_heads * hd) ** -0.5, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads, hd), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads, hd), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads, hd), dtype)
    return p


def qkv_project(params, x, cfg, positions, rope_fn):
    """x: (B, S, d) -> q (B,S,H,hd), k/v (B,S,KV,hd), rotated."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    if rope_fn is not None:
        q, k = rope_fn(q, positions), rope_fn(k, positions)
    return q, k, v


def _block_mask(q_pos, k_pos, causal: bool, window: int):
    """(Sq, Sk) additive mask for a block pair given absolute positions."""
    diff = q_pos[:, None] - k_pos[None, :]
    ok = jnp.ones(diff.shape, bool)
    if causal:
        ok &= diff >= 0
    if window:
        ok &= diff < window
    return jnp.where(ok, 0.0, NEG_INF)


def attention(q, k, v, *, causal: bool, window: int = 0,
              logit_cap: float = 0.0, q_block: int = 512, k_block: int = 1024,
              q_offset: int = 0) -> jnp.ndarray:
    """Blocked online-softmax attention.

    q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd).  Returns (B, Sq, H, hd).
    ``q_offset`` is the absolute position of q[0] (prefill continuation).
    """
    b, sq, h, hd = q.shape
    _, sk, kvh, _ = k.shape
    groups = h // kvh
    scale = hd ** -0.5
    q = q.reshape(b, sq, kvh, groups, hd) * scale

    q_block = min(q_block, sq)
    k_block = min(k_block, sk)
    nq = -(-sq // q_block)
    nk = -(-sk // k_block)
    sq_pad, sk_pad = nq * q_block, nk * k_block
    if sq_pad != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_pad - sq), (0, 0), (0, 0), (0, 0)))
    if sk_pad != sk:
        k = jnp.pad(k, ((0, 0), (0, sk_pad - sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_pad - sk), (0, 0), (0, 0)))

    q_pos_all = q_offset + jnp.arange(sq_pad)
    k_pos_all = jnp.arange(sk_pad)
    kv_valid = jnp.where(k_pos_all < sk, 0.0, NEG_INF)

    qb = q.reshape(b, nq, q_block, kvh, groups, hd)
    kb = k.reshape(b, nk, k_block, kvh, hd)
    vb = v.reshape(b, nk, k_block, kvh, hd)

    def q_step(qq, q_pos):
        # qq: (B, qb, KV, G, hd); q_pos: (qb,)

        def kv_step(carry, ki):
            m, l, acc = carry
            kk = kb[:, ki]                   # (B, kb, KV, hd)
            vv = vb[:, ki]
            k_pos = jax.lax.dynamic_slice_in_dim(k_pos_all, ki * k_block,
                                                 k_block)
            s = jnp.einsum("bqkgd,bpkd->bkgqp", qq, kk)  # (B,KV,G,qb,kb)
            s = softcap(s, logit_cap)
            mask = _block_mask(q_pos, k_pos, causal, window)
            kvv = jax.lax.dynamic_slice_in_dim(kv_valid, ki * k_block, k_block)
            s = s + mask + kvv[None, :]
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqp,bpkd->bkgqd", p.astype(vv.dtype), vv)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kvh, groups, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, groups, q_block), jnp.float32)
        a0 = jnp.zeros((b, kvh, groups, q_block, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out  # (B, KV, G, qb, hd)

    q_pos_blocks = q_pos_all.reshape(nq, q_block)
    outs = jax.vmap(q_step, in_axes=(1, 0), out_axes=1)(qb, q_pos_blocks)
    out = jnp.transpose(outs, (0, 1, 4, 2, 3, 5)).reshape(b, sq_pad, h, hd)
    return out[:, :sq].astype(v.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int = 0,
                     logit_cap: float = 0.0) -> jnp.ndarray:
    """One-token attention against a cache.

    q: (B, 1, H, hd); k/v_cache: (B, S, KV, hd); cache_len: () or (B,) int —
    number of valid cache positions (the new token's k/v must already be
    written at index cache_len - 1).
    """
    b, _, h, hd = q.shape
    _, s, kvh, _ = k_cache.shape
    groups = h // kvh
    scale = hd ** -0.5
    qq = q.reshape(b, kvh, groups, hd) * scale
    s_logits = jnp.einsum("bkgd,bpkd->bkgp", qq, k_cache)
    s_logits = softcap(s_logits, logit_cap)
    pos = jnp.arange(s)
    cl = jnp.asarray(cache_len)
    cl = cl[:, None] if cl.ndim else cl
    valid = pos[None, :] < jnp.broadcast_to(cl, (b, 1))
    if window:
        valid &= pos[None, :] >= (jnp.broadcast_to(cl, (b, 1)) - window)
    s_logits = jnp.where(valid[:, None, None, :], s_logits, NEG_INF)
    p = jax.nn.softmax(s_logits.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bkgp,bpkd->bkgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, h, hd)


def out_project(params, attn_out):
    return jnp.einsum("bshk,hkd->bsd", attn_out, params["wo"])
