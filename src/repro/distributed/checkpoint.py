"""Fault-tolerant checkpointing with elastic restore.

Layout:  root/step-<N>/  holding one ``.npy`` per leaf plus a msgpack
manifest; a top-level ``LATEST`` file names the newest *complete* checkpoint.
Writes go to a temp directory first and are published with an atomic rename,
so a crash mid-save can never corrupt the restore path (the previous
checkpoint stays LATEST).

Elastic restore: leaves are saved as full logical arrays (on multi-host,
each process writes its addressable shards and the manifest records the
global shape; this single-process build writes whole arrays).  On restore,
``device_put`` with the *target* mesh's shardings redistributes — the
restoring job may use a different mesh shape than the saving job.
"""

from __future__ import annotations

import os
import re
import shutil

import jax
import msgpack
import numpy as np

from .sharding import tree_paths


def _leaf_file(i: int) -> str:
    return f"leaf-{i:05d}.npy"


def save_checkpoint(root: str, step: int, tree, keep: int = 3) -> str:
    os.makedirs(root, exist_ok=True)
    tmp = os.path.join(root, f".tmp-step-{step}")
    final = os.path.join(root, f"step-{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = tree_paths(tree)
    manifest = []
    for i, (path, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, _leaf_file(i)), arr)
        manifest.append({"path": path, "shape": list(arr.shape),
                         "dtype": str(arr.dtype), "file": _leaf_file(i)})
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb({"step": step, "leaves": manifest}))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                       # atomic publish
    _write_latest(root, step)
    _gc(root, keep)
    return final


def _write_latest(root: str, step: int):
    tmp = os.path.join(root, ".LATEST.tmp")
    with open(tmp, "w") as f:
        f.write(str(step))
    os.replace(tmp, os.path.join(root, "LATEST"))


def latest_step(root: str) -> int | None:
    p = os.path.join(root, "LATEST")
    if not os.path.exists(p):
        return None
    step = int(open(p).read().strip())
    if not os.path.exists(os.path.join(root, f"step-{step}",
                                       "manifest.msgpack")):
        # LATEST points at a missing/incomplete checkpoint; fall back
        steps = checkpoint_steps(root)
        return steps[-1] if steps else None
    return step


def checkpoint_steps(root: str) -> list[int]:
    if not os.path.isdir(root):
        return []
    out = []
    for name in os.listdir(root):
        m = re.fullmatch(r"step-(\d+)", name)
        if m and os.path.exists(os.path.join(root, name, "manifest.msgpack")):
            out.append(int(m.group(1)))
    return sorted(out)


def restore_checkpoint(root: str, tree_like, step: int | None = None,
                       shardings=None):
    """Restore into the structure of ``tree_like``.  ``shardings``: optional
    pytree of NamedSharding for elastic redistribution onto the current
    mesh."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    d = os.path.join(root, f"step-{step}")
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    by_path = {e["path"]: e for e in manifest["leaves"]}

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat))
    leaves = []
    for (kp, like), shard in zip(flat, shard_flat):
        path = ".".join(_k(k) for k in kp)
        e = by_path[path]
        arr = np.load(os.path.join(d, e["file"]), mmap_mode="r")
        if list(arr.shape) != list(like.shape):
            raise ValueError(f"{path}: ckpt shape {arr.shape} != {like.shape}")
        if shard is not None:
            leaves.append(jax.device_put(np.asarray(arr), shard))
        else:
            leaves.append(jax.numpy.asarray(np.asarray(arr),
                                            dtype=like.dtype))
    return step, jax.tree_util.tree_unflatten(treedef, leaves)


def _k(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def _gc(root: str, keep: int):
    steps = checkpoint_steps(root)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(root, f"step-{s}"), ignore_errors=True)
