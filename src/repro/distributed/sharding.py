"""Sharding rules: parameter/optimizer/activation PartitionSpecs.

Mesh axes: ``pod`` (inter-pod, pure data parallel), ``data`` (intra-pod data
parallel / ZeRO / FSDP), ``model`` (tensor + expert parallel).

Weight-sharding presets:

* ``tp``      — weights sharded over ``model`` only (replicated across data).
* ``fsdp_tp`` — weights additionally sharded over ``data`` on a second dim
  (all-gathered at use).  Needed to fit arctic-480b / qwen2-vl-72b.

pjit requires every sharded dim to divide the axis size exactly, so every
rule is a *candidate list*: the first layout whose dims divide wins, with
replication as the final fallback (e.g. smollm's 9 heads don't divide a
16-way model axis — its attention falls back to d_model row-parallel).

Optimizer moments use ZeRO-1: the param spec plus ``data`` sharding on the
largest still-free divisible dim.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

DATA_AXES = ("pod", "data")  # batch shards over both where divisible


# ---------------------------------------------------------------------------
# fitting machinery
# ---------------------------------------------------------------------------

def _axis_size(mesh_sizes: dict, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, str):
        return mesh_sizes.get(entry, 1)
    n = 1
    for a in entry:
        n *= mesh_sizes.get(a, 1)
    return n


def _filter_entry(entry, mesh_sizes):
    if entry is None:
        return None
    if isinstance(entry, str):
        return entry if entry in mesh_sizes else None
    kept = tuple(a for a in entry if a in mesh_sizes)
    return kept if len(kept) > 1 else (kept[0] if kept else None)


def fit_spec(shape: tuple[int, ...], spec: P, mesh_sizes: dict) -> P | None:
    """Filter absent axes; return None if any dim doesn't divide or the
    spec has more entries than the value has dims."""
    entries = [_filter_entry(e, mesh_sizes) for e in spec]
    if len(entries) > len(shape):
        return None
    entries += [None] * (len(shape) - len(entries))
    for dim, entry in zip(shape, entries):
        n = _axis_size(mesh_sizes, entry)
        if n > 1 and dim % n != 0:
            return None
    return P(*entries)


def first_fit(shape: tuple[int, ...], candidates: list[P],
              mesh_sizes: dict) -> P:
    for c in candidates:
        got = fit_spec(shape, c, mesh_sizes)
        if got is not None:
            return got
    return P(*([None] * len(shape)))


def _mesh_sizes(mesh) -> dict:
    return dict(mesh.shape)


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

def _match(path: str, *keys: str) -> bool:
    return any(path.endswith(k) or f".{k}." in path for k in keys)


def _is_stacked(path: str) -> bool:
    return ".blocks." in path or path.startswith("blocks")


def param_candidates(path: str, ndim: int, preset: str) -> list[P]:
    """Candidate layouts, best first.  Specs are written WITHOUT the stacked
    layer dim; the caller prepends it."""
    fsdp = preset == "fsdp_tp"
    d2 = "data" if fsdp else None

    if _match(path, "embed"):                     # (V, d)
        return [P("model", d2), P("model", None), P(None, "model"), P()]
    if _match(path, "lm_head"):                   # (d, V)
        return [P(d2, "model"), P(None, "model"), P("model", None), P()]
    if _match(path, "wq", "wk", "wv") and ndim == 3:   # (d, H, hd)
        return [P(d2, "model", None), P(None, "model", None),
                P("model", None, None), P()]
    if _match(path, "attn.wo"):                   # (H, hd, d)
        return [P("model", None, d2), P("model", None, None),
                P(None, None, "model"), P()]
    if _match(path, "bq", "bk", "bv"):            # (H, hd)
        return [P("model", None), P()]
    if _match(path, "moe.wi", "moe.wg"):          # (E, d, ff)
        return [P("model", d2, None), P("model", None, None),
                P(None, None, "model"), P()]
    if _match(path, "moe.wo"):                    # (E, ff, d)
        return [P("model", None, d2), P("model", None, None),
                P(None, "model", None), P()]
    if _match(path, "router"):                    # (d, E)
        return [P()]
    if _match(path, "in_proj", "wi", "wg", "wx", "wy"):   # (d, ff)
        return [P(d2, "model"), P(None, "model"), P("model", None), P()]
    if _match(path, "out_proj", "wo"):            # (ff, d)
        return [P("model", d2), P("model", None), P(None, "model"), P()]
    if _match(path, "x_proj"):                    # (inner, dt_rank+2n)
        return [P("model", None), P()]
    if _match(path, "dt_proj"):                   # (dt_rank, inner)
        return [P(None, "model"), P()]
    if _match(path, "a_log"):                     # (inner, n)
        return [P("model", None), P()]
    if _match(path, "conv"):                      # (cw, width)
        return [P(None, "model"), P()]
    if _match(path, "dt_bias", "ssm.d", "a_param"):  # (width,)
        return [P("model"), P()]
    if _match(path, "w_input_gate", "w_rec_gate"):   # (w, w)
        return [P(None, "model"), P()]
    return [P()]


def param_spec(path: str, shape: tuple[int, ...], preset: str,
               mesh_sizes: dict) -> P:
    if preset == "dp":  # pure data parallelism: weights replicated
        return P(*([None] * len(shape)))
    stacked = _is_stacked(path)
    body = shape[1:] if stacked else shape
    cands = param_candidates(path, len(body), preset)
    got = first_fit(body, cands, mesh_sizes)
    if stacked:
        got = P(None, *got)
    return got


def tree_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(".".join(_key_str(k) for k in kp), leaf) for kp, leaf in flat]


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def _map_with_path(tree, fn):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = [fn(".".join(_key_str(k) for k in kp), leaf) for kp, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def param_specs(params_shape, mesh, preset: str = "tp"):
    ms = _mesh_sizes(mesh)
    return _map_with_path(params_shape,
                          lambda p, leaf: param_spec(p, leaf.shape, preset,
                                                     ms))


# ---------------------------------------------------------------------------
# optimizer (ZeRO-1)
# ---------------------------------------------------------------------------

def zero1_spec(spec: P, shape: tuple[int, ...], mesh_sizes: dict) -> P:
    used = set()
    for s in spec:
        if isinstance(s, str):
            used.add(s)
        elif s:
            used.update(s)
    if "data" in used:
        return spec
    data_size = mesh_sizes.get("data", 1)
    entries = list(spec) + [None] * (len(shape) - len(spec))
    best, best_dim = None, 0
    for i, (s, dim) in enumerate(zip(entries, shape)):
        if s is None and dim % data_size == 0 and dim > best_dim:
            best, best_dim = i, dim
    if best is None:
        return spec
    entries[best] = "data"
    return P(*entries)


def moment_specs(params_shape, mesh, preset: str = "tp"):
    ms = _mesh_sizes(mesh)

    def one(path, leaf):
        ps = param_spec(path, leaf.shape, preset, ms)
        return zero1_spec(ps, leaf.shape, ms)

    return _map_with_path(params_shape, one)


# ---------------------------------------------------------------------------
# batch / cache
# ---------------------------------------------------------------------------

def batch_specs(batch_shape, mesh, seq_shard: bool = False,
                axes: tuple = DATA_AXES):
    """Batch dim over ``axes`` ((pod, data) by default; all three for the
    pure-DP preset) when divisible; optional sequence sharding over 'model'
    (SP for long-context cells with tiny batch)."""
    ms = _mesh_sizes(mesh)

    def one(path, leaf):
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        if len(shape) >= 3 and shape[0] == 3 and "mrope" in path:
            cands = [P(None, axes, "model" if seq_shard else None),
                     P(None, axes, None), P(None, None, None)]
            return first_fit(shape, cands, ms)
        seq_entry = "model" if seq_shard and len(shape) >= 2 else None
        cands = [P(axes, seq_entry), P(axes,), P("data",), P()]
        return first_fit(shape, cands, ms)

    return _map_with_path(batch_shape, one)


def cache_specs(cache_shape, mesh):
    """Decode caches: batch over (pod,data); kv-heads over model when
    divisible else sequence over model; recurrent states width over model."""
    ms = _mesh_sizes(mesh)

    def one(path, leaf):
        shape = leaf.shape
        if len(shape) == 5:   # (L, B, S, KV, hd)
            cands = [P(None, DATA_AXES, None, "model", None),
                     P(None, DATA_AXES, "model", None, None),
                     P(None, None, None, "model", None),
                     P(None, None, "model", None, None), P()]
            return first_fit(shape, cands, ms)
        if len(shape) == 4 and "conv" in path:  # conv state (L, B, cw-1, W)
            cands = [P(None, DATA_AXES, None, "model"),
                     P(None, None, None, "model"), P()]
            return first_fit(shape, cands, ms)
        if len(shape) == 4:   # mamba h (L, B, inner, n)
            cands = [P(None, DATA_AXES, "model", None),
                     P(None, None, "model", None), P()]
            return first_fit(shape, cands, ms)
        if len(shape) == 3:   # conv state / rglru h (L, B, w)
            cands = [P(None, DATA_AXES, "model"),
                     P(None, None, "model"), P()]
            return first_fit(shape, cands, ms)
        return P(*([None] * len(shape)))

    return _map_with_path(cache_shape, one)


def shardings(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
