from .checkpoint import (checkpoint_steps, latest_step, restore_checkpoint,
                         save_checkpoint)
from .fault import StragglerWatchdog, TrainSupervisor
from .sharding import (batch_specs, cache_specs, moment_specs, param_specs,
                       shardings, zero1_spec)

__all__ = [
    "param_specs", "moment_specs", "batch_specs", "cache_specs",
    "shardings", "zero1_spec", "save_checkpoint", "restore_checkpoint",
    "latest_step", "checkpoint_steps", "StragglerWatchdog",
    "TrainSupervisor",
]
