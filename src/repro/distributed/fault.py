"""Fault tolerance: straggler detection, checkpoint/restart supervision,
elastic rescale hooks.

At 1000+-node scale the failure model is: (a) slow nodes (stragglers) that
stretch synchronous steps, (b) node loss (preemption/hardware), (c) planned
rescale.  This module provides the host-side machinery:

* ``StragglerWatchdog`` — per-step timing with a robust (median-based)
  outlier test; at scale its verdicts feed the scheduler (evict/replace),
  here they are surfaced as metrics and tested by simulation.
* ``TrainSupervisor`` — run loop with periodic checkpoints, crash recovery
  (resume from LATEST) and an injection hook for failure testing.
* elastic restore itself lives in checkpoint.restore_checkpoint(shardings=).
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable

from .checkpoint import latest_step, restore_checkpoint, save_checkpoint


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration: float
    median: float
    ratio: float


class StragglerWatchdog:
    """Flags steps slower than ``tolerance`` x the rolling median.

    On a real cluster each host reports its step time; the controller
    aggregates and decides mitigation (re-dispatch work, drop node from the
    next allocation).  ``policy`` receives each event.
    """

    def __init__(self, window: int = 32, tolerance: float = 2.0,
                 policy: Callable[[StragglerEvent], None] | None = None):
        self.window = collections.deque(maxlen=window)
        self.tolerance = tolerance
        self.policy = policy
        self.events: list[StragglerEvent] = []

    def record(self, step: int, duration: float) -> bool:
        med = self._median() if self.window else duration
        is_straggler = bool(self.window) and \
            duration > self.tolerance * max(med, 1e-9)
        if is_straggler:
            ev = StragglerEvent(step, duration, med, duration / med)
            self.events.append(ev)
            if self.policy:
                self.policy(ev)
        else:
            # stragglers are excluded from the baseline window
            self.window.append(duration)
        return is_straggler

    def _median(self) -> float:
        s = sorted(self.window)
        return s[len(s) // 2]


class TrainSupervisor:
    """Checkpointed training loop with restart-on-failure semantics.

    ``step_fn(state, step) -> (state, metrics)``; ``state`` must be a pytree
    (params/opt).  A crash (exception, preemption) loses at most
    ``ckpt_every`` steps: re-running ``run`` resumes from LATEST.
    """

    def __init__(self, ckpt_dir: str, step_fn, state_like,
                 ckpt_every: int = 50, keep: int = 3,
                 watchdog: StragglerWatchdog | None = None,
                 shardings=None):
        self.ckpt_dir = ckpt_dir
        self.step_fn = step_fn
        self.state_like = state_like
        self.ckpt_every = ckpt_every
        self.keep = keep
        self.watchdog = watchdog or StragglerWatchdog()
        self.shardings = shardings

    def resume(self, init_state):
        step = latest_step(self.ckpt_dir)
        if step is None:
            return 0, init_state
        step, state = restore_checkpoint(self.ckpt_dir, self.state_like,
                                         shardings=self.shardings)
        return step, state

    def run(self, init_state, total_steps: int,
            fail_at: int | None = None) -> tuple[int, object, list[dict]]:
        """Run to ``total_steps`` (resuming if checkpoints exist).
        ``fail_at``: raise a simulated failure at that global step (tests)."""
        start, state = self.resume(init_state)
        history = []
        for step in range(start, total_steps):
            if fail_at is not None and step == fail_at:
                raise RuntimeError(f"injected failure at step {step}")
            t0 = time.perf_counter()
            state, metrics = self.step_fn(state, step)
            dt = time.perf_counter() - t0
            self.watchdog.record(step, dt)
            history.append({"step": step, **metrics, "seconds": dt})
            if (step + 1) % self.ckpt_every == 0 or step + 1 == total_steps:
                save_checkpoint(self.ckpt_dir, step + 1, state,
                                keep=self.keep)
        return total_steps, state, history
