"""Process-global mesh context: lets deep model code (e.g. the expert-
parallel MoE shard_map) find the active mesh without threading it through
every call signature.  Set by launchers/dryrun; None on single-device runs."""

_MESH = None


def set_mesh(mesh):
    global _MESH
    _MESH = mesh


def get_mesh():
    return _MESH
