"""Shared-retrieval planner: one decode serves every in-flight consumer.

Two dedup mechanisms sit between queries and the store:

* **Interest coalescing** — admitted queries register the ``(stream, seg,
  sf_id) -> {cf}`` fetches their cascade stages may issue.  When a decode
  actually happens (cache miss), the planner decodes the *union* of the
  temporal indices wanted by every interested CF and caches the result under
  their knob-wise join (richer_eq of each member), so one decode satisfies
  all overlapping CF requests via the cache's richer-reuse rule.

* **Single-flight** — concurrent misses on the same ``(stream, seg, sf_id)``
  elect one leader to decode; followers wait and re-check the cache instead
  of issuing duplicate decodes.

``fetch`` has ``VideoStore.retrieve``'s signature and is what the serving
executor (and ``VideoStore.attach_retriever``) routes retrieval through.
"""

from __future__ import annotations

import dataclasses
import functools
import threading
from collections import Counter

import numpy as np

from ..core.knobs import FidelityOption
from ..obs import trace as obs
from .cache import DecodedSegmentCache, covering_rows


@dataclasses.dataclass(frozen=True)
class Request:
    """One stage-level fetch a query will issue."""
    stream: str
    seg: int
    sf_id: str
    cf: FidelityOption


@dataclasses.dataclass
class DecodeTask:
    """A planned decode: the union of all CFs interested in one stored
    segment (what ``plan`` emits and a miss executes)."""
    stream: str
    seg: int
    sf_id: str
    cfs: list[FidelityOption]
    want: np.ndarray           # sorted unique union of the CFs' indices
    cf_join: FidelityOption    # knob-wise lub; richer_eq every member


class _InFlight:
    """Single-flight slot for one in-progress union decode.  The leader
    parks its decoded frames here before signalling, so followers are
    served even when the decode was too large for the cache (``insert``
    returned False) — without this hand-off, every waiting follower would
    re-miss and become a serial leader, degrading N waiters to N
    sequential decodes of the same segment."""
    __slots__ = ("event", "cf", "want", "frames")

    def __init__(self):
        self.event = threading.Event()
        self.cf: FidelityOption | None = None
        self.want: np.ndarray | None = None
        self.frames: np.ndarray | None = None


class RetrievalPlanner:
    def __init__(self, store, cache: DecodedSegmentCache):
        self.store = store
        self.cache = cache
        self._lock = threading.Lock()
        self._interest: dict[tuple, Counter] = {}    # guarded-by: _lock
        self._inflight: dict[tuple, _InFlight] = {}  # guarded-by: _lock
        # counters: guarded by _lock; each comment names the meaning
        self.decodes = 0          # guarded-by: _lock (store decodes issued)
        self.coalesced_cfs = 0    # guarded-by: _lock (CFs folded into unions)
        self.inflight_hits = 0    # guarded-by: _lock (served from a leader)
        self.decode_bytes = 0     # guarded-by: _lock (blob bytes touched)
        self.decode_chunks = 0    # guarded-by: _lock (chunks reconstructed)

    # -- query lifecycle -----------------------------------------------------
    def register_query(self, requests: list[Request]):
        """Declare the fetches an admitted query may issue (all stages x
        segments; later stages may be filtered away, which only leaves the
        interest unused)."""
        with self._lock:
            for r in requests:
                key = (r.stream, r.seg, r.sf_id)
                self._interest.setdefault(key, Counter())[r.cf] += 1

    def release_query(self, requests: list[Request]):
        with self._lock:
            for r in requests:
                key = (r.stream, r.seg, r.sf_id)
                c = self._interest.get(key)
                if c is None:
                    continue
                c[r.cf] -= 1
                if c[r.cf] <= 0:
                    del c[r.cf]
                if not c:
                    del self._interest[key]

    # -- planning ------------------------------------------------------------
    def plan(self, requests: list[Request]) -> list[DecodeTask]:
        """Coalesce a batch of fetches into per-segment decode tasks: dedupe
        identical ``(stream, seg, sf_id)`` fetches, union the CFs' temporal
        wants so each stored segment is decoded at most once."""
        groups: dict[tuple, list[FidelityOption]] = {}
        for r in requests:
            cfs = groups.setdefault((r.stream, r.seg, r.sf_id), [])
            if r.cf not in cfs:
                cfs.append(r.cf)
        return [self._task(*key, cfs) for key, cfs in groups.items()]

    def _task(self, stream: str, seg: int, sf_id: str,
              cfs: list[FidelityOption]) -> DecodeTask:
        wants = [self.store.want_indices(sf_id, cf) for cf in cfs]
        union = np.unique(np.concatenate(wants))
        return DecodeTask(stream, seg, sf_id, cfs, union,
                          functools.reduce(lambda a, b: a.join(b), cfs))

    # -- the cache-aware retrieve hook ---------------------------------------
    def fetch(self, stream: str, seg: int, sf_id: str,
              cf: FidelityOption) -> tuple[np.ndarray, dict]:
        """Drop-in for ``VideoStore.retrieve``: cache lookup (exact or
        richer-CF reuse), else a single-flight union decode."""
        if not obs.TRACER.enabled:
            return self._fetch(stream, seg, sf_id, cf)
        with obs.span("retrieve", seg=seg, sf=sf_id, cf=cf.name()) as sp:
            out, cost = self._fetch(stream, seg, sf_id, cf)
            sp.set(cache=cost.get("cache", ""), bytes=cost.get("bytes", 0),
                   chunks=cost.get("chunks", 0),
                   frames=cost.get("frames", 0))
            return out, cost

    def _fetch(self, stream: str, seg: int, sf_id: str,
               cf: FidelityOption) -> tuple[np.ndarray, dict]:
        want = self.store.want_indices(sf_id, cf)
        gkey = (stream, seg, sf_id)
        while True:
            found = self.cache.lookup(stream, seg, sf_id, cf, want)
            if found is not None:
                frames, kind = found
                out = self.store.convert(frames, sf_id, cf)
                return out, {"decode_s": 0.0, "convert_s": 0.0, "bytes": 0,
                             "chunks": 0, "frames": len(want), "cache": kind}
            with self._lock:
                slot = self._inflight.get(gkey)
                if slot is None:
                    self._inflight[gkey] = _InFlight()
            if slot is not None:
                with obs.span("inflight.wait", seg=seg, sf=sf_id):
                    slot.event.wait()
                served = self._from_slot(slot, sf_id, cf, want)
                if served is not None:
                    return served
                continue  # leader's decode can't serve this CF; retry
            try:
                return self._decode_miss(stream, seg, sf_id, cf, want, gkey)
            finally:
                with self._lock:
                    self._inflight.pop(gkey).event.set()

    def _from_slot(self, slot: _InFlight, sf_id, cf, want):
        """Serve a follower from the leader's parked decode (the slot's CF
        join must cover the follower's CF and temporal want)."""
        if slot.frames is None or not slot.cf.richer_eq(cf):
            return None
        rows = covering_rows(slot.want, want)
        if rows is None:
            return None
        with self._lock:
            self.inflight_hits += 1
        out = self.store.convert(slot.frames[rows], sf_id, cf)
        return out, {"decode_s": 0.0, "convert_s": 0.0, "bytes": 0,
                     "chunks": 0, "frames": len(want), "cache": "inflight"}

    def _decode_miss(self, stream, seg, sf_id, cf, want, gkey):
        with self._lock:
            interested = list(self._interest.get((stream, seg, sf_id), ()))
        sf = self.store.formats[sf_id]
        cfs = [cf] + [c for c in interested
                      if c != cf and sf.fidelity.richer_eq(c)]
        task = self._task(stream, seg, sf_id, cfs)
        frames, cost = self.store.decode_for(stream, seg, sf_id, task.want)
        with self._lock:
            self.decodes += 1
            self.coalesced_cfs += len(cfs) - 1
            # decode_for's cost reflects bytes/chunks actually touched (v2
            # blobs charge only the wanted chunks' spans), so these counters
            # track real I/O+decompression work, not blob sizes.
            self.decode_bytes += cost["bytes"]
            self.decode_chunks += cost["chunks"]
        self.cache.insert(stream, seg, sf_id, task.cf_join, task.want, frames)
        with self._lock:
            slot = self._inflight.get(gkey)
        if slot is not None:  # park for followers before the event fires
            slot.cf, slot.want, slot.frames = task.cf_join, task.want, frames
        rows = np.searchsorted(task.want, want)
        out = self.store.convert(frames[rows], sf_id, cf)
        cost["cache"] = "miss"
        return out, cost

    def stats(self) -> dict:
        """Snapshot of the planner's counters under its own lock — the
        form ``VStoreServer.stats`` merges in, so a reader racing a decode
        can't see a torn decodes/bytes pair."""
        with self._lock:
            return {"decodes": self.decodes,
                    "coalesced_cfs": self.coalesced_cfs,
                    "inflight_hits": self.inflight_hits,
                    "decode_bytes": self.decode_bytes,
                    "decode_chunks": self.decode_chunks}
