"""Shared consumption scheduler: continuous cross-query detect batching.

``BatchedConsumer`` (repro.analytics.batch) fuses one query's segments into
few ``op.detect`` calls; this module lifts that fusion across *queries*, the
way continuous-batching LLM servers fuse decode steps across requests.  The
server owns one ``ConsumptionScheduler``; every in-flight query's pipelined
executor enqueues each segment's activated frames here as they come out of
retrieval instead of running its own private flush, and a dispatcher thread
continuously drains the queues into fused detects on the same static
shape-bucket ladder.  Aggregate throughput then scales with *unique* work,
not with query count.

Mechanics, in the order work flows:

* **Per-(op, cf) queues.**  A work unit is one segment's activated frames
  for one cascade stage; units for the same ``(op, cf)`` are batchable (one
  jit cache, one shape ladder) and queue together.  Queues are kept in
  deadline order (earliest-deadline-first *within* the queue, not just
  across queues): under the uniform default max-wait that degenerates to
  FIFO, but a query admitted with a per-query SLO (``deadline_s``) is
  inserted ahead of laxer work that arrived earlier, so tight-deadline
  units neither wait out the full batching timer behind bulk traffic nor
  reorder anything when every query runs at the default.

* **Cross-query work dedup.**  The unit's identity is
  ``(stream, seg, sf_id, op, cf, activated positions)``.  Store content is
  deterministic and operators are pure, so two queries enqueuing the same
  identity want the *same* detect: the second attaches to the first's
  future instead of adding work (PR 1's whole-query request collapsing,
  reduced to frame granularity — it fires even when the queries differ
  elsewhere, e.g. two accuracies that resolve to the same CF).  Dedup only
  joins units still waiting in a queue; once dispatched, a unit's frames
  are on the operator and a late twin starts a fresh unit.

* **Fused dispatch.**  The dispatcher picks the queue whose head has the
  earliest deadline (oldest-deadline-first across queues — a lone
  low-rate query's unit cannot starve behind heavy duplicate traffic),
  then drains whole units up to the largest batch shape and runs
  ``BatchedConsumer.consume_entries``: each unit gets its own slot, so two
  queries' different activated subsets of the *same* segment batch
  together bit-exactly (the slot-gap invariant holds per slot, not per
  segment — see batch.py).

* **Batching timer.**  A non-full batch waits for co-batching partners
  until its head's deadline (``max_wait_ms``), *unless* no producer is
  still feeding the queue — executors bracket each stage with
  ``producer_inc``/``producer_dec``, so a stage that has enqueued its last
  segment dispatches immediately instead of burning its max-wait.  The
  timer bounds added latency; the producer gate makes the common
  uncontended case pay none of it.

* **Result routing.**  Every unit resolves a ``Future`` with its item set
  in the unit's own (local) position coordinates plus its share of the
  consume accounting; each attached query scatters the items under its own
  segment.  Dispatch accounting (detect calls, padded rows) is attributed
  to the batch's first unit so per-server sums stay exact.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future

import numpy as np

from ..analytics.batch import DEFAULT_BATCH_SHAPES, BatchedConsumer
from ..obs.metrics import Histogram
from ..obs.trace import span as _span


@dataclasses.dataclass(eq=False)  # identity eq: frames arrays don't compare
class WorkUnit:
    """One segment's activated frames for one cascade stage of one query."""
    key: tuple                # (stream, seg, sf_id, op_name, cf, pos_bytes)
    op: object                # the operator instance (shared per op_name)
    cf: object
    frames: np.ndarray
    positions: np.ndarray
    future: Future
    deadline: float           # enqueue time + SLO slack (max_wait default)
    waiters: int = 1          # queries attached to this unit's future
    slo: bool = False         # admitted with an explicit deadline_s — its
    # dispatch lateness counts toward SLO accounting (uniform max-wait
    # units don't: the batching timer firing at the deadline is by design)


class ConsumptionScheduler:
    """Continuously drains per-(op, cf) queues into fused detects.

    One instance per ``VStoreServer``; ``close()`` stops the dispatcher.
    Thread-safe: executors enqueue from worker threads while the dispatcher
    drains.  The scheduler lock is a leaf — nothing else is acquired under
    it, and all operator work runs outside it.
    """

    def __init__(self, spec, shapes: tuple[int, ...] | None = None,
                 max_wait_ms: float = 4.0):
        if max_wait_ms < 0:
            raise ValueError(f"max_wait_ms must be >= 0, got {max_wait_ms}")
        self.consumer = BatchedConsumer(spec, shapes=shapes or
                                        DEFAULT_BATCH_SHAPES)
        self.max_wait_s = max_wait_ms / 1e3
        self._mu = threading.Lock()
        self._work = threading.Condition(self._mu)
        self._queues: dict[tuple, deque] = {}    # guarded-by: _mu
        self._by_key: dict[tuple, WorkUnit] = {} # guarded-by: _mu
        self._producers: dict[tuple, int] = {}   # guarded-by: _mu
        self._closed = False                     # guarded-by: _mu
        # lifetime counters (guarded-by: _mu): enqueued counts distinct
        # units, deduped counts attachments to an already-queued unit
        self._enqueued = 0        # guarded-by: _mu
        self._deduped = 0         # guarded-by: _mu
        self._dispatches = 0      # guarded-by: _mu (fused consume calls)
        self._dispatched_units = 0  # guarded-by: _mu
        self._detect_calls = 0    # guarded-by: _mu
        self._frames = 0          # guarded-by: _mu (real rows consumed)
        self._batched_frames = 0  # guarded-by: _mu (rows incl. padding)
        # SLO accounting per (op, cf) queue: dispatch-vs-deadline hit/miss
        # counts and a lateness histogram, for units admitted with an
        # explicit deadline (telemetry surfaces these per queue)
        self._slo_counts: dict[tuple, list] = {}  # guarded-by: _mu
        self._slo_lateness: dict[tuple, Histogram] = {}  # guarded-by: _mu
        self._dispatcher = threading.Thread(target=self._dispatch_loop,
                                            name="vstore-sched",
                                            daemon=True)
        self._dispatcher.start()

    # -- producer lifecycle --------------------------------------------------
    def producer_inc(self, op_name: str, cf) -> None:
        """A query stage began feeding the ``(op, cf)`` queue.  While any
        producer is registered the dispatcher holds non-full batches back
        (up to the max-wait deadline) to let the stage's remaining segments
        co-batch."""
        qkey = (op_name, cf)
        with self._mu:
            self._producers[qkey] = self._producers.get(qkey, 0) + 1

    def producer_dec(self, op_name: str, cf) -> None:
        qkey = (op_name, cf)
        with self._mu:
            n = self._producers.get(qkey, 0) - 1
            if n <= 0:
                self._producers.pop(qkey, None)
            else:
                self._producers[qkey] = n
            self._work.notify()  # pending work may now dispatch immediately

    # -- enqueue -------------------------------------------------------------
    def enqueue(self, op_name: str, op, cf, stream: str, seg: int,
                sf_id: str, frames: np.ndarray, positions: np.ndarray,
                deadline_s: float | None = None) -> tuple[Future, bool]:
        """Queue one segment's activated frames for a fused detect; returns
        ``(future, owner)`` where the future resolves to ``(items,
        stats_share)`` with items in the segment's local position
        coordinates.  An identical unit already waiting (same
        stream/seg/sf/op/cf *and* activated positions) is shared instead of
        re-queued — then ``owner`` is False, and the caller must not count
        the stats share (exactly one owner per unit keeps server-wide sums
        exact).

        ``deadline_s`` is the query's SLO slack: the unit's batching
        deadline becomes ``now + deadline_s`` instead of the uniform
        ``now + max_wait_s``, and the unit is admitted in deadline order
        within its queue (EDF), ahead of laxer work that arrived earlier.
        Attaching to an existing unit *tightens* that unit's deadline if
        the newcomer's is earlier — a shared detect serves its most
        urgent waiter."""
        pos = np.asarray(positions, np.int64)
        key = (stream, int(seg), sf_id, op_name, cf, pos.tobytes())
        qkey = (op_name, cf)
        wait = self.max_wait_s if deadline_s is None else max(0.0, deadline_s)
        with self._mu:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            deadline = time.perf_counter() + wait
            unit = self._by_key.get(key)
            if unit is not None:
                unit.waiters += 1
                self._deduped += 1
                unit.slo = unit.slo or deadline_s is not None
                if deadline < unit.deadline:
                    unit.deadline = deadline
                    self._reinsert_locked(qkey, unit)
                    self._work.notify()
                return unit.future, False
            unit = WorkUnit(key=key, op=op, cf=cf, frames=frames,
                            positions=pos, future=Future(),
                            deadline=deadline, slo=deadline_s is not None)
            self._by_key[key] = unit
            self._insert_locked(qkey, unit)
            self._enqueued += 1
            self._work.notify()
            return unit.future, True

    def _insert_locked(self, qkey: tuple, unit: WorkUnit) -> None:
        """Deadline-ordered insert (EDF within the queue).  Uniform
        deadlines append at the tail in O(1) — the scan only walks past
        units a per-query SLO made laxer than the newcomer."""
        q = self._queues.setdefault(qkey, deque())
        i = len(q)
        while i > 0 and q[i - 1].deadline > unit.deadline:
            i -= 1
        q.insert(i, unit)

    def _reinsert_locked(self, qkey: tuple, unit: WorkUnit) -> None:
        """Re-position a still-queued unit whose deadline just tightened
        (dedup attach).  The unit may already be dispatched and gone from
        its queue — then there is nothing to reorder."""
        q = self._queues.get(qkey)
        if q is None or unit not in q:
            return
        q.remove(unit)
        self._insert_locked(qkey, unit)

    # -- dispatcher ----------------------------------------------------------
    def _pick_locked(self, now: float, max_shape: int
                     ) -> tuple[tuple | None, float | None]:
        """``(best dispatchable queue, earliest head deadline overall)``.

        A queue is dispatchable when its pending frames fill the largest
        shape, its head is past deadline, or no producer is still feeding
        it.  Among dispatchable queues the earliest head deadline wins
        (oldest-deadline-first); the overall minimum bounds how long the
        dispatcher may sleep when nothing is ready yet."""
        best, best_dl, min_dl = None, None, None
        for qkey, q in self._queues.items():
            if not q:
                continue
            dl = q[0].deadline
            min_dl = dl if min_dl is None else min(min_dl, dl)
            ready = (now >= dl or not self._producers.get(qkey)
                     or sum(len(u.frames) for u in q) >= max_shape)
            if ready and (best_dl is None or dl < best_dl):
                best, best_dl = qkey, dl
        return best, min_dl

    def _dispatch_loop(self) -> None:
        max_shape = self.consumer.shapes[-1]
        while True:
            with self._mu:
                batch: list[WorkUnit] = []
                while True:
                    if self._closed:
                        return
                    now = time.perf_counter()
                    qkey, min_dl = self._pick_locked(now, max_shape)
                    if qkey is not None:
                        q = self._queues[qkey]
                        taken = 0
                        while q and (not batch
                                     or taken + len(q[0].frames)
                                     <= max_shape):
                            u = q.popleft()
                            taken += len(u.frames)
                            del self._by_key[u.key]
                            batch.append(u)
                        if not q:
                            del self._queues[qkey]
                        break
                    if min_dl is None:
                        self._work.wait()
                    else:
                        self._work.wait(timeout=max(0.0, min_dl - now))
            self._run_batch(qkey, batch)

    def _run_batch(self, qkey: tuple, batch: list[WorkUnit]) -> None:
        """Fused detect over one drained batch (no locks held — the
        operator call is the expensive part and must not serialize
        enqueues)."""
        op_name, cf = qkey
        try:
            with _span("sched.dispatch", op=op_name, cf=cf.name(),
                       units=len(batch),
                       waiters=sum(u.waiters for u in batch)):
                per_entry, cstats = self.consumer.consume_entries(
                    batch[0].op, cf,
                    [(u.frames, u.positions) for u in batch])
        except BaseException as e:  # noqa: BLE001 — route to every waiter
            for u in batch:
                u.future.set_exception(e)
            return
        done = time.perf_counter()
        observations: list[tuple[Histogram, float]] = []
        with self._mu:
            self._dispatches += 1
            self._dispatched_units += len(batch)
            self._detect_calls += cstats.detect_calls
            self._frames += cstats.frames
            self._batched_frames += cstats.batched_frames
            for u in batch:
                if not u.slo:
                    continue
                late = done - u.deadline
                counts = self._slo_counts.setdefault(qkey, [0, 0])
                counts[0 if late <= 0.0 else 1] += 1
                hist = self._slo_lateness.get(qkey)
                if hist is None:
                    hist = self._slo_lateness[qkey] = Histogram()
                observations.append((hist, max(0.0, late)))
        # the scheduler lock stays a leaf: histogram observes (which take
        # the histogram's own lock) run after _mu is released
        for hist, late in observations:
            hist.observe(late)
        for i, u in enumerate(batch):
            # accounting attributed to the batch leader: summing the
            # shares across a server's queries equals the true fused cost
            share = cstats if i == 0 else None
            u.future.set_result((per_entry[i], share))

    # -- stats / lifecycle ---------------------------------------------------
    @staticmethod
    def zero_stats() -> dict:
        """The all-zero stats shape — a server running *without* the shared
        scheduler reports these, so cluster rollups sum the same keys on
        every shard regardless of per-shard configuration."""
        return {k: 0 for k in (
            "sched_enqueued", "sched_deduped", "sched_dispatches",
            "sched_units", "sched_detect_calls", "sched_frames",
            "sched_batched_frames", "sched_queue_depth",
            "sched_deadline_hits", "sched_deadline_misses")} | {
            "sched_fusion_ratio": 0.0, "sched_batch_occupancy": 0.0}

    def stats(self) -> dict:
        """Counter snapshot plus live gauges, taken under the scheduler
        lock (a racing reader sees a consistent enqueued/deduped pair)."""
        with self._mu:
            depth = sum(len(q) for q in self._queues.values())
            enq, dup = self._enqueued, self._deduped
            frames, batched = self._frames, self._batched_frames
            hits = sum(c[0] for c in self._slo_counts.values())
            misses = sum(c[1] for c in self._slo_counts.values())
            return {
                "sched_enqueued": enq,
                "sched_deduped": dup,
                "sched_dispatches": self._dispatches,
                "sched_units": self._dispatched_units,
                "sched_detect_calls": self._detect_calls,
                "sched_frames": frames,
                "sched_batched_frames": batched,
                "sched_queue_depth": depth,
                "sched_deadline_hits": hits,
                "sched_deadline_misses": misses,
                # share of demanded work served by an already-queued twin
                "sched_fusion_ratio": dup / max(1, enq + dup),
                # real rows per operator row: 1.0 = no padding waste
                "sched_batch_occupancy": frames / max(1, batched),
            }

    def slo_snapshot(self) -> dict:
        """Per-(op, cf) SLO accounting, wire-safe: dispatch deadline
        hit/miss counts plus the lateness distribution of units admitted
        with an explicit deadline.  Keys are ``"op:cf_name"``; cluster
        rollups sum the counts and bucket-merge the histograms
        (``repro.obs.telemetry.merge_frames``)."""
        with self._mu:
            counts = {qk: list(c) for qk, c in self._slo_counts.items()}
            hists = dict(self._slo_lateness)
        out = {}
        for (op_name, cf), c in counts.items():
            out[f"{op_name}:{cf.name()}"] = {
                "hits": c[0], "misses": c[1],
                "lateness": hists[(op_name, cf)].snapshot()}
        return out

    def close(self) -> None:
        with self._mu:
            self._closed = True
            # strand nothing: anything still queued resolves with an error
            pending = [u for q in self._queues.values() for u in q]
            self._queues.clear()
            self._by_key.clear()
            self._work.notify_all()
        self._dispatcher.join()
        for u in pending:
            u.future.set_exception(RuntimeError("scheduler closed"))
