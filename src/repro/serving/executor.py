"""Pipelined cascade executor: overlap retrieval of segments k+1..k+d with
one fused operator call over segments <= k.

``run_query`` (repro.analytics.query) times both paths per stage and
*estimates* the perfectly-pipelined speed; this executor realizes it.  A
prefetch window keeps the decoder busy while the operator consumes, and the
window feeds a consumption *batch queue* instead of a strict per-segment
loop: retrieved segments accumulate until ``batch_segments`` of them are
ready, then the ``BatchedConsumer`` (repro.analytics.batch) runs one
``op.detect`` per static shape bucket over all their activated frames while
the pool decodes the next window.  The cascade semantics are shared with
``run_query`` via ``stage_specs``; item sets are identical by construction
(see batch.py for the bit-exactness argument).
"""

from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from ..analytics.batch import DEFAULT_BATCH_SHAPES, BatchedConsumer
from ..analytics.operators import _positions
from ..analytics.query import (QueryCost, QueryResult, StageStats,
                               _active_frame_mask, _charge_fetch,
                               apply_pushdown, stage_specs)
from ..obs import trace as obs


def run_pipelined(store, config, query: str, stream: str, segments: list[int],
                  accuracy: float, retriever=None,
                  prefetch_depth: int = 1,
                  batch_segments: int = 4,
                  batch_shapes: tuple[int, ...] | None = None,
                  scheduler=None, index=None, pushdown: str = "exact",
                  deadline_ms: float | None = None) -> QueryResult:
    """Execute a cascade with retrieval/consumption overlap.

    ``retriever`` has ``store.retrieve``'s signature (the serving layer
    passes the planner's cache-aware ``fetch``).  ``StageStats.retrieve_s``
    counts only time *blocked waiting* on retrieval — under good overlap it
    approaches zero while consumption runs.  ``batch_segments`` sets how
    many retrieved segments a fused detect consumes at once; 0 keeps the
    true per-segment path (exact shapes, no padding — the unbatched A/B
    baseline), still pipelined.

    ``scheduler`` (a ``repro.serving.sched.ConsumptionScheduler``) replaces
    the run-private ``BatchedConsumer`` with the server's *shared* one:
    each segment's activated frames are enqueued as retrieval delivers them
    and the stage waits on per-segment futures, so detects fuse across
    every in-flight query (and duplicate work dedups at frame granularity).
    Items are identical either way; consume accounting is attributed to
    each fused batch's leading unit, so per-query ``detect_calls``/
    ``frames`` are exact only summed across the server's queries.
    ``StageStats.consume_s`` then counts time blocked on the shared
    scheduler's futures, mirroring ``retrieve_s``.

    ``index`` enables predicate pushdown (see ``apply_pushdown`` in
    repro.analytics.query): sketched-inactive segments are pruned before
    any retrieval or prefetch — ``"exact"`` mode is bit-identical to the
    unpruned run, ``"conservative"`` also prunes across knob mismatches.
    ``deadline_ms`` is the query's SLO slack, forwarded to the shared
    scheduler so this query's units are admitted in deadline order (EDF)
    within the consumption queues instead of at the uniform max-wait.
    """
    if batch_segments < 0:
        raise ValueError(f"batch_segments must be >= 0, got {batch_segments}")
    spec = store.spec
    fetch = retriever or store.retrieve
    consumer = (BatchedConsumer(spec, shapes=batch_shapes or
                                DEFAULT_BATCH_SHAPES)
                if batch_segments and scheduler is None else None)
    group = batch_segments
    specs = stage_specs(config, query, accuracy)
    n_total = len(segments)  # video_seconds covers pruned segments too
    segments, (n_pruned, pruned_bytes, n_cons) = apply_pushdown(
        store, index, stream, segments, specs, accuracy, pushdown)
    deadline_s = None if deadline_ms is None else deadline_ms / 1e3
    stages: list[StageStats] = []
    active: dict[int, set] | None = None
    items_all: set = set()
    cost = QueryCost()
    t_start = time.perf_counter()

    tracing = obs.TRACER.enabled
    if tracing:
        # prefetch-pool threads have no span stack of their own; have them
        # adopt the current stage span's context (the cell is updated as
        # stages advance) so their retrieve spans parent under it
        _ctx = [obs.TRACER.current()]
        _raw_fetch = fetch

        def fetch(stream, seg, sf_id, cf):
            with obs.TRACER.activate(*_ctx[0]):
                return _raw_fetch(stream, seg, sf_id, cf)

    with ThreadPoolExecutor(max_workers=max(1, prefetch_depth),
                            thread_name_prefix="vstore-prefetch") as pool:
        for op_name, op, cf, sf_id in specs:
            stage_span = obs.span(f"stage:{op_name}", op=op_name,
                                  cf=cf.name(), sf=sf_id)
            stage_span.__enter__()
            if tracing:
                _ctx[0] = obs.TRACER.current()
            st = StageStats(op=op_name, cf=cf, sf_id=sf_id)
            stage_items: set = set()
            next_active: dict[int, set] = {}
            segs = [s for s in segments
                    if active is None or active.get(s)]
            st.segments_scanned = len(segs)
            pos = _positions(cf, spec)

            def flush(pending):
                nonlocal stage_items
                t0 = time.perf_counter()
                per_seg, cstats = consumer.consume(op, cf, pending)
                st.consume_s += time.perf_counter() - t0
                st.detect_calls += cstats.detect_calls
                st.frames += cstats.frames
                st.batched_frames += cstats.batched_frames
                cost.detect_calls += cstats.detect_calls
                cost.detect_frames += cstats.frames
                for seg, items in per_seg.items():
                    stage_items |= {(seg,) + it for it in items}
                    next_active[seg] = {it[1] for it in items}

            futures = {i: pool.submit(fetch, stream, segs[i], sf_id, cf)
                       for i in range(min(prefetch_depth, len(segs)))}
            pending: list[tuple] = []  # retrieved, awaiting a fused detect
            waits: list[tuple] = []    # (seg, future) from the shared sched
            if scheduler is not None:
                scheduler.producer_inc(op_name, cf)
            try:
                for i, seg in enumerate(segs):
                    t0 = time.perf_counter()
                    frames, fcost = futures.pop(i).result()
                    st.retrieve_s += time.perf_counter() - t0
                    _charge_fetch(cost, fcost, len(frames))
                    nxt = i + prefetch_depth
                    if nxt < len(segs):
                        futures[nxt] = pool.submit(fetch, stream, segs[nxt],
                                                   sf_id, cf)

                    mask = _active_frame_mask(pos, None if active is None
                                              else active.get(seg, set()),
                                              spec)
                    if not mask.any():
                        continue
                    sel = np.nonzero(mask)[0]
                    if scheduler is not None:
                        # hand the segment to the shared scheduler as soon
                        # as it is retrieved; the fused detect may co-batch
                        # it with other in-flight queries' work
                        fut, owner = scheduler.enqueue(
                            op_name, op, cf, stream, seg, sf_id,
                            frames[sel], pos[sel], deadline_s=deadline_s)
                        waits.append((seg, fut, owner))
                        continue
                    if consumer is None:  # per-segment detect, exact shapes
                        t0 = time.perf_counter()
                        items = op.detect(frames[sel], cf, spec,
                                          positions=pos[sel])
                        st.consume_s += time.perf_counter() - t0
                        st.detect_calls += 1
                        st.frames += int(mask.sum())
                        cost.detect_calls += 1
                        cost.detect_frames += int(mask.sum())
                        stage_items |= {(seg,) + it for it in items}
                        next_active[seg] = {it[1] for it in items}
                        continue
                    pending.append((seg, frames[sel], pos[sel]))
                    if len(pending) >= group:
                        # the fused detect runs here while the pool
                        # retrieves segments i+1 .. i+prefetch_depth in
                        # the background
                        flush(pending)
                        pending = []
            finally:
                if scheduler is not None:
                    # stage fed its last segment: pending work may dispatch
                    # without waiting out the batching timer
                    scheduler.producer_dec(op_name, cf)
            if pending:
                flush(pending)
            for seg, fut, owner in waits:
                t0 = time.perf_counter()
                items, share = fut.result()
                waited = time.perf_counter() - t0
                st.consume_s += waited
                cost.sched_wait_s += waited
                if owner and share is not None:  # unit led a fused dispatch
                    st.detect_calls += share.detect_calls
                    st.frames += share.frames
                    st.batched_frames += share.batched_frames
                    cost.detect_calls += share.detect_calls
                    cost.detect_frames += share.frames
                stage_items |= {(seg,) + it for it in items}
                next_active[seg] = {it[1] for it in items}

            st.items = len(stage_items)
            stages.append(st)
            active = next_active
            items_all = stage_items
            stage_span.set(segments=st.segments_scanned, items=st.items,
                           detect_calls=st.detect_calls)
            stage_span.__exit__(None, None, None)

    dur = n_total * spec.segment_seconds
    return QueryResult(items=items_all, stages=stages, video_seconds=dur,
                       wall_s=time.perf_counter() - t_start,
                       pruned_segments=n_pruned, pruned_bytes=pruned_bytes,
                       pruned_conservative=n_cons, cost=cost)
