"""Pipelined cascade executor: overlap retrieval of segment k+1 with
operator consumption of segment k.

``run_query`` (repro.analytics.query) times both paths per stage and
*estimates* the perfectly-pipelined speed; this executor realizes it — a
one-segment lookahead keeps the decoder busy while the operator consumes,
so ``QueryResult.wall_s`` (and ``measured_speed``) reflects true overlap.
The cascade semantics are shared with ``run_query`` via ``stage_specs``;
item sets are identical by construction.
"""

from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from ..analytics.query import (QueryResult, StageStats, _active_frame_mask,
                               stage_specs)
from ..analytics.operators import _positions


def run_pipelined(store, config, query: str, stream: str, segments: list[int],
                  accuracy: float, retriever=None,
                  prefetch_depth: int = 1) -> QueryResult:
    """Execute a cascade with retrieval/consumption overlap.

    ``retriever`` has ``store.retrieve``'s signature (the serving layer
    passes the planner's cache-aware ``fetch``).  ``StageStats.retrieve_s``
    counts only time *blocked waiting* on retrieval — under good overlap it
    approaches zero while consumption runs.
    """
    spec = store.spec
    fetch = retriever or store.retrieve
    stages: list[StageStats] = []
    active: dict[int, set] | None = None
    items_all: set = set()
    t_start = time.perf_counter()

    with ThreadPoolExecutor(max_workers=max(1, prefetch_depth),
                            thread_name_prefix="vstore-prefetch") as pool:
        for op_name, op, cf, sf_id in stage_specs(config, query, accuracy):
            st = StageStats(op=op_name, cf=cf, sf_id=sf_id)
            stage_items: set = set()
            next_active: dict[int, set] = {}
            segs = [s for s in segments
                    if active is None or active.get(s)]
            st.segments_scanned = len(segs)

            futures = {i: pool.submit(fetch, stream, segs[i], sf_id, cf)
                       for i in range(min(prefetch_depth, len(segs)))}
            for i, seg in enumerate(segs):
                t0 = time.perf_counter()
                frames, _cost = futures.pop(i).result()
                st.retrieve_s += time.perf_counter() - t0
                nxt = i + prefetch_depth
                if nxt < len(segs):
                    futures[nxt] = pool.submit(fetch, stream, segs[nxt],
                                               sf_id, cf)

                pos = _positions(cf, spec)
                mask = _active_frame_mask(pos, None if active is None
                                          else active.get(seg, set()), spec)
                if not mask.any():
                    continue
                t0 = time.perf_counter()
                sel = np.nonzero(mask)[0]
                items = op.detect(frames[sel], cf, spec, positions=pos[sel])
                st.consume_s += time.perf_counter() - t0
                st.frames += int(mask.sum())
                stage_items |= {(seg,) + it for it in items}
                next_active[seg] = {it[1] for it in items}

            st.items = len(stage_items)
            stages.append(st)
            active = next_active
            items_all = stage_items

    dur = len(segments) * spec.segment_seconds
    return QueryResult(items=items_all, stages=stages, video_seconds=dur,
                       wall_s=time.perf_counter() - t_start)
