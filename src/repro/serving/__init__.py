"""Concurrent query serving: the layer between storage and analytics.

cache -> planner -> executor -> server (see README.md):

* ``DecodedSegmentCache`` — byte-budgeted LRU of decoded segments with
  bit-exact richer-CF reuse;
* ``RetrievalPlanner`` — dedupes and coalesces the in-flight queries'
  fetches into single-flight union decodes;
* ``run_pipelined`` — cascade execution overlapping retrieval of segment
  k+1 with consumption of segment k;
* ``VStoreServer`` — worker pool + admission control + stats front end.
"""

from .cache import CacheStats, DecodedSegmentCache
from .executor import run_pipelined
from .planner import DecodeTask, Request, RetrievalPlanner
from .sched import ConsumptionScheduler, WorkUnit
from .server import (AdmissionError, QueryRequest, QueryTicket, VStoreServer,
                     recovery_rank_for)

__all__ = [
    "AdmissionError", "CacheStats", "ConsumptionScheduler",
    "DecodedSegmentCache", "DecodeTask", "QueryRequest", "QueryTicket",
    "Request", "RetrievalPlanner", "VStoreServer", "WorkUnit",
    "recovery_rank_for", "run_pipelined",
]
