"""VStoreServer: multi-tenant front end over one VideoStore.

Wires the serving stack together — decoded-segment cache, shared-retrieval
planner, pipelined cascade executor — behind a worker pool with admission
control:

* ``max_inflight`` — queries admitted beyond the cap are rejected with
  ``AdmissionError`` (or block for a slot with ``block=True``);
* ``cache_bytes`` — the decoded-segment cache's hard byte budget.

On admission a query's stage fetches are registered with the planner, so
concurrent queries over shared segments coalesce into single decodes; on
completion the interest is released.  Identical queries that are in flight
at the same time *collapse* onto one execution (single-flight at the query
level — results are pure functions of store content, so concurrent
duplicates share the leader's future instead of redoing the cascade).
``attach=True`` installs the planner as the store's retrieve hook, so even
plain ``run_query`` callers against the same store share the cache.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor

from ..analytics.query import QueryResult, stage_specs
from ..codec.transform import dct_backend
from ..obs import trace as obs
from ..obs.drift import DriftDetector
from ..obs.metrics import MetricsRegistry
from ..obs.telemetry import (AlertDeduper, BurnRate, SLOClass,
                             derive_deadline_ms, drift_alert_candidates)
from .cache import DecodedSegmentCache
from .executor import run_pipelined
from .planner import Request, RetrievalPlanner
from .sched import ConsumptionScheduler


class AdmissionError(RuntimeError):
    """Raised when the server is at max in-flight queries."""


@dataclasses.dataclass
class QueryRequest:
    """Serialize-friendly form of one cascade submission — plain scalars
    only, so a request can cross a process boundary (the cluster router
    ships these to shard workers) or be logged/replayed verbatim."""
    query: str
    stream: str
    segments: list[int]
    accuracy: float
    block: bool = False
    # distributed trace context (repro.obs): 0 means "no caller context" —
    # the server starts a fresh trace if tracing is enabled
    trace_id: int = 0
    parent_span: int = 0
    # per-query SLO slack in ms; 0 means "no deadline" — the consumption
    # scheduler then batches this query's units at the uniform max-wait
    deadline_ms: float = 0.0
    # named SLO class ("" = none): when set and deadline_ms is 0, the
    # server derives the deadline from the class's slack over the derived
    # config's profiled per-knob speeds (see obs.telemetry)
    slo_class: str = ""

    def to_wire(self) -> dict:
        return {"query": self.query, "stream": self.stream,
                "segments": [int(s) for s in self.segments],
                "accuracy": float(self.accuracy), "block": self.block,
                "trace_id": int(self.trace_id),
                "parent_span": int(self.parent_span),
                "deadline_ms": float(self.deadline_ms),
                "slo_class": self.slo_class}

    @staticmethod
    def from_wire(d: dict) -> "QueryRequest":
        return QueryRequest(d["query"], d["stream"],
                            [int(s) for s in d["segments"]],
                            float(d["accuracy"]), bool(d.get("block", False)),
                            int(d.get("trace_id", 0)),
                            int(d.get("parent_span", 0)),
                            float(d.get("deadline_ms", 0.0)),
                            str(d.get("slo_class", "")))


def recovery_rank_for(config, spec, profiler=None) -> dict[str, float]:
    """sf_id -> recovery cost for a derived configuration — the identical
    ranking the ingest scheduler prioritizes transcode work with
    (``repro.ingest.scheduler.recovery_rank_for``), reused here to rank
    cache entries.  Deferred import: serving must stay importable without
    dragging the ingest layer in at module load."""
    from ..ingest.scheduler import recovery_rank_for as rank
    return rank(config, spec, profiler)


@dataclasses.dataclass
class QueryTicket:
    qid: int
    query: str
    stream: str
    segments: list[int]
    accuracy: float
    future: Future
    submitted_at: float

    def result(self, timeout: float | None = None) -> QueryResult:
        return self.future.result(timeout)


class VStoreServer:
    def __init__(self, store, config, *, workers: int = 4,
                 max_inflight: int = 16, cache_bytes: int = 256 << 20,
                 prefetch_depth: int = 1, batch_segments: int = 4,
                 batch_shapes: tuple[int, ...] | None = None,
                 attach: bool = False, collapse: bool = True,
                 cache_policy: str = "lru",
                 cross_query_batching: bool = False,
                 batch_max_wait_ms: float = 4.0,
                 index=None, pushdown: str = "exact"):
        """``cache_policy`` selects the decoded-segment cache's eviction
        order: ``"lru"`` (default) or ``"erosion"`` — evict the entry whose
        storage format is cheapest to recover (``recovery_rank_for``), so
        byte pressure spares the decodes that are expensive to redo.
        ``batch_shapes`` overrides the batched consumer's static shape
        ladder (e.g. one derived from the profiler's measured dispatch
        overhead, ``repro.analytics.batch.derive_shapes``).

        ``cross_query_batching`` replaces each query's private batched
        consumer with one shared ``ConsumptionScheduler``: detects fuse
        *across* concurrent queries and duplicate ``(stream, segment, op,
        cf)`` work dedups at frame granularity (see sched.py).
        ``batch_max_wait_ms`` bounds how long a non-full fused batch may
        wait for co-batching partners — the fairness knob.

        ``index`` (a ``repro.index.SemanticIndex``) enables predicate
        pushdown: sketched-inactive segments are pruned before retrieval.
        ``pushdown`` sets the mode every query runs at — ``"exact"``
        (bit-identical results), ``"conservative"`` (also prunes across
        knob mismatches when the sketch's accuracy dominates; bounded
        recall loss, surfaced in ``QueryResult.pruned_conservative``), or
        ``"off"``."""
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        if cache_policy not in ("lru", "erosion"):
            raise ValueError(f"unknown cache_policy {cache_policy!r}")
        if pushdown not in ("exact", "conservative", "off"):
            raise ValueError(f"unknown pushdown mode {pushdown!r}")
        self.store = store
        self.config = config
        self.index = index
        self.pushdown = pushdown
        rank = (recovery_rank_for(config, store.spec)
                if cache_policy == "erosion" else None)
        self.cache = DecodedSegmentCache(cache_bytes, recovery_rank=rank)
        self.planner = RetrievalPlanner(store, self.cache)
        self.max_inflight = max_inflight
        self.prefetch_depth = prefetch_depth
        self.batch_segments = batch_segments
        self.batch_shapes = batch_shapes
        self.sched = (ConsumptionScheduler(store.spec, shapes=batch_shapes,
                                           max_wait_ms=batch_max_wait_ms)
                      if cross_query_batching else None)
        self._pool = ThreadPoolExecutor(workers,
                                        thread_name_prefix="vstore-query")
        self._mu = threading.Lock()
        self._slot_freed = threading.Condition(self._mu)
        self._inflight = 0   # guarded-by: _mu
        self._next_qid = 0   # guarded-by: _mu
        self._collapse = collapse
        self._live: dict[tuple, Future] = {}  # guarded-by: _mu
        self._attached = attach
        self._ingest = None      # live-ingest scheduler (attach_ingest)
        self._erosion = None     # erosion executor (attach_ingest)
        if attach:
            store.attach_retriever(self.planner.fetch)
        # aggregate stats live on a metrics registry (repro.obs.metrics):
        # counters for the lifecycle tallies, a latency histogram whose
        # snapshot the cluster rollup can merge distribution-correctly,
        # and a drift detector fed by every completed query
        self.metrics = MetricsRegistry()
        self._h_latency = self.metrics.histogram("query_latency_s")
        self.drift = DriftDetector(config, store.spec)
        # SLO accounting (repro.obs.telemetry): registered classes derive
        # deadlines at admission; completions feed per-class burn windows
        # and the deadline hit/miss counters + lateness histogram below;
        # persistent conditions (burn > 1, drifted knobs) surface as
        # deduplicated alert events in the telemetry stream
        self._h_lateness = self.metrics.histogram("deadline_lateness_s")
        self._h_queue_wait = self.metrics.histogram("queue_wait_s")
        self.slo_classes: dict[str, SLOClass] = {}  # guarded-by: _mu
        self._burn: dict[str, BurnRate] = {}        # guarded-by: _mu
        self.alerts = AlertDeduper()
        self._t_up = time.perf_counter()

    # -- SLO classes ---------------------------------------------------------
    def register_slo(self, name: str, slack_x: float = 3.0,
                     target_miss_frac: float = 0.01,
                     window_s: float = 60.0) -> SLOClass:
        """Register (or replace) a named SLO class.  A submission naming
        the class without an explicit ``deadline_ms`` gets one derived
        from the class's slack over the derived config's profiled
        per-knob speeds (``obs.telemetry.derive_deadline_ms``); its
        hit/miss outcome then feeds the class's windowed burn rate."""
        slo = SLOClass(name, slack_x=slack_x,
                       target_miss_frac=target_miss_frac, window_s=window_s)
        with self._mu:
            self.slo_classes[name] = slo
            self._burn[name] = BurnRate(slo)
        return slo

    def derive_deadline(self, query: str, accuracy: float,
                        n_segments: int, slo_class: str) -> float:
        """The ``deadline_ms`` a class-tagged submission runs under."""
        with self._mu:
            slo = self.slo_classes.get(slo_class)
        if slo is None:
            raise KeyError(f"unknown SLO class {slo_class!r} "
                           f"(registered: {sorted(self.slo_classes)})")
        ops = [s[0] for s in stage_specs(self.config, query, accuracy)]
        return derive_deadline_ms(self.config, self.store.spec, ops,
                                  accuracy, n_segments, slo.slack_x)

    # -- submission ----------------------------------------------------------
    def submit(self, query: str, stream: str, segments: list[int],
               accuracy: float, block: bool = False,
               trace: tuple[int, int] = (0, 0),
               deadline_ms: float | None = None,
               slo_class: str = "") -> QueryTicket:
        """Admit one cascade query; returns a ticket whose ``result()``
        yields the QueryResult.  Rejects with AdmissionError at capacity
        unless ``block`` (then waits for a slot).  An identical query
        already in flight is collapsed: the ticket shares its execution
        (and consumes no worker slot).  ``trace`` is an optional
        ``(trace_id, parent_span)`` context the execution's spans parent
        under (a collapsed duplicate keeps the leader's context).
        ``deadline_ms`` is this query's SLO slack — its consumption units
        are admitted in deadline order within the shared scheduler's
        queues instead of at the uniform batching max-wait.  ``slo_class``
        names a registered SLO class (``register_slo``): without an
        explicit ``deadline_ms`` the deadline is *derived* from the
        class's slack over the profiled per-knob speeds, and the query's
        hit/miss outcome feeds the class's windowed burn rate."""
        live_key = (query, stream, tuple(segments), accuracy)
        # resolved before taking an admission slot so a bad query name
        # (or an unknown SLO class) raises without leaking in-flight
        # accounting
        requests = [Request(stream, seg, sf_id, cf)
                    for _op_name, _op, cf, sf_id in
                    stage_specs(self.config, query, accuracy)
                    for seg in segments]
        if deadline_ms is None and slo_class:
            deadline_ms = self.derive_deadline(query, accuracy,
                                               len(segments), slo_class)
        with self._mu:
            if self._collapse and live_key in self._live:
                self.metrics.inc("collapsed")
                qid = self._next_qid
                self._next_qid += 1
                shared = self._live[live_key]
            else:
                shared = None
        if shared is not None:
            # outside _mu: a done future runs the callback synchronously in
            # this thread, and _account_collapsed takes _mu itself
            shared.add_done_callback(self._account_collapsed)
            return QueryTicket(qid, query, stream, list(segments),
                               accuracy, shared, time.perf_counter())
        with self._mu:
            while self._inflight >= self.max_inflight:
                if not block:
                    self.metrics.inc("rejected")
                    raise AdmissionError(
                        f"{self._inflight} queries in flight "
                        f"(max {self.max_inflight})")
                self._slot_freed.wait()
            self._inflight += 1
            qid = self._next_qid
            self._next_qid += 1
            fut: Future = Future()
            if self._collapse:
                self._live[live_key] = fut  # registered before dispatch, so
                # a duplicate submitted at any point attaches to this run

        self.planner.register_query(requests)
        try:
            self._pool.submit(self._run, fut, query, stream, segments,
                              accuracy, requests, live_key, trace,
                              deadline_ms, slo_class, time.perf_counter())
        except BaseException as e:  # pool shut down: roll back the slot
            self.planner.release_query(requests)
            with self._mu:
                self._live.pop(live_key, None)
                self._inflight -= 1
                self._slot_freed.notify()
            fut.set_exception(e)  # resolve any duplicate already attached
            raise
        return QueryTicket(qid, query, stream, list(segments), accuracy, fut,
                           time.perf_counter())

    def _account_collapsed(self, fut: Future):
        if fut.exception() is not None:
            return
        res = fut.result()
        self.metrics.inc("completed")
        self.metrics.inc("video_seconds", res.video_seconds)

    def _run(self, fut, query, stream, segments, accuracy, requests,
             live_key, trace=(0, 0), deadline_ms=None, slo_class="",
             submitted_at=None) -> None:
        queue_wait = (time.perf_counter() - submitted_at
                      if submitted_at is not None else 0.0)
        try:
            # adopt the caller's trace context (a router's rpc span when
            # the request came over the wire) and wrap the execution in a
            # query span — closed before set_result, so a worker can ship
            # the trace's spans as soon as the future resolves
            with obs.TRACER.activate(*trace), \
                    obs.span("query", query=query, stream=stream,
                             accuracy=accuracy, segments=len(segments)):
                res = run_pipelined(self.store, self.config, query, stream,
                                    segments, accuracy,
                                    retriever=self.planner.fetch,
                                    prefetch_depth=self.prefetch_depth,
                                    batch_segments=self.batch_segments,
                                    batch_shapes=self.batch_shapes,
                                    scheduler=self.sched,
                                    index=self.index,
                                    pushdown=self.pushdown,
                                    deadline_ms=deadline_ms)
            self.metrics.inc("completed")
            self.metrics.inc("video_seconds", res.video_seconds)
            self.metrics.inc("query_wall_s", res.wall_s)
            if res.pruned_segments:
                self.metrics.inc("index_pruned_segments", res.pruned_segments)
                self.metrics.inc("index_pruned_bytes", res.pruned_bytes)
                self.metrics.inc("index_pruned_conservative",
                                 res.pruned_conservative)
            self._h_latency.observe(res.wall_s)
            self._h_queue_wait.observe(queue_wait)
            res.cost.queue_wait_s = queue_wait
            if deadline_ms:
                # query-level SLO outcome: the whole cascade against its
                # deadline.  Hit/miss counters sum exactly across shards
                # (the telemetry rollup's bit-exactness gate); lateness is
                # distribution-valued and bucket-merges.
                slack = deadline_ms / 1e3 - res.wall_s
                missed = slack < 0
                self.metrics.inc("deadline_misses" if missed
                                 else "deadline_hits")
                self._h_lateness.observe(max(0.0, -slack))
                res.cost.deadline_ms = float(deadline_ms)
                res.cost.deadline_slack_s = slack
                res.cost.deadline_met = not missed
                if slo_class:
                    with self._mu:
                        burn = self._burn.get(slo_class)
                    if burn is not None:
                        burn.record(missed)
            self.drift.observe(accuracy, res)
            fut.set_result(res)
        except BaseException as e:
            self.metrics.inc("failed")
            fut.set_exception(e)
        finally:
            self.planner.release_query(requests)
            with self._mu:
                self._live.pop(live_key, None)
                self._inflight -= 1
                self._slot_freed.notify()

    def submit_request(self, req: QueryRequest) -> QueryTicket:
        """``submit`` over the serialize-friendly request form (what a
        shard worker calls after unpacking a router frame)."""
        return self.submit(req.query, req.stream, req.segments, req.accuracy,
                           block=req.block,
                           trace=(req.trace_id, req.parent_span),
                           deadline_ms=req.deadline_ms or None,
                           slo_class=req.slo_class)

    def run_batch(self, submissions: list[tuple], block: bool = True
                  ) -> list[QueryResult]:
        """Submit ``(query, stream, segments, accuracy)`` tuples and wait
        for all; returns results in submission order."""
        tickets = [self.submit(*s, block=block) for s in submissions]
        return [t.result() for t in tickets]

    def attach_ingest(self, scheduler, erosion=None) -> None:
        """Surface a live-ingest scheduler's (and optionally an erosion
        executor's) per-stream/per-format lag, debt and reclaim stats
        through this server's ``stats()`` — one observability endpoint for
        the whole ingest -> store -> serve path."""
        self._ingest = scheduler
        self._erosion = erosion

    # -- stats / lifecycle ---------------------------------------------------
    # registry-backed counter views, kept as attributes for compatibility
    @property
    def completed(self) -> int:
        return int(self.metrics.value("completed"))

    @property
    def rejected(self) -> int:
        return int(self.metrics.value("rejected"))

    @property
    def failed(self) -> int:
        return int(self.metrics.value("failed"))

    @property
    def collapsed(self) -> int:
        return int(self.metrics.value("collapsed"))

    @property
    def video_seconds(self) -> float:
        return float(self.metrics.value("video_seconds"))

    @property
    def query_wall_s(self) -> float:
        return float(self.metrics.value("query_wall_s"))

    def stats(self) -> dict:
        # every sub-snapshot is taken under its owner's lock (scheduler,
        # erosion, cache, planner, registry each lock internally), never
        # by reading their mutable state from here — a reader racing a
        # worker sees consistent counts
        ingest = self._ingest.stats() if self._ingest is not None else None
        erosion = self._erosion.stats() if self._erosion is not None else None
        cache = self.cache.stats_snapshot()
        planner = self.planner.stats()
        sched = (self.sched.stats() if self.sched is not None
                 else ConsumptionScheduler.zero_stats())
        # index stats are always emitted (zeros without an index) so the
        # cluster rollup sums the same keys on every shard; the pruned_*
        # counters accrue on the metrics registry as queries complete
        index = {"index_sketches": 0, "index_builds": 0,
                 "index_build_s": 0.0, "index_lookups": 0,
                 "index_invalidated": 0, "index_bytes": 0}
        if self.index is not None:
            index.update(self.index.stats())
        with self._mu:
            inflight = self._inflight
        # live occupancy as *gauges* (last-write-wins point-in-time reads,
        # not lifetime counters): admission occupancy plus the shared
        # scheduler's queue depth / batch occupancy / fusion ratio, so the
        # cluster rollup sees them in the same registry as everything else
        self.metrics.set_gauge("inflight", inflight)
        self.metrics.set_gauge("queue_depth", sched["sched_queue_depth"])
        self.metrics.set_gauge("fusion_ratio", sched["sched_fusion_ratio"])
        self.metrics.set_gauge("batch_occupancy",
                               sched["sched_batch_occupancy"])
        snap = self.metrics.snapshot()
        counters = snap["counters"]
        uptime = time.perf_counter() - self._t_up
        video_seconds = counters.get("video_seconds", 0.0)
        return {
            "ingest": ingest,
            "erosion": erosion,
            "completed": int(counters.get("completed", 0)),
            "rejected": int(counters.get("rejected", 0)),
            "failed": int(counters.get("failed", 0)),
            "collapsed": int(counters.get("collapsed", 0)),
            "deadline_hits": int(counters.get("deadline_hits", 0)),
            "deadline_misses": int(counters.get("deadline_misses", 0)),
            "inflight": inflight,
            "video_seconds": video_seconds,
            "query_wall_s": counters.get("query_wall_s", 0.0),
            # served video time per wall second since start — the
            # aggregate x-realtime of everything this server ran
            "aggregate_x_realtime": video_seconds / max(uptime, 1e-9),
            "uptime_s": uptime,
            "cache": cache,
            "cache_bytes": cache["bytes"],
            "latency": self._h_latency.snapshot(),
            "drift": self.drift.report(),
            # resolved codec transform backend this process serves with
            # (profiler-chosen via DerivedConfig.dct_backend when derived)
            "dct_backend": dct_backend(),
            "gauges": snap["gauges"],
            **sched,
            **index,
            "index_pruned_segments":
                int(counters.get("index_pruned_segments", 0)),
            "index_pruned_bytes": int(counters.get("index_pruned_bytes", 0)),
            "index_pruned_conservative":
                int(counters.get("index_pruned_conservative", 0)),
            **planner,
        }

    # -- telemetry ------------------------------------------------------------
    def _collect_alerts(self) -> list[dict]:
        """Fold persistent conditions into the deduplicated alert stream
        and drain it: one alert per drifted knob per window (not one per
        query — the drift report flags the knob on every sample while it
        under-performs) and one per SLO class whose burn exceeds its
        budget."""
        for key, msg, attrs in drift_alert_candidates(self.drift.report()):
            self.alerts.emit(key, "warn", msg, **attrs)
        with self._mu:
            burns = list(self._burn.items())
        for name, burn in burns:
            snap = burn.snapshot()
            if snap["burn"] > 1.0:
                self.alerts.emit(
                    f"slo_burn:{name}", "critical",
                    f"SLO class {name} burning {snap['burn']:.1f}x its "
                    f"error budget ({snap['window_misses']}/"
                    f"{snap['window_total']} missed in window)",
                    slo_class=name, burn=snap["burn"])
        return self.alerts.drain()

    def telemetry_body(self) -> dict:
        """One telemetry frame body: the full metrics registry snapshot
        (with the cache/planner/scheduler counters folded in, so the
        series is self-contained), per-queue and per-class SLO state, and
        the drained alert stream.  This is what the ``TelemetrySampler``
        writes every interval and what the ``telemetry`` wire op returns
        to the router's cluster scrape."""
        cache = self.cache.stats_snapshot()
        planner = self.planner.stats()
        sched = (self.sched.stats() if self.sched is not None
                 else ConsumptionScheduler.zero_stats())
        with self._mu:
            inflight = self._inflight
            burns = list(self._burn.items())
        self.metrics.set_gauge("inflight", inflight)
        self.metrics.set_gauge("queue_depth", sched["sched_queue_depth"])
        self.metrics.set_gauge("fusion_ratio", sched["sched_fusion_ratio"])
        self.metrics.set_gauge("batch_occupancy",
                               sched["sched_batch_occupancy"])
        snap = self.metrics.snapshot()
        counters = snap["counters"]
        for k in ("hits", "richer_hits", "misses", "lookups", "evictions"):
            counters[f"cache_{k}"] = cache.get(k, 0)
        for k in ("decodes", "decode_bytes", "decode_chunks",
                  "coalesced_cfs", "inflight_hits"):
            counters[k] = planner.get(k, 0)
        for k, v in sched.items():
            if k not in ("sched_fusion_ratio", "sched_batch_occupancy",
                         "sched_queue_depth"):
                counters[k] = v
        return {
            "metrics": snap,
            "slo": {
                "queues": (self.sched.slo_snapshot()
                           if self.sched is not None else {}),
                "classes": {name: b.snapshot() for name, b in burns},
            },
            "alerts": self._collect_alerts(),
        }

    def close(self):
        if self._attached:
            self.store.attach_retriever(None)
        self._pool.shutdown(wait=True)
        if self.sched is not None:
            self.sched.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
