"""Byte-budgeted LRU cache of decoded segments, shared across queries.

Entries are keyed ``(stream, seg, sf_id, cf)`` and hold the *decoded* frames
on the storage fidelity's pixel grid, restricted to the temporal indices the
CF's sampling wanted (``want``).  Keeping frames pre-conversion is what makes
reuse bit-exact: serving any request from a cached entry runs the identical
``spatial_convert`` a direct ``VideoStore.retrieve`` would run on a fresh
decode, so cached and uncached results cannot diverge.

Reuse rule (richer_eq): a request ``(stream, seg, sf_id, cf)`` is served by a
cached entry with the same ``(stream, seg, sf_id)`` when the entry's CF is
richer-than-or-equal (``FidelityOption.richer_eq``) *and* the entry's decoded
``want`` indices cover the request's — a richer CF decoded more frames, so
the poorer CF selects a subset and converts, instead of decoding again.  The
temporal-coverage check is explicit because the sampling ladder's index sets
do not always nest.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict

import numpy as np

from ..core.knobs import FidelityOption

Key = tuple  # (stream, seg, sf_id, FidelityOption)


def covering_rows(have: np.ndarray, want: np.ndarray) -> np.ndarray | None:
    """Row indices into a decode's ``have`` (sorted unique) frame-index set
    realizing ``want`` (which may repeat indices), or None if not fully
    covered.  Shared by cache entries and the planner's in-flight slots so
    the temporal-coverage rule lives in one place."""
    want = np.asarray(want)
    if want.size == 0:
        return np.empty(0, np.int64)  # nothing requested: covered
    if have.size == 0:
        # an empty decode covers nothing; without this guard the clip
        # below lands on -1 and "covers" via the last row
        return None
    rows = np.searchsorted(have, want)
    rows = np.clip(rows, 0, len(have) - 1)
    if not np.array_equal(have[rows], want):
        return None
    return rows


@dataclasses.dataclass
class CacheEntry:
    stream: str
    seg: int
    sf_id: str
    cf: FidelityOption
    want: np.ndarray       # sorted unique stored-frame indices decoded
    frames: np.ndarray     # (len(want), h_sf, w_sf) uint8, storage grid
    nbytes: int

    def covers(self, want: np.ndarray) -> np.ndarray | None:
        """Row indices into ``self.frames`` realizing ``want``, or None."""
        return covering_rows(self.want, want)


@dataclasses.dataclass
class CacheStats:
    hits: int = 0            # exact-key hits
    richer_hits: int = 0     # served via a richer cached CF
    misses: int = 0
    evictions: int = 0
    oversize: int = 0        # decodes too large to cache under the budget
    admission_rejects: int = 0  # ranked cheaper than everything resident
    inserted_bytes: int = 0

    @property
    def lookups(self) -> int:
        return self.hits + self.richer_hits + self.misses

    @property
    def hit_rate(self) -> float:
        return (self.hits + self.richer_hits) / max(1, self.lookups)

    def snapshot(self) -> dict:
        return dataclasses.asdict(self) | {"lookups": self.lookups,
                                           "hit_rate": self.hit_rate}


class DecodedSegmentCache:
    """Thread-safe LRU over decoded segments with a hard byte budget.

    ``recovery_rank`` switches eviction from pure LRU to the erosion value
    model: a map ``sf_id -> recovery cost`` (``core.erosion.recovery_cost``
    chain math — how much the consumer fleet slows down when that format
    must be re-fetched/reconstructed).  Under byte pressure the entry whose
    format is *cheapest to recover* is evicted first (LRU order breaks
    ties within a cost tier), so the cache spends its budget on the
    decodes that are genuinely expensive to regenerate instead of merely
    the most recently touched ones."""

    def __init__(self, max_bytes: int = 256 << 20,
                 recovery_rank: dict[str, float] | None = None):
        self.max_bytes = int(max_bytes)
        self.recovery_rank = dict(recovery_rank) if recovery_rank else None
        self._lock = threading.Lock()
        self._entries: OrderedDict[Key, CacheEntry] = OrderedDict()  # guarded-by: _lock
        self._by_segment: dict[tuple, list[Key]] = {}  # guarded-by: _lock
        self._bytes = 0  # guarded-by: _lock
        self.stats = CacheStats()  # guarded-by: _lock

    @property
    def bytes(self) -> int:
        return self._bytes

    def __len__(self) -> int:
        return len(self._entries)

    def stats_snapshot(self) -> dict:
        """``CacheStats.snapshot()`` plus resident bytes/entries, taken
        under the cache lock — external readers (``VStoreServer.stats``)
        must use this instead of reading ``self.stats`` racily."""
        with self._lock:
            return self.stats.snapshot() | {"bytes": self._bytes,
                                            "entries": len(self._entries)}

    # -- lookup --------------------------------------------------------------
    def lookup(self, stream: str, seg: int, sf_id: str, cf: FidelityOption,
               want: np.ndarray) -> tuple[np.ndarray, str] | None:
        """Storage-grid frames for ``want`` and the hit kind ('hit' or
        'richer'), or None on miss.  Returned arrays are copies of cache
        rows; callers convert them to the consumption fidelity."""
        skey = (stream, seg, sf_id)
        with self._lock:
            exact = self._entries.get((stream, seg, sf_id, cf))
            if exact is not None:
                rows = exact.covers(want)
                if rows is not None:
                    self._entries.move_to_end((stream, seg, sf_id, cf))
                    self.stats.hits += 1
                    return exact.frames[rows], "hit"
            for key in self._by_segment.get(skey, ()):
                entry = self._entries[key]
                if entry is exact or not entry.cf.richer_eq(cf):
                    continue
                rows = entry.covers(want)
                if rows is not None:
                    self._entries.move_to_end(key)
                    self.stats.richer_hits += 1
                    return entry.frames[rows], "richer"
            self.stats.misses += 1
            return None

    # -- insert / evict ------------------------------------------------------
    def insert(self, stream: str, seg: int, sf_id: str, cf: FidelityOption,
               want: np.ndarray, frames: np.ndarray) -> bool:
        """Cache a decode.  ``want`` must be sorted unique and match
        ``frames`` row-for-row.  Returns False when the decode was not
        admitted: it alone overflows the byte budget, or (erosion-aware
        eviction) it ranks cheaper to recover than everything resident —
        admitting it only to evict it in the same breath would make every
        cheap-format decode an insert/evict churn that callers would
        mistake for a successful cache fill."""
        frames = np.ascontiguousarray(frames)
        entry = CacheEntry(stream, seg, sf_id, cf, np.asarray(want).copy(),
                           frames, frames.nbytes)
        key = (stream, seg, sf_id, cf)
        with self._lock:
            if entry.nbytes > self.max_bytes:
                self.stats.oversize += 1
                return False
            old = self._entries.pop(key, None)
            if old is not None:
                self._drop_index_locked(old)
                self._bytes -= old.nbytes
            self._entries[key] = entry
            self._by_segment.setdefault((stream, seg, sf_id), []).append(key)
            self._bytes += entry.nbytes
            while self._bytes > self.max_bytes:
                victim = self._evict_one_locked()
                self._drop_index_locked(victim)
                self._bytes -= victim.nbytes
                if victim is entry:  # the newcomer lost to the residents
                    self.stats.admission_rejects += 1
                    return False
                self.stats.evictions += 1
            self.stats.inserted_bytes += entry.nbytes
            return True

    def _evict_one_locked(self) -> CacheEntry:
        if self.recovery_rank is None:
            return self._entries.popitem(last=False)[1]
        # erosion-aware: cheapest-to-recover format first; within a cost
        # tier the least recently used entry goes (min is stable and dict
        # order is recency, oldest first).  Unranked formats score +inf,
        # matching golden's never-shed rank.
        vkey = min(self._entries,
                   key=lambda k: self.recovery_rank.get(k[2], float("inf")))
        return self._entries.pop(vkey)

    def _drop_index_locked(self, entry: CacheEntry):
        skey = (entry.stream, entry.seg, entry.sf_id)
        keys = self._by_segment.get(skey, [])
        keys.remove((entry.stream, entry.seg, entry.sf_id, entry.cf))
        if not keys:
            self._by_segment.pop(skey, None)

    def clear(self):
        with self._lock:
            self._entries.clear()
            self._by_segment.clear()
            self._bytes = 0
