"""Self-tests for the invariant linter (``repro.analysis``).

Each seeded-violation fixture in ``analysis_fixtures/`` must produce
*exactly* its expected finding, and its clean twin must pass — this is
the linter's own regression net: a pass that silently stops firing
shows up here, not as quietly-ignored production violations.
"""

import os

import pytest

from repro.analysis import lint
from repro.analysis.core import Finding, Module, load_modules

FIXTURES = os.path.join(os.path.dirname(__file__), "analysis_fixtures")
SRC = os.path.normpath(
    os.path.join(os.path.dirname(__file__), os.pardir, "src"))


def run_on(name):
    findings, lock_an, _ = lint.run(
        [os.path.join(FIXTURES, name)], baseline_path=None)
    return findings, lock_an


@pytest.mark.parametrize("bad,good,rule", [
    ("bad_guard.py", "good_guard.py", "guard"),
    ("bad_lock_order.py", "good_lock_order.py", "lock-order"),
    ("bad_wire.py", "good_wire.py", "wire-field"),
    ("bad_determinism.py", "good_determinism.py", "determinism"),
    ("bad_jitshape.py", "good_jitshape.py", "jit-shape"),
])
def test_seeded_violation_caught_and_clean_twin_passes(bad, good, rule):
    findings, _ = run_on(bad)
    assert [f.rule for f in findings] == [rule], \
        f"{bad}: expected exactly one {rule!r}, got {findings}"
    clean, _ = run_on(good)
    assert clean == [], f"{good}: expected no findings, got {clean}"


def test_guard_finding_names_the_field():
    findings, _ = run_on("bad_guard.py")
    [f] = findings
    assert "n" in f.symbol and "_lock" in f.message


def test_wire_finding_names_the_dropped_field():
    findings, _ = run_on("bad_wire.py")
    [f] = findings
    assert f.symbol == "Packet.checksum"
    assert "to_wire" in f.message


def test_lock_order_cycle_names_both_locks():
    findings, _ = run_on("bad_lock_order.py")
    [f] = findings
    assert "MU_A" in f.symbol and "MU_B" in f.symbol


def test_good_lock_order_still_records_the_edge():
    # the clean twin is clean because both paths agree, not because the
    # analyzer failed to see the nesting
    _, lock_an = run_on("good_lock_order.py")
    edges = {(a.rsplit("::")[-1], b.rsplit("::")[-1])
             for a, b in lock_an.edges}
    assert ("MU_A", "MU_B") in edges


def test_inline_allow_suppresses_with_justification():
    mod = Module("f.py", (
        "# analysis: determinism-path\n"
        "def place(key, n):\n"
        "    # analysis: allow[determinism] key is an int, hash is identity\n"
        "    return hash(key) % n\n"))
    from repro.analysis import determinism
    assert determinism.check([mod]) == []
    assert mod.bare_allows == []


def test_bare_allow_is_itself_a_finding(tmp_path):
    p = tmp_path / "f.py"
    p.write_text("# analysis: determinism-path\n"
                 "def place(key, n):\n"
                 "    # analysis: allow[determinism]\n"
                 "    return hash(key) % n\n")
    findings, _, _ = lint.run([str(p)], baseline_path=None)
    assert [f.rule for f in findings] == ["bare-allow"]


def test_baseline_suppresses_only_with_reason(tmp_path):
    src = tmp_path / "f.py"
    src.write_text("# analysis: determinism-path\n"
                   "def place(key, n):\n"
                   "    return hash(key) % n\n")
    findings, _, _ = lint.run([str(src)], baseline_path=None)
    [f] = findings
    bl = tmp_path / "baseline.txt"

    bl.write_text(f"{f.fingerprint}  # int keys only, hash is identity\n")
    findings, _, stale = lint.run([str(src)], baseline_path=str(bl))
    assert findings == [] and stale == {}

    bl.write_text(f"{f.fingerprint}\n")
    findings, _, _ = lint.run([str(src)], baseline_path=str(bl))
    assert [f.rule for f in findings] == ["bare-allow"]


def test_stale_baseline_entries_reported(tmp_path):
    src = tmp_path / "f.py"
    src.write_text("x = 1\n")
    bl = tmp_path / "baseline.txt"
    bl.write_text("determinism:gone.py:place  # obsolete\n")
    findings, _, stale = lint.run([str(src)], baseline_path=str(bl))
    assert findings == []
    assert set(stale) == {"determinism:gone.py:place"}


def test_fingerprint_is_line_stable():
    f1 = Finding("guard", "a.py", 10, "C.n", "msg")
    f2 = Finding("guard", "a.py", 99, "C.n", "other msg")
    assert f1.fingerprint == f2.fingerprint


def test_src_tree_is_clean():
    """The linter's reason to exist: the shipped tree passes with no
    baseline entries (every deliberate pattern carries an inline
    justified allow)."""
    findings, _, _ = lint.run([SRC], baseline_path=None)
    assert findings == [], "\n".join(f.render() for f in findings)


def test_src_lock_graph_is_small_and_acyclic():
    _, lock_an, _ = lint.run([SRC], baseline_path=None)
    assert not any(f.rule == "lock-order" for f in lock_an.findings)
    # the static graph should stay near-empty: cross-component edges are
    # deadlock surface, and the scheduler/ingest fixes removed them all
    assert len(lock_an.edges) <= 6, sorted(lock_an.edges)


def test_cli_exit_codes(tmp_path, capsys):
    bad = os.path.join(FIXTURES, "bad_guard.py")
    good = os.path.join(FIXTURES, "good_guard.py")
    assert lint.main([good, "--no-baseline"]) == 0
    assert lint.main([bad, "--no-baseline"]) == 1
    out = capsys.readouterr().out
    assert "[guard]" in out


def test_cli_json_output(capsys):
    import json
    bad = os.path.join(FIXTURES, "bad_wire.py")
    assert lint.main([bad, "--no-baseline", "--json"]) == 1
    doc = json.loads(capsys.readouterr().out)
    assert [f["rule"] for f in doc["findings"]] == ["wire-field"]
    assert doc["findings"][0]["fingerprint"].startswith("wire-field:")


def test_load_modules_normalizes_paths(tmp_path):
    p = tmp_path / "sub" / "f.py"
    p.parent.mkdir()
    p.write_text("x = 1\n")
    [mod] = load_modules([str(p)])
    assert mod.path == os.path.normpath(str(p))
