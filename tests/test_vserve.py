"""Serving layer: decoded-segment cache (hit-after-miss, byte-budget
eviction, bit-exact richer-CF reuse), shared-retrieval planner (dedup +
coalescing + single-flight), pipelined executor and VStoreServer
(concurrent == sequential, admission control, request collapsing)."""

import threading

import numpy as np
import pytest

from repro.analytics.query import run_query
from repro.analytics.scene import generate_segment
from repro.core.coalesce import SFNode
from repro.core.configure import DerivedConfig
from repro.core.consumption import Consumer, ConsumerPlan
from repro.core.knobs import (GOLDEN_CODING, RAW, FidelityOption,
                              IngestSpec)
from repro.serving import (AdmissionError, DecodedSegmentCache, Request,
                           RetrievalPlanner, VStoreServer, run_pipelined)
from repro.videostore import VideoStore

CF_DIFF = FidelityOption("good", 1.0, 270, 1 / 2)
CF_SNN = FidelityOption("good", 1.0, 360, 1 / 2)
CF_NN = FidelityOption("best", 1.0, 720, 2 / 3)


def _config(accuracies=(0.8,)):
    plans = []
    for acc in accuracies:
        plans += [ConsumerPlan(Consumer("diff", acc), CF_DIFF, 0.85, 3000.0),
                  ConsumerPlan(Consumer("snn", acc), CF_SNN, 0.86, 500.0),
                  ConsumerPlan(Consumer("nn", acc), CF_NN, 0.82, 30.0)]
    fast_plans = [p for p in plans if p.consumer.op in ("diff", "snn")]
    nn_plans = [p for p in plans if p.consumer.op == "nn"]
    fast = SFNode(CF_DIFF.join(CF_SNN), RAW, fast_plans)
    golden = SFNode(FidelityOption(), GOLDEN_CODING, nn_plans, golden=True)

    class _Log:
        nodes = [fast, golden]
        ingest_cost = storage_cost = 0.0
        rounds = []
        budget_met = True

    return DerivedConfig(plans=plans, nodes=[fast, golden], coalesce_log=_Log())


@pytest.fixture(scope="module")
def served(tmp_path_factory):
    root = tmp_path_factory.mktemp("vserve")
    spec = IngestSpec()
    cfg = _config(accuracies=(0.8, 0.9))
    vs = VideoStore(str(root), spec)
    vs.set_formats(cfg.storage_formats())
    for seg in range(3):
        frames, _ = generate_segment("jackson", seg, spec)
        vs.ingest_segment("jackson", seg, frames)
    return vs, cfg


# ---------------------------------------------------------------------------
# DecodedSegmentCache
# ---------------------------------------------------------------------------

def test_cache_hit_after_miss(served):
    vs, _cfg = served
    cache = DecodedSegmentCache(64 << 20)
    planner = RetrievalPlanner(vs, cache)
    a1, c1 = planner.fetch("jackson", 0, "sf_g", CF_NN)
    assert c1["cache"] == "miss" and cache.stats.misses == 1
    a2, c2 = planner.fetch("jackson", 0, "sf_g", CF_NN)
    assert c2["cache"] == "hit" and cache.stats.hits == 1
    assert np.array_equal(a1, a2)
    assert planner.decodes == 1  # second fetch decoded nothing


def test_cache_eviction_under_byte_budget():
    rng = np.random.default_rng(0)
    frames = rng.integers(0, 255, (4, 16, 16), dtype=np.uint8)
    budget = 3 * frames.nbytes
    cache = DecodedSegmentCache(budget)
    want = np.arange(4)
    cf = FidelityOption()
    for seg in range(5):
        cache.insert("s", seg, "sf", cf, want, frames)
        assert cache.bytes <= budget
    assert cache.stats.evictions == 2 and len(cache) == 3
    # LRU: oldest two segments evicted
    assert cache.lookup("s", 0, "sf", cf, want) is None
    assert cache.lookup("s", 1, "sf", cf, want) is None
    assert cache.lookup("s", 4, "sf", cf, want) is not None
    # an entry larger than the whole budget is refused, not cached
    big = rng.integers(0, 255, (40, 64, 64), dtype=np.uint8)
    assert not cache.insert("s", 9, "sf", cf, np.arange(40), big)
    assert cache.stats.oversize == 1


def test_richer_cf_reuse_bit_exact(served):
    """A cached richer-CF decode serves a poorer CF bit-exactly: the cache
    keeps storage-grid frames, so reuse runs the same spatial_convert a
    direct retrieve would."""
    vs, _cfg = served
    cache = DecodedSegmentCache(64 << 20)
    planner = RetrievalPlanner(vs, cache)
    rich = FidelityOption("best", 1.0, 720, 1.0)
    poor = FidelityOption("bad", 0.75, 180, 1 / 5)
    assert rich.richer_eq(poor)
    planner.fetch("jackson", 1, "sf_g", rich)
    got, cost = planner.fetch("jackson", 1, "sf_g", poor)
    assert cost["cache"] == "richer" and cache.stats.richer_hits == 1
    direct, _ = vs.retrieve_direct("jackson", 1, "sf_g", poor)
    assert got.dtype == direct.dtype and np.array_equal(got, direct)
    assert planner.decodes == 1


def test_attached_retriever_serves_plain_retrieve(served):
    vs, cfg = served
    with VStoreServer(vs, cfg, attach=True) as srv:
        a, _ = vs.retrieve("jackson", 2, "sf_g", CF_NN)
        b, c = vs.retrieve("jackson", 2, "sf_g", CF_NN)
        assert c["cache"] == "hit" and np.array_equal(a, b)
        assert srv.cache.stats.hits >= 1
    # detached on close: direct path again
    _, c = vs.retrieve("jackson", 2, "sf_g", CF_NN)
    assert "cache" not in c


def test_cache_covers_empty_wants():
    """An entry with an empty want set must not 'cover' real requests (the
    old clip-to--1 indexed the last row); an empty request is trivially
    covered by anything."""
    from repro.serving.cache import CacheEntry
    cf = FidelityOption()
    empty = CacheEntry("s", 0, "sf", cf, np.array([], np.int64),
                       np.zeros((0, 8, 8), np.uint8), 0)
    assert empty.covers(np.array([0, 1])) is None
    rows = empty.covers(np.array([], np.int64))
    assert rows is not None and rows.size == 0
    full = CacheEntry("s", 0, "sf", cf, np.arange(4),
                      np.zeros((4, 8, 8), np.uint8), 4 * 64)
    rows = full.covers(np.array([], np.int64))
    assert rows is not None and rows.size == 0
    assert full.covers(np.array([2, 9])) is None  # out of range, no wrap


# ---------------------------------------------------------------------------
# RetrievalPlanner
# ---------------------------------------------------------------------------

def test_planner_dedup_and_coalesce(served):
    vs, _cfg = served
    planner = RetrievalPlanner(vs, DecodedSegmentCache(64 << 20))
    reqs = [Request("jackson", 0, "sf_g", CF_NN),
            Request("jackson", 0, "sf_g", CF_DIFF),
            Request("jackson", 0, "sf_g", CF_NN),      # duplicate fetch
            Request("jackson", 1, "sf_g", CF_DIFF)]
    tasks = planner.plan(reqs)
    assert len(tasks) == 2  # one decode per (stream, seg, sf_id)
    t0 = next(t for t in tasks if t.seg == 0)
    assert len(t0.cfs) == 2
    assert t0.cf_join.richer_eq(CF_NN) and t0.cf_join.richer_eq(CF_DIFF)
    for cf in t0.cfs:
        want = vs.want_indices("sf_g", cf)
        assert np.isin(want, t0.want).all()


def test_planner_interest_coalesces_decode(served):
    """With two CFs registered as in-flight interest, the first miss decodes
    the union once and the other CF is then served from cache."""
    vs, _cfg = served
    cache = DecodedSegmentCache(64 << 20)
    planner = RetrievalPlanner(vs, cache)
    reqs = [Request("jackson", 0, "sf_g", CF_NN),
            Request("jackson", 0, "sf_g", CF_DIFF)]
    planner.register_query(reqs)
    planner.fetch("jackson", 0, "sf_g", CF_NN)
    _, cost = planner.fetch("jackson", 0, "sf_g", CF_DIFF)
    assert planner.decodes == 1 and planner.coalesced_cfs == 1
    assert cost["cache"] in ("hit", "richer")
    planner.release_query(reqs)
    assert not planner._interest


def test_oversize_decode_single_flight_no_stampede(served):
    """When the leader's decode exceeds the cache budget (insert refused),
    waiting followers must be served from the leader's in-flight slot —
    not degrade into N serial decodes of the same segment."""
    vs, _cfg = served
    cache = DecodedSegmentCache(max_bytes=1)  # nothing is cacheable
    planner = RetrievalPlanner(vs, cache)

    decoding = threading.Event()
    release = threading.Event()
    real_decode = vs.decode_for

    class _GatedStore:
        """Store proxy whose decode blocks until every follower queues."""

        def __getattr__(self, name):
            return getattr(vs, name)

        def decode_for(self, stream, seg, sf_id, want):
            decoding.set()
            release.wait(5)
            return real_decode(stream, seg, sf_id, want)

    planner.store = _GatedStore()
    results, errors = [], []

    def fetch():
        try:
            results.append(planner.fetch("jackson", 0, "sf_g", CF_NN))
        except Exception as e:  # pragma: no cover - surfaced via assert
            errors.append(e)

    leader = threading.Thread(target=fetch)
    leader.start()
    assert decoding.wait(5)
    followers = [threading.Thread(target=fetch) for _ in range(4)]
    for t in followers:
        t.start()
    import time
    time.sleep(0.3)  # let followers reach the in-flight wait
    release.set()
    for t in [leader] + followers:
        t.join(10)
    assert not errors
    assert len(results) == 5
    assert planner.decodes == 1, \
        f"oversize decode stampeded: {planner.decodes} decodes for 5 fetches"
    assert cache.stats.oversize >= 1  # the scenario really was uncacheable
    assert planner.inflight_hits >= 1
    direct, _ = vs.retrieve_direct("jackson", 0, "sf_g", CF_NN)
    for frames, cost in results:
        assert np.array_equal(frames, direct)
        assert cost["cache"] in ("miss", "inflight")


# ---------------------------------------------------------------------------
# Pipelined executor / server
# ---------------------------------------------------------------------------

def test_pipelined_matches_sequential(served):
    vs, cfg = served
    seq = run_query(vs, cfg, "A", "jackson", [0, 1, 2], 0.8)
    pip = run_pipelined(vs, cfg, "A", "jackson", [0, 1, 2], 0.8)
    assert pip.items == seq.items
    assert [s.op for s in pip.stages] == [s.op for s in seq.stages]
    assert [s.segments_scanned for s in pip.stages] == \
        [s.segments_scanned for s in seq.stages]


def test_concurrent_queries_match_sequential(served):
    """N concurrent queries through the server return exactly the items of N
    sequential run_query calls (mixed accuracies: collapsed and distinct)."""
    vs, cfg = served
    subs = [("A", "jackson", [0, 1, 2], acc) for acc in (0.8, 0.9)] * 4
    expect = {(q, acc): run_query(vs, cfg, q, s, sg, acc).items
              for q, s, sg, acc in subs}
    with VStoreServer(vs, cfg, workers=4, max_inflight=8) as srv:
        results = srv.run_batch(subs)
        st = srv.stats()
    assert all(r.items == expect[(q, acc)]
               for r, (q, _s, _sg, acc) in zip(results, subs))
    assert st["completed"] == len(subs) and st["failed"] == 0
    assert st["cache"]["hit_rate"] > 0


def test_admission_control(served, monkeypatch):
    vs, cfg = served
    release = threading.Event()
    started = threading.Event()

    def slow_run(*a, **k):
        started.set()
        release.wait(5)
        return run_pipelined(*a, **k)

    import repro.serving.server as server_mod
    monkeypatch.setattr(server_mod, "run_pipelined", slow_run)
    with VStoreServer(vs, cfg, workers=2, max_inflight=1,
                      collapse=False) as srv:
        t1 = srv.submit("A", "jackson", [0], 0.8)
        assert started.wait(5)
        with pytest.raises(AdmissionError):
            srv.submit("A", "jackson", [1], 0.8)
        release.set()
        t1.result(10)
        st = srv.stats()
    assert st["rejected"] == 1 and st["completed"] == 1


def test_bad_query_does_not_leak_slot(served):
    vs, cfg = served
    with VStoreServer(vs, cfg, workers=1, max_inflight=1) as srv:
        with pytest.raises(KeyError):
            srv.submit("Z", "jackson", [0], 0.8)  # unknown query name
        # the admission slot must still be free
        t = srv.submit("A", "jackson", [0], 0.8)
        t.result(30)
        assert srv.stats()["inflight"] == 0


def test_request_collapsing(served, monkeypatch):
    """Identical in-flight queries share one execution."""
    vs, cfg = served
    gate = threading.Event()
    started = threading.Event()
    calls = []

    real = run_pipelined

    def gated_run(*a, **k):
        calls.append(a)
        started.set()
        gate.wait(5)
        return real(*a, **k)

    import repro.serving.server as server_mod
    monkeypatch.setattr(server_mod, "run_pipelined", gated_run)
    with VStoreServer(vs, cfg, workers=2, max_inflight=4) as srv:
        t1 = srv.submit("A", "jackson", [0, 1], 0.8)
        assert started.wait(5)
        t2 = srv.submit("A", "jackson", [0, 1], 0.8)  # identical, in flight
        gate.set()
        r1, r2 = t1.result(10), t2.result(10)
        st = srv.stats()
    assert len(calls) == 1 and r1 is r2
    assert st["collapsed"] == 1 and st["completed"] == 2


# ---------------------------------------------------------------------------
# erosion-aware cache eviction
# ---------------------------------------------------------------------------

def test_erosion_aware_eviction_ab():
    """A/B: same insert sequence, budget for two entries.  LRU evicts the
    oldest; the erosion-ranked cache evicts the cheapest-to-recover format
    regardless of recency, keeping the decode that is expensive to redo."""
    rng = np.random.default_rng(0)
    frames = rng.integers(0, 255, (4, 16, 16), dtype=np.uint8)
    want = np.arange(4)
    budget = 2 * frames.nbytes
    rank = {"sf_dear": 0.9, "sf_cheap": 0.1}

    lru = DecodedSegmentCache(budget)
    ero = DecodedSegmentCache(budget, recovery_rank=rank)
    for cache in (lru, ero):
        cache.insert("s", 0, "sf_dear", CF_NN, want, frames)   # oldest
        cache.insert("s", 1, "sf_cheap", CF_NN, want, frames)
        cache.insert("s", 2, "sf_dear", CF_NN, want, frames)   # overflow

    def held(cache, seg, sf_id):
        return cache.lookup("s", seg, sf_id, CF_NN, want) is not None

    # LRU: the oldest (seg 0, dear) died even though it's costly to redo
    assert not held(lru, 0, "sf_dear")
    assert held(lru, 1, "sf_cheap") and held(lru, 2, "sf_dear")
    # erosion-aware: the cheap-to-recover entry died, both dear survive
    assert not held(ero, 1, "sf_cheap")
    assert held(ero, 0, "sf_dear") and held(ero, 2, "sf_dear")
    assert lru.stats.evictions == ero.stats.evictions == 1


def test_erosion_rank_ties_break_lru():
    rng = np.random.default_rng(1)
    frames = rng.integers(0, 255, (4, 16, 16), dtype=np.uint8)
    want = np.arange(4)
    ero = DecodedSegmentCache(2 * frames.nbytes,
                              recovery_rank={"sf": 0.5})
    ero.insert("s", 0, "sf", CF_NN, want, frames)
    ero.insert("s", 1, "sf", CF_NN, want, frames)
    assert ero.lookup("s", 0, "sf", CF_NN, want) is not None  # refresh 0
    ero.insert("s", 2, "sf", CF_NN, want, frames)  # evicts LRU of the tier
    assert ero.lookup("s", 1, "sf", CF_NN, want) is None
    assert ero.lookup("s", 0, "sf", CF_NN, want) is not None


def test_server_cache_policy_erosion(served):
    vs, cfg = served
    from repro.serving import recovery_rank_for
    with VStoreServer(vs, cfg, workers=1, cache_policy="erosion") as srv:
        rank = srv.cache.recovery_rank
        assert rank == recovery_rank_for(cfg, vs.spec)
        assert rank["sf_g"] == float("inf")  # golden never evicted first
        assert any(v < float("inf") for v in rank.values())
        # the flag changes eviction policy, not results
        res = srv.submit("A", "jackson", [0, 1], 0.8).result()
        assert res.items == run_query(vs, cfg, "A", "jackson", [0, 1],
                                      0.8).items
    with pytest.raises(ValueError):
        VStoreServer(vs, cfg, cache_policy="mru")


def test_erosion_admission_reject_no_churn():
    """A decode ranked cheaper than everything resident is refused (False),
    not admitted-then-immediately-evicted — otherwise every cheap-format
    decode would churn insert/evict while callers believe it cached."""
    rng = np.random.default_rng(2)
    frames = rng.integers(0, 255, (4, 16, 16), dtype=np.uint8)
    want = np.arange(4)
    ero = DecodedSegmentCache(2 * frames.nbytes,
                              recovery_rank={"sf_dear": 0.9,
                                             "sf_cheap": 0.1})
    assert ero.insert("s", 0, "sf_dear", CF_NN, want, frames)
    assert ero.insert("s", 1, "sf_dear", CF_NN, want, frames)
    assert not ero.insert("s", 2, "sf_cheap", CF_NN, want, frames)
    assert ero.lookup("s", 0, "sf_dear", CF_NN, want) is not None
    assert ero.lookup("s", 1, "sf_dear", CF_NN, want) is not None
    assert ero.lookup("s", 2, "sf_cheap", CF_NN, want) is None
    assert ero.stats.evictions == 0
    assert ero.stats.admission_rejects == 1
