"""Checkpointing + fault tolerance: atomic publish, crash recovery resumes
to an identical state, garbage collection, straggler watchdog."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import (StragglerWatchdog, TrainSupervisor,
                               checkpoint_steps, latest_step,
                               restore_checkpoint, save_checkpoint)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 8)),
            "opt": {"mu": jnp.zeros((8, 8)), "step": jnp.asarray(3)}}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 10, t)
    step, got = restore_checkpoint(str(tmp_path), jax.eval_shape(lambda: t))
    assert step == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmp_path):
    t = _tree()
    for s in (5, 10, 15, 20):
        save_checkpoint(str(tmp_path), s, t, keep=2)
    assert latest_step(str(tmp_path)) == 20
    assert checkpoint_steps(str(tmp_path)) == [15, 20]


def test_latest_survives_partial_write(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 5, t)
    # simulate a crash mid-save: stray temp dir must not break restore
    os.makedirs(str(tmp_path / ".tmp-step-6"))
    assert latest_step(str(tmp_path)) == 5
    step, _ = restore_checkpoint(str(tmp_path), jax.eval_shape(lambda: t))
    assert step == 5


def test_shape_mismatch_rejected(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path),
                           jax.eval_shape(lambda: {"w": jnp.zeros((5,))}))


def test_supervisor_crash_resume(tmp_path):
    """A training run killed mid-flight resumes from the last checkpoint and
    ends in exactly the state of an uninterrupted run."""

    def step_fn(state, step):
        state = {"x": state["x"] + 1.0}
        return state, {"x": float(state["x"])}

    init = {"x": jnp.zeros(())}
    like = jax.eval_shape(lambda: init)

    sup = TrainSupervisor(str(tmp_path / "a"), step_fn, like, ckpt_every=4)
    with pytest.raises(RuntimeError):
        sup.run(init, total_steps=20, fail_at=10)
    # crashed at step 10; LATEST is step 8
    assert latest_step(str(tmp_path / "a")) == 8
    _, state, hist = sup.run(init, total_steps=20)  # resumes, no fail
    assert float(state["x"]) == 20.0
    assert hist[0]["step"] == 8  # resumed, not restarted

    ref = TrainSupervisor(str(tmp_path / "b"), step_fn, like, ckpt_every=4)
    _, ref_state, _ = ref.run(init, total_steps=20)
    assert float(ref_state["x"]) == float(state["x"])


def test_straggler_watchdog():
    wd = StragglerWatchdog(window=8, tolerance=2.0)
    for i in range(8):
        assert not wd.record(i, 1.0)
    assert wd.record(8, 5.0)          # 5x median -> straggler
    assert not wd.record(9, 1.1)      # normal again
    assert len(wd.events) == 1 and wd.events[0].ratio == pytest.approx(5.0)
    # straggler did not poison the baseline window
    assert wd._median() == pytest.approx(1.0, abs=0.2)
