"""Cross-segment batched consumption: batched cascades are bit-exact with
the per-segment path while issuing strictly fewer ``op.detect`` calls;
``BatchedConsumer`` scatter/padding mechanics; ``retrieve_many`` fusion;
friendly config lookup errors."""

import functools
import tempfile

import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.analytics.batch import (DEFAULT_BATCH_SHAPES, BatchedConsumer,
                                   _MIN_SLOT_GAP)
from repro.analytics.operators import OPERATORS, Operator
from repro.analytics.query import _active_frame_mask, run_query
from repro.analytics.scene import generate_segment
from repro.core.knobs import FidelityOption, IngestSpec
from repro.launch.vserve import demo_config
from repro.serving import run_pipelined
from repro.videostore import VideoStore

N_SEGS = 4
CF_FAST = FidelityOption("good", 1.0, 270, 1 / 2)


@functools.cache
def _built_store():
    # cached module-level (not a pytest fixture) so the hypothesis property
    # test can share it without tripping fixture health checks
    root = tempfile.mkdtemp(prefix="repro_batched_")
    spec = IngestSpec()
    cfg = demo_config()
    vs = VideoStore(root, spec)
    vs.set_formats(cfg.storage_formats())
    for seg in range(N_SEGS):
        frames, _ = generate_segment("jackson", seg, spec)
        vs.ingest_segment("jackson", seg, frames)
    # an all-black stream: the first cascade stage activates nothing, so
    # later stages exercise the empty-activation path
    n, h, w = spec.resolve(FidelityOption())
    for seg in range(2):
        vs.ingest_segment("blank", seg, np.zeros((n, h, w), np.uint8))
    return vs, cfg


@pytest.fixture(scope="module")
def store_and_config():
    return _built_store()


def _stage_key(res):
    return [(s.op, s.frames, s.segments_scanned, s.items)
            for s in res.stages]


# ---------------------------------------------------------------------------
# batched == per-segment, across executors and batch sizes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("query,accuracy", [("A", 0.8), ("B", 0.8),
                                            ("A", 0.9)])
def test_batched_matches_per_segment(store_and_config, query, accuracy):
    vs, cfg = store_and_config
    segs = list(range(N_SEGS))
    seq = run_query(vs, cfg, query, "jackson", segs, accuracy)
    for bs in (1, 2, 3, N_SEGS, N_SEGS + 3):
        bat = run_query(vs, cfg, query, "jackson", segs, accuracy,
                        batch_segments=bs)
        assert bat.items == seq.items
        assert _stage_key(bat) == _stage_key(seq)
        for s, b in zip(seq.stages, bat.stages):
            assert b.detect_calls <= s.detect_calls
            if bs > 1 and b.segments_scanned > 1:
                assert b.detect_calls < s.detect_calls
            assert b.batched_frames >= b.frames
    pip = run_pipelined(vs, cfg, query, "jackson", segs, accuracy,
                        prefetch_depth=2, batch_segments=3)
    assert pip.items == seq.items
    assert _stage_key(pip) == _stage_key(seq)


def test_batched_strictly_fewer_calls(store_and_config):
    """On a multi-segment stage the batched path must merge dispatches."""
    vs, cfg = store_and_config
    segs = list(range(N_SEGS))
    seq = run_query(vs, cfg, "B", "jackson", segs, 0.8)
    bat = run_query(vs, cfg, "B", "jackson", segs, 0.8,
                    batch_segments=N_SEGS)
    assert sum(b.detect_calls for b in bat.stages) < \
        sum(s.detect_calls for s in seq.stages)
    assert all(s.detect_calls == s.segments_scanned
               for s in seq.stages if s.frames)


def test_batch_segments_validation_and_fallback(store_and_config):
    vs, cfg = store_and_config
    with pytest.raises(ValueError):
        run_query(vs, cfg, "A", "jackson", [0], 0.8, batch_segments=-2)
    with pytest.raises(ValueError):
        run_pipelined(vs, cfg, "A", "jackson", [0], 0.8, batch_segments=-1)
    # batch_segments=0 is the true per-segment baseline: no padding, one
    # detect per consumed segment
    seq = run_query(vs, cfg, "B", "jackson", list(range(N_SEGS)), 0.8)
    pip = run_pipelined(vs, cfg, "B", "jackson", list(range(N_SEGS)), 0.8,
                        batch_segments=0)
    assert pip.items == seq.items
    for s, p in zip(seq.stages, pip.stages):
        assert p.detect_calls == s.detect_calls
        assert p.batched_frames == 0


def test_empty_activation(store_and_config):
    """A stream where stage 1 activates nothing: later stages consume zero
    frames and issue zero detect calls, batched and not."""
    vs, cfg = store_and_config
    seq = run_query(vs, cfg, "A", "blank", [0, 1], 0.8)
    bat = run_query(vs, cfg, "A", "blank", [0, 1], 0.8, batch_segments=2)
    pip = run_pipelined(vs, cfg, "A", "blank", [0, 1], 0.8)
    assert seq.items == bat.items == pip.items == set()
    for res in (seq, bat, pip):
        assert res.stages[1].frames == 0 and res.stages[2].frames == 0
    assert bat.stages[1].detect_calls == 0
    assert bat.stages[2].detect_calls == 0


@settings(max_examples=5, deadline=None)
@given(bs=st.integers(1, 6), n_take=st.integers(1, N_SEGS))
def test_batched_equivalence_property(bs, n_take):
    vs, cfg = _built_store()
    segs = list(range(n_take))
    seq = run_query(vs, cfg, "B", "jackson", segs, 0.9)
    bat = run_query(vs, cfg, "B", "jackson", segs, 0.9, batch_segments=bs)
    pip = run_pipelined(vs, cfg, "B", "jackson", segs, 0.9,
                        batch_segments=bs)
    assert bat.items == seq.items == pip.items
    assert _stage_key(bat) == _stage_key(seq) == _stage_key(pip)


# ---------------------------------------------------------------------------
# BatchedConsumer mechanics
# ---------------------------------------------------------------------------

class _Recorder(Operator):
    """Echoes one item per frame carrying its bucket, recording call
    shapes — exposes padding, fusion, and scatter directly."""
    name = "recorder"

    def __init__(self):
        self.calls = []

    def detect(self, frames_u8, cf, spec, positions=None):
        self.calls.append(frames_u8.shape)
        bsz = max(1, spec.fps // 2)
        # skip all-zero frames so padding rows are distinguishable
        return {("rec", int(p) // bsz, i)
                for i, p in enumerate(positions)
                if frames_u8[i].any()}


def test_consumer_scatter_and_padding():
    spec = IngestSpec()
    consumer = BatchedConsumer(spec)
    rng = np.random.default_rng(0)
    batch = []
    for seg, n in ((3, 5), (7, 1), (11, 9)):
        frames = rng.integers(1, 255, (n, 8, 8), dtype=np.uint8)
        pos = np.sort(rng.choice(spec.frames_per_segment, n, replace=False))
        batch.append((seg, frames, pos))
    op = _Recorder()
    per_seg, stats = consumer.consume(op, FidelityOption(), batch)
    assert stats.detect_calls == 1 and len(op.calls) == 1
    assert op.calls[0][0] in DEFAULT_BATCH_SHAPES  # padded to a static shape
    assert stats.frames == 15 and stats.batched_frames == op.calls[0][0]
    assert set(per_seg) == {3, 7, 11}
    bsz = max(1, spec.fps // 2)
    for (seg, frames, pos) in batch:
        got_buckets = {it[1] for it in per_seg[seg]}
        assert got_buckets == {int(p) // bsz for p in pos}  # exact scatter
        assert len(per_seg[seg]) == len(frames)  # no padding leakage


def test_consumer_empty_and_oversize_batches():
    spec = IngestSpec()
    consumer = BatchedConsumer(spec, shapes=(4, 8))
    op = _Recorder()
    per_seg, stats = consumer.consume(op, FidelityOption(), [])
    assert per_seg == {} and stats.detect_calls == 0
    # segments never split across chunks: 3 segments of 3 frames with an
    # 8-frame cap go as (3+3 padded to 8) + (3 padded to 4)
    rng = np.random.default_rng(1)
    batch = [(s, rng.integers(1, 255, (3, 8, 8), dtype=np.uint8),
              np.arange(3) * 4) for s in range(3)]
    per_seg, stats = consumer.consume(op, FidelityOption(), batch)
    assert [c[0] for c in op.calls] == [8, 4]
    assert stats.detect_calls == 2 and stats.batched_frames == 12
    assert all(len(v) == 3 for v in per_seg.values())


def test_single_frame_tail_diff_stays_empty():
    """Per-segment Diff on a single frame returns nothing; the batched call
    concatenates single-frame segments with others, and the slot gap must
    keep every cross-segment pair below threshold."""
    spec = IngestSpec()
    consumer = BatchedConsumer(spec)
    diff = OPERATORS["diff"]
    cf = FidelityOption()
    rng = np.random.default_rng(2)
    _, h, w = spec.resolve(cf)
    # extreme contrast between neighbours: black, white, black ...
    batch = [(s, np.full((1, h, w), 255 * (s % 2), np.uint8),
              np.array([0])) for s in range(6)]
    per_seg, stats = consumer.consume(diff, cf, batch)
    assert stats.detect_calls == 1
    assert all(items == set() for items in per_seg.values())
    # and the per-segment reference agrees
    for seg, frames, pos in batch:
        assert diff.detect(frames, cf, spec, positions=pos) == set()


def test_slot_gap_suppresses_cross_segment_diff():
    spec = IngestSpec()
    consumer = BatchedConsumer(spec)
    assert consumer._stride >= spec.frames_per_segment + _MIN_SLOT_GAP
    assert consumer._stride % max(1, spec.fps // 2) == 0
    assert _MIN_SLOT_GAP > 1.0 / OPERATORS["diff"].threshold


def test_active_frame_mask_empty_positions_bool():
    spec = IngestSpec()
    mask = _active_frame_mask(np.array([], np.int64), {1, 2}, spec)
    assert mask.dtype == np.bool_ and mask.size == 0
    mask = _active_frame_mask(np.array([], np.int64), None, spec)
    assert mask.dtype == np.bool_


# ---------------------------------------------------------------------------
# retrieve_many
# ---------------------------------------------------------------------------

def test_retrieve_many_bit_exact(store_and_config):
    vs, cfg = store_and_config
    sf_id = cfg.subscription(CF_FAST)
    segs = list(range(N_SEGS))
    many, cost = vs.retrieve_many("jackson", segs, sf_id, CF_FAST)
    assert len(many) == N_SEGS
    for seg, got in zip(segs, many):
        direct, _ = vs.retrieve_direct("jackson", seg, sf_id, CF_FAST)
        assert got.dtype == direct.dtype and np.array_equal(got, direct)
    assert cost["frames"] == sum(len(f) for f in many)
    assert vs.retrieve_many("jackson", [], sf_id, CF_FAST)[0] == []


def test_retrieve_many_routes_through_attached_retriever(store_and_config):
    vs, cfg = store_and_config
    seen = []

    def spy(stream, seg, sf_id, cf):
        seen.append(seg)
        return vs.retrieve_direct(stream, seg, sf_id, cf)

    sf_id = cfg.subscription(CF_FAST)
    vs.attach_retriever(spy)
    try:
        many, _ = vs.retrieve_many("jackson", [0, 2], sf_id, CF_FAST)
    finally:
        vs.attach_retriever(None)
    assert seen == [0, 2] and len(many) == 2


# ---------------------------------------------------------------------------
# friendly config lookup errors
# ---------------------------------------------------------------------------

def test_config_lookup_error_lists_available(store_and_config):
    _vs, cfg = store_and_config
    with pytest.raises(KeyError) as ei:
        cfg.consumption_format("nn", 0.123)
    msg = str(ei.value)
    assert "0.123" in msg and "profiled ops" in msg and "nn" in msg
    with pytest.raises(KeyError) as ei:
        cfg.consumer_speed("nosuchop", 0.8)
    assert "nosuchop" in str(ei.value) and "0.8" in str(ei.value)


# ---------------------------------------------------------------------------
# adaptive batch-shape ladder (profiler-derived)
# ---------------------------------------------------------------------------

def test_derive_shapes_monotone_in_overhead():
    from repro.analytics.batch import derive_shapes
    cheap = derive_shapes(0.0, 1e-4)          # dispatch ~free: fine ladder
    dear = derive_shapes(5e-2, 1e-4)          # dispatch-dominated: coarse
    for shapes in (cheap, dear):
        assert shapes == tuple(sorted(set(shapes)))
        assert shapes[0] == 8 and shapes[-1] == 256
        assert all(s % 8 == 0 for s in shapes)
    assert len(dear) <= len(cheap)
    # step ratios grow with the breakeven batch
    assert max(b / a for a, b in zip(dear, dear[1:])) >= \
        max(b / a for a, b in zip(cheap, cheap[1:]))
    with pytest.raises(ValueError):
        derive_shapes(1e-3, 0.0)
    with pytest.raises(ValueError):
        derive_shapes(1e-3, 1e-4, min_shape=0)


def test_derive_shapes_static_set_keeps_jit_cache_stable():
    """The derived ladder is a *static* set: any batch size maps to one of
    its rungs (or the exact oversize), so per-(op, cf) jit entries stay
    bounded by the rung count — same stability contract as the fixed
    power-of-two ladder."""
    from repro.analytics.batch import BatchedConsumer, derive_shapes
    spec = IngestSpec()
    shapes = derive_shapes(1e-3, 1e-4, max_shape=64)
    consumer = BatchedConsumer(spec, shapes=shapes)
    padded = {consumer._pad_to(n) for n in range(1, 65)}
    assert padded <= set(shapes)
    assert len(padded) <= len(shapes)


def test_run_query_with_derived_shapes_bit_exact(store_and_config):
    from repro.analytics.batch import derive_shapes
    vs, cfg = store_and_config
    segs = list(range(N_SEGS))
    base = run_query(vs, cfg, "A", "jackson", segs, 0.8)
    for shapes in (derive_shapes(0.0, 1e-4),
                   derive_shapes(5e-2, 1e-4)):
        got = run_query(vs, cfg, "A", "jackson", segs, 0.8,
                        batch_segments=4, batch_shapes=shapes)
        assert got.items == base.items


def test_profiler_dispatch_overhead_feeds_ladder():
    from repro.analytics.batch import derive_shapes
    from repro.core.profiler import Profiler
    prof = Profiler(n_segments=1, repeats=2)
    overhead, per_frame = prof.dispatch_overhead("diff", n_big=32)
    assert overhead >= 0 and per_frame > 0
    runs0 = prof.stats.consumption_runs
    again = prof.dispatch_overhead("diff", n_big=32)
    assert again == (overhead, per_frame)          # memoized
    assert prof.stats.consumption_runs == runs0    # no re-measure
    shapes = derive_shapes(overhead, per_frame)
    assert shapes[0] >= 8 and shapes[-1] == 256
