"""Shared consumption scheduler (continuous cross-query batching):
concurrent queries through one scheduler return items bit-identical to
sequential ``run_query`` (property-tested over mixed ops, accuracies and
overlapping/disjoint segment sets, Diff included); duplicate work dedups at
frame granularity with exact leader-attributed accounting; a lone low-rate
unit meets the max-wait bound under duplicate-heavy load on another queue;
SLO deadlines reorder admission within a queue (EDF) without changing any
query's items."""

import functools
import tempfile
import threading
import time

import numpy as np
from _hyp_compat import given, settings, st

from repro.analytics.query import run_query
from repro.analytics.scene import generate_segment
from repro.core.knobs import FidelityOption, IngestSpec
from repro.launch.vserve import demo_config
from repro.serving import ConsumptionScheduler, VStoreServer
from repro.videostore import VideoStore

N_SEGS = 4


@functools.cache
def _built_store():
    # cached module-level (not a pytest fixture) so the hypothesis property
    # test can share it without tripping fixture health checks
    root = tempfile.mkdtemp(prefix="repro_sched_")
    spec = IngestSpec()
    cfg = demo_config()
    vs = VideoStore(root, spec)
    vs.set_formats(cfg.storage_formats())
    for seg in range(N_SEGS):
        frames, _ = generate_segment("jackson", seg, spec)
        vs.ingest_segment("jackson", seg, frames)
    return vs, cfg


@functools.cache
def _golden(query: str, segs: tuple, acc: float):
    vs, cfg = _built_store()
    return run_query(vs, cfg, query, "jackson", list(segs), acc).items


# ---------------------------------------------------------------------------
# cross-query bit-exactness (the tentpole invariant)
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.lists(
    st.tuples(st.sampled_from(["A", "B"]),        # A starts with Diff
              st.sampled_from([(0, 1), (2, 3), (1, 2), (0, 1, 2, 3)]),
              st.sampled_from([0.8, 0.9])),
    min_size=2, max_size=6))
def test_concurrent_scheduler_matches_sequential(subs):
    """N concurrent queries — overlapping and disjoint segment sets, both
    ops, both accuracies — through the shared scheduler return exactly the
    items sequential ``run_query`` produces for each."""
    vs, cfg = _built_store()
    with VStoreServer(vs, cfg, workers=4, max_inflight=16, collapse=False,
                      cross_query_batching=True) as srv:
        tickets = [srv.submit(q, "jackson", list(sg), acc, block=True)
                   for q, sg, acc in subs]
        results = [t.result(120) for t in tickets]
        stats = srv.stats()
    for (q, sg, acc), res in zip(subs, results):
        assert res.items == _golden(q, sg, acc), (q, sg, acc)
    assert stats["failed"] == 0
    # everything enqueued was dispatched (nothing stranded at close)
    assert stats["sched_units"] == stats["sched_enqueued"]
    assert stats["sched_queue_depth"] == 0


def test_dedup_shares_detects_across_queries():
    """(A, 0.8) and (A, 0.9) resolve to the same CFs in demo_config:
    distinct live keys (whole-query collapsing can't fuse them) but
    identical per-frame work — the scheduler's frame-granular dedup must
    fire, and leader-attributed shares must sum to the true fused cost."""
    vs, cfg = _built_store()
    segs = list(range(N_SEGS))
    subs = [("A", "jackson", segs, 0.8), ("A", "jackson", segs, 0.9),
            ("B", "jackson", segs, 0.8), ("B", "jackson", segs, 0.9)] * 4
    with VStoreServer(vs, cfg, workers=4, max_inflight=len(subs),
                      collapse=False, cross_query_batching=True,
                      batch_max_wait_ms=50.0) as srv:
        results = srv.run_batch(subs)
        stats = srv.stats()
    for (q, _s, sg, acc), res in zip(subs, results):
        assert res.items == _golden(q, tuple(sg), acc)
    assert stats["sched_deduped"] > 0
    assert stats["sched_fusion_ratio"] > 0
    # exactly one owner per unit: per-query detect-call shares sum to the
    # scheduler's fused total, no double counting through shared futures
    share_sum = sum(s.detect_calls for r in results for s in r.stages)
    assert share_sum == stats["sched_detect_calls"]
    frame_sum = sum(s.frames for r in results for s in r.stages)
    assert frame_sum == stats["sched_frames"]
    # fused calls beat one call per unit (the per-query batching floor)
    assert stats["sched_detect_calls"] < stats["sched_units"]
    # the same gauges surface through the metrics registry snapshot
    assert stats["gauges"]["fusion_ratio"] == stats["sched_fusion_ratio"]
    assert stats["gauges"]["queue_depth"] == 0


# ---------------------------------------------------------------------------
# fairness: the max-wait bound under duplicate-heavy load
# ---------------------------------------------------------------------------

class _CountingOp:
    """Stand-in operator: records fused call sizes, emits nothing."""

    def __init__(self, sleep_s: float = 0.0):
        self.sleep_s = sleep_s
        self.calls: list[int] = []
        self._mu = threading.Lock()

    def detect(self, frames, cf, spec, positions=None):
        if self.sleep_s:
            time.sleep(self.sleep_s)
        with self._mu:
            self.calls.append(len(frames))
        return set()


def test_lone_unit_meets_max_wait_bound():
    """A lone unit on a quiet queue resolves within the max-wait bound even
    while another queue is flooded with duplicate-heavy traffic and the
    lone queue's producer is still registered (the batching timer, not the
    producer gate, must release it).  Oldest-deadline-first means hog units
    enqueued *after* the lone unit cannot preempt it."""
    spec = IngestSpec()
    max_wait_s = 0.04
    sched = ConsumptionScheduler(spec, max_wait_ms=max_wait_s * 1e3)
    hog_op, lone_op = _CountingOp(sleep_s=0.004), _CountingOp()
    cf = FidelityOption("good", 1.0, 270, 1 / 2)
    frames = np.zeros((8, 16, 16), np.uint8)
    pos = np.arange(8, dtype=np.int64)
    stop = threading.Event()

    def flood():
        sched.producer_inc("hog", cf)
        try:
            i = 0
            while not stop.is_set():
                # fresh segment ids: real queued work, not dedup no-ops
                sched.enqueue("hog", hog_op, cf, "s", i, "sf", frames, pos)
                i += 1
                time.sleep(0.001)
        finally:
            sched.producer_dec("hog", cf)

    try:
        t = threading.Thread(target=flood, daemon=True)
        t.start()
        time.sleep(0.1)  # let the hog queue build and churn
        sched.producer_inc("lone", cf)  # producer held: timer must fire
        t0 = time.perf_counter()
        fut, owner = sched.enqueue("lone", lone_op, cf, "q", 0, "sf",
                                   frames, pos)
        items, _share = fut.result(timeout=10)
        waited = time.perf_counter() - t0
        sched.producer_dec("lone", cf)
        stop.set()
        t.join(5)
        assert owner and items == set()
        assert lone_op.calls == [8]
        # bound: its own max-wait, plus at most two in-flight hog batches
        # the serial dispatcher may finish first, plus scheduling slack
        assert waited < max_wait_s + 2 * 0.004 + 0.25, waited
        assert hog_op.calls, "flood never dispatched"
    finally:
        stop.set()
        sched.close()


# ---------------------------------------------------------------------------
# SLO-aware admission: deadlines reorder within the queue (EDF)
# ---------------------------------------------------------------------------

def test_deadline_admission_is_edf_within_queue():
    """Tight-deadline work is admitted ahead of laxer work that arrived
    earlier; attaching a duplicate with an earlier deadline tightens the
    shared unit (it serves its most urgent waiter); a laxer duplicate
    changes nothing."""
    sched = ConsumptionScheduler(IngestSpec(), max_wait_ms=10_000.0)
    op = _CountingOp()
    cf = FidelityOption("good", 1.0, 270, 1 / 2)
    frames = np.zeros((4, 16, 16), np.uint8)
    pos = np.arange(4, dtype=np.int64)
    sched.producer_inc("op", cf)  # gate dispatch so order is observable
    try:
        sched.enqueue("op", op, cf, "s", 0, "sf", frames, pos)  # max-wait
        sched.enqueue("op", op, cf, "s", 1, "sf", frames, pos, deadline_s=5.0)
        sched.enqueue("op", op, cf, "s", 2, "sf", frames, pos, deadline_s=1.0)
        with sched._mu:
            order = [u.key[1] for u in sched._queues[("op", cf)]]
        assert order == [2, 1, 0]  # EDF, not arrival order
        # duplicate of seg 0 with a tighter deadline: the shared unit moves
        fut, owner = sched.enqueue("op", op, cf, "s", 0, "sf", frames, pos,
                                   deadline_s=0.5)
        assert not owner  # attached, not re-queued
        with sched._mu:
            order = [u.key[1] for u in sched._queues[("op", cf)]]
        assert order == [0, 2, 1]
        # a laxer duplicate must NOT relax the unit back
        sched.enqueue("op", op, cf, "s", 1, "sf", frames, pos,
                      deadline_s=60.0)
        with sched._mu:
            order = [u.key[1] for u in sched._queues[("op", cf)]]
        assert order == [0, 2, 1]
    finally:
        sched.producer_dec("op", cf)
        sched.close()


def test_deadline_overrides_max_wait_release():
    """A unit with a tight SLO deadline dispatches when *its* deadline
    expires, not the queue-wide max-wait — even while its producer is
    still registered."""
    sched = ConsumptionScheduler(IngestSpec(), max_wait_ms=10_000.0)
    op = _CountingOp()
    cf = FidelityOption("good", 1.0, 270, 1 / 2)
    frames = np.zeros((8, 16, 16), np.uint8)
    pos = np.arange(8, dtype=np.int64)
    sched.producer_inc("op", cf)
    try:
        t0 = time.perf_counter()
        fut, owner = sched.enqueue("op", op, cf, "s", 0, "sf", frames, pos,
                                   deadline_s=0.05)
        items, _share = fut.result(timeout=10)
        waited = time.perf_counter() - t0
        assert owner and items == set()
        assert op.calls == [8]
        assert waited < 2.0, waited  # nowhere near the 10s max-wait
    finally:
        sched.producer_dec("op", cf)
        sched.close()


def test_slo_deadline_queries_bit_identical():
    """deadline_ms threads request -> server -> executor -> scheduler and
    only reorders work: items stay exactly the sequential answers."""
    vs, cfg = _built_store()
    segs = list(range(N_SEGS))
    with VStoreServer(vs, cfg, workers=2, cross_query_batching=True) as srv:
        t1 = srv.submit("A", "jackson", segs, 0.8, block=True,
                        deadline_ms=5.0)
        t2 = srv.submit("B", "jackson", segs, 0.8, block=True)
        r1, r2 = t1.result(120), t2.result(120)
    assert r1.items == _golden("A", tuple(segs), 0.8)
    assert r2.items == _golden("B", tuple(segs), 0.8)


def test_enqueue_after_close_raises():
    sched = ConsumptionScheduler(IngestSpec(), max_wait_ms=1.0)
    sched.close()
    try:
        sched.enqueue("op", _CountingOp(), FidelityOption(), "s", 0, "sf",
                      np.zeros((1, 8, 8), np.uint8), np.zeros(1, np.int64))
        raise AssertionError("enqueue after close must raise")
    except RuntimeError:
        pass
