"""Auto-discovered wire round-trip property: ``from_wire(to_wire(x))``
must reproduce ``x`` for *every* wire form in the tree.

Discovery is the same syntactic net the ``wire-field`` lint pass casts:
any class with a ``to_wire``/``from_wire`` method pair, plus the
``<name>_to_wire``/``<name>_from_wire`` function pairs in
``cluster/wire.py``.  A new wire form without a factory here fails
``test_every_discovered_form_has_a_factory`` — you cannot add one and
dodge the round-trip check.
"""

import ast
import os

import pytest

from _hyp_compat import given, settings, st
from repro.analytics.query import QueryCost, QueryResult, StageStats
from repro.cluster import wire
from repro.core.coalesce import SFNode
from repro.core.configure import DerivedConfig
from repro.core.consumption import Consumer, ConsumerPlan
from repro.core.erosion import ErosionPlan
from repro.core.knobs import CodingOption, FidelityOption, IngestSpec
from repro.index import SketchRecord
from repro.obs.trace import Span
from repro.serving.server import QueryRequest

SRC = os.path.normpath(
    os.path.join(os.path.dirname(__file__), os.pardir, "src"))


def discover_wire_forms():
    """-> sorted names: 'Class' for method pairs, 'name()' for function
    pairs in cluster/wire.py."""
    forms = set()
    for dirpath, dirnames, filenames in os.walk(SRC):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fn in filenames:
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            with open(path, encoding="utf-8") as fh:
                tree = ast.parse(fh.read(), filename=path)
            for st_ in tree.body:
                if isinstance(st_, ast.ClassDef):
                    names = {m.name for m in st_.body
                             if isinstance(m, ast.FunctionDef)}
                    if {"to_wire", "from_wire"} <= names:
                        forms.add(st_.name)
                elif (isinstance(st_, ast.FunctionDef)
                      and path.replace("\\", "/").endswith(
                          "cluster/wire.py")
                      and st_.name.endswith("_to_wire")
                      and not st_.name.startswith("_")):
                    forms.add(st_.name[:-len("_to_wire")] + "()")
    return sorted(forms)


def _cf():
    return FidelityOption("good", 0.75, 360, 0.5)


def _plan():
    return ConsumerPlan(Consumer("nn", 0.9), _cf(), 0.95, 30.0)


def _config():
    p = _plan()
    node = SFNode(_cf(), CodingOption("fast", 10), [p], golden=True)
    return DerivedConfig(plans=[p], nodes=[node], coalesce_log=None)


def _stage():
    return StageStats(op="nn", cf=_cf(), sf_id="sf1", retrieve_s=0.125,
                      consume_s=0.5, frames=32, items=3,
                      segments_scanned=2, detect_calls=1,
                      batched_frames=64)


def _span():
    return Span("decode", 7 << 32 | 1, 7 << 32 | 2, 7 << 32 | 1,
                0.25, 0.125, 4242, 99, {"kind": "hit", "bytes": 4096})


def _eq_roundtrip(x):
    assert type(x).from_wire(x.to_wire()) == x


def _wire_eq_roundtrip(x):
    """For forms without value equality: the wire dict must be a fixed
    point of from_wire ∘ to_wire."""
    w = x.to_wire()
    assert type(x).from_wire(w).to_wire() == w


def _check_config():
    # ConsumerPlan is eq=False (plans key subscription maps by identity),
    # so the check is: the wire dict is a fixed point of from/to
    w = wire.config_to_wire(_config())
    assert wire.config_to_wire(wire.config_from_wire(w)) == w


def _check_spec():
    s = IngestSpec(96, 160, 8, 4, 720)
    assert wire.spec_from_wire(wire.spec_to_wire(s)) == s


def _check_erosion_plan():
    plan = ErosionPlan(k=0.5, ages=[0, 1, 7],
                       fractions=[{0: 0.5}, {1: 0.25, 2: 1.0}, {}],
                       overall_speed=[1.0, 2.0, 4.0],
                       daily_bytes=[100.0, 50.0, 0.0],
                       total_bytes=150.0, feasible=True)
    assert wire.erosion_plan_from_wire(
        wire.erosion_plan_to_wire(plan)) == plan


# name -> round-trip check; keep in sync with every discovered form
FACTORIES = {
    "QueryCost": lambda: _eq_roundtrip(
        QueryCost(decode_bytes=4096, decode_chunks=3, decoded_frames=96,
                  detect_frames=64, detect_calls=2, cache_hits=1,
                  cache_richer_hits=1, cache_inflight_hits=1,
                  cache_misses=2, queue_wait_s=0.125, sched_wait_s=0.25,
                  deadline_ms=50.0, deadline_slack_s=0.01,
                  deadline_met=False)),
    "QueryRequest": lambda: _eq_roundtrip(
        QueryRequest("A", "cam0", [1, 2, 3], 0.9, block=True,
                     trace_id=7, parent_span=9, deadline_ms=12.5,
                     slo_class="interactive")),
    "QueryResult": lambda: _eq_roundtrip(
        QueryResult(items={(3, 0.5, "car"), (4, 0.25, "bus")},
                    stages=[_stage()], video_seconds=12.0, wall_s=0.75,
                    pruned_segments=3, pruned_bytes=4096,
                    pruned_conservative=1,
                    cost=QueryCost(decode_bytes=64, detect_frames=8))),
    "SketchRecord": lambda: _eq_roundtrip(
        SketchRecord(op="diff", cf=_cf(), sf_id="sf1", accuracy=0.9,
                     n_buckets=8, buckets=(1, 3, 5), items=7,
                     quantiles=(1.0, 2.0, 3.0, 4.0))),
    "StageStats": lambda: _eq_roundtrip(_stage()),
    # Span has __slots__ and identity equality — compare wire dicts
    "Span": lambda: _wire_eq_roundtrip(_span()),
    "config()": _check_config,
    "spec()": _check_spec,
    "erosion_plan()": _check_erosion_plan,
}


def test_every_discovered_form_has_a_factory():
    discovered = discover_wire_forms()
    missing = [f for f in discovered if f not in FACTORIES]
    assert not missing, (
        f"wire forms without a round-trip factory: {missing} — add one "
        f"to FACTORIES in {__file__}")


@pytest.mark.parametrize("form", sorted(FACTORIES))
def test_roundtrip(form):
    FACTORIES[form]()


def test_erosion_plan_fraction_keys_are_ints_after_roundtrip():
    plan = ErosionPlan(k=1.0, ages=[0], fractions=[{3: 0.125}],
                       overall_speed=[1.0], daily_bytes=[1.0],
                       total_bytes=1.0, feasible=False)
    back = wire.erosion_plan_from_wire(wire.erosion_plan_to_wire(plan))
    [frac] = back.fractions
    assert all(isinstance(k, int) for k in frac)


def test_config_roundtrip_preserves_shared_plan_refs():
    w = wire.config_to_wire(_config())
    cfg = wire.config_from_wire(w)
    # the node's plan list must reference the config's plan objects
    assert cfg.nodes[0].plans[0] is cfg.plans[0]
    assert wire.config_to_wire(cfg) == w


def test_roundtrip_survives_msgpack_frame():
    """End-to-end: the wire dict also has to survive pack/unpack (msgpack
    turns tuples into lists and is strict about key types)."""
    req = QueryRequest("B", "cam7", [0, 5], 0.8)
    assert QueryRequest.from_wire(wire.unpack(wire.pack(req.to_wire()))) \
        == req
    span = _span()
    assert Span.from_wire(
        wire.unpack(wire.pack(span.to_wire()))).to_wire() == span.to_wire()


@settings(max_examples=25, deadline=None)
@given(st.text(max_size=8), st.lists(st.integers(0, 10_000), max_size=8),
       st.floats(0.0, 1.0, allow_nan=False), st.booleans(),
       st.integers(0, 2**63 - 1), st.integers(0, 2**63 - 1))
def test_query_request_roundtrip_property(stream, segments, accuracy,
                                          block, trace_id, parent_span):
    req = QueryRequest("A", stream, segments, accuracy, block,
                       trace_id, parent_span)
    assert QueryRequest.from_wire(req.to_wire()) == req


@settings(max_examples=25, deadline=None)
@given(st.dictionaries(st.text(min_size=1, max_size=8),
                       st.one_of(st.integers(-1000, 1000), st.booleans(),
                                 st.text(max_size=8)),
                       max_size=4))
def test_span_attrs_roundtrip_property(attrs):
    span = Span("s", 1, 2, 0, 0.0, 1.0, 1, 1, attrs)
    assert Span.from_wire(span.to_wire()).to_wire() == span.to_wire()
