"""repro.obs: trace spans, mergeable histograms, drift detection."""

import json
import threading

import pytest

from repro.analytics.query import QueryResult, StageStats
from repro.core.knobs import IngestSpec
from repro.launch.vserve import demo_config
from repro.obs import (DriftDetector, Histogram, MetricsRegistry,
                       Span, Tracer, merge_reports)
from repro.obs import trace as obstrace


def _tracer(**kw):
    tr = Tracer(**kw)
    tr.enabled = True
    return tr


# -- span facility ------------------------------------------------------------

def test_disabled_span_is_shared_noop():
    tr = Tracer()
    assert tr.enabled is False
    cm = tr.span("x", bytes=1)
    assert cm is obstrace._NOOP
    with cm as sp:
        sp.set(more=2)  # no-op, no error
    assert tr.spans() == []
    # the module-level helper takes the same fast path
    assert obstrace.TRACER.enabled is False
    assert obstrace.span("y") is obstrace._NOOP


def test_nesting_parents_and_attrs():
    tr = _tracer(pid=7)
    with tr.span("outer", key="a") as outer:
        with tr.span("inner") as inner:
            inner.set(bytes=42)
    spans = tr.spans()
    assert [s.name for s in spans] == ["inner", "outer"]  # exit order
    si, so = spans
    assert si.trace_id == so.trace_id
    assert si.parent_id == so.span_id
    assert so.parent_id == 0
    assert si.attrs == {"bytes": 42}
    assert so.attrs == {"key": "a"}
    assert si.pid == 7 and si.dur >= 0.0


def test_siblings_share_trace():
    tr = _tracer()
    with tr.span("root") as root:
        with tr.span("a"):
            pass
        with tr.span("b"):
            pass
    a, b, r = tr.spans()
    assert a.parent_id == b.parent_id == root.span_id
    assert a.trace_id == b.trace_id == r.trace_id


def test_ring_is_bounded():
    tr = _tracer(capacity=8)
    for i in range(50):
        with tr.span(f"s{i}"):
            pass
    got = tr.spans()
    assert len(got) == 8
    assert got[-1].name == "s49"  # newest survive


def test_thread_stacks_isolated():
    tr = _tracer()
    seen = {}

    def work(label):
        with tr.span(f"root-{label}"):
            with tr.span(f"leaf-{label}") as leaf:
                seen[label] = leaf.trace_id

    ts = [threading.Thread(target=work, args=(i,)) for i in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert len(set(seen.values())) == 4  # each thread its own trace
    by_name = {s.name: s for s in tr.spans()}
    for i in range(4):
        assert (by_name[f"leaf-{i}"].parent_id
                == by_name[f"root-{i}"].span_id)


def test_activate_adopts_remote_context():
    tr = _tracer()
    with tr.activate(111, 222):
        assert tr.current() == (111, 222)
        with tr.span("child"):
            pass
    child = tr.spans()[0]
    assert child.trace_id == 111 and child.parent_id == 222
    # falsy trace id: no adoption, spans start fresh traces
    with tr.activate(0, 0):
        with tr.span("fresh"):
            pass
    fresh = tr.spans()[-1]
    assert fresh.trace_id != 111 and fresh.parent_id == 0


def test_exception_unwinds_span_stack():
    tr = _tracer()
    with pytest.raises(RuntimeError):
        with tr.span("outer"):
            with tr.span("inner"):
                raise RuntimeError("boom")
    # both spans recorded, stack fully unwound
    assert [s.name for s in tr.spans()] == ["inner", "outer"]
    assert getattr(tr._tls, "stack", []) == []


def test_orphaned_manual_enter_heals():
    # a stage body that raises between explicit __enter__/__exit__ pairs
    # (executor style) must not leak stack entries into a reused thread
    tr = _tracer()
    outer = tr.span("outer")
    outer.__enter__()
    tr.span("leaked").__enter__()  # never exited
    outer.__exit__(None, None, None)
    assert getattr(tr._tls, "stack") == []
    with tr.span("next"):
        pass
    assert tr.spans()[-1].parent_id == 0  # not parented under leftovers


def test_take_removes_single_trace():
    tr = _tracer()
    with tr.activate(5, 0):
        with tr.span("mine"):
            pass
    with tr.span("other"):
        pass
    out = tr.take(5)
    assert [d["n"] for d in out] == ["mine"]
    assert [s.name for s in tr.spans()] == ["other"]
    assert tr.take(5) == []


def test_wire_roundtrip_through_cluster_pack():
    from repro.cluster.wire import pack, unpack
    tr = _tracer(pid=3)
    with tr.span("s", cf="cf_x", bytes=12345, arr=(1, 2)):
        pass
    sp = tr.spans()[0]
    d = unpack(pack(sp.to_wire()))
    back = Span.from_wire(d)
    assert (back.trace_id, back.span_id, back.parent_id) == \
        (sp.trace_id, sp.span_id, sp.parent_id)  # 64-bit ids survive
    assert back.name == "s" and back.pid == 3
    assert back.attrs["bytes"] == 12345
    assert back.attrs["arr"] == "(1, 2)"  # non-scalars coerced to str


def test_absorb_rebases_clock_and_pid():
    remote = _tracer(pid=99)
    with remote.span("remote-work"):
        pass
    wire = [s.to_wire() for s in remote.drain()]
    t0_remote = wire[0]["t0"]
    local = _tracer(pid=0)
    n = local.absorb(wire, pid=2, offset=10.0)
    assert n == 1
    sp = local.spans()[0]
    assert sp.pid == 2
    assert sp.t0 == pytest.approx(t0_remote + 10.0)
    assert sp.span_id == wire[0]["s"]  # ids kept verbatim


def test_cross_process_ids_do_not_collide():
    a, b = Tracer(), Tracer()
    ids = {a.new_id() for _ in range(1000)} | {b.new_id()
                                              for _ in range(1000)}
    assert len(ids) == 2000


def test_chrome_export_structure(tmp_path):
    tr = _tracer(pid=1)
    with tr.span("parent"):
        with tr.span("child", bytes=7):
            pass
    path = tmp_path / "trace.json"
    n = obstrace.export_trace(str(path), tracer=tr,
                              process_names={1: "worker"})
    assert n == 2
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert meta and meta[0]["args"]["name"] == "worker"
    xs = {e["name"]: e for e in evs if e["ph"] == "X"}
    assert set(xs) == {"parent", "child"}
    assert xs["child"]["args"]["parent"] == xs["parent"]["args"]["span"]
    assert xs["child"]["args"]["bytes"] == 7
    assert xs["parent"]["ts"] >= 0 and xs["parent"]["dur"] > 0
    # export is non-destructive
    assert len(tr.spans()) == 2


# -- histograms ---------------------------------------------------------------

def test_histogram_percentiles_basic():
    h = Histogram()
    for _ in range(100):
        h.observe(0.01)
    s = h.snapshot()
    assert s["count"] == 100
    assert s["mean"] == pytest.approx(0.01)
    assert s["p50"] == pytest.approx(0.01, rel=0.5)
    assert s["min"] == s["max"] == pytest.approx(0.01)


def test_histogram_merge_skewed_shards_p95():
    # satellite regression: two shards with wildly different latency
    # distributions must roll up to the p95 of the UNION, not an average
    # of the per-shard p95s
    fast, slow = Histogram(), Histogram()
    for _ in range(150):
        fast.observe(0.001)
    for _ in range(50):
        slow.observe(0.4)
    merged = Histogram.merge([fast.snapshot(), slow.snapshot()])
    assert merged["count"] == 200
    # 75% of samples at 1ms -> p50 stays fast
    assert merged["p50"] == pytest.approx(0.001, rel=0.6)
    # p95 lands in the slow shard's bucket (0.2, 0.5]; averaging the two
    # per-shard p95s (~0.001 and ~0.4) would misreport ~0.2
    assert 0.25 <= merged["p95"] <= 0.5
    assert merged["max"] == pytest.approx(0.4)
    assert merged["sum"] == pytest.approx(150 * 0.001 + 50 * 0.4)


def test_histogram_merge_rejects_mismatched_bounds():
    a = Histogram()
    b = Histogram(bounds=(0.1, 1.0))
    a.observe(0.2)
    b.observe(0.2)
    with pytest.raises(ValueError):
        Histogram.merge([a.snapshot(), b.snapshot()])


def test_histogram_merge_empty_and_none():
    merged = Histogram.merge([])
    assert merged["count"] == 0 and merged["p95"] == 0.0
    h = Histogram()
    h.observe(0.05)
    merged = Histogram.merge([None, {}, h.snapshot()])
    assert merged["count"] == 1


def test_metrics_registry():
    m = MetricsRegistry()
    m.inc("queries")
    m.inc("queries", 2)
    m.inc("video_seconds", 1.5)
    m.set_gauge("inflight", 3)
    m.observe("latency_s", 0.02)
    snap = m.snapshot()
    assert snap["counters"]["queries"] == 3
    assert snap["counters"]["video_seconds"] == pytest.approx(1.5)
    assert snap["gauges"]["inflight"] == 3
    assert snap["histograms"]["latency_s"]["count"] == 1
    assert m.value("queries") == 3


# -- drift detection ----------------------------------------------------------

def _result(op, sf_id, cf, segments, consume_s, retrieve_s=0.0):
    st = StageStats(op=op, cf=cf, sf_id=sf_id)
    st.segments_scanned = segments
    st.consume_s = consume_s
    st.retrieve_s = retrieve_s
    return QueryResult(items=set(), stages=[st],
                       video_seconds=segments * 4.0, wall_s=consume_s)


def test_drift_detector_flags_slow_consumption():
    cfg = demo_config()
    spec = IngestSpec()
    det = DriftDetector(cfg, spec, tolerance=3.0)
    plan = cfg.plans[0]
    op, acc, cf = plan.consumer.op, plan.consumer.target, plan.cf
    sf_id = cfg.node_id(0)
    # observed at the expected speed: no drift
    ok_consume = 10 * spec.segment_seconds / plan.speed
    det.observe(acc, _result(op, sf_id, cf, 10, ok_consume))
    rep = det.report()
    knob = f"{op}@{acc:g}"
    assert rep["consumption"][knob]["drifted"] is False
    assert rep["drifted"] is False
    # now 10x slower than profiled, repeatedly (EMA converges past 1/3)
    for _ in range(20):
        det.observe(acc, _result(op, sf_id, cf, 10, 10 * ok_consume))
    rep = det.report()
    assert rep["consumption"][knob]["drifted"] is True
    assert rep["consumption"][knob]["ratio"] < 1 / 3
    assert rep["drifted"] is True


def test_drift_retrieval_slow_only():
    cfg = demo_config()
    spec = IngestSpec()
    plan = cfg.plans[0]
    sf_id = cfg.node_id(0)
    det = DriftDetector(cfg, spec,
                        retrieval_speeds={(sf_id, plan.cf.name()): 100.0},
                        tolerance=3.0)
    acc, cf, op = plan.consumer.target, plan.cf, plan.consumer.op
    # retrieval far FASTER than profiled (cache hits): not drift
    for _ in range(20):
        det.observe(acc, _result(op, sf_id, cf, 10,
                                 consume_s=0.0,
                                 retrieve_s=10 * spec.segment_seconds
                                 / 10000.0))
    key = f"{sf_id}:{plan.cf.name()}"
    assert det.report()["retrieval"][key]["drifted"] is False
    # far slower: drift
    det2 = DriftDetector(cfg, spec,
                         retrieval_speeds={(sf_id, plan.cf.name()): 100.0},
                         tolerance=3.0)
    for _ in range(20):
        det2.observe(acc, _result(op, sf_id, cf, 10,
                                  consume_s=0.0,
                                  retrieve_s=10 * spec.segment_seconds
                                  / 2.0))
    assert det2.report()["retrieval"][key]["drifted"] is True


def test_merge_reports_keeps_worst_shard():
    row_ok = {"expected_x": 100.0, "observed_x": 90.0, "ratio": 0.9,
              "samples": 5, "drifted": False}
    row_bad = {"expected_x": 100.0, "observed_x": 10.0, "ratio": 0.1,
               "samples": 5, "drifted": True}
    merged = merge_reports([
        {"consumption": {"nn@0.9": row_ok}, "retrieval": {},
         "drifted": False},
        {"consumption": {"nn@0.9": row_bad}, "retrieval": {},
         "drifted": True},
        {},  # a shard with no observations yet
    ])
    assert merged["consumption"]["nn@0.9"]["ratio"] == 0.1
    assert merged["drifted"] is True


def test_invalid_tolerance_rejected():
    with pytest.raises(ValueError):
        DriftDetector(demo_config(), IngestSpec(), tolerance=1.0)


# -- request trace context ----------------------------------------------------

def test_query_request_trace_fields_roundtrip():
    from repro.cluster.wire import pack, unpack
    from repro.serving.server import QueryRequest
    req = QueryRequest("A", "jackson", [0, 1], 0.9,
                       trace_id=(7 << 32) | 1, parent_span=(7 << 32) | 2)
    back = QueryRequest.from_wire(unpack(pack(req.to_wire())))
    assert back.trace_id == req.trace_id
    assert back.parent_span == req.parent_span
    # old-style frames without trace fields default to "no context"
    legacy = {"query": "A", "stream": "s", "segments": [0],
              "accuracy": 0.8}
    assert QueryRequest.from_wire(legacy).trace_id == 0
