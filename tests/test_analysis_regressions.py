"""Regression tests for the concurrency defects the invariant linter
(``repro.analysis``) surfaced — each was a real unguarded-shared-state or
lock-scope bug fixed in the same change that introduced the linter.

1. ``ErosionExecutor``'s age ledger was completely unlocked: ingest
   threads ``note_ingested`` concurrently with ``advance``/``apply``.
2. ``ClusterIngest`` grants/budget were read by router pool threads
   (reattach callbacks) while ``rebalance`` replaced them — and pushing
   grants under the lock could self-deadlock through that callback.
3. ``IngestScheduler.stats()`` held ``_mu`` across calls into the
   fallback chain's and histograms' own locks (cross-component edges).
4. ``Histogram.percentile`` read bucket state without the lock.
"""

import dataclasses
import threading

from repro.cluster.ingest import ClusterIngest
from repro.core.erosion import ErosionPlan
from repro.ingest.erosion_exec import ErosionExecutor
from repro.obs.metrics import Histogram


# -- 1. erosion executor ledger ------------------------------------------------

@dataclasses.dataclass
class _ErodeResult:
    segments: int = 0
    bytes: int = 0
    chunks: int = 0
    chunk_bytes: int = 0


class _StubBackend:
    compactions = 0
    dead_bytes = 0


class _StubStore:
    """Duck-typed stand-in: erode() reports every requested segment gone."""

    def __init__(self):
        self.backend = _StubBackend()

    def erode(self, stream, sf_id, segments, count, seed):
        return _ErodeResult(segments=count, bytes=count * 100)

    def available_segments(self, stream, sf_id):
        return []


def _executor():
    plan = ErosionPlan(k=1.0, ages=[1], fractions=[{0: 1.0}],
                       overall_speed=[1.0], daily_bytes=[0.0],
                       total_bytes=0.0, feasible=True)
    return ErosionExecutor(_StubStore(), plan, ["sf1", "sf_g"],
                           compact=False)


def test_erosion_ledger_survives_concurrent_ingest_and_advance():
    ex = _executor()
    n_threads, n_notes = 4, 200
    errors = []

    def ingest_side(tid):
        try:
            for i in range(n_notes):
                ex.note_ingested(f"cam{tid}", i)
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    def clock_side():
        try:
            for _ in range(20):
                ex.advance()
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=ingest_side, args=(t,))
               for t in range(n_threads)]
    threads.append(threading.Thread(target=clock_side))
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert errors == []
    # no note was lost: every append landed in some cohort
    with ex._mu:
        total = sum(len(v) for v in ex._cohorts.values())
    assert total == n_threads * n_notes


def test_erosion_apply_erodes_snapshot_exactly_once():
    ex = _executor()
    for i in range(10):
        ex.note_ingested("cam0", i)
    rep = ex.advance()  # age 1, fraction 1.0 -> all 10 in format sf1
    assert rep.segments == 10
    # a second apply at the same day must not re-erode (the delta fold
    # into _eroded is what a racing apply used to corrupt)
    assert ex.apply().segments == 0
    assert ex.stats()["eroded_segments"] == 10


# -- 2. cluster ingest grants --------------------------------------------------

class _StubHost:
    def __init__(self, idx, router):
        self.idx = idx
        self.router = router
        self.on_reattach = []
        self.set_budgets = []
        self.reattaching = False

    def call(self, op, **kw):
        return self.router._op(self, op, kw)

    def call_retry(self, op, **kw):
        return self.router._op(self, op, kw)


class _StubRouter:
    """In-process router double: stats report fixed backlog; every
    ``set_budget`` push simulates the worst case — the worker respawned
    mid-RPC, so the reattach callback fires *during* the push."""

    def __init__(self, n_shards=3):
        self.n_shards = n_shards
        self.hosts = [_StubHost(i, self) for i in range(n_shards)]

    def _op(self, host, op, kw):
        if op == "set_budget":
            host.set_budgets.append(kw["budget_x"])
            if not host.reattaching:  # one respawn per push, like ShardHost
                host.reattaching = True
                try:
                    for cb in host.on_reattach:
                        cb(host)
                finally:
                    host.reattaching = False
        return None

    def broadcast(self, op, **kw):
        assert op == "stats"
        return [{"ingest": {"video_seconds": 10.0 * (h.idx + 1),
                            "debt_s": 1.0}} for h in self.hosts]


def test_reattach_callback_during_grant_push_does_not_deadlock():
    router = _StubRouter()
    done = []

    def drive():
        ci = ClusterIngest(router, budget_x=2.0)
        ci.rebalance()
        done.append(ci)

    t = threading.Thread(target=drive, daemon=True)
    t.start()
    t.join(timeout=30)
    assert done, "grant push deadlocked against the reattach callback"
    [ci] = done
    # the reattach push re-read the committed grant, not a torn one
    for host in router.hosts:
        assert host.set_budgets[-1] == ci.grant_for(host.idx)


def test_concurrent_rebalance_and_grant_reads_stay_consistent():
    router = _StubRouter()
    ci = ClusterIngest(router, budget_x=2.0)
    errors = []
    stop = threading.Event()

    def reader():
        try:
            while not stop.is_set():
                snap = ci.grants_snapshot()
                assert len(snap) == router.n_shards
                for i in range(router.n_shards):
                    g = ci.grant_for(i)
                    assert g is None or g >= 0.0
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    readers = [threading.Thread(target=reader) for _ in range(3)]
    for t in readers:
        t.start()
    try:
        for _ in range(25):
            ci.rebalance()
    finally:
        stop.set()
        for t in readers:
            t.join()
    assert errors == []
    assert ci.stats()["rebalances"] == 25


# -- 3. scheduler stats lock scope ---------------------------------------------

def _mini_config():
    from repro.core.coalesce import SFNode
    from repro.core.configure import DerivedConfig
    from repro.core.consumption import Consumer, ConsumerPlan
    from repro.core.knobs import GOLDEN_CODING, RAW, FidelityOption
    cf_lo = FidelityOption("bad", 1.0, 180, 1 / 5)
    cf_hi = FidelityOption("best", 1.0, 540, 1 / 2)
    plans = [ConsumerPlan(Consumer("diff", 0.8), cf_lo, 0.85, 2000.0),
             ConsumerPlan(Consumer("nn", 0.8), cf_hi, 0.82, 30.0)]
    nodes = [SFNode(cf_lo, RAW, [plans[0]]),
             SFNode(cf_hi, GOLDEN_CODING, [plans[1]], golden=True)]

    class _Log:
        nodes = []
        ingest_cost = storage_cost = 0.0
        rounds = []
        budget_met = True

    _Log.nodes = nodes
    return DerivedConfig(plans=plans, nodes=nodes, coalesce_log=_Log())


def test_scheduler_stats_does_not_hold_mu_across_component_locks(tmp_path):
    """stats() must treat the fallback chain's and histograms' locks as
    leaves: snapshotting them while holding the scheduler's ``_mu`` was
    the cross-component lock-order edge the static pass flagged."""
    from repro.core.knobs import IngestSpec
    from repro.ingest import IngestScheduler
    from repro.videostore import VideoStore

    cfg = _mini_config()
    vs = VideoStore(str(tmp_path / "vs"), IngestSpec())
    vs.set_formats(cfg.storage_formats())
    sched = IngestScheduler(vs, cfg, budget_x=0.0)

    seen = {}
    orig = sched.fallback.stats

    def probe():
        seen["mu_held_during_fallback_stats"] = sched._mu.locked()
        return orig()

    sched.fallback.stats = probe
    out = sched.stats()
    assert seen["mu_held_during_fallback_stats"] is False
    assert "fallback" in out and "golden_hist" in out


# -- 4. histogram percentile ---------------------------------------------------

def test_percentile_reads_consistent_state_under_writes():
    h = Histogram()
    stop = threading.Event()
    errors = []

    def writer():
        try:
            v = 0.0001
            while not stop.is_set():
                h.observe(v)
                v = v * 1.7 if v < 20.0 else 0.0001
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    t = threading.Thread(target=writer)
    t.start()
    try:
        for _ in range(300):
            p = h.percentile(0.9)  # the non-precomputed-q path
            assert 0.0 <= p <= 30.0
    finally:
        stop.set()
        t.join()
    assert errors == []
