"""Codec substrate: roundtrip exactness (RAW), size/quality monotonicity,
chunk-skip equivalence, fidelity conversion shapes."""

import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.codec import (convert_fidelity, decode_segment, encode_raw,
                         encode_segment, segment_info)
from repro.codec.transform import materialize, sample_indices
from repro.core.knobs import (QUALITY_QUANT_SCALE, FidelityOption,
                              IngestSpec)


def _frames(n=16, h=48, w=64, seed=0):
    rng = np.random.default_rng(seed)
    t = np.arange(n)[:, None, None]
    y = np.arange(h)[None, :, None]
    x = np.arange(w)[None, None, :]
    f = 120 + 50 * np.sin((x + 2 * t) / 9) + 30 * np.cos((y - t) / 7)
    return (f + rng.normal(0, 3, (n, h, w))).clip(0, 255).astype(np.uint8)


def test_raw_roundtrip_exact():
    f = _frames()
    blob = encode_raw(f)
    assert np.array_equal(decode_segment(blob), f)
    assert segment_info(blob)["raw"] is True


def test_size_monotone_in_quality():
    f = _frames()
    sizes = [len(encode_segment(f, quant_scale=QUALITY_QUANT_SCALE[q],
                                keyframe_interval=10, zstd_level=3))
             for q in ("best", "good", "bad", "worst")]
    assert sizes == sorted(sizes, reverse=True)


def test_size_monotone_in_zstd_level():
    f = _frames()
    s_fast = len(encode_segment(f, quant_scale=2.0, keyframe_interval=10,
                                zstd_level=1))
    s_slow = len(encode_segment(f, quant_scale=2.0, keyframe_interval=10,
                                zstd_level=19))
    assert s_slow <= s_fast


def test_psnr_monotone_in_quality():
    f = _frames()
    psnrs = []
    for q in ("best", "good", "bad", "worst"):
        blob = encode_segment(f, quant_scale=QUALITY_QUANT_SCALE[q],
                              keyframe_interval=10, zstd_level=3)
        rec = decode_segment(blob).astype(float)
        mse = np.mean((rec - f.astype(float)) ** 2)
        psnrs.append(10 * np.log10(255 ** 2 / max(mse, 1e-9)))
    assert all(a >= b - 0.5 for a, b in zip(psnrs, psnrs[1:]))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([5, 10, 50]),
       st.integers(1, 16))
def test_chunk_skip_exact(seed, kint, n_want):
    f = _frames(seed=seed)
    blob = encode_segment(f, quant_scale=2.0, keyframe_interval=kint,
                          zstd_level=1)
    full = decode_segment(blob)
    rng = np.random.default_rng(seed)
    want = np.sort(rng.choice(len(f), size=min(n_want, len(f)),
                              replace=False))
    part = decode_segment(blob, want)
    assert np.array_equal(part, full[want])


def test_convert_fidelity_shapes_and_r1():
    spec = IngestSpec()
    f = _frames(spec.frames_per_segment, spec.height, spec.width)
    hi = FidelityOption()
    lo = FidelityOption("bad", 0.75, 180, 1 / 5)
    out = np.asarray(convert_fidelity(f, hi, lo, spec))
    assert out.shape == spec.resolve(lo)
    with pytest.raises(ValueError):
        convert_fidelity(out, lo, hi, spec)  # R1: poorer can't serve richer


def test_sample_indices_monotone_density():
    for n in (30, 32, 240):
        prev = 0
        for s in (1 / 30, 1 / 5, 1 / 2, 2 / 3, 1.0):
            idx = sample_indices(n, s)
            assert len(idx) >= prev and (np.diff(idx) >= 0).all()
            prev = len(idx)
        assert len(sample_indices(n, 1.0)) == n


def test_materialize_identity_at_golden():
    spec = IngestSpec()
    f = _frames(spec.frames_per_segment, spec.height, spec.width)
    out = np.asarray(materialize(f, FidelityOption(), spec))
    assert np.array_equal(out, f)
