"""Optional-hypothesis shim: property tests skip cleanly on a bare
interpreter while the plain tests in the same module still run.

Usage: ``from _hyp_compat import given, settings, st``.
"""

import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # pragma: no cover - exercised on bare interpreters
    class _InertStrategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _InertStrategies()

    def given(*a, **k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*a, **k):
        return lambda f: f
