"""Real multi-device execution (8 host devices via subprocess): pjit'd
train step on a (2,2) mesh, EP-MoE numerics, elastic checkpoint restore
across different meshes."""

import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.filterwarnings("ignore")

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(ROOT, "src"))
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=420)


def test_pjit_train_step_executes():
    r = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_config
        from repro.models import init_params
        from repro.train import AdamWConfig, init_opt_state, make_train_step
        from repro.distributed import sharding as SH
        from repro.launch.mesh import make_test_mesh

        cfg = get_config("smollm-135m").reduced(n_layers=2, d_model=64,
                                                n_heads=4, vocab=256)
        mesh = make_test_mesh(data=2, model=2)
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt_cfg = AdamWConfig(lr=1e-3)
        opt = init_opt_state(params, opt_cfg)
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                         cfg.vocab_size),
            "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0,
                                         cfg.vocab_size),
        }
        p_sh = SH.shardings(mesh, SH.param_specs(params, mesh, "tp"))
        o_sh = {"mu": SH.shardings(mesh, SH.moment_specs(params, mesh)),
                "nu": SH.shardings(mesh, SH.moment_specs(params, mesh)),
                "step": SH.shardings(mesh, P())}
        b_sh = SH.shardings(mesh, SH.batch_specs(batch, mesh))
        params = jax.device_put(params, p_sh)
        opt = jax.device_put(opt, o_sh)
        batch = jax.device_put(batch, b_sh)
        step = jax.jit(make_train_step(cfg, opt_cfg, moe_dispatch="dense"),
                       in_shardings=(p_sh, o_sh, b_sh),
                       out_shardings=(p_sh, o_sh, None))
        losses = []
        for _ in range(3):
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
        assert losses[2] < losses[0], losses
        print("OK", losses)
    """)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout


def test_ep_moe_matches_dense_on_mesh():
    r = _run("""
        import jax, jax.numpy as jnp
        from repro.configs import ARCHS
        from repro.models.moe import init_moe, moe_dense, moe_ep
        from repro.distributed import context
        from repro.launch.mesh import make_test_mesh

        cfg = ARCHS["qwen2-moe-a2.7b"].reduced(n_experts=8)
        mesh = make_test_mesh(data=2, model=4)
        context.set_mesh(mesh)
        p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model)) * 0.3
        y_dense = moe_dense(p, x, cfg)
        y_ep = moe_ep(p, x, cfg, capacity_factor=8.0)
        err = float(jnp.max(jnp.abs(y_ep - y_dense)))
        assert err < 1e-5, err
        print("OK", err)
    """)
    assert r.returncode == 0, r.stdout + r.stderr


def test_elastic_restore_across_meshes(tmp_path):
    r = _run(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.distributed import save_checkpoint, restore_checkpoint
        from repro.launch.mesh import make_test_mesh

        tree = {{"w": jax.random.normal(jax.random.PRNGKey(0), (16, 16))}}
        mesh_a = make_test_mesh(data=4, model=2)
        sh_a = {{"w": NamedSharding(mesh_a, P("data", "model"))}}
        tree_a = jax.device_put(tree, sh_a)
        save_checkpoint({str(tmp_path)!r}, 1, tree_a)

        mesh_b = make_test_mesh(data=2, model=2)   # different topology
        sh_b = {{"w": NamedSharding(mesh_b, P("model", "data"))}}
        step, got = restore_checkpoint({str(tmp_path)!r},
                                       jax.eval_shape(lambda: tree),
                                       shardings=sh_b)
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.asarray(tree["w"]))
        assert got["w"].sharding == sh_b["w"]
        print("OK elastic")
    """)
    assert r.returncode == 0, r.stdout + r.stderr
