"""Segment store + VideoStore: KV semantics, ingest/retrieve path, R1
enforcement, erosion execution, compaction."""

import numpy as np
import pytest

from repro.analytics.scene import generate_segment
from repro.core.knobs import (RAW, CodingOption, FidelityOption, IngestSpec,
                              StorageFormat)
from repro.videostore import SegmentStore, VideoStore


def test_segment_store_kv(tmp_path):
    s = SegmentStore(str(tmp_path / "kv"))
    s.put("a", b"xyz")
    s.put("b", b"\x00" * 1000)
    assert s.get("a") == b"xyz" and s.size_of("b") == 1000
    assert s.keys() == ["a", "b"] and "a" in s
    assert s.delete("a") and not s.delete("a")
    assert s.keys() == ["b"]
    s.flush()
    s2 = SegmentStore(str(tmp_path / "kv"))
    assert s2.get("b") == b"\x00" * 1000


def test_segment_store_compact(tmp_path):
    s = SegmentStore(str(tmp_path / "kv"))
    for i in range(20):
        s.put(f"k{i:02d}", bytes([i]) * 5000)
    for i in range(0, 20, 2):
        s.delete(f"k{i:02d}")
    s.compact()
    for i in range(1, 20, 2):
        assert s.get(f"k{i:02d}") == bytes([i]) * 5000
    assert len(s.keys()) == 10


def test_segment_store_concurrent_get_compact(tmp_path):
    """Readers racing compact() must never observe bytes from a stale shard
    layout (the index is rewritten while old shard files are replaced)."""
    import threading

    s = SegmentStore(str(tmp_path / "kv"))
    expected = {f"k{i:03d}": bytes([i % 251]) * (3000 + 17 * i)
                for i in range(40)}
    for k, v in expected.items():
        s.put(k, v)
    live = sorted(expected)[10:]  # survive the deletes below
    for k in sorted(expected)[:10]:
        s.delete(k)

    errors: list[str] = []
    stop = threading.Event()

    def reader():
        rng = np.random.default_rng()
        while not stop.is_set():
            k = live[int(rng.integers(len(live)))]
            got = s.get(k)
            if got != expected[k]:
                errors.append(f"{k}: {len(got)} bytes, wrong content")
                return

    threads = [threading.Thread(target=reader) for _ in range(4)]
    for t in threads:
        t.start()
    for _ in range(5):
        s.compact()
    stop.set()
    for t in threads:
        t.join()
    assert not errors, errors
    assert len(s.keys()) == len(live)


def test_segment_store_missing_shard_raises(tmp_path):
    """A genuinely missing shard file (no compaction in flight) must raise,
    not retry forever."""
    import os
    s = SegmentStore(str(tmp_path / "kv"))
    s.put("a", b"xyz")
    s.flush()
    for name in os.listdir(s.root):
        if name.startswith("shard-"):
            os.remove(os.path.join(s.root, name))
    with pytest.raises(FileNotFoundError):
        s.get("a")


@pytest.fixture
def store(tmp_path):
    spec = IngestSpec()
    vs = VideoStore(str(tmp_path / "vs"), spec)
    vs.set_formats({
        "sf_g": StorageFormat(FidelityOption(),
                              CodingOption("fast", 10)),
        "sf1": StorageFormat(FidelityOption("good", 1.0, 360, 1 / 2),
                             RAW),
    })
    for seg in range(2):
        frames, _ = generate_segment("jackson", seg, spec)
        vs.ingest_segment("jackson", seg, frames)
    return vs


def test_ingest_and_retrieve(store):
    spec = store.spec
    cf = FidelityOption("good", 1.0, 360, 1 / 2)
    frames, cost = store.retrieve("jackson", 0, "sf1", cf)
    assert frames.shape == spec.resolve(cf)
    assert cost["bytes"] > 0 and cost["frames"] == frames.shape[0]
    # richer SF serves poorer CF
    poorer = FidelityOption("bad", 0.75, 180, 1 / 5)
    frames2, _ = store.retrieve("jackson", 0, "sf_g", poorer)
    assert frames2.shape == spec.resolve(poorer)


def test_r1_enforced(store):
    too_rich = FidelityOption("best", 1.0, 720, 1.0)
    with pytest.raises(ValueError):
        store.retrieve("jackson", 0, "sf1", too_rich)


def test_meta_persistence(store, tmp_path):
    vs2 = VideoStore(store.root, store.spec)
    assert set(vs2.formats) == {"sf_g", "sf1"}
    assert vs2.formats["sf1"].coding.bypass


def test_erosion_exec(store):
    before = store.available_segments("jackson", "sf1")
    assert len(before) == 2
    size_of = {s: store.backend.size_of(f"jackson:sf1:{s:06d}")
               for s in before}
    res = store.erode("jackson", "sf1", 0.5)
    assert res.segments == 1 and len(res.victims) == 1
    assert res.bytes == size_of[res.victims[0]] > 0
    assert len(store.available_segments("jackson", "sf1")) == 1
    # golden untouched
    assert len(store.available_segments("jackson", "sf_g")) == 2


def test_ingest_stats(store):
    st = store.ingest_stats["jackson"]
    assert st.segments == 2
    assert st.stored_bytes == store.storage_bytes("jackson")
    assert st.cost_xrealtime(store.spec) > 0


def test_readonly_attach(tmp_path):
    """Read-only attach: reads work, every mutation raises, and load never
    sweeps orphans (that's the owning process's job)."""
    root = str(tmp_path / "ro")
    rw = SegmentStore(root)
    rw.put("a", b"alpha")
    rw.put("b", b"beta")
    rw.flush()
    # an unreferenced shard file a crashed compaction might leave behind
    orphan = f"{root}/shard-9999.bin"
    with open(orphan, "wb") as f:
        f.write(b"junk")
    ro = SegmentStore(root, readonly=True)
    assert ro.get("a") == b"alpha" and "b" in ro
    assert sorted(ro.keys()) == ["a", "b"]
    assert ro.total_bytes() == 9
    import os
    assert os.path.exists(orphan)  # not swept by the read-only attach
    import pytest as _pytest
    with _pytest.raises(RuntimeError):
        ro.put("c", b"x")
    with _pytest.raises(RuntimeError):
        ro.delete("a")
    with _pytest.raises(RuntimeError):
        ro.compact()
    ro.flush()  # no-op, must not raise
    assert SegmentStore(root).get("a") == b"alpha"  # rw load still clean
