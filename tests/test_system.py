"""End-to-end system behaviour: ingest -> auto-configure (table-driven) ->
store -> query, exercising the full data path the paper's Figure 1 draws."""

from repro.analytics.query import run_query
from repro.analytics.scene import generate_segment
from repro.core import derive_config
from repro.core.knobs import IngestSpec
from repro.core.profiler import Profiler
from repro.videostore import VideoStore


def test_full_lifecycle(tmp_path):
    spec = IngestSpec()
    prof = Profiler(spec, n_segments=1, repeats=1)
    cfg = derive_config(prof, ops=("diff", "snn"), accuracies=(0.7,))

    vs = VideoStore(str(tmp_path / "store"), spec)
    vs.set_formats(cfg.storage_formats())
    for seg in range(2):
        frames, _ = generate_segment("jackson", seg, spec)
        vs.ingest_segment("jackson", seg, frames)

    # every stored version exists, every consumer can be served
    for sf_id in cfg.storage_formats():
        assert vs.available_segments("jackson", sf_id) == [0, 1]
    for p in cfg.plans:
        frames, cost = vs.retrieve("jackson", 0, cfg.subscription(p.cf),
                                   p.cf)
        assert frames.shape == spec.resolve(p.cf)

    # a two-stage cascade runs on the derived configuration
    class _Q:
        pass
    from repro.analytics import query as Q
    Q.QUERIES["mini"] = ("diff", "snn")
    try:
        res = run_query(vs, cfg, "mini", "jackson", [0, 1], 0.7)
        assert res.pipelined_speed > 0
        assert len(res.stages) == 2
    finally:
        Q.QUERIES.pop("mini")
