"""Fused batched decode path: bit-exact equivalence against the seed
per-chunk scan decoder (property over want sets, keyframe intervals, blob
versions, and entropy coders), v1 back-compat, chunk-granular byte
accounting, batched multi-segment decode, Pallas-vs-jnp oracle checks, and
jit-cache stability for tail chunks."""

import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.codec import segment as S
from repro.codec import transform as T
from repro.codec.transform import temporal_indices
from repro.core.knobs import FidelityOption, IngestSpec


def _frames(n=16, h=48, w=64, seed=0):
    rng = np.random.default_rng(seed)
    t = np.arange(n)[:, None, None]
    y = np.arange(h)[None, :, None]
    x = np.arange(w)[None, None, :]
    f = 120 + 50 * np.sin((x + 2 * t) / 9) + 30 * np.cos((y - t) / 7)
    return (f + rng.normal(0, 3, (n, h, w))).clip(0, 255).astype(np.uint8)


def _encode(f, *, kint=5, version=None, qs=2.0, lvl=3):
    return S.encode_segment(f, quant_scale=qs, keyframe_interval=kint,
                            zstd_level=lvl, version=version)


# ---------------------------------------------------------------------------
# bit-exact equivalence with the seed scan decoder
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([3, 5, 10, 50]),
       st.integers(0, 16), st.sampled_from([1, 2]))
def test_batched_decode_matches_seed_scan(seed, kint, n_want, version):
    f = _frames(n=13, seed=seed)  # 13 !% kint exercises the tail chunk
    blob = _encode(f, kint=kint, version=version)
    rng = np.random.default_rng(seed)
    want = np.sort(rng.choice(len(f), size=min(n_want, len(f)),
                              replace=False))
    assert np.array_equal(S.decode_segment(blob, want),
                          S.decode_segment_scan(blob, want))
    assert np.array_equal(S.decode_segment(blob),
                          S.decode_segment_scan(blob))


@pytest.mark.parametrize("version", [1, 2])
def test_zlib_coder_roundtrip(version, monkeypatch):
    """Both blob versions stay self-describing under the zlib fallback."""
    monkeypatch.setattr(S, "zstandard", None)
    f = _frames()
    blob = _encode(f, version=version)
    assert S.segment_info(blob)["ec"] == "zlib"
    assert np.array_equal(S.decode_segment(blob),
                          S.decode_segment_scan(blob))


def test_repeated_and_empty_want():
    f = _frames()
    blob = _encode(f)
    full = S.decode_segment(blob)
    want = np.array([2, 2, 7, 7, 7, 12])  # temporal_indices can repeat
    assert np.array_equal(S.decode_segment(blob, want), full[want])
    out, info = S.decode_segment_ex(blob, np.empty(0, np.int64))
    assert out.shape == (0, 48, 64) and info["chunks"] == 0


# ---------------------------------------------------------------------------
# v1 back-compat + byte accounting
# ---------------------------------------------------------------------------

def test_v1_blob_backcompat():
    f = _frames()
    blob = _encode(f, version=1)
    info = S.segment_info(blob)
    assert "v" not in info and "spans" not in info
    full, cost = S.decode_segment_ex(blob)
    assert np.array_equal(full, S.decode_segment_scan(blob))
    # v1 must decompress the whole payload whatever the want set
    _, sparse_cost = S.decode_segment_ex(blob, np.array([0]))
    assert sparse_cost["bytes"] == cost["bytes"] == len(blob)


def test_v2_sparse_read_touches_fewer_bytes():
    f = _frames(n=32)
    blob = _encode(f, kint=5, version=2)
    info = S.segment_info(blob)
    header_bytes = len(blob) - sum(info["spans"])
    full, cost_full = S.decode_segment_ex(blob)
    assert cost_full["bytes"] == len(blob)  # dense touches everything
    part, cost = S.decode_segment_ex(blob, np.array([7]))
    assert np.array_equal(part, full[[7]])
    assert cost["chunks"] == 1
    assert cost["bytes"] == header_bytes + info["spans"][1]
    assert cost["bytes"] < len(blob) // 2


def test_decode_for_cost_from_single_parse(tmp_path):
    """VideoStore.decode_for reports touched bytes/chunks without a second
    segment_info parse, and sparse v2 reads are charged per chunk."""
    from repro.core.knobs import CodingOption, StorageFormat
    from repro.videostore import VideoStore

    spec = IngestSpec()
    vs = VideoStore(str(tmp_path), spec)
    sf = StorageFormat(FidelityOption(), CodingOption("fast", 5))
    vs.set_formats({"sf0": sf})
    f = _frames(spec.frames_per_segment, spec.height, spec.width)
    vs.ingest_segment("s", 0, f)
    blob_len = vs.backend.get("s:sf0:000000")
    dense, dcost = vs.decode_for("s", 0, "sf0", np.arange(len(f)))
    sparse, scost = vs.decode_for("s", 0, "sf0", np.array([3]))
    assert np.array_equal(sparse[0], dense[3])
    assert scost["chunks"] == 1 and dcost["chunks"] == -(-len(f) // 5)
    assert scost["bytes"] < dcost["bytes"] == len(blob_len)


# ---------------------------------------------------------------------------
# batched multi-segment decode
# ---------------------------------------------------------------------------

def test_decode_many_matches_per_blob():
    blobs = [_encode(_frames(seed=s), kint=5) for s in range(4)]
    want = np.array([0, 6, 11])
    outs, cost = S.decode_many(blobs, want)
    for blob, out in zip(blobs, outs):
        assert np.array_equal(out, S.decode_segment(blob, want))
    assert cost["dispatches"] == 1  # one fused jit call for all four
    assert cost["chunks"] == 4 * 3 and cost["frames"] == 4 * 3


def test_decode_many_dense_and_mixed_raw():
    coded = _encode(_frames(seed=1), kint=10)
    raw = S.encode_raw(_frames(seed=2))
    outs, cost = S.decode_many([coded, raw, coded], None)
    assert np.array_equal(outs[0], S.decode_segment(coded))
    assert np.array_equal(outs[1], S.decode_segment(raw))
    assert np.array_equal(outs[2], outs[0])
    assert cost["dispatches"] == 1  # raw needs no jit dispatch at all
    outs[1][0, 0, 0] ^= 0xFF  # raw fallback must also be writable


def test_retrieve_many_uses_batched_decode(tmp_path):
    from repro.core.knobs import CodingOption, StorageFormat
    from repro.videostore import VideoStore

    spec = IngestSpec()
    vs = VideoStore(str(tmp_path), spec)
    sf = StorageFormat(FidelityOption(), CodingOption("fast", 10))
    vs.set_formats({"sf0": sf})
    for seg in range(3):
        vs.ingest_segment("s", seg, _frames(spec.frames_per_segment,
                                            spec.height, spec.width,
                                            seed=seg))
    cf = FidelityOption("good", 1.0, 360, 1 / 2)
    many, cost = vs.retrieve_many("s", [0, 1, 2], "sf0", cf)
    for seg, out in enumerate(many):
        one, _ = vs.retrieve("s", seg, "sf0", cf)
        assert np.array_equal(out, one)
    assert cost["chunks"] > 0 and cost["bytes"] > 0


# ---------------------------------------------------------------------------
# raw-blob decode is writable
# ---------------------------------------------------------------------------

def test_raw_decode_returns_writable_copy():
    f = _frames()
    blob = S.encode_raw(f)
    out = S.decode_segment(blob)
    assert out.flags.writeable
    out += 1  # must not raise, must not corrupt the blob
    again = S.decode_segment(blob)
    assert np.array_equal(again, f)
    assert S.decode_segment_scan(blob).flags.writeable


# ---------------------------------------------------------------------------
# Pallas kernel wiring: oracle equivalence through the codec
# ---------------------------------------------------------------------------

@pytest.fixture
def _restore_backend():
    yield
    T.set_dct_backend("auto")


def test_pallas_backend_bit_identical(_restore_backend):
    f = _frames(n=6, h=16, w=24)
    T.set_dct_backend("jnp")
    blob_jnp = _encode(f, kint=3)
    dec_jnp = S.decode_segment(blob_jnp)
    T.set_dct_backend("pallas")  # interpret mode off-TPU
    blob_pl = _encode(f, kint=3)
    dec_pl = S.decode_segment(blob_jnp)
    assert blob_pl == blob_jnp          # encoder forward DCT matches
    assert np.array_equal(dec_pl, dec_jnp)  # fused residual IDCT matches


def test_ops_dispatch_follows_backend(_restore_backend):
    import jax.numpy as jnp

    from repro.kernels.dct8.ops import dct_dequantize, dct_quantize

    x = jnp.asarray(_frames(n=2, h=16, w=16), jnp.float32)
    for backend in ("jnp", "pallas"):
        T.set_dct_backend(backend)
        sym = dct_quantize(x, 2.0)
        rec = dct_dequantize(sym, 2.0)
        if backend == "jnp":
            base_sym, base_rec = np.asarray(sym), np.asarray(rec)
    np.testing.assert_array_equal(np.asarray(sym), base_sym)
    np.testing.assert_allclose(np.asarray(rec), base_rec, atol=1e-4)


def test_bad_backend_rejected():
    with pytest.raises(ValueError):
        T.set_dct_backend("cuda")


# ---------------------------------------------------------------------------
# jit-cache stability: tail chunks share the (k, h, w) compile
# ---------------------------------------------------------------------------

def test_tail_chunk_shares_jit_cache_entry():
    import jax

    if not hasattr(S._encode_chunk, "_cache_size"):
        pytest.skip("jit cache introspection unavailable on this jax")
    jax.clear_caches()
    f = _frames(n=13)  # 13 = 5 + 5 + 3: tail chunk shorter than k
    blob = _encode(f, kint=5)
    assert S._encode_chunk._cache_size() == 1
    S.decode_segment(blob)                    # 3 chunks -> padded to 4
    S.decode_segment(blob, np.array([1, 12]))  # 2 chunks -> padded to 2
    S.decode_segment(blob, np.array([0]))     # 1 chunk
    # one entry per padded chunk-count on the power-of-two ladder, never
    # one per raw tail shape
    assert S._chunk_residuals._cache_size() <= 3
    assert np.array_equal(S.decode_segment(blob),
                          S.decode_segment_scan(blob))


def test_pad_tail_does_not_change_real_symbols():
    """DPCM is causal: padding frames after the tail cannot change the
    stored symbols, so padded-encode == seed unpadded-encode."""
    f = _frames(n=13)
    import jax.numpy as jnp
    tail = f[10:13]
    sym_padded = np.asarray(S._encode_chunk(
        jnp.asarray(S._pad_tail(tail, 5), jnp.float32),
        jnp.float32(2.0)))[:3]
    sym_exact = np.asarray(S._encode_chunk(
        jnp.asarray(tail, jnp.float32), jnp.float32(2.0)))
    np.testing.assert_array_equal(sym_padded, sym_exact)


# ---------------------------------------------------------------------------
# end-to-end: query results identical on v1 and v2 blobs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("query", ["A", "B"])
def test_run_query_items_identical_v1_v2(query, tmp_path, monkeypatch):
    from repro.analytics.query import run_query
    from repro.analytics.scene import generate_segment
    from repro.launch.vserve import demo_config
    from repro.videostore import VideoStore

    spec = IngestSpec()
    cfg = demo_config()
    results = {}
    for version in (1, 2):
        monkeypatch.setattr(S, "DEFAULT_VERSION", version)
        vs = VideoStore(str(tmp_path / f"v{version}"), spec)
        vs.set_formats(cfg.storage_formats())
        for seg in range(3):
            frames, _ = generate_segment("jackson", seg, spec)
            vs.ingest_segment("jackson", seg, frames)
        results[version] = (
            run_query(vs, cfg, query, "jackson", [0, 1, 2], 0.8),
            run_query(vs, cfg, query, "jackson", [0, 1, 2], 0.8,
                      batch_segments=3))
    assert results[1][0].items == results[2][0].items
    assert results[1][1].items == results[2][0].items
    assert results[2][1].items == results[2][0].items


def test_sparse_sampling_decode_via_temporal_indices():
    """The chunk-skip driver (temporal_indices) composed with v2 spans: a
    1/30-sampled read of a 32-frame segment touches exactly one chunk."""
    spec = IngestSpec()
    f = _frames(spec.frames_per_segment, spec.height, spec.width)
    blob = _encode(f, kint=10, version=2)
    want = temporal_indices(FidelityOption(),
                            FidelityOption(sampling=1 / 30), spec)
    out, cost = S.decode_segment_ex(blob, want)
    assert np.array_equal(out, S.decode_segment_scan(blob, want))
    assert cost["chunks"] == len(np.unique(want // 10))
    assert cost["bytes"] < len(blob) // 2
