"""Self-tests for the runtime concurrency checker (mini-TSan).

Each test installs the tracer if the session hasn't (REPRO_ANALYSIS=1
sessions already have), injects a violation inside ``runtime.scoped()``
so the injected edges never leak into the session-end check, and
asserts the checker catches it.
"""

import threading
import time

import pytest

from repro.analysis import runtime


@pytest.fixture
def traced():
    installed_here = runtime.install()
    try:
        with runtime.scoped():
            runtime.reset()
            yield
    finally:
        if installed_here:
            runtime.uninstall()


def test_injected_lock_order_inversion_detected(traced):
    a = threading.Lock()
    b = threading.Lock()
    with a:
        with b:
            pass
    with b:
        with a:
            pass
    violations = runtime.check()
    assert any("lock-order cycle observed" in v for v in violations), \
        violations


def test_cross_thread_inversion_detected(traced):
    a = threading.Lock()
    b = threading.Lock()

    def thread_side():
        with a:
            with b:
                pass

    t = threading.Thread(target=thread_side)
    t.start()
    t.join()
    with b:
        with a:
            pass
    violations = runtime.check()
    assert any("lock-order cycle observed" in v for v in violations), \
        violations


def test_consistent_order_is_clean(traced):
    a = threading.Lock()
    b = threading.Lock()
    for _ in range(3):
        with a:
            with b:
                pass
    assert runtime.check() == []


def test_sleep_while_holding_lock_flagged(traced):
    mu = threading.Lock()
    with mu:
        time.sleep(0)
    violations = runtime.check()
    assert any("time.sleep" in v and "holding lock" in v
               for v in violations), violations


def test_sleep_without_lock_is_clean(traced):
    time.sleep(0)
    assert runtime.check() == []


def test_allow_block_suppresses_only_its_region(traced):
    mu = threading.Lock()
    with mu, runtime.allow_block("self-test: deliberate blocking"):
        time.sleep(0)
    assert runtime.check() == []
    # outside the region the same pattern is flagged again
    with mu:
        time.sleep(0)
    assert any("time.sleep" in v for v in runtime.check())


def test_allow_block_requires_justification():
    with pytest.raises(ValueError):
        runtime.allow_block("")
    with pytest.raises(ValueError):
        runtime.allow_block("   ")


def test_observed_edge_reversing_static_order_flagged(traced):
    a = threading.Lock()
    b = threading.Lock()
    sites = {}
    for lock, node in ((a, "T.a"), (b, "T.b")):
        path, _, line = lock.site.rpartition(":")
        sites[(path, int(line))] = node
    # static analysis says a -> b; observe only the reversal (no cycle
    # at runtime, so this is the static cross-check firing, not the
    # observed-cycle rule)
    with b:
        with a:
            pass
    violations = runtime.check(static_sites=sites,
                               static_edges={("T.a", "T.b")})
    assert any("reverses the static lock order" in v
               for v in violations), violations
    assert not any("cycle" in v for v in violations)


def test_condition_wait_keeps_held_set_straight(traced):
    mu = threading.RLock()
    cond = threading.Condition(mu)
    done = threading.Event()

    def waker():
        done.wait(5)
        with cond:
            cond.notify_all()

    t = threading.Thread(target=waker)
    t.start()
    with cond:
        done.set()
        cond.wait(5)
        # after wait() reacquires, the lock must be back in the held set:
        # a nested acquire here must record an edge, not nothing
        inner = threading.Lock()
        with inner:
            pass
    t.join()
    edges = runtime.edges()
    assert any(b == inner.site for (_, b) in edges), edges
    assert runtime.check() == []


def test_scoped_restores_graph(traced):
    a = threading.Lock()
    b = threading.Lock()
    before = runtime.edges()
    with runtime.scoped():
        with b:
            with a:
                pass
        assert runtime.edges() != before
    assert runtime.edges() == before


def test_install_is_idempotent():
    first = runtime.install()
    try:
        assert runtime.install() is False
        assert runtime.installed()
    finally:
        if first:
            runtime.uninstall()


def test_traced_locks_survive_uninstall(traced):
    # a lock created while traced keeps working after uninstall (the
    # wrapper object is still a lock); only *new* locks go untraced
    mu = threading.Lock()
    runtime.uninstall()
    try:
        with mu:
            assert mu.locked()
    finally:
        runtime.install()
