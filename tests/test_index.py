"""Semantic index subsystem (repro.index): IndexStore crash-safety,
scheduler-driven sketch builds and backfill, and predicate pushdown —
including the load-bearing property that exact-match pushdown is
bit-identical to the unpruned cascade."""

import os

import numpy as np
import pytest

from _hyp_compat import given, settings, st
from repro.analytics import generate_segment
from repro.analytics.query import run_query
from repro.core.coalesce import SFNode
from repro.core.configure import DerivedConfig
from repro.core.consumption import Consumer, ConsumerPlan
from repro.core.knobs import (GOLDEN_CODING, RAW, CodingOption,
                              FidelityOption, IngestSpec)
from repro.index import IndexStore, SemanticIndex, SketchRecord, sketch_specs
from repro.index.sketch import _key, segment_buckets
from repro.ingest import IngestScheduler
from repro.videostore import VideoStore

SPEC = IngestSpec()

# full sampling: at 1/5 the per-frame change rate (score / gap) never
# clears Diff's threshold, so every sketch would be empty
CF_LOW = FidelityOption("bad", 1.0, 180, 1.0)
CF_MID = FidelityOption("good", 1.0, 360, 1 / 2)
CF_HI = FidelityOption("best", 1.0, 540, 1.0)  # golden: richer-eq the rest


def _mini_config(index_ops=("diff",)) -> DerivedConfig:
    """Three-format chain with query A's cascade subscribed across it and
    ingest-time indexing of the cascade head (hand-built: no profiling)."""
    plans = [
        ConsumerPlan(Consumer("diff", 0.8), CF_LOW, 0.85, 2000.0),
        ConsumerPlan(Consumer("snn", 0.8), CF_MID, 0.86, 400.0),
        ConsumerPlan(Consumer("nn", 0.8), CF_HI, 0.82, 30.0),
    ]
    nodes = [
        SFNode(CF_LOW, RAW, [plans[0]]),
        SFNode(CF_MID, CodingOption("fast", 10), [plans[1]]),
        SFNode(CF_HI, GOLDEN_CODING, [plans[2]], golden=True),
    ]

    class _Log:
        ingest_cost = storage_cost = 0.0
        rounds = []
        budget_met = True

    _Log.nodes = nodes
    return DerivedConfig(plans=plans, nodes=nodes, coalesce_log=_Log(),
                         index_ops=tuple(index_ops))


@pytest.fixture(scope="module")
def cfg():
    return _mini_config()


def _static_frames() -> np.ndarray:
    """A segment with nothing happening: zero diff/motion activations, so
    its sketch is empty and pushdown may prune it."""
    return np.full((SPEC.frames_per_segment, SPEC.height, SPEC.width), 127,
                   np.uint8)


def _busy_frames() -> np.ndarray:
    """Alternate-frame brightness flicker: a global mean-abs-diff of
    60/255 per frame, far over Diff's threshold and immune to the
    smoothing the quality knob applies — every bucket activates,
    deterministically (scene simulation is too marginal at sketch
    knobs to guarantee that)."""
    frames = np.full((SPEC.frames_per_segment, SPEC.height, SPEC.width),
                     100, np.uint8)
    frames[::2] += 60
    return frames


def _store(tmp_path, cfg, active=(0,), static=(1, 2)) -> VideoStore:
    vs = VideoStore(str(tmp_path / "vs"), SPEC)
    vs.set_formats(cfg.storage_formats())
    for seg in active:
        vs.ingest_segment("jackson", seg, _busy_frames())
    for seg in static:
        vs.ingest_segment("jackson", seg, _static_frames())
    return vs


def _index_for(tmp_path, cfg, vs, segments) -> SemanticIndex:
    idx = SemanticIndex(str(tmp_path / "idx"), SPEC, cfg)
    for seg in segments:
        for op in idx.ops:
            idx.build(vs, "jackson", seg, op)
    idx.flush()
    return idx


# -- IndexStore crash-safety -------------------------------------------------

def test_index_store_roundtrip_and_reload(tmp_path):
    s = IndexStore(str(tmp_path / "i"))
    s.put("a", b"alpha")
    s.put("b", b"beta")
    s.flush()
    assert s.get("a") == b"alpha" and len(s) == 2
    assert s.keys("a") == ["a"]
    again = IndexStore(str(tmp_path / "i"))
    assert again.get("b") == b"beta" and len(again) == 2


def test_index_store_truncates_unacked_tail(tmp_path):
    """A crash after put but before flush: the record is unacked; reload
    discards the log tail instead of serving (or tripping over) it."""
    s = IndexStore(str(tmp_path / "i"))
    s.put("acked", b"durable")
    s.flush()
    s.put("unacked", b"lost-by-crash")  # no flush: crash swallows it
    again = IndexStore(str(tmp_path / "i"))
    assert "acked" in again and "unacked" not in again
    assert again.truncated_bytes == len(b"lost-by-crash")
    # the truncation is real: a new put lands where the torn tail was
    again.put("next", b"fresh")
    again.flush()
    assert IndexStore(str(tmp_path / "i")).get("next") == b"fresh"


def test_index_store_torn_record_never_addressable(tmp_path):
    """Garbage appended to the active log (a torn final write) is cut on
    reload — every indexed record remains byte-exact."""
    s = IndexStore(str(tmp_path / "i"))
    s.put("k", b"value")
    s.flush()
    log = next(n for n in os.listdir(s.root) if n.startswith("log-"))
    with open(os.path.join(s.root, log), "ab") as f:
        f.write(b"\xff" * 17)  # half-written record
    again = IndexStore(str(tmp_path / "i"))
    assert again.get("k") == b"value"
    assert again.truncated_bytes == 17


def test_index_store_rejects_foreign_log(tmp_path):
    s = IndexStore(str(tmp_path / "i"))
    s.put("k", b"v")
    s.flush()
    log = next(n for n in os.listdir(s.root) if n.startswith("log-"))
    path = os.path.join(s.root, log)
    with open(path, "r+b") as f:
        f.write(b"NOTANIDX")
    with pytest.raises(ValueError, match="bad header"):
        IndexStore(str(tmp_path / "i"))


def test_index_store_sweeps_orphan_logs(tmp_path):
    s = IndexStore(str(tmp_path / "i"))
    s.put("k", b"v")
    s.flush()
    orphan = os.path.join(s.root, "log-0099.bin")
    with open(orphan, "wb") as f:
        f.write(b"VIDX0001garbage-from-a-crashed-compaction")
    again = IndexStore(str(tmp_path / "i"))
    assert not os.path.exists(orphan)
    assert again.get("k") == b"v"


def test_index_store_readonly_never_mutates(tmp_path):
    s = IndexStore(str(tmp_path / "i"))
    s.put("k", b"v")
    s.flush()
    s.put("tail", b"unflushed")
    orphan = os.path.join(s.root, "log-0099.bin")
    with open(orphan, "wb") as f:
        f.write(b"VIDX0001x")
    sizes = {n: os.path.getsize(os.path.join(s.root, n))
             for n in os.listdir(s.root)}
    ro = IndexStore(str(tmp_path / "i"), readonly=True)
    assert ro.get("k") == b"v"
    with pytest.raises(RuntimeError, match="read-only"):
        ro.put("x", b"y")
    with pytest.raises(RuntimeError, match="read-only"):
        ro.delete("k")
    assert os.path.exists(orphan)  # no sweep
    assert sizes == {n: os.path.getsize(os.path.join(s.root, n))
                     for n in os.listdir(s.root)}  # no truncation


def test_index_store_compaction_preserves_records(tmp_path):
    s = IndexStore(str(tmp_path / "i"), auto_compact_frac=None)
    for i in range(50):
        s.put(f"k{i:02d}", bytes([i]) * 40)
    for i in range(0, 50, 2):
        s.delete(f"k{i:02d}")
    s.put("k01", b"rewritten")  # overwrite: more dead bytes
    before = {k: s.get(k) for k in s.keys()}
    s.compact()
    assert s.compactions == 1
    assert {k: s.get(k) for k in s.keys()} == before
    # durable across reload, and the old logs are gone
    again = IndexStore(str(tmp_path / "i"))
    assert {k: again.get(k) for k in again.keys()} == before


def test_index_store_auto_compacts_on_dead_fraction(tmp_path):
    s = IndexStore(str(tmp_path / "i"), auto_compact_frac=0.5,
                   auto_compact_min_bytes=64)
    for _ in range(8):
        s.put("hot", os.urandom(64))  # every overwrite deadens 64 bytes
    assert s.compactions >= 1
    assert len(s) == 1


# -- sketch build + prune ----------------------------------------------------

def test_sketch_specs_resolve_head_knobs(cfg):
    specs = sketch_specs(cfg)
    assert set(specs) == {"diff"}
    _op, cf, sf_id, acc = specs["diff"]
    assert cf == CF_LOW and sf_id == cfg.subscription(CF_LOW)
    assert acc == 0.8
    with pytest.raises(KeyError):
        sketch_specs(cfg, ops=("ocr",))  # no plan in the mini config


def test_build_records_activations(tmp_path, cfg):
    vs = _store(tmp_path, cfg)
    idx = _index_for(tmp_path, cfg, vs, [0, 1, 2])
    busy = idx.get("jackson", 0, "diff")
    quiet = idx.get("jackson", 1, "diff")
    assert busy.buckets and busy.items > 0
    assert busy.n_buckets == segment_buckets(SPEC)
    assert quiet.buckets == () and quiet.items == 0
    assert quiet.quantiles == (0.0, 0.0, 0.0, 0.0)


def test_prune_exact_only_on_matching_knobs(tmp_path, cfg):
    vs = _store(tmp_path, cfg)
    idx = _index_for(tmp_path, cfg, vs, [0, 1, 2])
    _op, cf, sf_id, _acc = idx.specs["diff"]
    dec = idx.prune("jackson", [0, 1, 2, 7], "diff", cf, sf_id, 0.8)
    assert dec.kept == [0, 7] and dec.pruned == [1, 2]
    assert dec.missing == 1 and dec.conservative == 0
    # knob mismatch: exact mode must keep the empty-sketch segments
    other = FidelityOption("good", 0.5, 360, 1 / 2)
    dec = idx.prune("jackson", [1, 2], "diff", other, sf_id, 0.8)
    assert dec.kept == [1, 2] and not dec.pruned


def test_prune_conservative_requires_dominating_accuracy(tmp_path, cfg):
    vs = _store(tmp_path, cfg)
    idx = _index_for(tmp_path, cfg, vs, [1])
    _op, _cf, sf_id, _acc = idx.specs["diff"]
    other = FidelityOption("good", 0.5, 360, 1 / 2)
    # sketch accuracy 0.8 >= query 0.8: conservative prunes the mismatch
    dec = idx.prune("jackson", [1], "diff", other, sf_id, 0.8,
                    mode="conservative")
    assert dec.pruned == [1] and dec.conservative == 1
    # query wants more accuracy than the sketch was built at: keep
    dec = idx.prune("jackson", [1], "diff", other, sf_id, 0.95,
                    mode="conservative")
    assert dec.kept == [1] and dec.conservative == 0
    with pytest.raises(ValueError):
        idx.prune("jackson", [1], "diff", other, sf_id, 0.8, mode="bogus")


def test_run_query_pushdown_exact_bit_identical(tmp_path, cfg):
    """Pushdown over real street scenes (which survive the whole cascade:
    the identity is over a non-empty item set) mixed with static
    segments pushdown prunes."""
    vs = VideoStore(str(tmp_path / "vs"), SPEC)
    vs.set_formats(cfg.storage_formats())
    for seg in (1, 5, 6):  # scenes with diff activations AND cascade items
        frames, _ = generate_segment("jackson", seg, SPEC)
        vs.ingest_segment("jackson", seg, frames)
    for seg in (0, 2, 3):
        vs.ingest_segment("jackson", seg, _static_frames())
    segs = [0, 1, 2, 3, 5, 6]
    idx = _index_for(tmp_path, cfg, vs, segs)
    plain = run_query(vs, cfg, "A", "jackson", segs, 0.8)
    pushed = run_query(vs, cfg, "A", "jackson", segs, 0.8, index=idx)
    assert plain.items  # non-trivial identity
    assert pushed.items == plain.items
    assert pushed.pruned_segments == 3 and pushed.pruned_bytes > 0
    assert pushed.pruned_conservative == 0
    assert pushed.video_seconds == plain.video_seconds  # pruned still count
    # the pruned segments were never retrieved by stage 0
    assert pushed.stages[0].segments_scanned \
        == plain.stages[0].segments_scanned - 3


@settings(max_examples=8, deadline=None)
@given(st.lists(st.booleans(), min_size=1, max_size=5),
       st.sets(st.integers(0, 4), max_size=5),
       st.sampled_from(["A"]))
def test_pushdown_bit_identity_property(tmp_path_factory, layout, subset,
                                        query):
    """THE pushdown contract: for any mix of busy/static segments and any
    queried subset, exact-mode pushdown returns bit-identical items."""
    tmp = tmp_path_factory.mktemp("prop")
    cfg = _mini_config()
    vs = VideoStore(str(tmp / "vs"), SPEC)
    vs.set_formats(cfg.storage_formats())
    for seg, busy in enumerate(layout):
        vs.ingest_segment("jackson", seg,
                          _busy_frames() if busy else _static_frames())
    idx = SemanticIndex(str(tmp / "idx"), SPEC, cfg)
    for seg in range(len(layout)):
        idx.build(vs, "jackson", seg, "diff")
    segs = sorted(s for s in subset if s < len(layout))
    plain = run_query(vs, cfg, query, "jackson", list(segs), 0.8)
    pushed = run_query(vs, cfg, query, "jackson", list(segs), 0.8, index=idx)
    assert pushed.items == plain.items
    n_static = sum(1 for s in segs if not layout[s])
    assert pushed.pruned_segments == n_static


# -- scheduler integration ---------------------------------------------------

def test_scheduler_builds_sketches_under_budget(tmp_path, cfg):
    vs = VideoStore(str(tmp_path / "vs"), SPEC)
    vs.set_formats(cfg.storage_formats())
    idx = SemanticIndex(str(tmp_path / "idx"), SPEC, cfg)
    sched = IngestScheduler(vs, cfg, budget_x=0.0)  # nothing runs yet
    sched.attach_sketcher(idx)
    for seg in range(2):
        sched.ingest("jackson", seg, _busy_frames())
    st = sched.stats()
    assert st["sketch_pending"] == 2 and st["sketches"] == 0
    assert not idx.has_sketch("jackson", 0, "diff")
    sched.drain()
    st = sched.stats()
    assert st["sketches"] == 2 and st["sketch_pending"] == 0
    assert st["sketch_s"] > 0
    assert all(idx.has_sketch("jackson", s, "diff") for s in (0, 1))
    # sketch work was charged to the budget like a transcode
    assert idx.stats()["index_builds"] == 2


def test_scheduler_sketch_orders_after_source_transcode(tmp_path, cfg):
    """A sketch task sorts immediately after its source format's transcode
    of the same segment (tuple-prefix ordering), so the build usually
    decodes a materialized blob instead of walking the fallback chain."""
    vs = VideoStore(str(tmp_path / "vs"), SPEC)
    vs.set_formats(cfg.storage_formats())
    idx = SemanticIndex(str(tmp_path / "idx"), SPEC, cfg)
    sched = IngestScheduler(vs, cfg, budget_x=0.0)
    sched.attach_sketcher(idx)
    sched.ingest("jackson", 0, _busy_frames())
    src = idx.specs["diff"][2]
    with sched._mu:
        kinds = [(t.sf_id, t.kind) for t in sched._queue]
    assert (src, "sketch") in kinds
    assert kinds.index((src, "sketch")) == kinds.index((src, "transcode")) + 1


def test_scheduler_reingest_invalidates_sketch(tmp_path, cfg):
    vs = VideoStore(str(tmp_path / "vs"), SPEC)
    vs.set_formats(cfg.storage_formats())
    idx = SemanticIndex(str(tmp_path / "idx"), SPEC, cfg)
    sched = IngestScheduler(vs, cfg)
    sched.attach_sketcher(idx)
    sched.ingest("jackson", 0, _busy_frames())
    sched.drain()
    assert idx.get("jackson", 0, "diff").buckets  # busy footage
    sched.ingest("jackson", 0, _static_frames())  # same segment, new footage
    assert not idx.has_sketch("jackson", 0, "diff")  # stale sketch dropped
    sched.drain()
    assert idx.get("jackson", 0, "diff").buckets == ()  # rebuilt from new bytes
    assert idx.stats()["index_invalidated"] == 1


def test_adopt_missing_backfills_sketches(tmp_path, cfg):
    """Footage ingested before the index existed (or whose sketch a crash
    lost) gets sketch tasks from the same backlog sweep as transcodes."""
    vs = _store(tmp_path, cfg, active=(0,), static=(1,))
    idx = SemanticIndex(str(tmp_path / "idx"), SPEC, cfg)
    sched = IngestScheduler(vs, cfg)
    sched.attach_sketcher(idx)
    n = sched.adopt_missing(["jackson"])
    # every format is materialized (blocking ingest): the 2 missing
    # sketches are the whole backlog
    assert n == 2 and sched.stats()["sketch_pending"] == 2
    # idempotent: queued tasks are not re-adopted
    assert sched.adopt_missing(["jackson"]) == 0
    sched.drain()
    assert idx.get("jackson", 0, "diff").buckets
    assert idx.get("jackson", 1, "diff").buckets == ()
    assert sched.adopt_missing(["jackson"]) == 0  # everything materialized


def test_sketch_survives_erosion_bit_exact(tmp_path, cfg):
    """Eroding the sketch's source format must NOT invalidate sketches:
    fallback reconstruction is bit-exact, so the pruned query still
    matches the unpruned one over the eroded store."""
    vs = _store(tmp_path, cfg, active=(0,), static=(1, 2))
    idx = _index_for(tmp_path, cfg, vs, [0, 1, 2])
    src = idx.specs["diff"][2]
    # materialize everything, then erode the sketch source format
    sched = IngestScheduler(vs, cfg)
    sched.adopt_missing(["jackson"])
    sched.drain()
    vs.erode("jackson", src, 1.0)
    assert not vs.has_segment("jackson", 0, src)
    assert idx.has_sketch("jackson", 0, "diff")  # survived
    plain = run_query(vs, cfg, "A", "jackson", [0, 1, 2], 0.8)
    pushed = run_query(vs, cfg, "A", "jackson", [0, 1, 2], 0.8, index=idx)
    assert pushed.items == plain.items and pushed.pruned_segments == 2


def test_index_reload_serves_acked_sketches(tmp_path, cfg):
    vs = _store(tmp_path, cfg, active=(0,), static=(1,))
    idx = _index_for(tmp_path, cfg, vs, [0, 1])
    reloaded = SemanticIndex(str(tmp_path / "idx"), SPEC, cfg)
    assert reloaded.get("jackson", 0, "diff") == idx.get("jackson", 0, "diff")
    assert reloaded.get("jackson", 1, "diff") == idx.get("jackson", 1, "diff")
    pushed = run_query(vs, cfg, "A", "jackson", [0, 1], 0.8, index=reloaded)
    assert pushed.pruned_segments == 1


def test_missing_lists_backfill_pairs(tmp_path, cfg):
    vs = _store(tmp_path, cfg, active=(0,), static=(1,))
    idx = SemanticIndex(str(tmp_path / "idx"), SPEC, cfg)
    assert idx.missing("jackson", [0, 1]) == [(0, "diff"), (1, "diff")]
    idx.build(vs, "jackson", 0, "diff")
    assert idx.missing("jackson", [0, 1]) == [(1, "diff")]
    assert _key("jackson", "diff", 0) in idx.store
