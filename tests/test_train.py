"""Training step: loss decreases on an overfit batch, microbatching matches
single-batch gradients, int8 gradient compression converges."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.train import (AdamWConfig, init_feedback, init_opt_state,
                         make_train_step)

RNG = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-135m").reduced(n_layers=2, d_model=64,
                                            vocab=128)
    params = init_params(cfg, RNG)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0,
                                     cfg.vocab_size),
    }
    return cfg, params, batch


def test_loss_decreases(setup):
    cfg, params, batch = setup
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=100,
                          weight_decay=0.0)
    step = jax.jit(make_train_step(cfg, opt_cfg, moe_dispatch="dense"))
    state = init_opt_state(params, opt_cfg)
    losses = []
    for _ in range(30):
        params, state, metrics = step(params, state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.7
    assert int(state["step"]) == 30


def test_microbatch_equivalence(setup):
    """Gradient accumulation over micro-slices equals the full-batch
    gradient (checked on grads and loss; Adam's sign-like first step would
    amplify float-reassociation noise if compared on params)."""
    cfg, params, batch = setup
    from repro.models import lm_loss
    from repro.train.train_step import _split_micro
    loss_full, g_full = jax.value_and_grad(
        lambda p: lm_loss(p, cfg, batch, "dense"))(params)
    micro = _split_micro(batch, 2)
    losses, gs = [], []
    for i in range(2):
        mb = jax.tree.map(lambda x: x[i], micro)
        l, g = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, mb, "dense"))(params)
        losses.append(l)
        gs.append(g)
    g_acc = jax.tree.map(lambda a, b: (a + b) / 2, *gs)
    assert abs(float(loss_full) - float(sum(losses) / 2)) < 1e-5
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_acc)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_int8_compression_converges(setup):
    cfg, params, batch = setup
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=100,
                          weight_decay=0.0)
    step = jax.jit(make_train_step(cfg, opt_cfg, moe_dispatch="dense",
                                   compress="int8"))
    state = init_opt_state(params, opt_cfg)
    state["fb"] = init_feedback(params)
    losses = []
    for _ in range(30):
        params, state, metrics = step(params, state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.75  # converges despite quantization
    assert "fb" in state
