"""Analytics operators: golden self-consistency, negative control on empty
scenes, genuine fidelity sensitivity."""

import numpy as np
import pytest

from repro.analytics import OPERATORS, f1_score, generate_segment
from repro.codec.transform import materialize
from repro.core.knobs import FidelityOption, IngestSpec

SPEC = IngestSpec()
GOLDEN = FidelityOption()


@pytest.fixture(scope="module")
def segs():
    return [generate_segment("jackson", i, SPEC)[0] for i in range(3)]


@pytest.fixture(scope="module")
def empty_seg():
    return generate_segment("empty", 0, SPEC)[0]


def test_golden_self_consistency(segs):
    for name, op in OPERATORS.items():
        items = op.detect(segs[0], GOLDEN, SPEC)
        again = op.detect(segs[0], GOLDEN, SPEC)
        assert items == again, name  # deterministic
        assert f1_score(items, items) == 1.0


def test_negative_control(empty_seg):
    # no cars -> (almost) no detections for object-level operators
    for name in ("motion", "snn", "nn", "license", "ocr"):
        items = OPERATORS[name].detect(empty_seg, GOLDEN, SPEC)
        assert len(items) <= 2, (name, items)


def test_cars_detected(segs):
    counts = {name: sum(len(OPERATORS[name].detect(s, GOLDEN, SPEC))
                        for s in segs)
              for name in OPERATORS}
    for name in ("motion", "snn", "license"):
        assert counts[name] > 0, name


def test_f1_score_basics():
    assert f1_score(set(), set()) == 1.0
    assert f1_score({1}, set()) == 0.0
    assert f1_score(set(), {1}) == 0.0
    assert f1_score({1, 2}, {2, 3}) == pytest.approx(0.5)


@pytest.mark.parametrize("op_name", ["snn", "license"])
def test_accuracy_degrades_with_resolution(segs, op_name):
    op = OPERATORS[op_name]
    accs = []
    for res in (144, 400, 720):
        cf = FidelityOption("best", 1.0, res, 1.0)
        acc = np.mean([
            f1_score(op.detect(np.asarray(materialize(s, cf, SPEC)), cf,
                               SPEC),
                     op.detect(s, GOLDEN, SPEC)) for s in segs])
        accs.append(acc)
    assert accs[-1] == 1.0
    assert accs[0] <= accs[-1] - 0.2  # low resolution genuinely hurts


def test_positions_subset(segs):
    """Cascades pass activated frame subsets with explicit positions."""
    op = OPERATORS["motion"]
    cf = FidelityOption()
    full = op.detect(segs[0], cf, SPEC)
    pos = np.arange(SPEC.frames_per_segment)
    sel = pos[: SPEC.frames_per_segment // 2]
    half = op.detect(segs[0][sel], cf, SPEC, positions=sel)
    buckets_half = {it[1] for it in half}
    assert buckets_half <= {it[1] for it in full} | buckets_half
    assert all(b <= max(sel) // max(1, SPEC.fps // 2) for b in buckets_half)
