"""Live ingestion subsystem (repro.ingest): budgeted scheduler, bit-exact
fallback-chain retrieval, erosion executor, stratified erode byte
accounting, and SegmentStore auto-compaction."""

import numpy as np
import pytest

from repro.analytics.query import run_query
from repro.analytics.scene import generate_segment
from repro.core.coalesce import SFNode
from repro.core.configure import DerivedConfig
from repro.core.consumption import Consumer, ConsumerPlan
from repro.core.erosion import ErosionPlan
from repro.core.knobs import (GOLDEN_CODING, RAW, CodingOption,
                              FidelityOption, IngestSpec, StorageFormat)
from repro.ingest import (ErosionExecutor, IngestScheduler, StreamSource,
                          build_parents, chain_of, interleave)
from repro.serving import VStoreServer
from repro.videostore import SegmentStore, VideoStore
from repro.videostore.video_store import _sf_key, stratified_pick

SPEC = IngestSpec()

CF_LOW = FidelityOption("bad", 1.0, 180, 1 / 5)
CF_MID = FidelityOption("good", 1.0, 360, 1 / 2)
CF_HI = FidelityOption("best", 1.0, 540, 1 / 2)


def _mini_config() -> DerivedConfig:
    """Three-format chain low -> mid -> golden with query A's cascade ops
    subscribed across it (hand-built: no profiling)."""
    plans = [
        ConsumerPlan(Consumer("diff", 0.8), CF_LOW, 0.85, 2000.0),
        ConsumerPlan(Consumer("snn", 0.8), CF_MID, 0.86, 400.0),
        ConsumerPlan(Consumer("nn", 0.8), CF_HI, 0.82, 30.0),
    ]
    nodes = [
        SFNode(CF_LOW, RAW, [plans[0]]),
        SFNode(CF_MID, CodingOption("fast", 10), [plans[1]]),
        SFNode(CF_HI, GOLDEN_CODING, [plans[2]], golden=True),
    ]

    class _Log:
        ingest_cost = storage_cost = 0.0
        rounds = []
        budget_met = True

    _Log.nodes = nodes
    return DerivedConfig(plans=plans, nodes=nodes, coalesce_log=_Log())


@pytest.fixture(scope="module")
def cfg():
    return _mini_config()


def _golden_only_store(tmp_path, cfg, streams=("jackson",), n_segs=2,
                       budget_x=0.0):
    vs = VideoStore(str(tmp_path / "vs"), SPEC)
    vs.set_formats(cfg.storage_formats())
    sched = IngestScheduler(vs, cfg, budget_x=budget_x)
    for stream in streams:
        for seg in range(n_segs):
            frames, _ = generate_segment(stream, seg, SPEC)
            sched.ingest(stream, seg, frames)
    return vs, sched


# -- format tree ------------------------------------------------------------

def test_build_parents_chain(cfg):
    formats = cfg.storage_formats()
    golden_id, parents = build_parents(formats)
    assert golden_id == "sf_g"
    low = cfg.subscription(CF_LOW)
    mid = cfg.subscription(CF_MID)
    assert parents[low] == mid and parents[mid] == "sf_g"
    assert chain_of(low, golden_id, parents) == [low, mid, "sf_g"]


def test_build_parents_rejects_no_root():
    a = StorageFormat(FidelityOption("best", 1.0, 720, 1 / 5), RAW)
    b = StorageFormat(FidelityOption("bad", 1.0, 180, 1.0), RAW)
    with pytest.raises(ValueError):
        build_parents({"x": a, "y": b})


# -- fallback-chain retrieval ----------------------------------------------

def test_fallback_blob_bit_exact(tmp_path, cfg):
    """Read-time reconstruction of an unmaterialized format produces the
    exact bytes the background transcoder later writes."""
    vs, sched = _golden_only_store(tmp_path, cfg)
    low = cfg.subscription(CF_LOW)
    mid = cfg.subscription(CF_MID)
    assert not vs.has_segment("jackson", 0, low)
    recon = {sid: sched.fallback.reconstruct(vs, "jackson", 0, sid)
             for sid in (low, mid)}
    assert sched.drain() == 4  # 2 segments x 2 deferred formats
    for sid, blob in recon.items():
        assert vs.backend.get(_sf_key(sid, "jackson", 0)) == blob


def test_query_mid_ingest_identical(tmp_path, cfg):
    """A cascade run while only golden exists returns items identical to
    the fully materialized store."""
    vs, sched = _golden_only_store(tmp_path, cfg)
    segs = [0, 1]
    mid = run_query(vs, cfg, "A", "jackson", segs, 0.8)
    assert sched.pending() == 4
    fb = sched.fallback.stats()
    assert fb["fallback_reads"] > 0
    sched.drain()
    full = run_query(vs, cfg, "A", "jackson", segs, 0.8)
    assert mid.items == full.items


def test_fallback_after_erosion_identical(tmp_path, cfg):
    """Eroding a format's segments does not change query answers: reads
    fall back to the ancestor and reconstruct the identical blob."""
    vs, sched = _golden_only_store(tmp_path, cfg)
    sched.drain()
    before = run_query(vs, cfg, "A", "jackson", [0, 1], 0.8)
    low = cfg.subscription(CF_LOW)
    res = vs.erode("jackson", low, 1.0)
    assert res.segments == 2
    after = run_query(vs, cfg, "A", "jackson", [0, 1], 0.8)
    assert after.items == before.items


def test_missing_golden_raises(tmp_path, cfg):
    vs, sched = _golden_only_store(tmp_path, cfg, n_segs=1)
    with pytest.raises(KeyError):
        vs.retrieve("jackson", 7, cfg.subscription(CF_LOW), CF_LOW)
    assert vs.can_serve("jackson", 0, cfg.subscription(CF_LOW))
    assert not vs.can_serve("jackson", 7, cfg.subscription(CF_LOW))


# -- scheduler budget / debt / shedding ------------------------------------

def test_scheduler_budget_gates_background(tmp_path, cfg):
    vs, sched = _golden_only_store(tmp_path, cfg, budget_x=0.0)
    assert sched.pump() == 0            # no credit: nothing runs
    st = sched.stats()
    assert st["debt_s"] > 0 and st["pending"] == 4
    assert st["streams"]["jackson"]["segments"] == 2
    sched.set_budget_x(None)
    assert sched.pump() == 4            # unbounded: queue drains
    assert sched.debt_seconds() == 0
    for sid in cfg.storage_formats():
        assert vs.available_segments("jackson", sid) == [0, 1]


def test_scheduler_priority_order(tmp_path, cfg):
    """Most-expensive-to-recover formats materialize first; the rank comes
    from the erosion chain math (absence of mid hurts its consumer more
    than absence of low, whose fallback is the nearby mid)."""
    vs, sched = _golden_only_store(tmp_path, cfg, budget_x=0.0)
    rank = sched.recovery_rank()
    low = cfg.subscription(CF_LOW)
    mid = cfg.subscription(CF_MID)
    assert rank["sf_g"] == float("inf")
    first = sorted({low, mid},
                   key=lambda sid: -rank[sid])[0]
    sched.set_budget_x(None)
    sched.pump(max_tasks=1)
    done = [sid for sid in (low, mid)
            if vs.has_segment("jackson", 0, sid)]
    assert done == [first]


def test_budget_raise_recredits_retroactively(tmp_path, cfg):
    """Raising to a *finite* budget that covers the arrived footage must
    drain the debt immediately — the bucket is re-credited as
    rate x video-arrived - spent, not left at its accumulated deficit."""
    vs, sched = _golden_only_store(tmp_path, cfg, budget_x=0.0)
    assert sched.stats()["credit_s"] < 0   # golden overran the zero budget
    assert sched.pump() == 0
    sched.set_budget_x(100.0)              # generous but finite
    assert sched.stats()["credit_s"] > 0
    assert sched.pump() == 4
    assert sched.debt_seconds() == 0


def test_scheduler_shed_and_requeue(tmp_path, cfg):
    vs = VideoStore(str(tmp_path / "vs"), SPEC)
    vs.set_formats(cfg.storage_formats())
    sched = IngestScheduler(vs, cfg, budget_x=0.0, shed_debt_s=0.0)
    frames, _ = generate_segment("jackson", 0, SPEC)
    sched.ingest("jackson", 0, frames)
    st = sched.stats()
    assert st["pending"] == 0 and st["shed"] == 2  # everything shed
    assert sched.requeue_shed() == 2
    sched.set_budget_x(None)
    assert sched.drain() == 2
    assert sched.stats()["shed"] == 0


def test_stream_source_deterministic():
    src = StreamSource("jackson", SPEC, n_segments=2)
    arrs = list(src)
    assert [a.seg for a in arrs] == [0, 1]
    again = list(StreamSource("jackson", SPEC, n_segments=2))
    assert all(np.array_equal(a.frames, b.frames)
               for a, b in zip(arrs, again))
    order = [(a.stream, a.seg) for a in interleave(
        [StreamSource("a", SPEC, 2), StreamSource("b", SPEC, 2)])]
    assert order == [("a", 0), ("b", 0), ("a", 1), ("b", 1)]


# -- concurrent ingest + serve (the stress test) ----------------------------

def test_server_queries_during_materialization(tmp_path, cfg):
    """VStoreServer answers cascades (fallback-chain retrieval through the
    planner) while the scheduler's worker thread is still materializing
    formats; every answer matches the fully-ingested store."""
    streams = ("jackson", "tucson")
    n_segs = 2
    vs = VideoStore(str(tmp_path / "vs"), SPEC)
    vs.set_formats(cfg.storage_formats())
    # reference: an independently fully-ingested store via the same
    # golden-derived path (blocking drain after each segment)
    ref = VideoStore(str(tmp_path / "ref"), SPEC)
    ref.set_formats(cfg.storage_formats())
    ref_sched = IngestScheduler(ref, cfg)
    truth = {}
    for stream in streams:
        for seg in range(n_segs):
            frames, _ = generate_segment(stream, seg, SPEC)
            ref_sched.ingest(stream, seg, frames)
    ref_sched.drain()
    for stream in streams:
        truth[stream] = run_query(ref, cfg, "A", stream,
                                  list(range(n_segs)), 0.8).items

    sched = IngestScheduler(vs, cfg, budget_x=0.02)  # a trickle: the
    # worker materializes slowly while queries run against fallback
    sched.start()
    try:
        with VStoreServer(vs, cfg, workers=2) as srv:
            srv.attach_ingest(sched)
            tickets = []
            for stream in streams:
                for seg in range(n_segs):
                    frames, _ = generate_segment(stream, seg, SPEC)
                    sched.ingest(stream, seg, frames)
                # query everything golden-ingested so far, mid-ingest
                tickets.append((stream, srv.submit(
                    "A", stream, list(range(n_segs)), 0.8, block=True)))
            results = [(s, t.result()) for s, t in tickets]
            stats = srv.stats()
    finally:
        sched.stop(drain=True)
    assert stats["ingest"] is not None
    for stream, res in results:
        assert res.items == truth[stream], stream
    # and after the drain the store serves the same answers physically
    for stream in streams:
        assert run_query(vs, cfg, "A", stream, list(range(n_segs)),
                         0.8).items == truth[stream]
        for sid in cfg.storage_formats():
            assert vs.available_segments(stream, sid) == list(range(n_segs))


# -- erode: stratified spread + byte accounting -----------------------------

def test_stratified_pick_spread_and_determinism():
    items = list(range(20))
    picks = stratified_pick(items, 5, seed=7)
    assert picks == stratified_pick(items, 5, seed=7)
    assert len(picks) == len(set(picks)) == 5
    # one victim per stratum of 4: no two picks land in one stratum
    assert all(b - a >= 2 for a, b in zip(picks, picks[1:]))
    assert stratified_pick(items, 5, seed=1) != picks
    assert stratified_pick(items, 25, seed=0) == items
    assert stratified_pick([], 3, seed=0) == []


def test_erode_returns_bytes(tmp_path, cfg):
    vs, sched = _golden_only_store(tmp_path, cfg, n_segs=4)
    sched.drain()
    mid = cfg.subscription(CF_MID)
    sizes = {s: vs.backend.size_of(_sf_key(mid, "jackson", s))
             for s in range(4)}
    res = vs.erode("jackson", mid, 0.5, seed=3)
    assert res.segments == 2 and len(res.victims) == 2
    assert res.bytes == sum(sizes[s] for s in res.victims)
    assert res.chunks > 0 and 0 < res.chunk_bytes <= res.bytes
    # deterministic: the same seed picks the same victims
    vs2, sched2 = _golden_only_store(tmp_path / "b", cfg, n_segs=4)
    sched2.drain()
    assert vs2.erode("jackson", mid, 0.5, seed=3).victims == res.victims


def test_erode_subset_and_count(tmp_path, cfg):
    vs, sched = _golden_only_store(tmp_path, cfg, n_segs=4)
    sched.drain()
    low = cfg.subscription(CF_LOW)
    res = vs.erode("jackson", low, segments=[0, 1], count=1)
    assert res.segments == 1 and res.victims[0] in (0, 1)
    assert res.chunks == 0 and res.chunk_bytes > 0  # RAW: chunkless payload
    left = vs.available_segments("jackson", low)
    assert len(left) == 3 and {2, 3} <= set(left)


def test_ingest_stats_chunk_spans(tmp_path, cfg):
    vs, sched = _golden_only_store(tmp_path, cfg, n_segs=1)
    sched.drain()
    st = vs.ingest_stats["jackson"]
    assert st.segments == 1
    assert st.chunks > 0           # golden + mid are entropy-coded
    assert 0 < st.chunk_bytes <= st.stored_bytes


# -- erosion executor -------------------------------------------------------

def test_erosion_executor_age_schedule(tmp_path, cfg):
    vs, sched = _golden_only_store(tmp_path, cfg, n_segs=4)
    sched.drain()
    low = cfg.subscription(CF_LOW)
    mid = cfg.subscription(CF_MID)
    low_idx = next(i for i in range(3) if cfg.node_id(i) == low)
    plan = ErosionPlan(k=1.0, ages=[1, 2],
                       fractions=[{low_idx: 0.5}, {low_idx: 1.0}],
                       overall_speed=[0.9, 0.8], daily_bytes=[0, 0],
                       total_bytes=0, feasible=True)
    ex = ErosionExecutor(vs, plan, [cfg.node_id(i) for i in range(3)])
    ex.register_existing(["jackson"])
    b0 = vs.storage_bytes("jackson")

    rep1 = ex.advance()
    assert rep1.segments == 2 and rep1.bytes > 0
    assert len(vs.available_segments("jackson", low)) == 2
    rep2 = ex.advance()
    assert rep2.segments == 2
    assert vs.available_segments("jackson", low) == []
    # plan saturates at its last age: nothing more to erode
    assert ex.advance().segments == 0
    # golden and unplanned formats intact; bytes actually reclaimed
    assert len(vs.available_segments("jackson", "sf_g")) == 4
    assert len(vs.available_segments("jackson", mid)) == 4
    assert vs.storage_bytes("jackson") == b0 - rep1.bytes - rep2.bytes
    assert vs.backend.dead_bytes == 0  # compaction reclaimed the shards
    assert ex.stats()["eroded_segments"] == 4


def test_erosion_executor_cohorts_by_day(tmp_path, cfg):
    """Segments ingested on different days erode on their own schedules."""
    vs = VideoStore(str(tmp_path / "vs"), SPEC)
    vs.set_formats(cfg.storage_formats())
    sched = IngestScheduler(vs, cfg)
    low = cfg.subscription(CF_LOW)
    low_idx = next(i for i in range(3) if cfg.node_id(i) == low)
    plan = ErosionPlan(k=1.0, ages=[1, 2],
                       fractions=[{low_idx: 0.0}, {low_idx: 1.0}],
                       overall_speed=[1.0, 0.8], daily_bytes=[0, 0],
                       total_bytes=0, feasible=True)
    ex = ErosionExecutor(vs, plan, [cfg.node_id(i) for i in range(3)])
    sched.on_ingest(ex.note_ingested)

    def ingest(seg):
        frames, _ = generate_segment("jackson", seg, SPEC)
        sched.ingest("jackson", seg, frames)

    ingest(0)                      # day 0 cohort
    sched.drain()
    rep = ex.advance()             # day 1: age 1 -> fraction 0
    assert rep.segments == 0
    ingest(1)                      # day 1 cohort
    sched.drain()
    rep = ex.advance()             # day 2: seg 0 is age 2 -> fully eroded
    assert rep.segments == 1
    assert vs.available_segments("jackson", low) == [1]
    rep = ex.advance()             # day 3: seg 1 reaches age 2
    assert rep.segments == 1
    assert vs.available_segments("jackson", low) == []


# -- SegmentStore auto-compaction ------------------------------------------

def test_auto_compact_on_delete(tmp_path):
    s = SegmentStore(str(tmp_path / "kv"), auto_compact_frac=0.4,
                     auto_compact_min_bytes=0)
    for i in range(10):
        s.put(f"k{i}", bytes([i]) * 4000)
    assert s.auto_compactions == 0
    for i in range(5):
        s.delete(f"k{i}")
    assert s.auto_compactions >= 1
    assert s.dead_bytes == 0
    for i in range(5, 10):
        assert s.get(f"k{i}") == bytes([i]) * 4000
    # the compacted index is durable: a reload sees the new layout
    s2 = SegmentStore(str(tmp_path / "kv"))
    assert s2.get("k7") == bytes([7]) * 4000


def test_auto_compact_on_overwrite(tmp_path):
    s = SegmentStore(str(tmp_path / "kv"), auto_compact_frac=0.4,
                     auto_compact_min_bytes=0)
    s.put("a", b"x" * 4000)
    s.put("b", b"y" * 4000)
    s.put("a", b"z" * 4000)   # orphans the old value
    s.put("a", b"w" * 4000)
    assert s.auto_compactions >= 1 and s.dead_bytes == 0
    assert s.get("a") == b"w" * 4000 and s.get("b") == b"y" * 4000


def test_compact_is_crash_safe_layout(tmp_path):
    """Compaction copies survivors into fresh shard ids and makes the
    index durable before deleting old shards — a reload mid-sequence can
    never resolve stale offsets into new files.  Orphan shards (what a
    crash leaves on either side of the flush) are swept on load."""
    import os
    s = SegmentStore(str(tmp_path / "kv"), auto_compact_frac=None)
    for i in range(6):
        s.put(f"k{i}", bytes([i]) * 3000)
    for i in range(3):
        s.delete(f"k{i}")
    s.compact()
    # fresh ids: the pre-compaction shard file name is gone, not reused
    assert not os.path.exists(os.path.join(s.root, "shard-0000.bin"))
    # the durable index already points at the new layout
    s2 = SegmentStore(str(tmp_path / "kv"))
    for i in range(3, 6):
        assert s2.get(f"k{i}") == bytes([i]) * 3000
    # a crash-orphaned shard is cleaned up by load, data intact
    orphan = os.path.join(s.root, "shard-0042.bin")
    with open(orphan, "wb") as f:
        f.write(b"garbage")
    s3 = SegmentStore(str(tmp_path / "kv"))
    assert not os.path.exists(orphan)
    assert s3.get("k4") == bytes([4]) * 3000


def test_auto_compact_disabled(tmp_path):
    s = SegmentStore(str(tmp_path / "kv"), auto_compact_frac=None)
    for i in range(4):
        s.put(f"k{i}", bytes([i]) * 4000)
    for i in range(4):
        s.delete(f"k{i}")
    assert s.auto_compactions == 0 and s.dead_bytes == 16000
    s.compact()
    assert s.dead_bytes == 0


# -- materialize-on-read + budget lease -------------------------------------

def test_materialize_on_read_charges_budget(tmp_path, cfg):
    """A fallback reconstruction is written back (so the next read is a
    physical hit) and its transcode seconds are debited from the token
    bucket exactly like a background task's."""
    vs = VideoStore(str(tmp_path / "vs"), SPEC)
    vs.set_formats(cfg.storage_formats())
    sched = IngestScheduler(vs, cfg, budget_x=100.0,
                            materialize_on_read=True)
    frames, _ = generate_segment("jackson", 0, SPEC)
    sched.ingest("jackson", 0, frames)  # golden only; others queued
    low = cfg.subscription(CF_LOW)
    mid = cfg.subscription(CF_MID)
    assert not vs.has_segment("jackson", 0, low)
    credit0 = sched.stats()["credit_s"]
    out, cost = vs.retrieve("jackson", 0, low, CF_LOW)
    assert cost.get("fallback") == 1
    # the chain walk low -> mid -> golden materialized both ancestors'
    # reconstructions, each charged to the bucket
    assert vs.has_segment("jackson", 0, low)
    assert vs.has_segment("jackson", 0, mid)
    st = sched.stats()
    assert st["write_backs"] == 2
    assert st["write_back_s"] > 0
    assert st["credit_s"] < credit0
    # the write-back is the exact blob deferred materialization stores:
    # a drain later finds the segments present and skips them bit-safely
    before = vs.backend.get(_sf_key(low, "jackson", 0))
    sched.drain()
    assert vs.backend.get(_sf_key(low, "jackson", 0)) == before
    # next read is a physical hit, no further fallback
    _, cost2 = vs.retrieve_direct("jackson", 0, low, CF_LOW)
    assert "fallback" not in cost2


def test_materialize_on_read_skipped_without_credit(tmp_path, cfg):
    """Under budget pressure (no credit) the reconstruction still serves
    the read but is NOT persisted — materialization can't sneak past the
    budget."""
    vs = VideoStore(str(tmp_path / "vs"), SPEC)
    vs.set_formats(cfg.storage_formats())
    sched = IngestScheduler(vs, cfg, budget_x=0.0,
                            materialize_on_read=True)
    frames, _ = generate_segment("jackson", 0, SPEC)
    sched.ingest("jackson", 0, frames)
    assert sched.stats()["credit_s"] <= 0
    low = cfg.subscription(CF_LOW)
    out, cost = vs.retrieve("jackson", 0, low, CF_LOW)
    assert cost.get("fallback") == 1
    assert not vs.has_segment("jackson", 0, low)
    st = sched.stats()
    assert st["write_backs"] == 0
    assert st["write_backs_skipped"] >= 1


def test_budget_lease_external_owner(tmp_path, cfg):
    """A lease owned outside the scheduler (the cluster coordinator's
    model) adjusts the rate with grant(); raises re-credit retroactively
    exactly like set_budget_x always did."""
    from repro.ingest import BudgetLease
    lease = BudgetLease(0.0)
    vs = VideoStore(str(tmp_path / "vs"), SPEC)
    vs.set_formats(cfg.storage_formats())
    sched = IngestScheduler(vs, cfg, lease=lease)
    assert sched.budget_x == 0.0
    frames, _ = generate_segment("jackson", 0, SPEC)
    sched.ingest("jackson", 0, frames)
    assert sched.pump() == 0               # zero rate: nothing runnable
    lease.grant(100.0)                     # owner raises the share
    assert sched.budget_x == 100.0
    assert sched.stats()["credit_s"] > 0   # retroactive re-credit
    assert sched.pump() == 2
    assert sched.debt_seconds() == 0
    with pytest.raises(ValueError):
        IngestScheduler(vs, cfg, budget_x=1.0, lease=BudgetLease(2.0))
    with pytest.raises(ValueError):
        lease.attach(IngestScheduler(vs, cfg))  # already owned


def test_adopt_missing_restores_lost_queue(tmp_path, cfg):
    """A process crash loses the in-memory transcode queue; a new
    scheduler over the same (durable) store re-adopts the backlog so the
    debt is visible and drainable again."""
    vs, sched = _golden_only_store(tmp_path, cfg, n_segs=2, budget_x=0.0)
    assert sched.pending() == 4  # 2 segs x 2 non-golden formats
    vs.flush()  # the durability receipt the cluster worker issues per ack
    # "restart": fresh store handle + scheduler, no ingest() calls
    vs2 = VideoStore(str(tmp_path / "vs"), SPEC)
    sched2 = IngestScheduler(vs2, cfg, budget_x=0.0)
    assert sched2.pending() == 0          # the queue died with the process
    assert sched2.adopt_missing() == 4    # backlog re-adopted from disk
    assert sched2.debt_seconds() > 0
    assert sched2.adopt_missing() == 0    # idempotent
    sched2.set_budget_x(None)
    assert sched2.drain() == 4
    for sid in cfg.storage_formats():
        assert vs2.has_segment("jackson", 0, sid)


def test_background_task_charges_only_own_level(tmp_path, cfg):
    """Running a deep format's task before its parent's must not bill the
    parent's transcode twice: each level is charged by its own task (or
    write-back), so total spent stays ~= sum of per-level encode costs."""
    vs, sched = _golden_only_store(tmp_path, cfg, n_segs=1, budget_x=0.0)
    low = cfg.subscription(CF_LOW)
    mid = cfg.subscription(CF_MID)
    # force the deep format first (its parent mid is unmaterialized)
    with sched._mu:
        sched._queue.sort(key=lambda t: 0 if t.sf_id == low else 1)
        low_first = [t.sf_id for t in sched._queue]
    assert low_first[0] == low
    sched.set_budget_x(1000.0)
    assert sched.pump() == 2
    st = sched.stats()
    # both formats materialized; the recursive parent reconstruction was
    # not billed inside low's task, so transcode_s is the sum of the two
    # own-level costs (each also recorded in the per-format EMA)
    assert vs.has_segment("jackson", 0, low)
    assert vs.has_segment("jackson", 0, mid)
    assert st["transcodes"] == 2
    est = sched._est_s
    assert st["transcode_s"] == pytest.approx(est[low] + est[mid],
                                              rel=0.75)
