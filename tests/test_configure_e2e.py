"""End-to-end backward derivation (integration): real measured profiling on
a reduced consumer set; asserts the R1-R4 configuration requirements and
the boundary-search overhead bound (paper Fig. 13)."""

import pytest

from repro.core import Profiler, derive_config
from repro.core.knobs import (CROP_VALUES, QUALITY_VALUES, RESOLUTION_VALUES,
                              SAMPLING_VALUES, IngestSpec)

OPS = ("diff", "motion")
ACCS = (0.8,)


@pytest.fixture(scope="module")
def cfg_and_prof():
    prof = Profiler(IngestSpec(), n_segments=2, repeats=1)
    cfg = derive_config(prof, ops=OPS, accuracies=ACCS,
                        storage_budget_bytes=None)
    return cfg, prof


def test_r1_satisfiable_fidelity(cfg_and_prof):
    cfg, _ = cfg_and_prof
    for node in cfg.nodes:
        for p in node.plans:
            assert node.fidelity.richer_eq(p.cf)


def test_r2_adequate_retrieval(cfg_and_prof):
    from repro.core.coalesce import choose_coding
    cfg, prof = cfg_and_prof
    for node in cfg.nodes:
        for p in node.plans:
            # R2: retrieval keeps up with consumption — unless the engine
            # hit its documented terminal fallback (coalesce.choose_coding
            # returns None when even RAW can't beat a memory-bound
            # consumer; RAW is still the fastest retrieval there is).
            if prof.retrieval_speed(node.sf, p.cf) > p.speed:
                continue
            assert node.sf.coding.bypass and \
                choose_coding(prof, node.fidelity, node.plans) is None


def test_r3_consumers_subscribed_once(cfg_and_prof):
    cfg, _ = cfg_and_prof
    subscribed = [p for n in cfg.nodes for p in n.plans]
    assert len(subscribed) == len(cfg.plans) == len(OPS) * len(ACCS)
    for p in cfg.plans:
        sf_id = cfg.subscription(p.cf)
        assert sf_id in cfg.storage_formats()


def test_golden_exists_and_dominates(cfg_and_prof):
    cfg, _ = cfg_and_prof
    golden = [n for n in cfg.nodes if n.golden]
    assert len(golden) == 1
    for p in cfg.plans:
        assert golden[0].fidelity.richer_eq(p.cf)


def test_accuracy_targets_met(cfg_and_prof):
    cfg, _ = cfg_and_prof
    for p in cfg.plans:
        assert p.accuracy >= p.consumer.target - 1e-9


def test_profiling_far_below_exhaustive(cfg_and_prof):
    """Boundary search profiles a small fraction of the 600-option fidelity
    space (paper: 9-15x fewer runs)."""
    _, prof = cfg_and_prof
    exhaustive = len(OPS) * len(QUALITY_VALUES) * len(CROP_VALUES) * \
        len(RESOLUTION_VALUES) * len(SAMPLING_VALUES)
    assert prof.stats.consumption_runs < exhaustive / 4
    assert prof.stats.memo_hits > 0
