"""Storage-format coalescing (paper §4.3): R1/R2 invariants, golden format,
budget adaptation, and the paper's own validation — identical result to
exhaustive enumeration on a small CF set (§6.4)."""

import itertools

from repro.core.coalesce import choose_coding, coalesce
from repro.core.consumption import Consumer, ConsumerPlan
from repro.core.knobs import (GOLDEN_CODING, RAW, CodingOption,
                              FidelityOption, StorageFormat, coding_space)
from repro.core.profiler import TableProfiler


def _mk_profiler(plans, fast_decode=300.0):
    """Synthetic storage/retrieval tables with paper-like structure:
    bytes grow with fidelity rank and cheaper coding; encode cost grows with
    fidelity and slower speed steps; decode speed higher for RAW and for
    sparser consumer sampling."""
    from repro.core.knobs import SPEED_VALUES
    storage, retrieve = {}, {}
    all_f = {p.cf for p in plans}
    # include joins of all subsets (coalescing candidates)
    fids = set(all_f)
    for a in list(all_f):
        for b in list(all_f):
            fids.add(a.join(b))
    more = set()
    for a in fids:
        for b in fids:
            more.add(a.join(b))
    fids |= more
    for f in fids:
        for c in coding_space():
            rank = sum(f.rank()) + 1
            if c.bypass:
                size = 4000.0 * rank
                enc = 0.1 * rank
            else:
                speed_i = SPEED_VALUES.index(c.speed)
                size = 100.0 * rank * (1 + 0.15 * speed_i) * \
                    (1 + 10.0 / c.keyframe)
                enc = rank * (2.0 - 0.3 * speed_i)
            storage[(f, c)] = (enc, size)
            for p in plans:
                if c.bypass:
                    spd = fast_decode * 40 / max(p.cf.sampling, 1e-3)
                else:
                    spd = fast_decode / rank * (1 + 5.0 / c.keyframe) / \
                        max(p.cf.sampling, 0.05)
                retrieve[(f, c, p.cf)] = spd
    return TableProfiler({}, {}, storage, retrieve)


def _plans():
    fids = [
        FidelityOption("best", 1.0, 720, 1.0),
        FidelityOption("good", 1.0, 540, 1 / 2),
        FidelityOption("bad", 0.75, 180, 1 / 30),
        FidelityOption("best", 1.0, 200, 1.0),
    ]
    speeds = [10.0, 60.0, 2000.0, 400.0]
    return [ConsumerPlan(Consumer(f"op{i}", 0.9), f, 0.92, s)
            for i, (f, s) in enumerate(zip(fids, speeds))]


def test_invariants_r1_r2():
    plans = _plans()
    prof = _mk_profiler(plans)
    res = coalesce(prof, plans)
    assert any(n.golden for n in res.nodes)
    seen_plans = []
    for node in res.nodes:
        for p in node.plans:
            # R1: satisfiable fidelity
            assert node.fidelity.richer_eq(p.cf)
            # R2: adequate retrieval speed
            assert prof.retrieval_speed(node.sf, p.cf) > p.speed
            seen_plans.append(p)
    assert len(seen_plans) == len(plans)  # every consumer subscribed once
    golden = next(n for n in res.nodes if n.golden)
    for p in plans:
        assert golden.fidelity.richer_eq(p.cf)  # golden is global ubound


def test_coalescing_reduces_cost_vs_n_to_n():
    plans = _plans()
    prof = _mk_profiler(plans)
    res = coalesce(prof, plans)
    # N->N: one SF per unique CF + golden, no merging
    from repro.core.coalesce import _golden_node, _unique_nodes
    n2n = _unique_nodes(plans, prof) + [_golden_node(plans)]
    ing_n2n = sum(prof.storage_profile(n.sf)[0] for n in n2n)
    assert res.ingest_cost <= ing_n2n + 1e-9


def test_matches_exhaustive_enumeration():
    """Paper §6.4: greedy coalescing finds the same minimal-cost SF set as
    enumerating every partition of the CF set."""
    plans = _plans()[:3]
    prof = _mk_profiler(plans)
    res = coalesce(prof, plans)

    def best_partition():
        """Enumerate every partition of consumers into SF groups; the extra
        label assigns consumers to the golden format (which participates in
        coalescing, paper §4.3)."""
        n = len(plans)
        fg = plans[0].cf
        for p in plans[1:]:
            fg = fg.join(p.cf)
        best = None
        for labels in itertools.product(range(n + 1), repeat=n):
            groups: dict = {}
            for i, g in enumerate(labels):
                groups.setdefault(g, []).append(plans[i])
            golden_group = groups.pop(n, [])
            nodes = []
            feasible = True
            for ps in groups.values():
                fid = ps[0].cf
                for p in ps[1:]:
                    fid = fid.join(p.cf)
                coding = choose_coding(prof, fid, ps)
                if coding is None:
                    feasible = False
                    break
                nodes.append(StorageFormat(fid, coding))
            if not feasible:
                continue
            g_coding = (choose_coding(prof, fg, golden_group)
                        if golden_group else GOLDEN_CODING)
            if g_coding is None:
                continue
            nodes.append(StorageFormat(fg, g_coding))
            sto = sum(prof.storage_profile(sf)[1] for sf in set(nodes))
            ing = sum(prof.storage_profile(sf)[0] for sf in set(nodes))
            key = (sto, ing)
            if best is None or key < best[0]:
                best = (key, set(nodes))
        return best

    (best_cost, best_set) = best_partition()
    got = {n.sf for n in res.nodes}
    got_cost = (res.storage_cost, res.ingest_cost)
    # same storage cost as the optimum (identical sets modulo ties)
    assert abs(got_cost[0] - best_cost[0]) < 1e-6 or got == best_set


def test_ingest_budget_adaptation():
    plans = _plans()
    prof = _mk_profiler(plans)
    free = coalesce(prof, plans)
    budget = free.ingest_cost * 0.6
    tight = coalesce(prof, plans, ingest_budget=budget)
    assert tight.ingest_cost <= budget or not tight.budget_met
    if tight.budget_met:
        # trades storage for ingest (Table 3)
        assert tight.storage_cost >= free.storage_cost - 1e-9


def test_choose_coding_prefers_cheapest_feasible():
    plans = [_plans()[0]]  # slow consumer: everything feasible
    prof = _mk_profiler(plans)
    c = choose_coding(prof, plans[0].cf, plans)
    assert c == CodingOption("slowest", 250)  # min storage in the table
    fast = [ConsumerPlan(Consumer("fast", 0.9), plans[0].cf, 0.9, 1e9)]
    assert choose_coding(prof, fast[0].cf, fast) is None or \
        choose_coding(prof, fast[0].cf, fast) == RAW
