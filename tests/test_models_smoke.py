"""Per-architecture smoke tests: reduced same-family configs run one
forward + one grad step on CPU with finite outputs and correct shapes
(assignment requirement f).  Full configs are exercised only via the
dry-run."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.models import forward, init_params, lm_loss

RNG = jax.random.PRNGKey(0)
KT, KL, KE = jax.random.split(RNG, 3)
B, S = 2, 16


def _batch(cfg):
    batch = {}
    if cfg.frontend == "tokens":
        batch["tokens"] = jax.random.randint(KT, (B, S), 0, cfg.vocab_size)
    else:
        batch["embeds"] = jax.random.normal(KE, (B, S, cfg.d_model)) * 0.02
        if cfg.mrope:
            batch["mrope_positions"] = jnp.broadcast_to(
                jnp.arange(S), (3, B, S))
    batch["labels"] = jax.random.randint(KL, (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch_id", sorted(ARCHS))
def test_smoke_forward_and_grad(arch_id):
    cfg = get_config(arch_id).reduced()
    params = init_params(cfg, RNG)
    batch = _batch(cfg)
    logits = forward(params, cfg, batch, moe_dispatch="dense")
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, grads = jax.value_and_grad(lm_loss)(params, cfg, batch, "dense")
    assert bool(jnp.isfinite(loss)) and loss > 0
    gnorm = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads))
    assert bool(jnp.isfinite(gnorm)) and gnorm > 0


def test_registry_complete():
    assert len(ARCHS) == 10
    with pytest.raises(KeyError):
        get_config("nope")


def test_param_counts_plausible():
    """Analytic parameter counts should be near the published sizes."""
    expect = {
        "starcoder2-3b": 3.0e9, "smollm-135m": 1.35e8, "gemma2-2b": 2.6e9,
        "qwen1.5-0.5b": 4.6e8, "recurrentgemma-9b": 9e9,
        "qwen2-moe-a2.7b": 1.4e10, "arctic-480b": 4.8e11,
        "qwen2-vl-72b": 7.2e10, "falcon-mamba-7b": 7.3e9,
        "hubert-xlarge": 1e9,
    }
    for arch_id, n in expect.items():
        got = ARCHS[arch_id].param_count()
        assert 0.5 * n < got < 2.0 * n, (arch_id, got, n)


def test_moe_active_params_smaller():
    for arch_id in ("qwen2-moe-a2.7b", "arctic-480b"):
        cfg = ARCHS[arch_id]
        assert cfg.active_param_count() < 0.5 * cfg.param_count()
