"""Continuous telemetry & SLO accounting: crash-safe log durability
(append/fsync, torn-tail truncation, read-only tailing), sampler
behavior, SLO deadline derivation from profiled speeds, burn-rate
windows, alert dedup, per-query cost attribution, cluster merge
bit-exactness (hypothesis property included), a SIGKILL'd shard whose
log reopens cleanly, and the vtop dashboard."""

import functools
import os
import struct
import tempfile
import time

import pytest

from _hyp_compat import given, settings, st
from repro.analytics.query import QueryCost, run_query, stage_specs
from repro.analytics.scene import generate_segment
from repro.core.knobs import IngestSpec
from repro.launch import vtop
from repro.launch.vserve import demo_config
from repro.obs.metrics import Histogram, MetricsRegistry
from repro.obs.telemetry import (AlertDeduper, BurnRate, SLOClass,
                                 TelemetryError, TelemetryLog,
                                 TelemetrySampler, derive_deadline_ms,
                                 drift_alert_candidates, merge_frames,
                                 read_frames)
from repro.serving import VStoreServer
from repro.videostore import VideoStore

N_SEGS = 2


@functools.cache
def _built_store():
    root = tempfile.mkdtemp(prefix="repro_telemetry_")
    spec = IngestSpec()
    cfg = demo_config()
    vs = VideoStore(root, spec)
    vs.set_formats(cfg.storage_formats())
    for seg in range(N_SEGS):
        frames, _ = generate_segment("jackson", seg, spec)
        vs.ingest_segment("jackson", seg, frames)
    return vs, cfg


# ---------------------------------------------------------------------------
# TelemetryLog durability
# ---------------------------------------------------------------------------

def test_log_append_read_roundtrip(tmp_path):
    path = str(tmp_path / "a.vtl")
    with TelemetryLog(path) as log:
        assert log.append({"t": 1.0, "x": 1}) == 1
        assert log.append({"t": 2.0, "x": 2}) == 2
        assert log.seq == 2
    frames = read_frames(path)
    assert [f["seq"] for f in frames] == [1, 2]
    assert [f["x"] for f in frames] == [1, 2]


def test_log_reopen_resumes_sequence(tmp_path):
    path = str(tmp_path / "a.vtl")
    with TelemetryLog(path) as log:
        for i in range(3):
            log.append({"i": i})
    log2 = TelemetryLog(path)
    assert log2.frames_recovered == 3
    assert log2.truncated_bytes == 0
    assert log2.append({"i": 3}) == 4
    log2.close()
    assert [f["seq"] for f in read_frames(path)] == [1, 2, 3, 4]


def test_log_truncates_torn_tail_on_writable_reopen(tmp_path):
    path = str(tmp_path / "a.vtl")
    with TelemetryLog(path) as log:
        log.append({"i": 0})
        log.append({"i": 1})
    # simulate a crash mid-append: a length prefix promising more bytes
    # than were ever written
    with open(path, "ab") as f:
        f.write(struct.pack(">I", 1 << 20) + b"\x00\x01\x02")
    log2 = TelemetryLog(path)
    assert log2.frames_recovered == 2
    assert log2.truncated_bytes == 7  # 4-byte length prefix + 3 torn bytes
    assert log2.append({"i": 2}) == 3  # lands on a clean frame boundary
    log2.close()
    assert [f["i"] for f in read_frames(path)] == [0, 1, 2]


def test_read_frames_skips_torn_tail_without_mutating(tmp_path):
    path = str(tmp_path / "a.vtl")
    with TelemetryLog(path) as log:
        log.append({"i": 0})
    with open(path, "ab") as f:
        f.write(struct.pack(">I", 64) + b"short")
    size = os.path.getsize(path)
    frames = read_frames(path)
    assert [f["i"] for f in frames] == [0]
    assert os.path.getsize(path) == size  # read-only: tail untouched


def test_log_rejects_bad_magic(tmp_path):
    path = str(tmp_path / "junk.vtl")
    with open(path, "wb") as f:
        f.write(b"NOTATELEMETRYLOG")
    with pytest.raises(TelemetryError):
        read_frames(path)
    with pytest.raises(TelemetryError):
        TelemetryLog(path)


def test_closed_log_refuses_appends(tmp_path):
    log = TelemetryLog(str(tmp_path / "a.vtl"))
    log.close()
    with pytest.raises(TelemetryError):
        log.append({})


# ---------------------------------------------------------------------------
# sampler
# ---------------------------------------------------------------------------

def test_sampler_sample_now_and_final_frame(tmp_path):
    path = str(tmp_path / "s.vtl")
    reg = MetricsRegistry()
    reg.inc("completed", 5)

    def body():
        return {"metrics": reg.snapshot()}

    s = TelemetrySampler(body, TelemetryLog(path), interval_s=30.0,
                         clock=lambda: 123.0)
    assert s.sample_now() == 1
    s.stop(final=True)  # second (final) frame, then close
    assert s.samples == 2
    frames = read_frames(path)
    assert len(frames) == 2
    assert frames[0]["t"] == 123.0
    assert frames[0]["metrics"]["counters"]["completed"] == 5


def test_sampler_swallows_source_failures(tmp_path):
    s = TelemetrySampler(lambda: 1 / 0, TelemetryLog(str(tmp_path / "e.vtl")),
                         interval_s=30.0)
    assert s.sample_now() is None
    assert s.errors == 1 and s.samples == 0
    s.stop(final=False)


def test_sampler_background_loop(tmp_path):
    path = str(tmp_path / "bg.vtl")
    s = TelemetrySampler(lambda: {"x": 1}, TelemetryLog(path),
                         interval_s=0.01).start()
    deadline = time.monotonic() + 5.0
    while s.samples < 3 and time.monotonic() < deadline:
        time.sleep(0.01)
    s.stop(final=True)
    frames = read_frames(path)
    assert len(frames) >= 4
    assert [f["seq"] for f in frames] == list(range(1, len(frames) + 1))


# ---------------------------------------------------------------------------
# SLO classes / deadline derivation / burn / alerts
# ---------------------------------------------------------------------------

def test_slo_class_validation():
    with pytest.raises(ValueError):
        SLOClass("x", slack_x=0.0)
    with pytest.raises(ValueError):
        SLOClass("x", target_miss_frac=0.0)


def test_derive_deadline_from_profiled_speeds():
    """The satellite contract: a class-tagged query's deadline comes from
    the DerivedConfig's *profiled* per-knob speeds — slack_x times the
    sum of per-stage full-scan times at the chosen accuracy."""
    cfg = demo_config()
    spec = IngestSpec()
    for q, acc in (("A", 0.8), ("B", 0.9)):
        ops = [s[0] for s in stage_specs(cfg, q, acc)]
        video_s = 3 * spec.segment_seconds
        want = 2.5 * sum(video_s / cfg.consumer_speed(op, acc)
                         for op in ops) * 1e3
        got = derive_deadline_ms(cfg, spec, ops, acc, 3, slack_x=2.5)
        assert got == pytest.approx(want)
        assert got > 0


def test_server_derive_deadline_matches_module_fn():
    vs, cfg = _built_store()
    with VStoreServer(vs, cfg, workers=1) as srv:
        srv.register_slo("interactive", slack_x=4.0)
        ops = [s[0] for s in stage_specs(cfg, "A", 0.8)]
        want = derive_deadline_ms(cfg, vs.spec, ops, 0.8, N_SEGS,
                                  slack_x=4.0)
        assert srv.derive_deadline("A", 0.8, N_SEGS,
                                   "interactive") == pytest.approx(want)
        with pytest.raises(KeyError):
            srv.derive_deadline("A", 0.8, N_SEGS, "nope")


def test_burn_rate_windowing():
    now = [0.0]
    br = BurnRate(SLOClass("x", target_miss_frac=0.1, window_s=10.0),
                  clock=lambda: now[0])
    for _ in range(8):
        br.record(False)
    br.record(True)
    br.record(True)
    s = br.snapshot()
    assert s["window_total"] == 10 and s["window_misses"] == 2
    assert s["burn"] == pytest.approx(0.2 / 0.1)
    now[0] = 11.0  # everything ages out of the window
    s = br.snapshot()
    assert s["window_total"] == 0 and s["burn"] == 0.0
    assert s["hits"] == 8 and s["misses"] == 2  # lifetime counters stay


def test_alert_deduper_window():
    now = [0.0]
    d = AlertDeduper(window_s=30.0, clock=lambda: now[0],
                     wall=lambda: 99.0)
    assert d.emit("k", "warn", "m1") is True
    assert d.emit("k", "warn", "m2") is False  # deduped inside the window
    assert d.emit("other", "warn", "m3") is True
    now[0] = 31.0
    assert d.emit("k", "warn", "m4") is True
    drained = d.drain()
    assert [a["message"] for a in drained] == ["m1", "m3", "m4"]
    assert all(a["t"] == 99.0 for a in drained)
    assert d.drain() == []


def test_drift_alerts_dedup_across_reports():
    report = {"consumption": {
        "nn@0.9": {"drifted": True, "expected_x": 30.0, "observed_x": 10.0,
                   "ratio": 0.33},
        "diff@0.8": {"drifted": False, "expected_x": 1.0, "observed_x": 1.0,
                     "ratio": 1.0}},
        "retrieval": {}}
    cands = drift_alert_candidates(report)
    assert [k for k, _m, _a in cands] == ["drift:consumption:nn@0.9"]
    now = [0.0]
    d = AlertDeduper(window_s=30.0, clock=lambda: now[0])
    emitted = [d.emit(k, "warn", m, **a) for k, m, a in cands]
    # the same report scraped again inside the window adds nothing
    emitted += [d.emit(k, "warn", m, **a) for k, m, a in cands]
    assert emitted == [True, False]
    assert len(d.drain()) == 1


# ---------------------------------------------------------------------------
# cluster merge semantics (incl. the hypothesis property)
# ---------------------------------------------------------------------------

def _body(counters=None, hist_vals=(), queues=None, classes=None,
          alerts=()):
    h = Histogram()
    for v in hist_vals:
        h.observe(v)
    return {"metrics": {"counters": dict(counters or {}), "gauges": {},
                        "histograms": {"query_latency_s": h.snapshot()}},
            "slo": {"queues": queues or {}, "classes": classes or {}},
            "alerts": list(alerts)}


def test_merge_frames_sums_counters_and_keeps_worst_burn():
    a = _body({"deadline_hits": 3, "deadline_misses": 1}, (0.1, 0.2),
              classes={"x": {"hits": 3, "misses": 1, "window_total": 4,
                             "window_misses": 1, "burn": 0.5,
                             "window_miss_rate": 0.25}},
              alerts=[{"key": "k1", "severity": "warn", "message": "m"}])
    b = _body({"deadline_hits": 2, "deadline_misses": 4}, (0.4,),
              classes={"x": {"hits": 2, "misses": 4, "window_total": 6,
                             "window_misses": 4, "burn": 2.0,
                             "window_miss_rate": 0.66}})
    m = merge_frames([a, b])
    c = m["metrics"]["counters"]
    assert c["deadline_hits"] == 5 and c["deadline_misses"] == 5
    assert m["metrics"]["histograms"]["query_latency_s"]["count"] == 3
    cls = m["slo"]["classes"]["x"]
    assert cls["hits"] == 5 and cls["misses"] == 5
    assert cls["burn"] == 2.0  # worst shard, never averaged
    assert m["alerts"] == [{"key": "k1", "severity": "warn",
                            "message": "m", "source": 0}]
    assert m["sources"] == 2


def test_merge_frames_merges_slo_queues():
    qa = {"nn:q1.00_c1.00_r720_s0.67": {
        "hits": 2, "misses": 1, "lateness": _hist_snap([0.01])}}
    qb = {"nn:q1.00_c1.00_r720_s0.67": {
        "hits": 1, "misses": 0, "lateness": _hist_snap([0.5])}}
    m = merge_frames([_body(queues=qa), _body(queues=qb)])
    row = m["slo"]["queues"]["nn:q1.00_c1.00_r720_s0.67"]
    assert row["hits"] == 3 and row["misses"] == 1
    assert row["lateness"]["count"] == 2


def _hist_snap(vals):
    h = Histogram()
    for v in vals:
        h.observe(v)
    return h.snapshot()


@settings(max_examples=30, deadline=None)
@given(st.lists(st.lists(st.floats(0.0, 20.0, allow_nan=False,
                                   allow_infinity=False),
                         max_size=20), min_size=1, max_size=5))
def test_merged_histogram_equals_single_process(shards):
    """The bit-exactness property behind the cluster rollup: sharding the
    observations across N processes and bucket-merging their snapshots
    yields the same distribution as one process observing everything —
    identical bucket counts, extrema, and (hence) percentiles."""
    single = Histogram()
    for vals in shards:
        for v in vals:
            single.observe(v)
    merged = Histogram.merge([_hist_snap(vals) for vals in shards])
    want = single.snapshot()
    for k in ("count", "counts", "min", "max", "p50", "p95", "p99",
              "bounds"):
        assert merged[k] == want[k], k
    assert merged["sum"] == pytest.approx(want["sum"])


# ---------------------------------------------------------------------------
# server SLO accounting + per-query cost attribution
# ---------------------------------------------------------------------------

def test_server_slo_accounting_and_query_cost():
    vs, cfg = _built_store()
    segs = list(range(N_SEGS))
    run_query(vs, cfg, "A", "jackson", segs, 0.8)  # warm jit caches
    with VStoreServer(vs, cfg, workers=2, collapse=False) as srv:
        srv.register_slo("interactive", slack_x=50.0,
                         target_miss_frac=0.5)
        srv.register_slo("doomed", slack_x=50.0, target_miss_frac=0.01)
        # generous derived deadline -> hit
        hit = srv.submit("A", "jackson", segs, 0.8, block=True,
                         slo_class="interactive").result(120)
        # explicit impossible deadline -> miss, burns the tight class
        miss = srv.submit("A", "jackson", segs, 0.8, block=True,
                          deadline_ms=0.001, slo_class="doomed").result(120)
        st_ = srv.stats()
        body = srv.telemetry_body()
    assert hit.cost.deadline_met and hit.cost.deadline_ms > 0
    assert hit.cost.deadline_slack_s > 0
    assert not miss.cost.deadline_met and miss.cost.deadline_slack_s < 0
    assert st_["deadline_hits"] == 1 and st_["deadline_misses"] == 1
    # cost attribution: the cold query decoded real bytes
    first = hit if hit.cost.decode_bytes else miss
    assert first.cost.decode_bytes > 0 and first.cost.decode_chunks > 0
    assert first.cost.decoded_frames > 0
    assert (hit.cost.detect_calls > 0 and hit.cost.detect_frames > 0)
    total = QueryCost()
    total.add(hit.cost)
    total.add(miss.cost)
    # the second identical query was served from cache/planner sharing:
    # summed ledgers still account every fetch
    assert (total.cache_hits + total.cache_richer_hits
            + total.cache_inflight_hits + total.cache_misses) > 0
    assert total.queue_wait_s >= 0.0
    # telemetry frame: counters folded in, burn + alert for the miss
    c = body["metrics"]["counters"]
    assert c["deadline_hits"] == 1 and c["deadline_misses"] == 1
    assert c["completed"] == 2
    assert body["slo"]["classes"]["doomed"]["burn"] > 1.0
    assert any(a["key"] == "slo_burn:doomed" for a in body["alerts"])
    assert "query_latency_s" in body["metrics"]["histograms"]


def test_scheduler_slo_snapshot_counts_deadlined_units():
    vs, cfg = _built_store()
    segs = list(range(N_SEGS))
    with VStoreServer(vs, cfg, workers=2, collapse=False,
                      cross_query_batching=True) as srv:
        srv.submit("A", "jackson", segs, 0.8, block=True,
                   deadline_ms=600_000.0).result(120)
        srv.submit("A", "jackson", segs, 0.8, block=True).result(120)
        snap = srv.sched.slo_snapshot()
        st_ = srv.stats()
    assert snap, "deadlined units must appear in the SLO snapshot"
    hits = sum(r["hits"] for r in snap.values())
    misses = sum(r["misses"] for r in snap.values())
    assert hits > 0 and misses == 0  # 10-minute slack cannot miss
    for row in snap.values():
        assert row["lateness"]["count"] == row["hits"] + row["misses"]
    assert st_["sched_deadline_hits"] == hits
    assert st_["sched_deadline_misses"] == 0


def test_query_cost_rides_the_wire():
    from repro.analytics.query import QueryResult
    from repro.cluster import pack, unpack
    res = QueryResult(items={(1, 0.5, "car")}, stages=[],
                      video_seconds=1.0, wall_s=0.5,
                      cost=QueryCost(decode_bytes=7, deadline_ms=9.0,
                                     deadline_met=False))
    back = QueryResult.from_wire(unpack(pack(res.to_wire())))
    assert back.cost == res.cost
    # pre-cost peers (older wire frames) default to an empty ledger
    d = res.to_wire()
    del d["cost"]
    assert QueryResult.from_wire(d).cost == QueryCost()


# ---------------------------------------------------------------------------
# cluster: per-shard logs, merged scrape, SIGKILL mid-sampling
# ---------------------------------------------------------------------------

def test_cluster_telemetry_survives_sigkill_mid_sampling(tmp_path):
    """Workers sample their own crash-safe logs; the router's scrape
    merges live shards with exact counter sums; a SIGKILL'd worker's log
    reopens readable to the last fsync'd frame with a contiguous seq."""
    from repro.cluster import ShardRouter
    spec = IngestSpec()
    cfg = demo_config()
    tdir = str(tmp_path / "vtl")
    streams = ["jackson", "tucson"]  # hash to shards 1 and 0
    router = ShardRouter(str(tmp_path / "cluster"), cfg, 2, spec=spec,
                         opts={"workers": 1, "telemetry_dir": tdir,
                               "telemetry_interval_s": 0.05,
                               "slo_classes": {
                                   "interactive": {"slack_x": 50.0}}})
    try:
        router.start()
        router.attach_telemetry(interval_s=0.05)
        for s in streams:
            router.ingest(s, 0, generate_segment(s, 0, spec)[0])
        # distinct submissions: identical in-flight queries collapse onto
        # one execution, which would (correctly) count one SLO outcome
        subs = [("A", s, [0], acc, {"slo_class": "interactive"})
                for s in streams for acc in (0.8, 0.9)]
        router.query_many(subs)

        # force one durable sample per worker, then check the merged
        # scrape's deadline counters equal the per-shard sums bit-exactly
        for h in router.hosts:
            assert h.call("sample_telemetry") >= 1
        parts = [h.call("telemetry") for h in router.hosts]
        merged = router.telemetry_scrape()
        for key in ("deadline_hits", "deadline_misses", "completed"):
            want = sum(p["metrics"]["counters"].get(key, 0) for p in parts)
            assert merged["metrics"]["counters"].get(key, 0) == want, key
        assert merged["metrics"]["counters"]["deadline_hits"] == len(subs)
        assert merged["sources"] == 2
        assert all(s["alive"] for s in merged["shards"])

        victim = router.host_of("jackson")
        path = os.path.join(tdir, f"shard-{victim.idx:02d}.vtl")
        deadline = time.monotonic() + 10.0
        while len(read_frames(path)) < 3 and time.monotonic() < deadline:
            time.sleep(0.02)
        victim.kill()  # SIGKILL with the sampler loop mid-flight

        # the dead worker's log reads cleanly to the last fsync'd frame
        frames = read_frames(path)
        assert len(frames) >= 3
        assert [f["seq"] for f in frames] == list(range(1, len(frames) + 1))
        assert frames[-1]["metrics"]["counters"]["deadline_hits"] >= 1

        # a monitoring scrape skips the dead shard instead of respawning
        merged2 = router.telemetry_scrape()
        assert merged2["sources"] == 1
        dead = [s for s in merged2["shards"] if not s["alive"]]
        assert [s["shard"] for s in dead] == [victim.idx]
        assert (victim.process is None
                or not victim.process.is_alive())

        # a writable reopen (what the respawned worker does) lands on a
        # frame boundary and resumes the sequence
        relog = TelemetryLog(path)
        assert relog.frames_recovered == len(frames)
        assert relog.append({"probe": True}) == len(frames) + 1
        relog.close()
    finally:
        router.close()

    # the router's own merged series reached cluster.vtl durably
    cluster_frames = read_frames(os.path.join(tdir, "cluster.vtl"))
    assert cluster_frames
    assert [f["seq"] for f in cluster_frames] == \
        list(range(1, len(cluster_frames) + 1))
    assert cluster_frames[-1]["shards"]


# ---------------------------------------------------------------------------
# vtop
# ---------------------------------------------------------------------------

def test_vtop_render_sources():
    frames = [
        _body({"completed": 4, "deadline_hits": 3, "deadline_misses": 1,
               "cache_lookups": 10, "cache_hits": 6, "decodes": 4,
               "decode_bytes": 1 << 20},
              (0.05, 0.1),
              classes={"x": {"burn": 2.0, "window_misses": 1,
                             "window_total": 4, "target_miss_frac": 0.01,
                             "window_s": 60.0}},
              alerts=[{"key": "slo_burn:x", "severity": "critical",
                       "message": "budget exceeded"}]),
    ]
    frames[0]["t"] = 100.0
    frames[0]["seq"] = 1
    cluster = dict(frames[0])
    cluster["shards"] = [{"shard": 0, "alive": True, "generation": 1,
                          "restarts": 0},
                         {"shard": 1, "alive": False, "generation": 2,
                          "restarts": 1}]
    cluster["sources"] = 2
    out = vtop.render({"cluster": [cluster], "shard-00": frames},
                      clock=lambda: 101.0)
    assert "cluster" in out.splitlines()[2]  # merged series renders first
    assert "3 hit / 1 missed" in out
    assert "BURNING" in out
    assert "slo_burn:x" in out
    assert "0:up/g1/r0" in out and "1:DOWN/g2/r1" in out
    assert "60% hit" in out
    assert vtop.render({}) == "vtop: no telemetry frames yet"


def test_vtop_rate_from_counter_deltas():
    a = _body({"completed": 10})
    b = _body({"completed": 25})
    a["t"], b["t"] = 100.0, 105.0
    assert vtop._rate([a, b], "completed") == pytest.approx(3.0)
    assert vtop._rate([a], "completed") == 0.0


def test_vtop_once_over_real_logs(tmp_path, capsys):
    d = str(tmp_path)
    with TelemetryLog(os.path.join(d, "server.vtl")) as log:
        body = _body({"completed": 2})
        body["t"] = 1.0
        log.append(body)
    assert vtop.main(["--telemetry", d, "--once"]) == 0
    out = capsys.readouterr().out
    assert "server: 1 frames" in out and "2 done" in out
