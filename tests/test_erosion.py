"""Erosion planning (paper §4.4): relative-speed formula, max-min overall
speed, monotone decay, golden-format immunity, storage-budget respect,
binary search on k."""

import pytest

from repro.core.coalesce import SFNode
from repro.core.consumption import Consumer, ConsumerPlan
from repro.core.erosion import _Chains, plan_erosion
from repro.core.knobs import (GOLDEN_CODING, RAW, CodingOption,
                              FidelityOption)
from repro.core.profiler import TableProfiler


def _setup():
    f_lo = FidelityOption("bad", 1.0, 180, 1 / 5)
    f_mid = FidelityOption("good", 1.0, 540, 1 / 2)
    f_hi = FidelityOption("best", 1.0, 720, 1.0)
    p1 = ConsumerPlan(Consumer("fast", 0.8), f_lo, 0.85, 1000.0)
    p2 = ConsumerPlan(Consumer("slow", 0.9), f_mid, 0.92, 50.0)
    nodes = [
        SFNode(f_lo, RAW, [p1]),
        SFNode(f_mid, CodingOption("slow", 50), [p2]),
        SFNode(f_hi, GOLDEN_CODING, [], golden=True),
    ]
    retrieve = {
        (f_lo, RAW, f_lo): 5000.0,
        (f_mid, CodingOption("slow", 50), f_lo): 400.0,
        (f_mid, CodingOption("slow", 50), f_mid): 300.0,
        (f_hi, GOLDEN_CODING, f_lo): 60.0,
        (f_hi, GOLDEN_CODING, f_mid): 80.0,
    }
    prof = TableProfiler({}, {}, {}, retrieve)
    subs = {p1: 0, p2: 1}
    return nodes, subs, prof, (p1, p2)


def test_relative_speed_closed_form():
    nodes, subs, prof, (p1, p2) = _setup()
    chains = _Chains(prof, nodes, subs)
    # consumer p1: own speed min(5000, 1000)=1000; on parent f_mid:
    # min(400, 1000)=400 -> alpha=0.4
    for p_frac in (0.0, 0.25, 0.5, 1.0):
        e = {0: p_frac}
        i = next(i for i, (pl, _, _) in enumerate(chains.chains)
                 if pl is p1)
        alpha = 0.4
        expected = alpha / ((1 - p_frac) * alpha + p_frac) if p_frac < 1 \
            else alpha
        assert chains.relative_speed(i, e) == pytest.approx(expected,
                                                            rel=1e-6)


def test_overall_is_min_and_pmin():
    nodes, subs, prof, _ = _setup()
    chains = _Chains(prof, nodes, subs)
    assert chains.overall({}) == pytest.approx(1.0)
    pmin = chains.p_min()
    assert 0 < pmin < 1
    # golden can serve everyone
    assert chains.overall({0: 1.0, 1: 1.0}) == pytest.approx(pmin)


def test_plan_respects_budget_and_monotonicity():
    nodes, subs, prof, _ = _setup()
    daily = [1000.0, 3000.0, 5000.0]
    lifespan = 8
    full = sum(daily) * lifespan
    plan = plan_erosion(prof, nodes, subs, daily, lifespan,
                        storage_budget_bytes=0.7 * full)
    assert plan.feasible
    assert plan.total_bytes <= 0.7 * full + 1e-6
    # fractions monotone over ages; golden (idx 2) never eroded
    for a in range(1, lifespan):
        for i in range(3):
            assert plan.fractions[a].get(i, 0) >= \
                plan.fractions[a - 1].get(i, 0) - 1e-9
        assert plan.fractions[a].get(2, 0) == 0
    # overall speed non-increasing
    assert all(s1 >= s2 - 1e-9 for s1, s2 in
               zip(plan.overall_speed, plan.overall_speed[1:]))


def test_no_decay_when_budget_ample():
    nodes, subs, prof, _ = _setup()
    daily = [1.0, 1.0, 1.0]
    plan = plan_erosion(prof, nodes, subs, daily, 5,
                        storage_budget_bytes=1e9)
    assert plan.k == 0.0 and all(s == 1.0 for s in plan.overall_speed)


def test_infeasible_budget_flagged():
    nodes, subs, prof, _ = _setup()
    daily = [1000.0, 1000.0, 1000.0]
    # even keeping only golden exceeds this budget
    plan = plan_erosion(prof, nodes, subs, daily, 5,
                        storage_budget_bytes=100.0)
    assert not plan.feasible


def test_higher_k_never_costs_more():
    nodes, subs, prof, _ = _setup()
    daily = [1000.0, 3000.0, 5000.0]
    full = sum(daily) * 8
    gentle = plan_erosion(prof, nodes, subs, daily, 8, 0.9 * full)
    harsh = plan_erosion(prof, nodes, subs, daily, 8, 0.4 * full)
    assert harsh.k >= gentle.k
    assert harsh.total_bytes <= gentle.total_bytes + 1e-6
