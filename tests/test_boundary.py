"""Boundary search (paper §4.2): on arbitrary monotone 2D grids the
staircase walk probes O(rows+cols) cells, finds every per-row minimal
adequate cell, and — combined with cost selection — matches exhaustive
search exactly."""

import numpy as np
from _hyp_compat import given, settings, st

from repro.core.boundary import boundary_search


def _monotone_grid(rng, rows, cols):
    """Random accuracy grid monotone non-decreasing in both axes: a 2D
    cumulative sum of non-negative increments."""
    inc = rng.uniform(0, 0.3, (rows, cols))
    g = np.cumsum(np.cumsum(inc, axis=0), axis=1)
    return g / g.max()


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 9), st.integers(2, 12),
       st.floats(0.05, 0.95))
def test_boundary_matches_exhaustive(seed, rows, cols, target):
    rng = np.random.default_rng(seed)
    acc = _monotone_grid(rng, rows, cols)
    probes_count = [0]

    def adequate(r, c):
        probes_count[0] += 1
        return acc[r, c] >= target

    points, probes = boundary_search(rows, cols, adequate)
    assert probes == probes_count[0] <= rows + cols

    # exhaustive minimal adequate cells per row
    expected = []
    for r in range(rows - 1, -1, -1):
        ok = np.nonzero(acc[r] >= target)[0]
        if len(ok) == 0:
            break
        expected.append((r, int(ok[0])))
    assert points == expected

    # min-cost adequate point is on the boundary when cost is monotone
    cost = _monotone_grid(rng, rows, cols)  # richer = costlier
    adequate_cells = [(r, c) for r in range(rows) for c in range(cols)
                      if acc[r, c] >= target]
    if adequate_cells:
        best = min(adequate_cells, key=lambda rc: cost[rc])
        assert cost[best] >= min(cost[p] for p in points) - 1e-12


def test_probe_bound_tight():
    # all adequate: walk stays in the first column -> rows probes
    points, probes = boundary_search(5, 7, lambda r, c: True)
    assert probes == 5 and len(points) == 5
    # none adequate: walk exits after one row -> cols probes
    points, probes = boundary_search(5, 7, lambda r, c: False)
    assert probes == 7 and points == []
