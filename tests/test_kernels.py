"""Pallas kernels vs pure-jnp oracles (interpret mode): shape/dtype sweeps
with assert_allclose, plus hypothesis properties for the scan kernels."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hyp_compat import given, settings, st

from repro.kernels.attention.attention import flash_attention
from repro.kernels.attention.ops import gqa_attention
from repro.kernels.attention.ref import attention_ref
from repro.kernels.dct8.dct8 import dct8_dequantize, dct8_quantize
from repro.kernels.dct8.ref import dct8_dequantize_ref, dct8_quantize_ref
from repro.kernels.mamba_scan.mamba_scan import mamba_scan
from repro.kernels.mamba_scan.ref import mamba_scan_ref
from repro.kernels.resize.resize import resize_bilinear
from repro.kernels.resize.ref import resize_ref
from repro.kernels.rglru.ref import rglru_scan_ref
from repro.kernels.rglru.rglru import rglru_scan

RNG = jax.random.PRNGKey(7)


# -- dct8 --------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(1, 8, 8), (3, 24, 48), (2, 16, 128)])
@pytest.mark.parametrize("qs", [1.0, 6.0, 16.0])
def test_dct8_matches_ref(shape, qs):
    x = jax.random.normal(RNG, shape) * 40 + 128
    a = np.asarray(dct8_quantize(x, qs, interpret=True))
    b = np.asarray(dct8_quantize_ref(x, qs))
    np.testing.assert_array_equal(a, b)
    ra = np.asarray(dct8_dequantize(jnp.asarray(a), qs, interpret=True))
    rb = np.asarray(dct8_dequantize_ref(jnp.asarray(b), qs))
    np.testing.assert_allclose(ra, rb, atol=1e-3)


# -- flash attention ----------------------------------------------------------

@pytest.mark.parametrize(
    "b,h,s,hd,causal,window,cap,dtype",
    [(2, 3, 192, 64, True, 0, 0.0, jnp.float32),
     (1, 2, 256, 32, True, 64, 50.0, jnp.float32),
     (2, 2, 128, 64, False, 0, 0.0, jnp.float32),
     (1, 2, 130, 64, True, 0, 0.0, jnp.float32),    # non-divisible seq
     (1, 2, 128, 64, True, 0, 0.0, jnp.bfloat16)])
def test_flash_attention_matches_ref(b, h, s, hd, causal, window, cap,
                                     dtype):
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, h, s, hd), dtype)
    k = jax.random.normal(ks[1], (b, h, s, hd), dtype)
    v = jax.random.normal(ks[2], (b, h, s, hd), dtype)
    a = flash_attention(q, k, v, causal=causal, window=window, logit_cap=cap,
                        q_block=64, k_block=64, interpret=True)
    r = attention_ref(q, k, v, causal=causal, window=window, logit_cap=cap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(r, np.float32), atol=tol)


def test_gqa_wrapper_broadcasts_kv():
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (2, 64, 8, 32))   # (B, S, H, hd)
    k = jax.random.normal(ks[1], (2, 64, 2, 32))   # KV=2
    v = jax.random.normal(ks[2], (2, 64, 2, 32))
    out_pl = gqa_attention(q, k, v, causal=True, use_pallas=True,
                           interpret=True)
    out_ref = gqa_attention(q, k, v, causal=True, use_pallas=False)
    np.testing.assert_allclose(np.asarray(out_pl), np.asarray(out_ref),
                               atol=2e-5)


# -- rglru --------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.integers(0, 999), st.integers(1, 3), st.integers(3, 130),
       st.integers(4, 70))
def test_rglru_matches_ref(seed, b, s, w):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = jax.nn.sigmoid(jax.random.normal(k1, (b, s, w)))
    bb = jax.random.normal(k2, (b, s, w)) * 0.1
    got = rglru_scan(a, bb, width_tile=32, seq_chunk=32, interpret=True)
    ref = rglru_scan_ref(a, bb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


# -- mamba scan ---------------------------------------------------------------

@settings(max_examples=4, deadline=None)
@given(st.integers(0, 999), st.integers(3, 70), st.integers(8, 40),
       st.sampled_from([4, 8, 16]))
def test_mamba_scan_matches_ref(seed, s, inner, n):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    da = jax.nn.sigmoid(jax.random.normal(ks[0], (2, s, inner, n)))
    dbx = jax.random.normal(ks[1], (2, s, inner, n)) * 0.1
    c = jax.random.normal(ks[2], (2, s, n))
    y1, h1 = mamba_scan(da, dbx, c, inner_tile=8, seq_chunk=16,
                        interpret=True)
    y2, h2 = mamba_scan_ref(da, dbx, c)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-5)


# -- resize -------------------------------------------------------------------

@pytest.mark.parametrize("h2,w2", [(24, 40), (16, 32), (48, 80), (36, 60),
                                   (96, 160)])
def test_resize_matches_jax_image(h2, w2):
    x = jax.random.normal(RNG, (2, 48, 80)) * 50 + 128
    a = resize_bilinear(x, h2, w2, interpret=True)
    b = resize_ref(x, h2, w2)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)
