"""Cascade query execution over a real store: early stages filter later
ones; speed accounting; accuracy/cost tradeoff across target levels."""

import pytest

from repro.analytics.query import QUERIES, run_query
from repro.analytics.scene import generate_segment
from repro.core.coalesce import SFNode
from repro.core.configure import DerivedConfig
from repro.core.consumption import Consumer, ConsumerPlan
from repro.core.knobs import (GOLDEN_CODING, RAW, FidelityOption,
                              IngestSpec)
from repro.videostore import VideoStore


def _manual_config():
    """Hand-built two-SF configuration for query A at one accuracy level."""
    cf_diff = FidelityOption("good", 1.0, 270, 1 / 2)
    cf_snn = FidelityOption("good", 1.0, 360, 1 / 2)
    cf_nn = FidelityOption("best", 1.0, 720, 2 / 3)
    plans = [
        ConsumerPlan(Consumer("diff", 0.8), cf_diff, 0.85, 3000.0),
        ConsumerPlan(Consumer("snn", 0.8), cf_snn, 0.86, 500.0),
        ConsumerPlan(Consumer("nn", 0.8), cf_nn, 0.82, 30.0),
    ]
    fast = SFNode(cf_diff.join(cf_snn), RAW, plans[:2])
    golden = SFNode(FidelityOption(), GOLDEN_CODING, [plans[2]], golden=True)

    class _Log:
        nodes = [fast, golden]
        ingest_cost = storage_cost = 0.0
        rounds = []
        budget_met = True

    return DerivedConfig(plans=plans, nodes=[fast, golden], coalesce_log=_Log())


@pytest.fixture(scope="module")
def store_and_config(tmp_path_factory):
    root = tmp_path_factory.mktemp("qstore")
    spec = IngestSpec()
    cfg = _manual_config()
    vs = VideoStore(str(root), spec)
    vs.set_formats(cfg.storage_formats())
    for seg in range(3):
        frames, _ = generate_segment("jackson", seg, spec)
        vs.ingest_segment("jackson", seg, frames)
    return vs, cfg


def test_query_a_runs(store_and_config):
    vs, cfg = store_and_config
    res = run_query(vs, cfg, "A", "jackson", [0, 1, 2], 0.8)
    assert res.video_seconds == 3 * vs.spec.segment_seconds
    assert len(res.stages) == 3
    assert res.pipelined_speed > 0 and \
        res.pipelined_speed >= res.sequential_speed


def test_cascade_filters(store_and_config):
    vs, cfg = store_and_config
    res = run_query(vs, cfg, "A", "jackson", [0, 1, 2], 0.8)
    # later stages never consume more frames than earlier ones
    assert res.stages[1].frames <= res.stages[0].frames * 2  # cf sampling may differ
    assert res.stages[2].segments_scanned <= res.stages[0].segments_scanned


def test_queries_defined():
    assert QUERIES["A"] == ("diff", "snn", "nn")
    assert QUERIES["B"] == ("motion", "license", "ocr")
