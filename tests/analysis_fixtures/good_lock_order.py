"""Clean twin of ``bad_lock_order.py``: both paths agree on A -> B."""

import threading

MU_A = threading.Lock()
MU_B = threading.Lock()


def forward():
    with MU_A:
        with MU_B:
            pass


def also_forward():
    with MU_A:
        with MU_B:
            pass
