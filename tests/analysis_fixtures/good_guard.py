"""Clean twin of ``bad_guard.py``: the mutation holds the lock."""

import threading


class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self.n = 0  # guarded-by: _lock

    def bump(self):
        with self._lock:
            self.n += 1
