"""Seeded violation: mutates a guarded field without holding its lock.

Expected finding: exactly one ``guard`` on ``Counter.bump``.
"""

import threading


class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self.n = 0  # guarded-by: _lock

    def bump(self):
        self.n += 1
