"""Clean twin of ``bad_wire.py``: every field crosses the wire."""

from dataclasses import dataclass


@dataclass
class Packet:
    seq: int
    payload: bytes
    checksum: int

    def to_wire(self) -> dict:
        return {"seq": self.seq, "payload": self.payload,
                "checksum": self.checksum}

    @classmethod
    def from_wire(cls, d: dict) -> "Packet":
        return cls(seq=d["seq"], payload=d["payload"],
                   checksum=d["checksum"])
