"""Seeded violation: a data-dependent slice fed straight to a jitted
function — every distinct bound retraces.

Expected finding: exactly one ``jit-shape`` in ``consume``.
"""

import jax


@jax.jit
def kernel(x):
    return x * 2


def consume(x, k):
    return kernel(x[:k])
