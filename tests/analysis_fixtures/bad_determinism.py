"""Seeded violation: hash() in a placement path.

Expected finding: exactly one ``determinism`` on ``place``.
"""

# analysis: determinism-path


def place(key: str, n_shards: int) -> int:
    return hash(key) % n_shards
