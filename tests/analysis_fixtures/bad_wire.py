"""Seeded violation: ``to_wire`` silently drops a dataclass field.

Expected finding: exactly one ``wire-field`` on ``Packet.checksum``.
"""

from dataclasses import dataclass


@dataclass
class Packet:
    seq: int
    payload: bytes
    checksum: int

    def to_wire(self) -> dict:
        return {"seq": self.seq, "payload": self.payload}

    @classmethod
    def from_wire(cls, d: dict) -> "Packet":
        return cls(seq=d["seq"], payload=d["payload"],
                   checksum=d["checksum"])
