"""Clean twin of ``bad_determinism.py``: crc32 is process-stable."""

# analysis: determinism-path

import zlib


def place(key: str, n_shards: int) -> int:
    return zlib.crc32(key.encode()) % n_shards
