"""Clean twin of ``bad_jitshape.py``: the slice goes through a pad
helper, so the jitted call sees a static shape."""

import jax


@jax.jit
def kernel(x):
    return x * 2


def _pad_to(x, n):
    return x  # stand-in for the real pad-then-slice helper


def consume(x, k):
    return kernel(_pad_to(x[:k], 16))
