"""Seeded violation: two code paths nest the same locks in opposite
orders — the classic ABBA deadlock shape.

Expected finding: exactly one ``lock-order`` cycle.
"""

import threading

MU_A = threading.Lock()
MU_B = threading.Lock()


def forward():
    with MU_A:
        with MU_B:
            pass


def backward():
    with MU_B:
        with MU_A:
            pass
