"""benchmarks.run --check regression-guard logic."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks.check import check_rows, parse_derived  # noqa: E402


def _row(name, derived):
    return {"name": name, "us_per_call": 0.0, "derived": derived}


BASE = [
    _row("decode_path", "mode=dense;seed_x=400;fused_x=700;speedup=1.7;"
                        "identical=True"),
    _row("decode_path", "mode=sparse;seed_x=900;fused_x=2500;speedup=2.6;"
                        "identical=True"),
    _row("other_bench", "query=A;speedup=1.3;identical=True"),
]


def test_parse_derived():
    assert parse_derived("a=1;b=x;c=2.5") == {"a": "1", "b": "x", "c": "2.5"}


def test_identical_run_passes():
    assert check_rows(BASE, list(BASE)) == []


def test_slower_but_within_factor_passes():
    rows = [_row("decode_path", "mode=dense;seed_x=300;fused_x=400;"
                                "speedup=1.0;identical=True"),
            _row("decode_path", "mode=sparse;seed_x=600;fused_x=1400;"
                                "speedup=1.4;identical=True")]
    assert check_rows(BASE, rows, factor=0.5) == []


def test_ratio_regression_fails():
    rows = [_row("decode_path", "mode=dense;seed_x=400;fused_x=100;"
                                "speedup=0.2;identical=True"),
            _row("decode_path", "mode=sparse;seed_x=900;fused_x=2500;"
                                "speedup=2.6;identical=True")]
    violations = check_rows(BASE, rows, factor=0.5)
    assert any("speedup" in v and "mode" in v for v in violations)


def test_boolean_claim_regression_fails():
    rows = [_row("decode_path", "mode=dense;seed_x=400;fused_x=700;"
                                "speedup=1.7;identical=False"),
            _row("decode_path", "mode=sparse;seed_x=900;fused_x=2500;"
                                "speedup=2.6;identical=True")]
    violations = check_rows(BASE, rows)
    assert any("identical regressed" in v for v in violations)


def test_boolean_claim_missing_fails():
    rows = [_row("decode_path", "mode=dense;seed_x=400;fused_x=700;"
                                "speedup=1.7"),  # identical= vanished
            _row("decode_path", "mode=sparse;seed_x=900;fused_x=2500;"
                                "speedup=2.6;identical=True")]
    violations = check_rows(BASE, rows)
    assert any("boolean claim identical missing" in v for v in violations)


def test_absolute_x_metrics_not_compared():
    # *_x x-realtime speeds are host-dependent; a 10x slower machine with
    # intact ratios must pass
    rows = [_row("decode_path", "mode=dense;seed_x=40;fused_x=70;"
                                "speedup=1.7;identical=True"),
            _row("decode_path", "mode=sparse;seed_x=90;fused_x=250;"
                                "speedup=2.6;identical=True")]
    assert check_rows(BASE, rows) == []


def test_error_rows_fail():
    rows = list(BASE) + [_row("decode_path", "ERROR=RuntimeError:boom")]
    violations = check_rows(BASE, rows)
    assert any("ERROR" in v for v in violations)


def test_only_subset_is_checked():
    # other_bench didn't run (--only): its baseline rows are not enforced
    rows = BASE[:2]
    assert check_rows(BASE, rows) == []


def test_missing_row_within_running_bench_fails():
    rows = BASE[:1]  # dense ran, sparse row vanished
    violations = check_rows(BASE, rows)
    assert any("missing" in v for v in violations)


def test_duplicates_keep_best_value():
    rows = list(BASE) + [_row("other_bench", "query=A;speedup=0.1;"
                                             "identical=True")]
    # best duplicate (1.3) passes the ratio check; booleans all True
    assert check_rows(BASE, rows) == []


def test_duplicate_false_taints_boolean():
    rows = list(BASE) + [_row("other_bench", "query=A;speedup=1.3;"
                                             "identical=False")]
    violations = check_rows(BASE, rows)
    assert any("identical regressed" in v for v in violations)


# -- additive-key tolerance (rows that grew new identity knobs) ---------------

def test_added_id_key_still_matches():
    # the bench gained a new identity knob (kint=) after the baseline was
    # committed; the old baseline row must match via the superset fallback
    rows = [_row("decode_path", "mode=dense;kint=10;seed_x=400;fused_x=700;"
                                "speedup=1.7;identical=True"),
            _row("decode_path", "mode=sparse;kint=10;seed_x=900;"
                                "fused_x=2500;speedup=2.6;identical=True"),
            _row("other_bench", "query=A;speedup=1.3;identical=True")]
    assert check_rows(BASE, rows) == []


def test_added_id_key_regression_still_fails():
    rows = [_row("decode_path", "mode=dense;kint=10;seed_x=400;fused_x=100;"
                                "speedup=0.2;identical=True"),
            _row("decode_path", "mode=sparse;kint=10;seed_x=900;"
                                "fused_x=2500;speedup=2.6;identical=True")]
    violations = check_rows(BASE, rows, factor=0.5)
    assert any("speedup" in v for v in violations)


def test_added_id_key_splits_merge_conservatively():
    # one baseline row split into two (new knob, two values): a boolean
    # claim failing in EITHER split taints the match; the guarded ratio
    # takes the best split (duplicate-row semantics)
    rows = [_row("other_bench", "query=A;n=1;speedup=1.5;identical=True"),
            _row("other_bench", "query=A;n=4;speedup=0.9;identical=False")]
    violations = check_rows([BASE[2]], rows)
    assert any("identical regressed" in v for v in violations)
    assert not any("speedup" in v for v in violations)


def test_mismatched_ident_does_not_match():
    rows = [_row("other_bench", "query=B;speedup=1.3;identical=True")]
    violations = check_rows([BASE[2]], rows)
    assert any("row missing" in v for v in violations)
