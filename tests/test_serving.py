"""Serving correctness: prefill + single-token decode reproduces the full
forward pass exactly (fp32 cache, dense MoE dispatch) across attention
flavors, MoE, hybrid and SSM families."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import decode_step, forward, prefill

RNG = jax.random.PRNGKey(0)
KT, KE = jax.random.split(RNG)
B, S, P = 2, 16, 12

CASES = ["starcoder2-3b", "gemma2-2b", "qwen2-moe-a2.7b",
         "recurrentgemma-9b", "falcon-mamba-7b", "qwen2-vl-72b"]


@pytest.mark.parametrize("arch_id", CASES)
def test_decode_matches_forward(arch_id):
    cfg = get_config(arch_id).reduced()
    params = init = jax.tree.map(lambda x: x, None)
    from repro.models import init_params
    params = init_params(cfg, RNG)
    batch = {}
    if cfg.frontend == "tokens":
        batch["tokens"] = jax.random.randint(KT, (B, S), 0, cfg.vocab_size)
    else:
        batch["embeds"] = jax.random.normal(KE, (B, S, cfg.d_model)) * 0.02
        if cfg.mrope:
            batch["mrope_positions"] = jnp.broadcast_to(
                jnp.arange(S), (3, B, S))
    full = forward(params, cfg, batch, moe_dispatch="dense", remat=False)

    pre = {k: (v[:, :, :P] if k == "mrope_positions" else v[:, :P])
           for k, v in batch.items()}
    logits_p, cache = prefill(params, cfg, pre, max_len=S,
                              cache_dtype=jnp.float32, moe_dispatch="dense")
    assert float(jnp.max(jnp.abs(logits_p - full[:, :P]))) < 1e-4

    for t in range(P, S):
        sb = {}
        if cfg.frontend == "tokens":
            sb["tokens"] = batch["tokens"][:, t:t + 1]
        else:
            sb["embeds"] = batch["embeds"][:, t:t + 1]
            if cfg.mrope:
                sb["mrope_positions"] = batch["mrope_positions"][:, :, t:t + 1]
        logits, cache = decode_step(params, cfg, sb, cache,
                                    moe_dispatch="dense")
        err = float(jnp.max(jnp.abs(logits - full[:, t])))
        assert err < 2e-4, (arch_id, t, err)


def test_hybrid_ring_buffer_long_decode():
    """Decode far beyond the local window: the ring buffer keeps constant
    memory while matching the windowed full forward."""
    cfg = get_config("recurrentgemma-9b").reduced()
    # reduced window is 32; decode 48 tokens
    from repro.models import init_params
    params = init_params(cfg, RNG)
    S2 = 48
    toks = jax.random.randint(KT, (1, S2), 0, cfg.vocab_size)
    full = forward(params, cfg, {"tokens": toks}, remat=False)
    logits_p, cache = prefill(params, cfg, {"tokens": toks[:, :8]},
                              max_len=S2, cache_dtype=jnp.float32)
    assert cache["k"].shape[2] == cfg.rglru.window  # ring, not S2
    for t in range(8, S2):
        logits, cache = decode_step(params, cfg,
                                    {"tokens": toks[:, t:t + 1]}, cache)
        err = float(jnp.max(jnp.abs(logits - full[:, t])))
        assert err < 2e-4, (t, err)
