"""Sharding rules: every parameter/moment/batch/cache spec divides its dims
on the production meshes, for every architecture and preset; ZeRO-1 adds a
data axis where possible; the HLO cost walker stays trip-count-exact."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.distributed import sharding as SH
from repro.launch.specs import SHAPES, input_specs, params_specs, skip_reason


class FakeMesh:
    def __init__(self, **shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESHES = [FakeMesh(data=16, model=16), FakeMesh(pod=2, data=16, model=16),
          FakeMesh(data=2, model=2)]


def _check(spec_tree, shape_tree, mesh):
    ms = dict(mesh.shape)
    flat_s = jax.tree_util.tree_leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, P))
    flat_l = jax.tree_util.tree_leaves(shape_tree)
    assert len(flat_s) == len(flat_l)
    for spec, leaf in zip(flat_s, flat_l):
        assert len(spec) <= len(leaf.shape), (spec, leaf.shape)
        for entry, dim in zip(spec, leaf.shape):
            n = SH._axis_size(ms, entry)
            assert dim % n == 0, (spec, leaf.shape, entry)


@pytest.mark.parametrize("arch_id", sorted(ARCHS))
@pytest.mark.parametrize("mesh", MESHES, ids=lambda m: str(m.shape))
@pytest.mark.parametrize("preset", ["tp", "fsdp_tp", "dp"])
def test_param_specs_divide(arch_id, mesh, preset):
    cfg = ARCHS[arch_id]
    pspec = params_specs(cfg)
    specs = SH.param_specs(pspec, mesh, preset)
    _check(specs, pspec, mesh)
    moments = SH.moment_specs(pspec, mesh, preset)
    _check(moments, pspec, mesh)


@pytest.mark.parametrize("arch_id", sorted(ARCHS))
@pytest.mark.parametrize("shape_name", sorted(SHAPES))
def test_batch_and_cache_specs_divide(arch_id, shape_name):
    cfg = ARCHS[arch_id]
    shape = SHAPES[shape_name]
    if skip_reason(cfg, shape):
        pytest.skip(skip_reason(cfg, shape))
    mesh = MESHES[1]
    specs = input_specs(cfg, shape)
    _check(SH.batch_specs(specs["batch"], mesh), specs["batch"], mesh)
    if "cache" in specs:
        _check(SH.cache_specs(specs["cache"], mesh), specs["cache"], mesh)


def test_zero1_adds_data_axis():
    mesh = MESHES[0]
    spec = SH.zero1_spec(P(None, "model"), (1024, 1536), dict(mesh.shape))
    assert spec == P("data", "model")
    # nothing divisible -> unchanged
    spec = SH.zero1_spec(P(None, "model"), (9, 1536), dict(mesh.shape))
    assert spec == P(None, "model")


def test_dp_preset_replicates():
    mesh = MESHES[0]
    cfg = ARCHS["smollm-135m"]
    pspec = params_specs(cfg)
    specs = SH.param_specs(pspec, mesh, "dp")
    for s in jax.tree_util.tree_leaves(specs,
                                       is_leaf=lambda x: isinstance(x, P)):
        assert all(e is None for e in s)


# -- HLO walker ---------------------------------------------------------------

def test_hlo_walker_trip_counts():
    from repro.launch.hlo import hlo_cost

    def body(x, w):
        return x @ w, None

    def f_scan(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y

    def f_unroll(x, ws):
        for i in range(ws.shape[0]):
            x = x @ ws[i]
        return x

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
    expected = 10 * 2 * 256 ** 3
    for f in (f_scan, f_unroll):
        c = hlo_cost(jax.jit(f).lower(x, ws).compile().as_text())
        assert abs(c["flops"] / expected - 1.0) < 1e-6
