"""Knob-space properties: richer-than partial order, join = least upper
bound, space sizes (paper Table 1)."""

from _hyp_compat import given, settings, st

from repro.core.knobs import (CROP_VALUES, QUALITY_VALUES, RESOLUTION_VALUES,
                              SAMPLING_VALUES, FidelityOption, IngestSpec,
                              coding_space, fidelity_space)

fidelities = st.builds(
    FidelityOption,
    quality=st.sampled_from(QUALITY_VALUES),
    crop=st.sampled_from(CROP_VALUES),
    resolution=st.sampled_from(RESOLUTION_VALUES),
    sampling=st.sampled_from(SAMPLING_VALUES),
)


def test_space_sizes():
    f = fidelity_space()
    c = coding_space()
    assert len(f) == 4 * 3 * 10 * 5 == 600
    assert len(c) == 26  # 25 coded + RAW
    assert len(set(f)) == 600 and len(set(c)) == 26


@given(fidelities)
def test_richer_reflexive(f):
    assert f.richer_eq(f) and not f.richer(f)


@given(fidelities, fidelities)
def test_richer_antisymmetric(a, b):
    if a.richer_eq(b) and b.richer_eq(a):
        assert a == b


@settings(max_examples=200)
@given(fidelities, fidelities, fidelities)
def test_richer_transitive(a, b, c):
    if a.richer_eq(b) and b.richer_eq(c):
        assert a.richer_eq(c)


@settings(max_examples=200)
@given(fidelities, fidelities)
def test_join_is_upper_bound(a, b):
    j = a.join(b)
    assert j.richer_eq(a) and j.richer_eq(b)
    # least: any other upper bound is richer than the join
    for f in (a, b):
        if f.richer_eq(a) and f.richer_eq(b):
            assert f.richer_eq(j)


@given(fidelities)
def test_ingest_resolve_shapes(f):
    spec = IngestSpec()
    n, h, w = spec.resolve(f)
    assert n >= 1 and h % 8 == 0 and w % 8 == 0
    assert h <= spec.height and w <= spec.width


def test_richer_not_total():
    a = FidelityOption("good", 0.5, 720, 1 / 2)
    b = FidelityOption("bad", 1.0, 540, 1.0)
    assert not a.richer_eq(b) and not b.richer_eq(a)
